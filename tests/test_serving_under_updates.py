"""Serving under continuous update/deletion traffic (DESIGN.md §8).

The realistic serving regime (per the unlearning-benchmark literature)
interleaves recommendation requests with continuous addition AND
deletion traffic.  These tests drive a 520-event mixed stream through
the engine, serving through the request batcher after every chunk, and
pin that the corpus-cache row invalidation never goes stale:

  * the cached corpus — and therefore the fused recommendations — are
    BITWISE the fresh from-scratch rebuild of the live state at every
    serving point (the cache-staleness oracle: same arithmetic, so any
    difference can only be a stale row);
  * the served corpus stays within the established 1e-4 envelope of a
    fresh paper-faithful ``RefEngine`` rebuild of the current
    histories, at every serving point — a stale cache row is off by
    whole basket-update magnitudes (~0.1), far beyond it.  (Served item
    LISTS are pinned bitwise only between same-arithmetic paths,
    matching tests/test_sharded_engine.py: kNN neighbour selection is
    discontinuous, so an fp-level corpus difference can legitimately
    flip a near-tied neighbour and with it the blended ranking.);
  * both properties survive a mid-stream checkpoint/restore (the
    restored engine drops the cache and rebuilds it) and the restored
    engine keeps serving bitwise in step with the original under
    exactly-once replay;
  * the interpret-mode Pallas pipeline serves the same answers as the
    CPU path on the final corpus.
"""
import numpy as np

import jax.numpy as jnp

from repro.core import RefEngine, TifuParams, knn
from repro.core.types import KIND_ADD_BASKET, KIND_DEL_BASKET
from repro.kernels import ops
from repro.streaming import StateStore, StoreConfig, StreamingEngine

from test_sharded_engine import random_mixed_events

P = TifuParams(n_items=41, group_size=3, r_b=0.9, r_g=0.7)
M, N, B = 8, 48, 6
TOPN, K_NN = 5, 4


def make_engine(batch_size=16):
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B))
    return StreamingEngine(store, P, batch_size=batch_size), store


def ref_corpus(replay: RefEngine) -> np.ndarray:
    """The oracle corpus: a fresh, independent RefEngine replay of the
    stream prefix (ragged numpy, per-event — the paper-faithful
    implementation).  A from-scratch ``fit_from_scratch`` regrouping
    would NOT match: the maintained group structure is path-dependent
    after deletions (the §4.3 varying-group-size relaxation), so the
    oracle must replay the same events, independently."""
    return replay.user_matrix(list(range(M))).astype(np.float32)


def serve_all(eng: StreamingEngine) -> np.ndarray:
    return eng.recommend(np.arange(M), topn=TOPN, k=K_NN)


def test_serving_under_updates_matches_ref_rebuild(tmp_path):
    rng = np.random.default_rng(11)
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 520, M)

    # replay the ref stream prefix-by-prefix alongside the engine
    replay = RefEngine(P, dtype=np.float32)
    eng, store = make_engine()
    chunk = 65
    ckpt_dir = str(tmp_path / "ckpt")
    restored = None
    for lo in range(0, len(events), chunk):
        part = events[lo:lo + chunk]
        eng.submit(part)
        eng.run_until_drained()
        for ev in part:
            if ev.kind == KIND_ADD_BASKET:
                replay.add_basket(ev.user, ev.items)
            elif ev.kind == KIND_DEL_BASKET:
                replay.delete_basket(ev.user, ev.pos)
            else:
                replay.delete_item(ev.user, ev.pos, ev.item)

        # (1) cache contract: the incrementally-refreshed corpus is
        # bitwise the from-scratch materialization of the live state
        cached = np.asarray(store.corpus())
        np.testing.assert_array_equal(
            cached, np.asarray(store.state.materialized_user_vecs()),
            err_msg=f"stale corpus cache after {lo + len(part)} events")

        # (2a) fused recommendations == recommendations on the fresh
        # from-scratch materialization (bitwise: same state, so any
        # difference can only be a stale cache row)
        recs = serve_all(eng)
        fresh = np.asarray(knn.recommend_for_users(
            store.state.materialized_user_vecs(),
            jnp.asarray(np.arange(M, dtype=np.int32)),
            k=K_NN, alpha=P.alpha, topn=TOPN))
        np.testing.assert_array_equal(
            recs, fresh, err_msg=f"after {lo + len(part)} events")
        # (2b) independent oracle: the served corpus tracks the fresh
        # RefEngine replay (1e-4 envelope — a stale row would be off
        # by whole update magnitudes)
        np.testing.assert_allclose(
            cached, ref_corpus(replay), atol=1e-4,
            err_msg=f"after {lo + len(part)} events")

        # mid-stream: commit, and fork a restored engine that must
        # serve identically from its rebuilt cache
        if lo // chunk == 3:
            eng.checkpoint(ckpt_dir, step=lo)
            restored, _ = make_engine()
            restored.restore(ckpt_dir)
            np.testing.assert_array_equal(serve_all(restored), recs)
            # the restored engine replays the whole prefix (exactly-once
            # dedup skips the processed part) and keeps serving in step
            restored.submit(events[:lo + chunk])
            restored.run_until_drained()
            np.testing.assert_array_equal(serve_all(restored), recs)
        elif restored is not None:
            restored.submit(part)
            restored.run_until_drained()
            np.testing.assert_array_equal(serve_all(restored), recs)

    assert eng.metrics.events_processed == len(events)
    assert restored is not None

    # (3) the interpret-mode Pallas pipeline serves the same answers
    final = serve_all(eng)
    with ops.default_impl("interpret"):
        np.testing.assert_array_equal(serve_all(eng), final)
