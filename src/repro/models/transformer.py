"""Configurable decoder-only LM transformer covering the assigned archs:

  granite-3-2b / gemma3-27b / command-r-plus-104b  (dense; GQA; gemma3 adds
      5:1 local:global sliding-window attention)
  qwen2-moe-a2.7b   (shared + routed experts, top-4)
  deepseek-v3-671b  (MLA latent attention, 1 shared + 256 routed top-8, MTP)

Scale-critical choices (DESIGN.md §5):
  * ``lax.scan`` over stacked layer params (+ optional per-layer remat) —
    HLO size independent of depth;
  * flash-style attention: scan over query blocks, rematerialized block
    bodies — no S×S HBM residency at 32k (Pallas kernel is the TPU fast
    path, this is the portable lowering);
  * MoE as a *manual* ``shard_map`` over ("data","model"): experts live
    on the "model" axis, each data row routes its own tokens locally,
    expert weights are FSDP-stored (D over "data") and all-gathered per
    layer; one psum over "model" combines expert outputs.  No GSPMD
    surprises on the data-dependent dispatch;
  * chunked cross-entropy: logits are never materialized [B,S,V] —
    scan over sequence chunks with vocab TP-sharded.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

from repro.parallel.sharding import ShardingRules, batch_axes


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_experts_padded: int = 0        # storage padding so E % model_axis == 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: leading dense layers
    capacity_factor: float = 1.25

    @property
    def e_pad(self):
        return self.n_experts_padded or self.n_experts
    # --- MLA (deepseek) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- attention pattern ---
    sliding_window: int = 0          # 0 = full attention everywhere
    global_every: int = 0            # gemma3: layer i is global iff (i+1) % global_every == 0
    # --- misc ---
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    mtp: bool = False                # extra next-next-token head (deepseek)
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # "full": recompute everything in backward; "dots": keep un-batched
    # matmul outputs (§Perf H3c — trades HBM headroom for fewer backward
    # recomputes and re-gathers)
    remat_policy: str = "full"
    q_block: int = 512               # flash q-block size
    use_flash: bool = True

    @property
    def qk_dim(self):
        return (self.qk_nope_dim + self.qk_rope_dim) if self.mla else self.d_head

    @property
    def v_dim(self):
        return self.v_head_dim if self.mla else self.d_head

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        c = self
        emb = c.vocab_size * c.d_model * (1 if c.tie_embeddings else 2)
        if c.mla:
            attn = (c.d_model * c.q_lora_rank
                    + c.q_lora_rank * c.n_heads * c.qk_dim
                    + c.d_model * (c.kv_lora_rank + c.qk_rope_dim)
                    + c.kv_lora_rank * c.n_heads * (c.qk_nope_dim + c.v_dim)
                    + c.n_heads * c.v_dim * c.d_model)
        else:
            attn = c.d_model * (c.n_heads + 2 * c.n_kv_heads) * c.d_head \
                + c.n_heads * c.d_head * c.d_model
        dense_ffn = 3 * c.d_model * c.d_ff
        moe_ffn = 3 * c.d_model * c.moe_d_ff * (c.n_experts
                                                + c.n_shared_experts)
        n_moe = (c.n_layers - c.first_dense_layers) if c.moe else 0
        n_dense = c.n_layers - n_moe
        return emb + c.n_layers * attn + n_dense * dense_ffn + n_moe * moe_ffn

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        c = self
        if not c.moe:
            return self.n_params()
        emb = c.vocab_size * c.d_model * (1 if c.tie_embeddings else 2)
        if c.mla:
            attn = (c.d_model * c.q_lora_rank
                    + c.q_lora_rank * c.n_heads * c.qk_dim
                    + c.d_model * (c.kv_lora_rank + c.qk_rope_dim)
                    + c.kv_lora_rank * c.n_heads * (c.qk_nope_dim + c.v_dim)
                    + c.n_heads * c.v_dim * c.d_model)
        else:
            attn = c.d_model * (c.n_heads + 2 * c.n_kv_heads) * c.d_head \
                + c.n_heads * c.d_head * c.d_model
        dense_ffn = 3 * c.d_model * c.d_ff
        act_moe = 3 * c.d_model * c.moe_d_ff * (c.top_k + c.n_shared_experts)
        n_moe = c.n_layers - c.first_dense_layers
        return emb + c.n_layers * attn \
            + c.first_dense_layers * dense_ffn + n_moe * act_moe


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _dense_layer_shapes(c: TransformerConfig, ffn_dense: bool):
    """Shapes of one layer's params. ffn_dense: dense FFN vs MoE FFN."""
    s = {"ln1": (c.d_model,), "ln2": (c.d_model,)}
    if c.mla:
        s.update({
            "wq_a": (c.d_model, c.q_lora_rank),
            "q_ln": (c.q_lora_rank,),
            "wq_b": (c.q_lora_rank, c.n_heads * c.qk_dim),
            "wkv_a": (c.d_model, c.kv_lora_rank + c.qk_rope_dim),
            "kv_ln": (c.kv_lora_rank,),
            "wkv_b": (c.kv_lora_rank, c.n_heads * (c.qk_nope_dim + c.v_dim)),
            "wo": (c.n_heads * c.v_dim, c.d_model),
        })
    else:
        s.update({
            "wq": (c.d_model, c.n_heads * c.d_head),
            "wk": (c.d_model, c.n_kv_heads * c.d_head),
            "wv": (c.d_model, c.n_kv_heads * c.d_head),
            "wo": (c.n_heads * c.d_head, c.d_model),
        })
    if ffn_dense:
        s.update({"w_gate": (c.d_model, c.d_ff),
                  "w_up": (c.d_model, c.d_ff),
                  "w_down": (c.d_ff, c.d_model)})
    else:
        s.update({
            "router": (c.d_model, c.n_experts),
            "we_gate": (c.e_pad, c.d_model, c.moe_d_ff),
            "we_up": (c.e_pad, c.d_model, c.moe_d_ff),
            "we_down": (c.e_pad, c.moe_d_ff, c.d_model),
        })
        if c.n_shared_experts:
            f = c.moe_d_ff * c.n_shared_experts
            s.update({"ws_gate": (c.d_model, f), "ws_up": (c.d_model, f),
                      "ws_down": (f, c.d_model)})
    return s


def param_shapes(c: TransformerConfig):
    """Abstract shapes of the full parameter pytree (stacked layers)."""
    n_moe = (c.n_layers - c.first_dense_layers) if c.moe else 0
    n_dense = c.n_layers - n_moe
    shapes = {"embed": (c.vocab_size, c.d_model), "final_ln": (c.d_model,)}
    if not c.tie_embeddings:
        shapes["unembed"] = (c.d_model, c.vocab_size)
    if n_dense:
        shapes["dense_layers"] = {k: (n_dense,) + v for k, v in
                                  _dense_layer_shapes(c, True).items()}
    if n_moe:
        shapes["moe_layers"] = {k: (n_moe,) + v for k, v in
                                _dense_layer_shapes(c, False).items()}
    if c.mtp:
        shapes["mtp_proj"] = (2 * c.d_model, c.d_model)
        shapes["mtp_ln"] = (c.d_model,)
    return shapes


def init_params(c: TransformerConfig, key):
    shapes = param_shapes(c)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes,
        is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shape), k in zip(flat, keys):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name.startswith(("ln", "final_ln", "q_ln", "kv_ln", "mtp_ln")) \
                or name in ("ln1", "ln2"):
            leaves.append(jnp.ones(shape, c.dtype))
        else:
            scale = 0.02
            leaves.append((jax.random.normal(k, shape, jnp.float32)
                           * scale).astype(c.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(c: TransformerConfig):
    """ShapeDtypeStructs for the param pytree (dry-run, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, c.dtype),
        param_shapes(c), is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

_TP_DIMS = {
    # layer params: (stacked) dim index carrying the TP axis (post-stack)
    "wq": 2, "wk": 2, "wv": 2, "wo": 1,
    "wq_b": 2, "wkv_b": 2,
    "w_gate": 2, "w_up": 2, "w_down": 1,
    "ws_gate": 2, "ws_up": 2, "ws_down": 1,
    "we_gate": 1, "we_up": 1, "we_down": 1,   # experts over model axis
    "router": None, "wq_a": None, "wkv_a": None,
    "ln1": None, "ln2": None, "q_ln": None, "kv_ln": None,
}
_FSDP_DIMS = {
    "wq": 1, "wk": 1, "wv": 1, "wo": 2,
    "wq_a": 1, "wq_b": 1, "wkv_a": 1, "wkv_b": 1,
    "w_gate": 1, "w_up": 1, "w_down": 2,
    "ws_gate": 1, "ws_up": 1, "ws_down": 2,
    "we_gate": 2, "we_up": 2, "we_down": 3,   # D dim over data (gathered in MoE blk)
    "router": None,
    "ln1": None, "ln2": None, "q_ln": None, "kv_ln": None,
}


def param_pspecs(c: TransformerConfig, mesh: Mesh, rules: ShardingRules):
    """PartitionSpec pytree matching param_shapes(c).

    TP over attention heads only when n_kv_heads divides the model axis
    (keeps the GQA head reshape shard-aligned; otherwise attention params
    are FSDP-only — e.g. command-r-plus kv=8 on a 16-way model axis).
    """
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = rules.tensor if rules.tensor in mesh.axis_names else None
    fsa = rules.fsdp_axes(mesh)
    fsn = rules.fsdp_size(mesh)
    kv_tp_ok = tp is not None and (c.mla or c.n_kv_heads % msize[tp] == 0)

    def spec_for(name, shape):
        axes = [None] * len(shape)
        tpd = _TP_DIMS.get(name)
        if name in ("wq", "wk", "wv", "wo") and not kv_tp_ok:
            tpd = None
        if tp and tpd is not None and tpd < len(shape) \
                and shape[tpd] % msize[tp] == 0:
            axes[tpd] = tp
        else:
            tpd = None
        fsd = _FSDP_DIMS.get(name)
        if fsa and fsd is not None and fsd < len(shape) and fsd != tpd \
                and shape[fsd] % fsn == 0:
            axes[fsd] = fsa
        return P(*axes)

    def build(node, name=""):
        if isinstance(node, dict):
            return {k: build(v, k) for k, v in node.items()}
        shape = node
        if name == "embed":
            axes = [None, None]
            if tp and shape[0] % msize[tp] == 0:
                axes[0] = tp
            if fsa and shape[1] % fsn == 0:
                axes[1] = fsa
            return P(*axes)
        if name == "unembed":
            axes = [None, None]
            if tp and shape[1] % msize[tp] == 0:
                axes[1] = tp
            if fsa and shape[0] % fsn == 0:
                axes[0] = fsa
            return P(*axes)
        if name in ("final_ln", "mtp_ln"):
            return P(None)
        if name == "mtp_proj":
            return spec_for("wo", shape)
        return spec_for(name, shape)

    return build(param_shapes(c))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _constrain(x, mesh, spec):
    """Activation sharding constraint (no-op without a mesh).

    GSPMD does not reliably propagate batch sharding through gathers
    (embedding lookups) and long scan chains — without these anchors the
    compiler replicates activations (measured: granite train_4k peak
    1458 GiB/device → 4.9 GiB/device with constraints)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _bspec(mesh, rules, batch: int, extra_dims: int):
    ax = batch_axes(mesh, rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = int(np.prod([sizes[a] for a in ax])) if ax else 1
    first = ax if (n > 1 and batch % n == 0) else None
    return P(first, *([None] * extra_dims))


def _hspec(mesh, rules, batch: int, seq: int):
    """Residual-stream sharding [B, S, D]: batch over (pod,data) and —
    sequence parallelism — S over the tensor axis.  SP keeps the
    remat-saved per-layer activations 1/TP-sized; attention/MoE gather S
    transiently inside the (rematted) layer."""
    b = _bspec(mesh, rules, batch, 0)[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = rules.tensor if rules.tensor in mesh.axis_names else None
    s_ax = tp if (tp and seq > 1 and seq % sizes[tp] == 0) else None
    return P(b, s_ax, None)


def rms_norm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * w


def rope(x, positions, theta, dims: Optional[int] = None):
    """Rotary embedding over the last ``dims`` features (default: all)."""
    d = x.shape[-1] if dims is None else dims
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    rot, keep = x[..., :d], x[..., d:]
    x1, x2 = rot[..., :half], rot[..., half:]
    cos = cos[:, :, None, :] if rot.ndim == 4 else cos
    sin = sin[:, :, None, :] if rot.ndim == 4 else sin
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), keep], axis=-1)


def _attend_block(q, k, v, qpos, kpos, window, scale):
    """One (q-block × full-K) attention with causal/sliding mask.

    q: [B,Cq,H,dq] k: [B,S,KV,dq] v: [B,S,KV,dv] → [B,Cq,H,dv]
    """
    b, cq, h, dq = q.shape
    s, kv = k.shape[1], k.shape[2]
    groups = h // kv
    qg = q.reshape(b, cq, kv, groups, dq)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = (kpos[None, :] <= qpos[:, None]) \
        & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, cq, h, v.shape[-1])


def flash_attention(q, k, v, q_offset, window, scale, q_block, use_remat=True):
    """Scan over q blocks; each block attends to all K with masking.

    q: [B,S,H,dq]; k,v: [B,S,KV,d*]. window: traced or static scalar
    (big value = full causal). Returns [B,S,H,dv].
    """
    b, s, h, dq = q.shape
    if s % q_block != 0:
        nq, qb = 1, s          # short/ragged sequence: one block
    else:
        nq, qb = s // q_block, q_block
    kpos = jnp.arange(k.shape[1])

    def body(_, qblk_and_start):
        qblk, start = qblk_and_start
        qpos = q_offset + start + jnp.arange(qb)
        fn = _attend_block
        if use_remat:
            fn = jax.checkpoint(_attend_block,
                                static_argnums=())
        return None, fn(qblk, k, v, qpos, kpos, window, scale)

    qs = q.reshape(b, nq, qb, h, dq).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nq) * qb
    _, outs = jax.lax.scan(body, None, (qs, starts))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, -1)


def _head_spec(mesh, rules, batch, n_heads):
    """[B, S, H, D] attention tensors: batch over (pod,data), HEADS over
    'model', S gathered — §Perf H3: with SP residuals, gathering the
    per-shard head slice over S costs TP× less than gathering all heads."""
    b = _bspec(mesh, rules, batch, 0)[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = rules.tensor if rules.tensor in mesh.axis_names else None
    h_ax = tp if (tp and n_heads % sizes[tp] == 0) else None
    return P(b, None, h_ax, None)


def attention_dense(x, layer, c: TransformerConfig, positions, window,
                    kv_cache=None, cache_pos=None, mesh=None, rules=None):
    """GQA attention. Returns (out, new_kv) where kv = (k_all, v_all)."""
    b, s, _ = x.shape
    q = (x @ layer["wq"]).reshape(b, s, c.n_heads, c.d_head)
    k = (x @ layer["wk"]).reshape(b, s, c.n_kv_heads, c.d_head)
    v = (x @ layer["wv"]).reshape(b, s, c.n_kv_heads, c.d_head)
    q = rope(q, positions, c.rope_theta)
    k = rope(k, positions, c.rope_theta)
    if mesh is not None and s > 1:
        q = _constrain(q, mesh, _head_spec(mesh, rules, b, c.n_heads))
        kvs = _head_spec(mesh, rules, b, c.n_kv_heads)
        k = _constrain(k, mesh, kvs)
        v = _constrain(v, mesh, kvs)
    scale = 1.0 / math.sqrt(c.d_head)
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cache_pos, 0, 0))
        if s > 1:
            # prefill: flash attention over the in-context K/V; the cache
            # write above is independent of the attention compute.
            out = flash_attention(q, k, v, 0, window, scale, c.q_block,
                                  use_remat=True)
        else:
            # decode: one query row against the whole cache
            kpos = jnp.arange(ck.shape[1])
            qpos = positions[0]                       # [1] (uniform batch)
            qg = q.reshape(b, s, c.n_kv_heads, c.n_heads // c.n_kv_heads,
                           c.d_head)
            scores = jnp.einsum(
                "bqkgd,bskd->bkgqs", qg,
                ck.astype(q.dtype)).astype(jnp.float32) * scale
            mask = (kpos[None, :] <= qpos[:, None]) \
                & (kpos[None, :] > qpos[:, None] - window)
            scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cv.astype(x.dtype))
        out = out.reshape(b, s, c.n_heads * c.d_head)
        return out @ layer["wo"], (ck, cv)
    out = flash_attention(q, k, v, 0, window, scale, c.q_block,
                          use_remat=True)
    out = out.reshape(b, s, c.n_heads * c.d_head)
    return out @ layer["wo"], None


def attention_mla(x, layer, c: TransformerConfig, positions, window,
                  kv_cache=None, cache_pos=None, mesh=None, rules=None):
    """DeepSeek-style Multi-head Latent Attention.

    Cache stores only the compressed latent (kv_lora_rank) + rope key —
    the MLA memory win.  Decode uses the absorbed-matmul path (scores in
    latent space); train/prefill expands per-head keys/values.

    §Perf H3: under sequence parallelism the cross-shard gather happens
    on the COMPRESSED latent (r+dr dims ≈ 0.14 GB bf16/layer) — the
    per-head K/V expansion runs after, locally, for the shard's heads
    only.  Baseline (expanded-K gather) moved 3 GB f32/layer × 4.
    """
    b, s, _ = x.shape
    h, dn, dr, dv = c.n_heads, c.qk_nope_dim, c.qk_rope_dim, c.v_dim
    r = c.kv_lora_rank
    q_lat = rms_norm(x @ layer["wq_a"], layer["q_ln"], c.norm_eps)
    q = (q_lat @ layer["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, c.rope_theta)

    kv_a = x @ layer["wkv_a"]                        # [b,s,r+dr]
    c_kv = rms_norm(kv_a[..., :r], layer["kv_ln"], c.norm_eps)
    k_rope = rope(kv_a[..., None, r:], positions, c.rope_theta)  # [b,s,1,dr]
    if mesh is not None and s > 1:
        bspec = _bspec(mesh, rules, b, 0)[0]
        # gather S on the latent only; q/k/v stay head-sharded
        c_kv = _constrain(c_kv, mesh, P(bspec, None, None))
        k_rope = _constrain(k_rope, mesh, P(bspec, None, None, None))
        hs = _head_spec(mesh, rules, b, h)
        q_nope = _constrain(q_nope, mesh, hs)
        q_rope = _constrain(q_rope, mesh, hs)

    wkv_b = layer["wkv_b"].reshape(r, h, dn + dv)
    w_k = wkv_b[..., :dn]                            # [r,h,dn]
    w_v = wkv_b[..., dn:]                            # [r,h,dv]
    scale = 1.0 / math.sqrt(dn + dr)

    if kv_cache is not None:
        cl, cr = kv_cache                            # [b,S,r], [b,S,dr]
        cl = jax.lax.dynamic_update_slice(cl, c_kv.astype(cl.dtype),
                                          (0, cache_pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cr, k_rope[:, :, 0, :].astype(cr.dtype), (0, cache_pos, 0))
        if s > 1:
            # prefill: expand and run flash over the in-context K/V
            k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, w_k)
            v = jnp.einsum("bsr,rhd->bshd", c_kv, w_v)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
            q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = flash_attention(q_full, k, v, 0, window, scale, c.q_block,
                                  use_remat=True)
            out = out.reshape(b, s, h * dv)
            return out @ layer["wo"], (cl, cr)
        # decode: absorbed path — q_nope projected into latent space, the
        # per-head K/V expansion never materializes (the MLA decode win).
        q_lat_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k)
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat_abs, cl.astype(q.dtype))
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope,
                               cr.astype(q.dtype))).astype(jnp.float32) * scale
        kpos = jnp.arange(cl.shape[1])
        qpos = positions[0]                          # [1] (uniform batch)
        mask = (kpos[None, :] <= qpos[:, None]) \
            & (kpos[None, :] > qpos[:, None] - window)
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cl.astype(x.dtype))
        out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_v)
        out = out.reshape(b, s, h * dv)
        return out @ layer["wo"], (cl, cr)

    # train/prefill: expand keys/values per head
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, w_k)
    v = jnp.einsum("bsr,rhd->bshd", c_kv, w_v)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))],
                        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = flash_attention(q_full, k, v, 0, window, scale, c.q_block,
                          use_remat=True)
    out = out.reshape(b, s, h * dv)
    return out @ layer["wo"], None


def ffn_dense(x, layer):
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) \
        @ layer["w_down"]


def moe_block(x, layer, c: TransformerConfig, mesh: Optional[Mesh],
              rules: Optional[ShardingRules]):
    """Routed top-k MoE with shared experts.

    With a mesh: manual shard_map over ("data","model") — see module
    docstring.  Without a mesh (smoke tests): single-device same math.
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)

    if mesh is None or rules is None or \
            rules.tensor not in getattr(mesh, "axis_names", ()):
        out = _moe_local(xf, layer, c, n_local=c.e_pad, expert_offset=0,
                         capacity=_capacity(b * s, c, 1))
    else:
        msize = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_model = msize[rules.tensor]
        n_local = c.e_pad // n_model
        n_bsh = _batch_shards(mesh, rules)
        # decode (tokens < batch shards): replicate tokens over data too
        if (b * s) % n_bsh == 0 and (b * s) >= n_bsh:
            batch_ax = batch_axes(mesh, rules) or None
            cap = _capacity(b * s // n_bsh, c, 1)
        else:
            batch_ax = None
            cap = _capacity(b * s, c, 1)
        fs = rules.fsdp_axes(mesh) or None
        fs_ok = fs is not None and c.d_model % rules.fsdp_size(mesh) == 0
        wspec_df = P(rules.tensor, fs if fs_ok else None, None)
        wspec_fd = P(rules.tensor, None, fs if fs_ok else None)

        # §Perf H3b (REFUTED, kept switchable for the record): combining
        # expert outputs with psum_scatter into the sequence-parallel
        # layout halves psum bytes ON PAPER, but GSPMD cannot reshard the
        # scattered {devices=[256,1]} layout through the backward pass
        # ("involuntary full rematerialization") — measured all-gathers
        # EXPLODED 845→3465 GiB/device.  Default stays psum.
        use_psum_scatter = False
        t_loc = (b * s // n_bsh) if batch_ax else (b * s)
        scatter_ok = use_psum_scatter and s > 1 \
            and t_loc % n_model == 0 and t_loc >= n_model

        def body(xl, router, wg, wu, wd):
            # barrier first: keeps XLA's CPU bf16-dot legalization from
            # commuting converts above the per-layer slice and hoisting a
            # full-depth f32 weight stack out of the layer scan
            xl, router, wg, wu, wd = compat.optimization_barrier(
                (xl, router, wg, wu, wd))
            # gather the FSDP dim (D) of the expert weights
            if fs_ok:
                wg = jax.lax.all_gather(wg, fs, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, fs, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, fs, axis=2, tiled=True)
            mi = jax.lax.axis_index(rules.tensor)
            out = _moe_local(xl, {"router": router, "we_gate": wg,
                                  "we_up": wu, "we_down": wd}, c,
                             n_local=n_local, expert_offset=mi * n_local,
                             capacity=cap)
            if scatter_ok:
                return jax.lax.psum_scatter(out, rules.tensor,
                                            scatter_dimension=0, tiled=True)
            return jax.lax.psum(out, rules.tensor)

        if scatter_ok:
            tok_axes = tuple(batch_ax) + (rules.tensor,) if batch_ax \
                else (rules.tensor,)
            out_spec = P(tok_axes, None)
        else:
            out_spec = P(batch_ax, None)
        out = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(batch_ax, None),
                      P(None, None), wspec_df, wspec_df, wspec_fd),
            out_specs=out_spec,
            check_vma=False,
        )(xf, layer["router"], layer["we_gate"], layer["we_up"],
          layer["we_down"])

    if c.n_shared_experts:
        out = out + (jax.nn.silu(xf @ layer["ws_gate"])
                     * (xf @ layer["ws_up"])) @ layer["ws_down"]
    return out.reshape(b, s, d)


def _batch_shards(mesh, rules):
    m = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([m[a] for a in batch_axes(mesh, rules)]))


def _capacity(tokens_local: int, c: TransformerConfig, n_rows: int) -> int:
    cap = int(tokens_local * c.top_k / max(c.n_experts, 1)
              * c.capacity_factor)
    return max(8, min(cap, tokens_local))


def _moe_local(xf, layer, c: TransformerConfig, n_local: int,
               expert_offset, capacity: int):
    """Device-local top-k dispatch → expert matmuls → combine.

    xf: [T, D] local tokens; expert weights [n_local, D, F] etc.
    Tokens routed to experts outside [offset, offset+n_local) are
    handled by other shards (psum combines).
    """
    t, d = xf.shape
    logits = (xf @ layer["router"]).astype(jnp.float32)       # [T, E]
    gates, experts = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), c.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # flatten assignments
    flat_e = experts.reshape(-1)                              # [T*k]
    flat_g = gates.reshape(-1).astype(xf.dtype)
    flat_t = jnp.repeat(jnp.arange(t), c.top_k)
    local = (flat_e >= expert_offset) & (flat_e < expert_offset + n_local)
    le = jnp.where(local, flat_e - expert_offset, n_local)    # n_local = drop
    # position of each assignment within its expert (capacity check)
    onehot = jax.nn.one_hot(le, n_local + 1, dtype=jnp.int32)  # [T*k, nl+1]
    pos = jnp.cumsum(onehot, axis=0) * onehot                  # 1-based
    pos_in_e = jnp.sum(pos, axis=-1) - 1                       # [T*k]
    keep = local & (pos_in_e < capacity)
    slot = jnp.where(keep, le * capacity + pos_in_e, n_local * capacity)
    # dispatch: buffer [n_local*capacity (+1 trash), D]
    buf = jnp.zeros((n_local * capacity + 1, d), xf.dtype)
    buf = buf.at[slot].set(xf[flat_t], mode="drop")
    eb = buf[:n_local * capacity].reshape(n_local, capacity, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, layer["we_gate"])) \
        * jnp.einsum("ecd,edf->ecf", eb, layer["we_up"])
    eo = jnp.einsum("ecf,efd->ecd", h, layer["we_down"])       # [nl,C,D]
    flat_out = eo.reshape(n_local * capacity, d)
    contrib = jnp.where(keep[:, None], flat_out[jnp.minimum(
        slot, n_local * capacity - 1)], 0.0) * flat_g[:, None]
    out = jnp.zeros((t, d), xf.dtype).at[flat_t].add(contrib)
    return out


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _layer_windows(c: TransformerConfig, n_layers: int, offset: int):
    """Per-layer attention window (big number = full causal)."""
    FULL = np.int32(2 ** 30)
    idx = np.arange(offset, offset + n_layers)
    if c.sliding_window and c.global_every:
        w = np.where((idx + 1) % c.global_every == 0, FULL,
                     np.int32(c.sliding_window))
    elif c.sliding_window:
        w = np.full(n_layers, np.int32(c.sliding_window))
    else:
        w = np.full(n_layers, FULL)
    return jnp.asarray(w, jnp.int32)


def _scan_layers(x, layers, c, positions, windows, ffn_fn, attn_fn,
                 caches=None, cache_pos=None, mesh=None, rules=None):
    """lax.scan over stacked layer params; optional whole-layer remat."""
    hspec = _hspec(mesh, rules, x.shape[0], x.shape[1]) \
        if mesh is not None else None

    def layer_body(carry, inputs):
        h = carry
        # Barrier the per-layer weight slice: without it XLA hoists
        # bf16→f32 weight converts (a CPU-backend dot legalization) out of
        # the while loop, materializing ALL layers' weights in f32 at once
        # (measured +12 GiB on deepseek decode).  TPU never inserts these
        # converts; the barrier makes the portable lowering match.
        inputs = compat.optimization_barrier(inputs)
        if hspec is not None:
            h = _constrain(h, mesh, hspec)
        if caches is not None:
            layer, window, cache_k, cache_v = inputs
            cache = (cache_k, cache_v)
        else:
            layer, window = inputs
            cache = None
        a, new_cache = attn_fn(rms_norm(h, layer["ln1"], c.norm_eps), layer,
                               c, positions, window, cache, cache_pos,
                               mesh, rules)
        h = h + a
        if hspec is not None:
            h = _constrain(h, mesh, hspec)
        f = ffn_fn(rms_norm(h, layer["ln2"], c.norm_eps), layer)
        h = h + f
        if hspec is not None:
            # exit constraint: the remat-saved carry stack inherits this
            h = _constrain(h, mesh, hspec)
        if caches is not None:
            return h, new_cache
        return h, None

    if c.remat and caches is None:
        policy = None
        if c.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(layer_body, policy=policy)
    else:
        body = layer_body
    xs = (layers, windows) if caches is None \
        else (layers, windows, caches[0], caches[1])
    h, new_caches = jax.lax.scan(body, x, xs)
    return h, new_caches


def forward(params, tokens, c: TransformerConfig, mesh=None, rules=None,
            caches=None, cache_pos=None, positions=None):
    """Token ids [B,S] → final hidden states [B,S,D] (+ updated caches)."""
    x = params["embed"][tokens].astype(c.dtype) * math.sqrt(c.d_model)
    if mesh is not None:
        x = _constrain(x, mesh, _bspec(mesh, rules, x.shape[0], 2))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
    attn = attention_mla if c.mla else attention_dense

    n_moe = (c.n_layers - c.first_dense_layers) if c.moe else 0
    n_dense = c.n_layers - n_moe
    new_caches = {}
    if n_dense:
        wins = _layer_windows(c, n_dense, 0)
        cache_d = caches.get("dense") if caches else None
        x, nc = _scan_layers(
            x, params["dense_layers"], c, positions, wins,
            lambda h, l: ffn_dense(h, l), attn, cache_d, cache_pos,
            mesh, rules)
        new_caches["dense"] = nc
    if n_moe:
        wins = _layer_windows(c, n_moe, n_dense)
        cache_m = caches.get("moe") if caches else None
        x, nc = _scan_layers(
            x, params["moe_layers"], c, positions, wins,
            lambda h, l: moe_block(h, l, c, mesh, rules), attn,
            cache_m, cache_pos, mesh, rules)
        new_caches["moe"] = nc
    x = rms_norm(x, params["final_ln"], c.norm_eps)
    return x, (new_caches if caches is not None else None)


def _unembed(params, c):
    return params["embed"].T if c.tie_embeddings else params["unembed"]


def chunked_softmax_xent(x, labels, unembed, c: TransformerConfig,
                         chunk: int = 512, mesh=None, rules=None):
    """Mean next-token CE without materializing [B,S,V] logits."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    nc = s // chunk
    if mesh is not None:
        lspec = P(_bspec(mesh, rules, b, 0)[0], None,
                  rules.tensor if rules.tensor in mesh.axis_names else None)

    def body(acc, inp):
        xc, yc = inp                                   # [b,chunk,d],[b,chunk]
        logits = (xc @ unembed).astype(jnp.float32)    # [b,chunk,V] TP-sharded
        if mesh is not None:
            logits = _constrain(logits, mesh, lspec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (yc >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - gold) * mask)
        return (acc[0] + loss, acc[1] + jnp.sum(mask)), None

    xs = (x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3),
          labels.reshape(b, nc, chunk).transpose(1, 0, 2))
    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs)
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, c: TransformerConfig, mesh=None, rules=None):
    x, _ = forward(params, batch["tokens"], c, mesh, rules)
    loss = chunked_softmax_xent(x, batch["labels"], _unembed(params, c), c,
                                mesh=mesh, rules=rules)
    if c.mtp:
        # next-next-token prediction: combine h_t with emb(t+1), one proj
        emb_next = params["embed"][batch["tokens"]].astype(c.dtype)
        emb_next = jnp.roll(emb_next, -1, axis=1)
        h2 = jnp.concatenate([rms_norm(x, params["mtp_ln"], c.norm_eps),
                              emb_next], axis=-1) @ params["mtp_proj"]
        labels2 = jnp.roll(batch["labels"], -1, axis=1).at[:, -1].set(-1)
        loss = loss + 0.3 * chunked_softmax_xent(h2, labels2,
                                                 _unembed(params, c), c)
    return loss


def make_train_step(c: TransformerConfig, optimizer, mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, batch, c, mesh, rules))(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}
    return train_step


# ---------------------------------------------------------------------------
# Serving (prefill + decode with KV caches)
# ---------------------------------------------------------------------------

def cache_shapes(c: TransformerConfig, batch: int, max_len: int):
    """Abstract shapes of the KV caches (stacked per scan group)."""
    n_moe = (c.n_layers - c.first_dense_layers) if c.moe else 0
    n_dense = c.n_layers - n_moe

    def one(n):
        if c.mla:
            return (jax.ShapeDtypeStruct((n, batch, max_len, c.kv_lora_rank),
                                         jnp.bfloat16),
                    jax.ShapeDtypeStruct((n, batch, max_len, c.qk_rope_dim),
                                         jnp.bfloat16))
        return (jax.ShapeDtypeStruct(
                    (n, batch, max_len, c.n_kv_heads, c.d_head), jnp.bfloat16),
                jax.ShapeDtypeStruct(
                    (n, batch, max_len, c.n_kv_heads, c.d_head), jnp.bfloat16))

    out = {}
    if n_dense:
        out["dense"] = one(n_dense)
    if n_moe:
        out["moe"] = one(n_moe)
    return out


def init_caches(c: TransformerConfig, batch: int, max_len: int):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_shapes(c, batch, max_len),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def decode_step(params, caches, token, pos, c: TransformerConfig,
                mesh=None, rules=None):
    """One decode step: token [B,1] + caches → logits [B,V], new caches."""
    positions = jnp.broadcast_to(pos, token.shape)
    x, new_caches = forward(params, token, c, mesh, rules, caches=caches,
                            cache_pos=pos, positions=positions)
    logits = (x[:, -1, :] @ _unembed(params, c)).astype(jnp.float32)
    return logits, new_caches


def prefill(params, tokens, c: TransformerConfig, max_len: int,
            mesh=None, rules=None):
    """Prefill: run tokens through, return last logits + filled caches."""
    caches = init_caches(c, tokens.shape[0], max_len)
    x, new_caches = forward(params, tokens, c, mesh, rules, caches=caches,
                            cache_pos=0)
    logits = (x[:, -1, :] @ _unembed(params, c)).astype(jnp.float32)
    return logits, new_caches
