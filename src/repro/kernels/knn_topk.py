"""Fused similarity × streaming top-k Pallas kernel (TPU target).

Serves TIFU-kNN neighbour search (paper §2.2) and the two-tower /
bert4rec ``retrieval_cand`` cells: Q queries against M corpus rows,
returning per-query top-k WITHOUT materializing the [Q, M] score matrix
in HBM — the win over the reference path at M = 10⁶.

Design (DESIGN.md §3.4 / §8):
  grid = (⌈Q/bq⌉, ⌈M/bm⌉), M innermost (sequential).  Per step the MXU
  computes a [bq, bm] score tile in VMEM (2·q@cᵀ − |c|², the monotone
  euclidean surrogate); a running [bq, k] top-k buffer lives in VMEM
  scratch and is merged tile-by-tile; only [Q, k] leaves the chip.

  Neither Q nor M needs to divide its block size: tail blocks are
  masked inside the kernel (out-of-range corpus columns score −inf,
  out-of-range query rows are write-masked by Pallas), so prime-sized
  request batches and corpora run the same schedule — no host-side
  padding copy of the corpus.

  Self-exclusion is fused into the scan: when ``query_gids`` is given,
  a column whose GLOBAL id equals the query's global id is masked to
  −inf in its score tile.  Column global ids are
  ``local_idx · col_stride + col_offset`` — identity for a single
  corpus, ``(row · n_shards + shard)`` for one shard of a user-axis
  sharded corpus (DESIGN.md §7.1), so a query user is excluded only on
  its owner shard.

  The merge uses lax.top_k on the concatenated [bq, k+bm] tile, which
  preserves lax.top_k's tie-break (lowest index wins): the running
  buffer holds candidates from earlier (lower-id) tiles and sits first
  in the concat, so an equal-score later column never displaces an
  earlier one (pinned by tests/test_serving_pipeline.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(qid_ref, q_ref, c_ref, cn_ref, vals_ref, idx_ref, acc_vals,
            acc_idx, *, k: int, bm: int, metric: str, m: int,
            col_offset: int, col_stride: int, sub_qnorm: bool):
    mi = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        acc_vals[...] = jnp.full_like(acc_vals, -jnp.inf)
        acc_idx[...] = jnp.zeros_like(acc_idx)

    q = q_ref[...]                                   # [bq, D]
    c = c_ref[...]                                   # [bm, D]
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [bq, bm]
    if metric == "euclidean":
        scores = 2.0 * scores - cn_ref[...][None, :]
        if sub_qnorm:
            # full −|q−c|²: the shard-candidate path emits these scores
            # into the cross-shard merge, where they must be the same
            # per-pair values the reference path computes (§7.3); the
            # per-query constant is rank-irrelevant, so the single-
            # corpus path skips it.
            qf = q.astype(jnp.float32)
            scores = scores - jnp.sum(qf * qf, axis=1, keepdims=True)
    tile_idx = mi * bm + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    # tail mask: columns past the corpus end carry garbage (the block
    # read is out of bounds); they must never win the merge
    scores = jnp.where(tile_idx >= m, -jnp.inf, scores)
    # fused self-exclusion on GLOBAL ids (qid = -1 disables: gids >= 0)
    col_gid = tile_idx * col_stride + col_offset
    scores = jnp.where(col_gid == qid_ref[...][:, None], -jnp.inf, scores)

    merged_vals = jnp.concatenate([acc_vals[...], scores], axis=1)
    merged_idx = jnp.concatenate([acc_idx[...], tile_idx], axis=1)
    top_vals, top_pos = jax.lax.top_k(merged_vals, k)
    acc_vals[...] = top_vals
    acc_idx[...] = jnp.take_along_axis(merged_idx, top_pos, axis=1)

    @pl.when(mi == nm - 1)
    def _done():
        vals_ref[...] = acc_vals[...]
        idx_ref[...] = acc_idx[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bm", "metric", "interpret",
                                    "col_offset", "col_stride",
                                    "sub_qnorm"))
def knn_topk(queries, corpus, k: int, bq: int = 128, bm: int = 512,
             metric: str = "euclidean", interpret: bool = False,
             query_gids=None, col_offset: int = 0, col_stride: int = 1,
             sub_qnorm: bool = False):
    """queries [Q, D] × corpus [M, D] → (vals [Q, k], idx [Q, k]).

    ``idx`` are LOCAL corpus row indices; ``query_gids`` (i32[Q],
    optional) excludes the column whose global id
    ``idx·col_stride + col_offset`` equals the query's global id.
    Q and M need not divide ``bq``/``bm`` (masked tail blocks).  When
    ``k > M`` the trailing entries are −inf with unspecified indices —
    callers clamp (``ops.fused_recommend`` does).  ``sub_qnorm`` makes
    the euclidean scores the full −|q−c|² (the shard-candidate merge
    needs comparable values); off, they are the monotone surrogate
    2qc − |c|².
    """
    qn, d = queries.shape
    m = corpus.shape[0]
    if qn == 0 or m == 0:
        return (jnp.full((qn, k), -jnp.inf, jnp.float32),
                jnp.zeros((qn, k), jnp.int32))
    bq = min(bq, qn)
    bm = min(bm, m)
    if query_gids is None:
        query_gids = jnp.full((qn,), -1, jnp.int32)
    cnorm = jnp.sum(corpus.astype(jnp.float32) ** 2, axis=-1)
    grid = (pl.cdiv(qn, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel, k=k, bm=bm, metric=metric, m=m,
                               col_offset=col_offset, col_stride=col_stride,
                               sub_qnorm=sub_qnorm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda qi, mi: (qi,)),
            pl.BlockSpec((bq, d), lambda qi, mi: (qi, 0)),
            pl.BlockSpec((bm, d), lambda qi, mi: (mi, 0)),
            pl.BlockSpec((bm,), lambda qi, mi: (mi,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qi, mi: (qi, 0)),
            pl.BlockSpec((bq, k), lambda qi, mi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),   # running top-k vals
            pltpu.VMEM((bq, k), jnp.int32),     # running top-k idx
        ],
        interpret=interpret,
    )(query_gids.astype(jnp.int32), queries, corpus, cnorm)
