"""Paper-faithful reference engine (ragged numpy, per-update).

Implements §4.2 (incremental) and §4.3 (decremental) of the paper exactly
as written, one update at a time, touching only the data the paper's
algorithms touch:

  * ``add_basket``    — O(1)              (Eq. 7 / Eq. 8 + Eq. 9)
  * ``delete_basket`` — O(|H| - p)        (Eq. 10 + Eq. 11 / Eq. 12)
  * ``delete_item``   — O(m) or fallback  (Eq. 13 + Eq. 11)

This engine is (a) the semantics oracle for the batched JAX engine and
(b) the implementation whose per-update latencies reproduce Fig. 2a/2b/2c
(benchmarks/fig2*).  Group vectors are recomputed from the history slice
on demand (the paper's f_decr signature takes H for exactly this reason);
only ``user_vec`` and ``last_group_vec`` are maintained as state, giving
O(1) incremental updates.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import decay
from repro.core.tifu import (default_group_sizes, group_vector_ragged,
                             multi_hot, user_vector_ragged)
from repro.core.types import RaggedUserState, TifuParams


class RefEngine:
    """Maintains a set of RaggedUserState under additions and deletions."""

    def __init__(self, params: TifuParams, dtype=np.float64,
                 stability_threshold: Optional[float] = None):
        """``stability_threshold``: if set, a user whose accumulated
        worst-case error multiplier exceeds it is refreshed from scratch
        (beyond-paper; see core.stability).  ``None`` reproduces the paper
        exactly (unbounded error growth, §6.3)."""
        self.params = params
        self.dtype = dtype
        self.stability_threshold = stability_threshold
        self.users: dict[int, RaggedUserState] = {}

    # -- state management ---------------------------------------------------

    def state(self, user: int) -> RaggedUserState:
        if user not in self.users:
            self.users[user] = RaggedUserState.empty(self.params.n_items)
            self.users[user].user_vec = self.users[user].user_vec.astype(self.dtype)
            self.users[user].last_group_vec = (
                self.users[user].last_group_vec.astype(self.dtype))
        return self.users[user]

    def fit_from_scratch(self, user: int, history: Sequence[np.ndarray]):
        """Baseline "training": full recomputation (the paper's baseline)."""
        st = self.state(user)
        st.history = [np.asarray(b, dtype=np.int64) for b in history]
        st.group_sizes = default_group_sizes(len(st.history),
                                             self.params.group_size)
        self._refresh(st)
        return st

    def _refresh(self, st: RaggedUserState):
        """Recompute user_vec / last_group_vec from scratch; reset error."""
        p = self.params
        st.user_vec = user_vector_ragged(st.history, st.group_sizes, p,
                                         self.dtype)
        if st.group_sizes:
            start = sum(st.group_sizes[:-1])
            st.last_group_vec = group_vector_ragged(
                st.history[start:], p.n_items, p.r_b, self.dtype)
        else:
            st.last_group_vec = np.zeros(p.n_items, dtype=self.dtype)
        st.err_mult = 1.0

    def _maybe_stabilize(self, st: RaggedUserState):
        if (self.stability_threshold is not None
                and st.err_mult > self.stability_threshold):
            self._refresh(st)

    # -- incremental updates (paper §4.2) ------------------------------------

    def add_basket(self, user: int, basket: np.ndarray) -> RaggedUserState:
        """f_incr: O(1) w.r.t. history size."""
        p = self.params
        st = self.state(user)
        basket = np.asarray(basket, dtype=np.int64)
        v_b = multi_hot(basket, p.n_items, self.dtype)
        k = st.n_groups
        tau = st.group_sizes[-1] if k else 0
        if k == 0 or tau >= p.group_size:
            # Scenario 1 (Eq. 7): open a new group containing one basket.
            st.user_vec = (k * p.r_g * st.user_vec + v_b) / (k + 1)
            st.last_group_vec = v_b
            st.group_sizes.append(1)
            # Eq. 7 scales the old user vector (and its error) by k*r_g/(k+1).
            st.err_mult *= decay.error_shrink_factor(k, p.r_g) if k else 0.0
            st.err_mult = max(st.err_mult, 1.0e-30)
        else:
            # Scenario 2 (Eq. 8 + Eq. 9): append to the last group.
            v_gk = st.last_group_vec
            v_gk_new = (tau * p.r_b * v_gk + v_b) / (tau + 1)
            st.user_vec = st.user_vec + (v_gk_new - v_gk) / k
            st.last_group_vec = v_gk_new
            st.group_sizes[-1] = tau + 1
            # Eq. 9 adds a correction; the user-vector error is unchanged.
        st.history.append(basket)
        return st

    # -- decremental updates (paper §4.3) ------------------------------------

    def _locate(self, st: RaggedUserState, pos: int):
        """Group index j (0-based) and in-group position i (1-based)."""
        if not 0 <= pos < st.n_baskets:
            raise IndexError(f"basket position {pos} out of range "
                             f"(n={st.n_baskets})")
        start = 0
        for j, tau in enumerate(st.group_sizes):
            if pos < start + tau:
                return j, pos - start + 1, start, tau
            start += tau
        raise AssertionError("inconsistent group bookkeeping")

    def delete_basket(self, user: int, pos: int) -> RaggedUserState:
        """f_decr for a basket: O(|H| - pos)."""
        p = self.params
        st = self.state(user)
        j, i, start, tau = self._locate(st, pos)
        k = st.n_groups
        if tau > 1:
            # Scenario 1 (Eq. 10 + Eq. 11): delete inside a multi-basket group.
            group = st.history[start:start + tau]
            v_gj = group_vector_ragged(group, p.n_items, p.r_b, self.dtype)
            suffix = np.stack([multi_hot(b, p.n_items, self.dtype)
                               for b in group[i - 1:]])
            v_gj_new = decay.decremental_delete(v_gj, tau, suffix, i, p.r_b,
                                                xp=np)
            st.user_vec = st.user_vec + (
                (p.r_g ** (k - 1 - j)) * (v_gj_new - v_gj) / k)
            st.group_sizes[j] = tau - 1
            if j == k - 1:
                st.last_group_vec = v_gj_new
            # v_gj is recomputed from history here, so the user-vector error
            # does not grow through Eq. 10 in this engine (factor 1).
        elif k == 1:
            # Deleting the only basket of the only group: state vanishes.
            st.user_vec = np.zeros(p.n_items, dtype=self.dtype)
            st.last_group_vec = np.zeros(p.n_items, dtype=self.dtype)
            st.group_sizes = []
            st.err_mult = 1.0
        else:
            # Scenario 2 (Eq. 12): a single-basket group vanishes.
            gvecs = []
            s = start
            for g in range(j, k):
                tau_g = st.group_sizes[g]
                gvecs.append(group_vector_ragged(
                    st.history[s:s + tau_g], p.n_items, p.r_b, self.dtype))
                s += tau_g
            suffix = np.stack(gvecs)
            st.user_vec = decay.decremental_delete(st.user_vec, k, suffix,
                                                   j + 1, p.r_g, xp=np)
            st.group_sizes.pop(j)
            if j == k - 1:
                # the previous group becomes the last one
                s2 = sum(st.group_sizes[:-1])
                st.last_group_vec = group_vector_ragged(
                    st.history[s2:s2 + st.group_sizes[-1]] if st.group_sizes
                    else [], p.n_items, p.r_b, self.dtype) \
                    if st.group_sizes else np.zeros(p.n_items, self.dtype)
            st.err_mult *= decay.error_growth_factor(k, p.r_g)
        del st.history[pos]
        self._maybe_stabilize(st)
        return st

    def delete_item(self, user: int, pos: int, item: int) -> RaggedUserState:
        """f_decr for a single item (scenario 3, Eq. 13 + Eq. 11)."""
        p = self.params
        st = self.state(user)
        j, i, start, tau = self._locate(st, pos)
        basket = st.history[pos]
        if item not in basket:
            return st  # nothing to forget
        if len(basket) == 1:
            # the basket vanishes: fall back to basket deletion
            return self.delete_basket(user, pos)
        k = st.n_groups
        new_basket = basket[basket != item]
        delta = -multi_hot(np.array([item]), p.n_items, self.dtype)
        # Eq. 13: in-place update of the group vector.
        group = st.history[start:start + tau]
        v_gj = group_vector_ragged(group, p.n_items, p.r_b, self.dtype)
        v_gj_new = v_gj + (p.r_b ** (tau - i)) * delta / tau
        # Eq. 11: in-place update of the user vector.
        st.user_vec = st.user_vec + (
            (p.r_g ** (k - 1 - j)) * (v_gj_new - v_gj) / k)
        if j == k - 1:
            st.last_group_vec = st.last_group_vec + (
                (p.r_b ** (tau - i)) * delta / tau)
        st.history[pos] = new_basket
        self._maybe_stabilize(st)
        return st

    # -- bulk accessors -------------------------------------------------------

    def user_matrix(self, user_ids: Sequence[int]) -> np.ndarray:
        return np.stack([self.state(u).user_vec for u in user_ids])
