"""CI-size dry-run: lower + compile representative cells on a small
multi-device mesh in a SUBPROCESS (jax locks the host device count on
first init, so the fake-device env var cannot be set in this process).
The full 512-chip sweep is launch/dryrun.py (results/ JSON)."""
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys
sys.path.insert(0, "src")
from repro import compat
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.sharding import ShardingRules
from repro.models import transformer as T
from repro.optim import adamw, adamw_state_pspecs
from repro.configs.base import named

mesh = jax.make_mesh((4, 4), ("data", "model"))
rules = ShardingRules(batch=("data",), fsdp=("data",))
c = T.TransformerConfig(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab_size=256, moe=True, n_experts=8, n_shared_experts=1, top_k=2,
    moe_d_ff=32, first_dense_layers=1, q_block=8, dtype=jnp.bfloat16)
params = T.abstract_params(c)
pspecs = T.param_pspecs(c, mesh, rules)
opt = adamw(total_steps=10)
opt_state = jax.eval_shape(opt.init, params)
batch = {"tokens": jax.ShapeDtypeStruct((16, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((16, 32), jnp.int32)}
bshard = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
with compat.set_mesh(mesh):
    fn = T.make_train_step(c, opt, mesh, rules)
    lowered = jax.jit(fn, in_shardings=(
        named(mesh, pspecs), named(mesh, adamw_state_pspecs(pspecs)),
        bshard), donate_argnums=(0, 1)).lower(params, opt_state, batch)
    compiled = lowered.compile()
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
hlo = compiled.as_text()
assert "all-reduce" in hlo or "all-gather" in hlo, "no collectives?!"
# roofline terms extract cleanly
from repro.launch.roofline import analyze
terms = analyze(compiled, hlo, 16)
assert terms.flops > 0 and terms.hbm_bytes > 0
print("SMALL_DRYRUN_OK", int(terms.flops), terms.bottleneck)
"""


def test_small_mesh_moe_train_lowers_and_compiles():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=580,
                       cwd="/root/repo")
    assert "SMALL_DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_production_cell_builders_construct():
    """Every (arch × shape) builder must at least CONSTRUCT its program
    spec (ShapeDtypeStructs + shardings) on the production mesh shape —
    without compiling (that is the full dry-run's job)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
import jax
from repro.configs import REGISTRY
from repro.launch.mesh import make_production_mesh, make_rules
mesh = make_production_mesh(multi_pod=True)
rules = make_rules(mesh)
n = 0
for name, arch in REGISTRY.items():
    for shape, builder in arch.cells.items():
        prog = builder(mesh, rules)
        assert prog.fn is not None and prog.args
        n += 1
print("BUILT", n)
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=580,
                       cwd="/root/repo")
    # 40 assigned cells + tifu-knn stream_update/serve_topk/serve_topk_opt
    assert "BUILT 43" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
