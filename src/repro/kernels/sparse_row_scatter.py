"""Sparse per-row scatter-add into a [M, I] table (TPU Pallas).

The batched add path (core.updates.apply_add_batch, DESIGN.md §3.3)
produces per-event deltas whose support is only the touched items:
``(rows[U], ids[U, W], vals[U, W])`` with W ≪ I.  This kernel applies

    table[rows[r], ids[r, w]] += vals[r, w]        (PAD ids skipped)

in place (``input_output_aliases``), so the full [M, I] state never
leaves HBM and only the touched *rows* are streamed through VMEM.

TPUs dislike data-dependent scatter, so per tile the update is a compare
+ reduce: the [W, bi] one-hot of the row's ids against the item tile's
iota, contracted with vals.  Grid = (I / bi item tiles, U batch rows),
batch rows innermost and **sorted by target row** by the dispatcher:
duplicate target rows become *consecutive* grid steps, which the kernel
accumulates in a VMEM scratch and writes back once per (row, tile) block
— revisiting an output block non-consecutively would be undefined.

The scalar-prefetched ``rows`` drive the block index map (the classic
embedding-update pattern), so a step only fetches the [1, bi] tile of
the row it actually updates: HBM traffic is O(U·I) worst case (touched
rows only) instead of O(M·I), and compute is O(U·W·I/bi) compares per
tile sweep.  A future refinement (ROADMAP) is a per-row touched-tile
list to skip clean tiles and reach O(U·W) traffic on TPU as well; the
XLA reference path (kernels.ref.sparse_row_scatter_ref) is already
O(U·W) and is what CPU uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, ids_ref, vals_ref, tab_ref, out_ref, acc, *, bi: int):
    ii = pl.program_id(0)
    r = pl.program_id(1)
    nr = pl.num_programs(1)

    row = rows_ref[r]
    prev_same = jnp.where(r > 0, rows_ref[jnp.maximum(r - 1, 0)] == row,
                          False)
    next_same = jnp.where(r < nr - 1,
                          rows_ref[jnp.minimum(r + 1, nr - 1)] == row, False)

    @pl.when(jnp.logical_not(prev_same))
    def _load():
        acc[...] = tab_ref[0, :]

    ids = ids_ref[0, :]                              # [W] i32, PAD=-1
    vals = vals_ref[0, :]                            # [W] f32
    base = ii * bi
    tile = base + jax.lax.broadcasted_iota(jnp.int32,
                                           (ids.shape[0], bi), 1)
    onehot = (ids[:, None] == tile).astype(jnp.float32)   # PAD never matches
    acc[...] += jnp.sum(onehot * vals[:, None], axis=0)

    @pl.when(jnp.logical_not(next_same))
    def _store():
        out_ref[0, :] = acc[...]


@functools.partial(jax.jit, static_argnames=("bi", "interpret"))
def sparse_row_scatter(table, rows, ids, vals, bi: int = 512,
                       interpret: bool = False):
    """table f32[M, I] (+)= scatter(rows i32[U], ids i32[U, W] PAD=-1,
    vals f32[U, W]).  Returns the updated table (aliased in place).

    Duplicate rows are handled (sorted internally so they land on
    consecutive grid steps and accumulate).  Requires I % bi == 0 —
    the ops.py dispatcher picks bi / falls back to the XLA reference.
    """
    m, n_items = table.shape
    u, w = ids.shape
    bi = min(bi, n_items)
    assert n_items % bi == 0, (n_items, bi)
    order = jnp.argsort(rows)
    rows_s = jnp.clip(rows[order], 0, m - 1).astype(jnp.int32)
    ids_s = ids[order]
    vals_s = jnp.where(ids_s >= 0, vals[order], 0.0)

    grid = (n_items // bi, u)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda ii, r, rows: (r, 0)),
            pl.BlockSpec((1, w), lambda ii, r, rows: (r, 0)),
            pl.BlockSpec((1, bi), lambda ii, r, rows: (rows[r], ii)),
        ],
        out_specs=pl.BlockSpec((1, bi), lambda ii, r, rows: (rows[r], ii)),
        scratch_shapes=[pltpu.VMEM((bi,), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bi=bi),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={3: 0},   # table (after the prefetch arg)
        interpret=interpret,
    )(rows_s, ids_s, vals_s, table)
