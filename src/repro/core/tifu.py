"""From-scratch TIFU-kNN user-vector computation (paper §2.2).

This is the "retraining" baseline against which the incremental and
decremental algorithms are validated, and the refresh path of the
stability tracker.  Two implementations:

* ragged numpy (``user_vector_ragged``) — mirrors the paper text
  step-by-step (multi-hot → group vectors → user vector);

* padded JAX (``user_vector_padded`` / ``batch_user_vectors``) — a single
  weighted multi-hot scatter using the closed-form per-basket weight

      w(basket at in-group position p of group j) =
          r_b^(tau_j - p) / tau_j * r_g^(k - j) / k

  which follows from substituting Eq. 1 into Eq. 2.  The scatter itself
  is ``kernels.decayed_scatter`` (one-hot matmul on TPU) with a
  segment-sum reference.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import PAD_ID, TifuParams


def multi_hot(basket: np.ndarray, n_items: int, dtype=np.float64) -> np.ndarray:
    """Multi-hot encode one basket (set of item ids) into a |I| vector."""
    v = np.zeros(n_items, dtype=dtype)
    ids = np.asarray(basket, dtype=np.int64)
    ids = ids[ids >= 0]
    v[ids] = 1.0
    return v


def default_group_sizes(n_baskets: int, m: int) -> List[int]:
    """Initial (fixed-size) grouping: ceil(n/m) groups.

    Paper §2.2: baskets are partitioned into groups of equal length m,
    except the last group which holds the remainder.  NOTE the paper's
    Eq. 1 averages with the *nominal* size m semantics per group; we
    follow the standard TIFU-kNN formulation where each group of size
    tau is averaged over its own tau baskets (the varying-group-size
    relaxation of §4.3 makes per-group sizes first-class anyway).
    """
    if n_baskets == 0:
        return []
    k = int(np.ceil(n_baskets / m))
    sizes = [m] * (k - 1)
    sizes.append(n_baskets - m * (k - 1))
    return sizes


def group_vector_ragged(baskets: Sequence[np.ndarray], n_items: int, r_b: float,
                        dtype=np.float64) -> np.ndarray:
    """Eq. 1: time-decayed average of the multi-hot basket vectors."""
    tau = len(baskets)
    v = np.zeros(n_items, dtype=dtype)
    for p, b in enumerate(baskets, start=1):
        v += (r_b ** (tau - p)) * multi_hot(b, n_items, dtype)
    return v / tau


def user_vector_ragged(history: Sequence[np.ndarray], group_sizes: Sequence[int],
                       params: TifuParams, dtype=np.float64) -> np.ndarray:
    """Eq. 2: decayed average of group vectors. The from-scratch oracle."""
    if len(history) == 0:
        return np.zeros(params.n_items, dtype=dtype)
    assert sum(group_sizes) == len(history), (group_sizes, len(history))
    k = len(group_sizes)
    v_u = np.zeros(params.n_items, dtype=dtype)
    start = 0
    for j, tau in enumerate(group_sizes, start=1):
        v_g = group_vector_ragged(history[start:start + tau], params.n_items,
                                  params.r_b, dtype)
        v_u += (params.r_g ** (k - j)) * v_g
        start += tau
    return v_u / k


def group_vectors_ragged(history: Sequence[np.ndarray],
                         group_sizes: Sequence[int], params: TifuParams,
                         dtype=np.float64) -> List[np.ndarray]:
    """All group vectors (needed by decremental scenario 2)."""
    out, start = [], 0
    for tau in group_sizes:
        out.append(group_vector_ragged(history[start:start + tau],
                                       params.n_items, params.r_b, dtype))
        start += tau
    return out


# ---------------------------------------------------------------------------
# Padded JAX path
# ---------------------------------------------------------------------------

def closed_form_basket_weights(group_sizes, n_groups, r_b, r_g, max_baskets):
    """Per-basket weight for every history row (padded, traced-friendly).

    group_sizes: i32[K] (padded with zeros), n_groups: traced scalar.
    Returns f32[max_baskets]: w_t = r_b^(tau_j - p_t) / tau_j * r_g^(k-j) / k
    for valid rows, 0 for padding rows.
    """
    k = n_groups
    sizes = group_sizes.astype(jnp.int32)
    # start offset of each group
    starts = jnp.cumsum(sizes) - sizes            # [K]
    t = jnp.arange(max_baskets)                   # global basket index, 0-based
    # group index of each row: number of groups whose start <= t given row is
    # within total; use searchsorted over cumsum.
    ends = jnp.cumsum(sizes)                      # [K]
    g = jnp.searchsorted(ends, t, side="right")   # [N] in [0, K]
    g = jnp.clip(g, 0, sizes.shape[0] - 1)
    tau = sizes[g]                                # [N]
    p = t - starts[g] + 1                         # 1-based in-group position
    n_total = ends[jnp.maximum(k - 1, 0)] * (k > 0)
    valid = (t < n_total) & (tau > 0)
    w_b = jnp.asarray(r_b, jnp.float32) ** (tau - p) / jnp.maximum(tau, 1)
    w_g = jnp.asarray(r_g, jnp.float32) ** (k - 1 - g) / jnp.maximum(k, 1)
    return jnp.where(valid, w_b * w_g, 0.0)


def weighted_multihot_scatter(history, weights, n_items):
    """sum_t weights[t] * multihot(history[t])  →  f32[n_items].

    history: i32[N, B] (PAD_ID padded); weights: f32[N].
    Reference implementation via one flat segment-style scatter-add; the
    TPU fast path is kernels.decayed_scatter (one-hot matmul).
    """
    ids = history.reshape(-1)
    w = jnp.repeat(weights, history.shape[1])
    valid = ids >= 0
    ids = jnp.where(valid, ids, 0)
    w = jnp.where(valid, w, 0.0)
    return jnp.zeros((n_items,), jnp.float32).at[ids].add(w)


def user_vector_padded(history, group_sizes, n_groups, params: TifuParams):
    """From-scratch user vector on padded arrays (jit/vmap friendly)."""
    w = closed_form_basket_weights(group_sizes, n_groups, params.r_b,
                                   params.r_g, history.shape[0])
    return weighted_multihot_scatter(history, w, params.n_items)


def last_group_vector_padded(history, group_sizes, n_groups, params: TifuParams):
    """Recompute the last group's vector from padded history (O(m) rows)."""
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    k = jnp.maximum(n_groups, 1)
    tau = sizes[k - 1]
    start = ends[k - 1] - tau
    t = jnp.arange(history.shape[0])
    p = t - start + 1
    valid = (p >= 1) & (p <= tau)
    w = jnp.where(valid,
                  jnp.asarray(params.r_b, jnp.float32) ** (tau - p)
                  / jnp.maximum(tau, 1), 0.0)
    out = weighted_multihot_scatter(history, w, params.n_items)
    return jnp.where(n_groups > 0, out, jnp.zeros_like(out))


def batch_user_vectors(histories, group_sizes, n_groups, params: TifuParams):
    """vmap'd from-scratch user vectors: [M,N,B],[M,K],[M] → [M,I]."""
    return jax.vmap(
        lambda h, gs, ng: user_vector_padded(h, gs, ng, params))(
            histories, group_sizes, n_groups)
