"""Corpus case: exact-division grid without the precondition (KC04).

Both grid axes use plain floor division but the contract does not
declare divisible=True (and there is no divisibility assert), so a
non-multiple input silently drops its tail elements.
"""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref):
    acc_ref[...] = x_ref[...]
    o_ref[...] = acc_ref[...]


def thing(x, n, m, bq=128, bm=256):
    grid = (n // bq, m // bm)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi))],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )(x)
