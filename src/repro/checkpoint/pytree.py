"""Fault-tolerant pytree checkpointing (no orbax dependency).

* atomic writes (tmp + rename) — a preempted writer never corrupts the
  latest checkpoint;
* ``AsyncCheckpointer`` overlaps serialization with training (snapshot to
  host, write on a worker thread);
* **elastic restore**: ``restore_pytree(..., shardings=...)`` re-shards
  onto a DIFFERENT mesh than the one that saved — scale-up/down restart
  (tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy cannot serialize ml_dtypes (bf16/fp8): view as a same-width uint
# and record the true dtype in the metadata.
_EXOTIC = {np.dtype(ml_dtypes.bfloat16): np.uint16,
           np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
           np.dtype(ml_dtypes.float8_e5m2): np.uint8}


def _encode(x: np.ndarray):
    if x.dtype in _EXOTIC:
        return x.view(_EXOTIC[x.dtype]), str(x.dtype)
    return x, str(x.dtype)


def _decode(x: np.ndarray, dtype_name: str):
    if str(x.dtype) != dtype_name:
        return x.view(np.dtype(dtype_name))
    return x


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(tree: Any, directory: str, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, _ = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        enc, name = _encode(np.asarray(x))
        arrays[f"leaf_{i}"] = enc
        dtypes.append(name)
    path = os.path.join(directory, f"ckpt_{step:010d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    meta = {"step": step, "n_leaves": len(leaves), "dtypes": dtypes}
    mtmp = os.path.join(directory, "LATEST.tmp")
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, os.path.join(directory, "LATEST"))
    return path


def latest_step(directory: str) -> Optional[int]:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return json.load(f)["step"]
    except FileNotFoundError:
        return None


def restore_pytree(like: Any, directory: str, step: Optional[int] = None,
                   shardings: Any = None) -> Any:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of NamedSharding matching ``like`` —
    leaves are device_put with these shardings, enabling restore onto a
    different mesh shape than the writer's (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step:010d}.npz")
    data = np.load(path)
    with open(os.path.join(directory, "LATEST")) as f:
        dtypes = json.load(f).get("dtypes")
    leaves, treedef = _flatten(like)
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    if dtypes:
        new_leaves = [_decode(x, d) for x, d in zip(new_leaves, dtypes)]
    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)
        new_leaves = [jax.device_put(x, s)
                      for x, s in zip(new_leaves, shard_leaves)]
    else:
        new_leaves = [jax.numpy.asarray(x) for x in new_leaves]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class AsyncCheckpointer:
    """Background writer: snapshot on submit, serialize off-thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        ckpts = sorted(f for f in os.listdir(self.directory)
                       if f.startswith("ckpt_") and f.endswith(".npz"))
        for f in ckpts[:-self.keep]:
            os.remove(os.path.join(self.directory, f))

    def save(self, tree: Any, step: int):
        if self._err:
            raise self._err
        # snapshot to host memory NOW so training can mutate buffers
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((host, step))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
