"""Cell builders: (architecture × input shape) → lowerable program.

Each assigned architecture registers an ``ArchDef`` whose ``cells`` map
shape names to builders.  A builder returns a ``CellProgram``:
``jax.jit(fn, in_shardings, donate).lower(*args)`` must compile on the
production meshes (launch/dryrun.py runs every cell on both meshes).

All inputs are ``ShapeDtypeStruct`` stand-ins — nothing is allocated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import (adamw, adafactor, adamw_state_pspecs,
                         adafactor_state_pspecs)
from repro.parallel.sharding import ShardingRules, batch_axes


@dataclasses.dataclass
class CellProgram:
    fn: Callable
    args: tuple
    in_shardings: Any
    donate_argnums: tuple = ()
    static_argnums: tuple = ()
    description: str = ""
    model_flops_per_step: float = 0.0   # 6·N·D (train) / 2·N·D (serve)


@dataclasses.dataclass
class ArchDef:
    name: str
    family: str                       # "lm" | "gnn" | "recsys" | "tifu"
    cells: Dict[str, Callable]        # shape → (mesh, rules) → CellProgram
    make_smoke: Callable              # () -> (config, smoke_fn)
    notes: str = ""


def named(mesh, tree_of_pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shardable(n, mesh, axes):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod([sizes[a] for a in axes])) if axes else 1
    return total > 1 and n % total == 0


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_train_flops(c, batch, seq):
    return 6.0 * c.n_active_params() * batch * seq


def lm_train_cell(make_config, global_batch: int, seq: int,
                  optimizer: str = "adamw"):
    from repro.models import transformer as T

    def build(mesh: Mesh, rules: ShardingRules) -> CellProgram:
        c = make_config()
        params = T.abstract_params(c)
        pspecs = T.param_pspecs(c, mesh, rules)
        opt = adamw(total_steps=10000) if optimizer == "adamw" \
            else adafactor()
        opt_state = jax.eval_shape(opt.init, params)
        opt_pspecs = adamw_state_pspecs(pspecs) if optimizer == "adamw" \
            else adafactor_state_pspecs(params, pspecs)
        b_ax = batch_axes(mesh, rules)
        bspec = P(b_ax if _shardable(global_batch, mesh, b_ax) else None,
                  None)
        batch = {"tokens": sds((global_batch, seq), jnp.int32),
                 "labels": sds((global_batch, seq), jnp.int32)}
        bshard = {"tokens": NamedSharding(mesh, bspec),
                  "labels": NamedSharding(mesh, bspec)}
        fn = T.make_train_step(c, opt, mesh, rules)
        return CellProgram(
            fn=fn, args=(params, opt_state, batch),
            in_shardings=(named(mesh, pspecs), named(mesh, opt_pspecs),
                          bshard),
            donate_argnums=(0, 1),
            description=f"train_step B={global_batch} S={seq}",
            model_flops_per_step=lm_train_flops(c, global_batch, seq))
    return build


def _cache_pspecs(c, batch: int, mesh, rules):
    """KV-cache sharding: B over batch axes when divisible, S over the
    context axis ('model'; + 'data' too when B is unshardable)."""
    from repro.models import transformer as T
    b_ax = batch_axes(mesh, rules)
    b_ok = _shardable(batch, mesh, b_ax)
    if b_ok:
        s_ax = rules.context if rules.context in mesh.axis_names else None
        bs = b_ax
    else:
        axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
        s_ax, bs = axes, None

    def one(sd):
        if len(sd.shape) == 5:      # [L,B,S,kv,dh]
            return P(None, bs, s_ax, None, None)
        return P(None, bs, s_ax, None)  # MLA latent [L,B,S,r]

    return jax.tree.map(one, T.cache_shapes(c, batch, 1),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lm_decode_cell(make_config, global_batch: int, cache_len: int):
    from repro.models import transformer as T

    def build(mesh: Mesh, rules: ShardingRules) -> CellProgram:
        c = make_config()
        params = T.abstract_params(c)
        pspecs = T.param_pspecs(c, mesh, rules)
        caches = T.cache_shapes(c, global_batch, cache_len)
        cache_ps = _cache_pspecs(c, global_batch, mesh, rules)
        b_ax = batch_axes(mesh, rules)
        bspec = P(b_ax if _shardable(global_batch, mesh, b_ax) else None,
                  None)
        token = sds((global_batch, 1), jnp.int32)
        pos = sds((), jnp.int32)

        def fn(params, caches, token, pos):
            return T.decode_step(params, caches, token, pos, c, mesh, rules)

        return CellProgram(
            fn=fn, args=(params, caches, token, pos),
            in_shardings=(named(mesh, pspecs), named(mesh, cache_ps),
                          NamedSharding(mesh, bspec),
                          NamedSharding(mesh, P())),
            donate_argnums=(1,),
            description=f"decode_step B={global_batch} cache={cache_len}",
            model_flops_per_step=2.0 * c.n_active_params() * global_batch)
    return build


def lm_prefill_cell(make_config, global_batch: int, seq: int):
    from repro.models import transformer as T

    def build(mesh: Mesh, rules: ShardingRules) -> CellProgram:
        c = make_config()
        params = T.abstract_params(c)
        pspecs = T.param_pspecs(c, mesh, rules)
        b_ax = batch_axes(mesh, rules)
        bspec = P(b_ax if _shardable(global_batch, mesh, b_ax) else None,
                  None)
        tokens = sds((global_batch, seq), jnp.int32)

        def fn(params, tokens):
            return T.prefill(params, tokens, c, max_len=seq, mesh=mesh,
                             rules=rules)

        return CellProgram(
            fn=fn, args=(params, tokens),
            in_shardings=(named(mesh, pspecs), NamedSharding(mesh, bspec)),
            description=f"prefill B={global_batch} S={seq}",
            model_flops_per_step=2.0 * c.n_active_params() * global_batch
            * seq)
    return build


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def recsys_cell(module, make_config, batch_builder, kind: str,
                flops_fn=None, train: bool = False, serve_fn="serve_step",
                train_kwargs: Optional[dict] = None, pass_mesh: bool = False):
    """Generic builder for the recsys/GNN models.

    ``batch_builder(c, mesh, rules) -> (batch_sds, batch_shardings)``.
    ``pass_mesh``: forward (mesh, rules) into the model step (models with
    a shard_map distributed path, e.g. DimeNet).
    """
    def build(mesh: Mesh, rules: ShardingRules) -> CellProgram:
        c = make_config()
        params = module.abstract_params(c)
        pspecs = module.param_pspecs(c, mesh, rules)
        batch, bshard = batch_builder(c, mesh, rules)
        mesh_kw = {"mesh": mesh, "rules": rules} if pass_mesh else {}
        if train:
            opt = adamw(total_steps=10000)
            opt_state = jax.eval_shape(opt.init, params)
            opt_pspecs = adamw_state_pspecs(pspecs)
            fn = module.make_train_step(c, opt, **(train_kwargs or {}),
                                        **mesh_kw)
            return CellProgram(
                fn=fn, args=(params, opt_state, batch),
                in_shardings=(named(mesh, pspecs), named(mesh, opt_pspecs),
                              bshard),
                donate_argnums=(0, 1), description=kind,
                model_flops_per_step=flops_fn(c) if flops_fn else 0.0)

        def fn(params, batch):
            return getattr(module, serve_fn)(params, batch, c, **mesh_kw)

        return CellProgram(
            fn=fn, args=(params, batch),
            in_shardings=(named(mesh, pspecs), bshard),
            description=kind,
            model_flops_per_step=flops_fn(c) if flops_fn else 0.0)
    return build


def batch_spec(mesh, rules, n):
    b_ax = batch_axes(mesh, rules)
    return b_ax if _shardable(n, mesh, b_ax) else None
