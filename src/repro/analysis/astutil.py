"""Stdlib-``ast`` plumbing shared by the analysis rule modules.

Pure syntax: nothing here imports jax or executes repo code.  The main
jobs are (a) extracting ``pl.pallas_call`` sites — grid, scalar-prefetch
count, BlockSpec index-map arities, scratch dtypes, kernel body name —
through the local-name indirections the kernel modules actually use
(``grid = (...)``, ``kernel = functools.partial(_kernel, ...)``,
``grid_spec = pltpu.PrefetchScalarGridSpec(...)``), and (b) normalized
function-body comparison for the intentional-duplicate rule (OR03),
which canonicalizes ``pl.cdiv(a, b)`` to ``-(-a // b)`` and strips
docstrings so the two legal spellings of ceil-div compare equal.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple


@dataclasses.dataclass
class SourceFile:
    """A parsed file: path, raw text, module AST."""

    path: Path
    text: str
    tree: ast.Module


def load(path) -> SourceFile:
    """Parse ``path`` into a :class:`SourceFile`."""
    p = Path(path)
    text = p.read_text()
    return SourceFile(path=p, text=text, tree=ast.parse(text))


def top_level_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """Module-level function definitions by name (classes excluded)."""
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def local_env(fn: ast.AST) -> Dict[str, ast.expr]:
    """name -> value for simple single-target assignments under ``fn``.

    Shallow by design: used to chase the one-hop indirections
    (``grid``/``grid_spec``/``kernel``/dtype aliases) kernel entry
    functions introduce, not to evaluate code.
    """
    env: Dict[str, ast.expr] = {}
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            env[node.targets[0].id] = node.value
    return env


def resolve(expr: Optional[ast.expr], env: Dict[str, ast.expr],
            depth: int = 4) -> Optional[ast.expr]:
    """Follow Name -> assigned-value links up to ``depth`` hops."""
    while (depth and isinstance(expr, ast.Name) and expr.id in env
           and env[expr.id] is not expr):
        expr = env[expr.id]
        depth -= 1
    return expr


def _called_name(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


@dataclasses.dataclass
class BlockSpecInfo:
    """One BlockSpec at a pallas_call site: index-map arity + location."""

    arity: Optional[int]
    lineno: int


@dataclasses.dataclass
class PallasSite:
    """One ``pl.pallas_call`` site, structurally decomposed."""

    entry: str
    entry_node: ast.FunctionDef
    lineno: int
    kernel_body: Optional[str]
    grid: List[ast.expr]
    grid_parsed: bool
    scalar_prefetch: int
    in_specs: List[BlockSpecInfo]
    out_specs: List[BlockSpecInfo]
    scratch_dtypes: List[Optional[str]]


def _spec_list(expr: Optional[ast.expr],
               env: Dict[str, ast.expr]) -> List[ast.expr]:
    expr = resolve(expr, env)
    if expr is None:
        return []
    if isinstance(expr, (ast.List, ast.Tuple)):
        return list(expr.elts)
    return [expr]


def _block_spec_info(expr: ast.expr) -> BlockSpecInfo:
    arity: Optional[int] = None
    if isinstance(expr, ast.Call) and _called_name(expr.func) == "BlockSpec":
        index_map: Optional[ast.expr] = None
        if len(expr.args) >= 2:
            index_map = expr.args[1]
        for kw in expr.keywords:
            if kw.arg == "index_map":
                index_map = kw.value
        if isinstance(index_map, ast.Lambda):
            arity = len(index_map.args.args)
    return BlockSpecInfo(arity=arity, lineno=expr.lineno)


def _scratch_dtypes(expr: Optional[ast.expr],
                    env: Dict[str, ast.expr]) -> List[Optional[str]]:
    out: List[Optional[str]] = []
    for item in _spec_list(expr, env):
        dtype: Optional[str] = None
        if (isinstance(item, ast.Call) and len(item.args) >= 2
                and _called_name(item.func) in ("VMEM", "SMEM", "ANY")):
            val = resolve(item.args[1], env)
            if isinstance(val, ast.Attribute):
                dtype = val.attr
            elif isinstance(val, ast.Name):
                dtype = val.id
        out.append(dtype)
    return out


def _kernel_body_name(expr: Optional[ast.expr],
                      env: Dict[str, ast.expr]) -> Optional[str]:
    expr = resolve(expr, env)
    if isinstance(expr, ast.Call) and expr.args:
        # functools.partial(_kernel, ...) -> _kernel
        expr = resolve(expr.args[0], env)
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _extract_site(entry: ast.FunctionDef, call: ast.Call,
                  env: Dict[str, ast.expr]) -> PallasSite:
    kw = {k.arg: k.value for k in call.keywords if k.arg}
    grid_expr = kw.get("grid")
    prefetch = 0
    in_specs_expr = kw.get("in_specs")
    out_specs_expr = kw.get("out_specs")
    scratch_expr = kw.get("scratch_shapes")

    grid_spec = resolve(kw.get("grid_spec"), env)
    if isinstance(grid_spec, ast.Call):
        gs_kw = {k.arg: k.value for k in grid_spec.keywords if k.arg}
        grid_expr = gs_kw.get("grid", grid_expr)
        in_specs_expr = gs_kw.get("in_specs", in_specs_expr)
        out_specs_expr = gs_kw.get("out_specs", out_specs_expr)
        scratch_expr = gs_kw.get("scratch_shapes", scratch_expr)
        npf = gs_kw.get("num_scalar_prefetch")
        if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
            prefetch = npf.value

    grid_expr = resolve(grid_expr, env)
    if isinstance(grid_expr, (ast.Tuple, ast.List)):
        grid, parsed = list(grid_expr.elts), True
    elif grid_expr is not None:
        grid, parsed = [grid_expr], True
    else:
        grid, parsed = [], False

    return PallasSite(
        entry=entry.name,
        entry_node=entry,
        lineno=call.lineno,
        kernel_body=_kernel_body_name(
            call.args[0] if call.args else None, env),
        grid=grid,
        grid_parsed=parsed,
        scalar_prefetch=prefetch,
        in_specs=[_block_spec_info(e)
                  for e in _spec_list(in_specs_expr, env)],
        out_specs=[_block_spec_info(e)
                   for e in _spec_list(out_specs_expr, env)],
        scratch_dtypes=_scratch_dtypes(scratch_expr, env),
    )


def find_pallas_sites(tree: ast.Module) -> List[PallasSite]:
    """Every ``pl.pallas_call`` site under a top-level function."""
    sites = []
    for fn in top_level_functions(tree).values():
        env = local_env(fn)
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "pallas_call"):
                sites.append(_extract_site(fn, node, env))
    return sites


def grid_axis_kind(expr: ast.expr) -> str:
    """'cdiv' | 'floordiv' | 'other' for one grid-axis expression."""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call) and _called_name(sub.func) == "cdiv":
            return "cdiv"
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.FloorDiv):
        return "floordiv"
    return "other"


def has_mod_assert(fn: ast.FunctionDef) -> bool:
    """True when ``fn`` contains an assert over a ``%`` expression."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Assert):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op,
                                                             ast.Mod):
                    return True
    return False


def referenced_names(fn: ast.AST) -> Set[str]:
    """Every name referenced under ``fn``: bare names as ``name``,
    one-level attribute access as ``base.attr`` (plus bare ``attr``
    for deeper chains).  Nested defs/lambdas fold in automatically."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name):
                out.add(f"{node.value.id}.{node.attr}")
            else:
                out.add(node.attr)
    return out


def writes_raw(fn: ast.AST) -> bool:
    """True when ``fn`` performs a raw durable write: ``open`` in a
    writable mode, ``os.replace``, or ``np.savez*``/``np.save``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if (isinstance(f, ast.Name) and f.id == "open"
                and len(node.args) >= 2):
            mode = node.args[1]
            if (isinstance(mode, ast.Constant)
                    and isinstance(mode.value, str)
                    and any(c in mode.value for c in "wa+x")):
                return True
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            if f.value.id == "os" and f.attr == "replace":
                return True
            if f.value.id in ("np", "numpy") and (
                    f.attr.startswith("savez") or f.attr == "save"):
                return True
    return False


class _CdivNormalizer(ast.NodeTransformer):
    """Rewrite ``cdiv(a, b)`` / ``pl.cdiv(a, b)`` to ``-(-a // b)``."""

    def visit_Call(self, node: ast.Call) -> ast.expr:
        self.generic_visit(node)
        if (_called_name(node.func) == "cdiv" and len(node.args) == 2
                and not node.keywords):
            a, b = node.args
            return ast.UnaryOp(
                op=ast.USub(),
                operand=ast.BinOp(
                    left=ast.UnaryOp(op=ast.USub(), operand=a),
                    op=ast.FloorDiv(), right=b))
        return node


def normalized_body_dump(fn: ast.FunctionDef) -> str:
    """Deterministic dump of ``fn``'s body, docstring stripped and
    ceil-div spellings canonicalized — signatures are NOT compared, so
    duplicates may legally differ in defaults/annotations (OR03)."""
    body = list(fn.body)
    if (body and isinstance(body[0], ast.Expr)
            and isinstance(body[0].value, ast.Constant)
            and isinstance(body[0].value.value, str)):
        body = body[1:]
    module = ast.Module(body=body, type_ignores=[])
    module = _CdivNormalizer().visit(module)
    return ast.dump(module, annotate_fields=False)


@dataclasses.dataclass
class FuncInfo:
    """A function at module or class scope, for call-graph rules."""

    qualname: str
    node: ast.FunctionDef
    cls: Optional[str] = None


def collect_functions(tree: ast.Module) -> Dict[str, FuncInfo]:
    """Module-level functions plus class methods (``Cls.meth``).

    Nested defs are folded into their enclosing function by the
    ``ast.walk``-based predicates, so the graph stays at this
    granularity on purpose.
    """
    out: Dict[str, FuncInfo] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = FuncInfo(qualname=node.name, node=node)
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{node.name}.{sub.name}"
                    out[q] = FuncInfo(qualname=q, node=sub, cls=node.name)
    return out


def call_edges(funcs: Dict[str, FuncInfo]) -> Dict[str, Set[str]]:
    """qualname -> qualnames it references (module-local resolution:
    bare names to module functions, ``self.x`` to same-class methods)."""
    edges: Dict[str, Set[str]] = {}
    module_level = {q for q, f in funcs.items() if f.cls is None}
    for q, info in funcs.items():
        refs = referenced_names(info.node)
        tgt: Set[str] = set()
        for r in refs:
            if r in module_level:
                tgt.add(r)
            if r.startswith("self."):
                meth = f"{info.cls}.{r[5:]}"
                if meth in funcs:
                    tgt.add(meth)
        tgt.discard(q)
        edges[q] = tgt
    return edges


def transitive_closure(start: str,
                       edges: Dict[str, Set[str]]) -> Set[str]:
    """Every qualname reachable from ``start`` (inclusive)."""
    seen = {start}
    frontier = [start]
    while frontier:
        cur = frontier.pop()
        for nxt in edges.get(cur, ()):
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    return seen


def module_for(root: Path, path: Path) -> str:
    """Dotted module name of ``path`` under ``root/src``."""
    rel = path.resolve().relative_to((root / "src").resolve())
    return ".".join(rel.with_suffix("").parts)


def path_for(root: Path, module: str) -> Path:
    """Source path of dotted ``module`` under ``root/src``."""
    return root / "src" / Path(*module.split(".")).with_suffix(".py")
