"""Per-architecture smoke tests: REDUCED configs of the same family run
one forward/train step on CPU; assert output shapes and no NaNs.
(The FULL assigned configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.optim import adamw


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x))) for x in
               jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


LM_ARCHS = ["granite-3-2b", "gemma3-27b", "command-r-plus-104b",
            "qwen2-moe-a2.7b", "deepseek-v3-671b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch, rng):
    from repro.models import transformer as T
    c = REGISTRY[arch].make_smoke()
    params = T.init_params(c, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, c.vocab_size, (2, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, c.vocab_size, (2, 16)),
                              jnp.int32)}
    opt = adamw(total_steps=3)
    step = jax.jit(T.make_train_step(c, opt))
    p2, o2, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"])) and float(m["loss"]) > 0
    assert _finite(p2)
    # serving: prefill 8 + decode 2
    logits, caches = T.prefill(params, batch["tokens"][:, :8], c, max_len=16)
    assert logits.shape == (2, c.vocab_size)
    lg, caches = T.decode_step(params, caches, batch["tokens"][:, 8:9], 8, c)
    assert lg.shape == (2, c.vocab_size) and _finite(lg)


def test_dimenet_smoke(rng):
    from repro.models import dimenet
    from repro.data.graph_sampler import build_triplets, molecule_batch
    c = REGISTRY["dimenet"].make_smoke()
    z, pos, src, dst, gid = molecule_batch(4, 10, 24)
    tkj, tji = build_triplets(src, dst)
    dist, angle = dimenet.geometry_from_positions(
        jnp.asarray(pos), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(tkj), jnp.asarray(tji))
    batch = {"z": jnp.asarray(z), "edge_src": jnp.asarray(src),
             "edge_dst": jnp.asarray(dst), "dist": dist, "angle": angle,
             "tri_kj": jnp.asarray(tkj), "tri_ji": jnp.asarray(tji),
             "graph_id": jnp.asarray(gid),
             "labels": jnp.zeros((4,), jnp.float32)}
    params = dimenet.init_params(c, jax.random.PRNGKey(0))
    out = dimenet.forward(params, batch, c)
    assert out.shape == (4, 1) and _finite(out)
    opt = adamw(total_steps=3)
    step = jax.jit(dimenet.make_train_step(c, opt))
    p2, o2, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))


def test_dlrm_smoke(rng):
    from repro.models import dlrm
    c = REGISTRY["dlrm-mlperf"].make_smoke()
    params = dlrm.init_params(c, jax.random.PRNGKey(0))
    batch = {"dense": jnp.asarray(rng.normal(size=(8, 13)), jnp.float32),
             "sparse": jnp.asarray(rng.integers(0, 64, (8, 26)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 2, 8), jnp.float32)}
    opt = adamw(total_steps=3)
    p2, o2, m = jax.jit(dlrm.make_train_step(c, opt))(
        params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    scores = dlrm.serve_step(params, batch, c)
    assert scores.shape == (8,) and _finite(scores)


def test_deepfm_smoke(rng):
    from repro.models import deepfm
    c = REGISTRY["deepfm"].make_smoke()
    params = deepfm.init_params(c, jax.random.PRNGKey(0))
    batch = {"sparse": jnp.asarray(rng.integers(0, 32, (8, 39)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 2, 8), jnp.float32)}
    opt = adamw(total_steps=3)
    p2, o2, m = jax.jit(deepfm.make_train_step(c, opt))(
        params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))


def test_bert4rec_smoke(rng):
    from repro.models import bert4rec
    c = REGISTRY["bert4rec"].make_smoke()
    params = bert4rec.init_params(c, jax.random.PRNGKey(0))
    ids = jnp.asarray(rng.integers(2, 400, (4, c.seq_len)), jnp.int32)
    tgt = jnp.where(jnp.asarray(rng.random((4, c.seq_len)) < 0.2), ids, -1)
    batch = {"ids": jnp.where(tgt >= 0, 1, ids), "targets": tgt}
    opt = adamw(total_steps=3)
    p2, o2, m = jax.jit(bert4rec.make_train_step(c, opt))(
        params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    # sampled path (the production train cell)
    batch2 = {"ids": ids,
              "mask_pos": jnp.asarray(rng.integers(0, c.seq_len, (4, 3)),
                                      jnp.int32),
              "targets": jnp.asarray(rng.integers(2, 400, (4, 3)),
                                     jnp.int32),
              "negatives": jnp.asarray(rng.integers(2, 400, 16), jnp.int32)}
    loss = bert4rec.sampled_cloze_loss(params, batch2, c)
    assert np.isfinite(float(loss))
    vals, idx = bert4rec.serve_step(params, {"ids": ids}, c, top_n=5,
                                    vocab_chunk=256)
    assert idx.shape == (4, 5)


def test_two_tower_smoke(rng):
    from repro.models import two_tower
    c = REGISTRY["two-tower-retrieval"].make_smoke()
    params = two_tower.init_params(c, jax.random.PRNGKey(0))
    batch = {"user_id": jnp.arange(8),
             "history": jnp.asarray(rng.integers(-1, 500, (8, c.hist_len)),
                                    jnp.int32),
             "item_id": jnp.arange(8),
             "item_cat": jnp.zeros((8,), jnp.int32),
             "logq": jnp.zeros((8,), jnp.float32)}
    opt = adamw(total_steps=3)
    p2, o2, m = jax.jit(two_tower.make_train_step(c, opt))(
        params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    cand = jnp.asarray(rng.normal(size=(128, c.tower_mlp[-1])), jnp.float32)
    vals, idx = two_tower.retrieval_step(
        params, {"user_id": batch["user_id"][:1],
                 "history": batch["history"][:1], "candidates": cand}, c,
        top_n=10)
    assert idx.shape == (1, 10)


def test_tifu_smoke(rng):
    """The paper's own arch as a config."""
    from repro.core import RefEngine
    p = REGISTRY["tifu-knn"].make_smoke()
    eng = RefEngine(p)
    for _ in range(6):
        eng.add_basket(0, rng.choice(p.n_items, size=3, replace=False))
    assert eng.state(0).n_baskets == 6
    assert np.isfinite(eng.state(0).user_vec).all()


def test_registry_covers_assignment():
    from repro.configs import ASSIGNED
    assert len(ASSIGNED) == 10
    cells = sum(len(REGISTRY[a].cells) for a in ASSIGNED)
    assert cells == 40, f"expected 40 assigned cells, got {cells}"
