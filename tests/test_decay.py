"""Property tests for the decaying-average maintenance rules (paper §4.1).

These are the paper's core mathematical claims:
  Eq. 3 incremental  — EXACT vs from-scratch;
  Eq. 4 decremental  — matches from-scratch (up to float error), touches
                       only the suffix;
  Eq. 5 in-place     — exact;
  §6.3 instability   — error multiplier k/((k-1)r) per deletion.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import decay

floats = st.floats(min_value=-100, max_value=100, allow_nan=False,
                   allow_infinity=False, width=32)


@given(xs=st.lists(floats, min_size=1, max_size=40),
       x_new=floats,
       r=st.floats(min_value=0.05, max_value=1.0))
@settings(max_examples=200, deadline=None)
def test_incremental_matches_scratch(xs, x_new, r):
    xs = np.asarray(xs, np.float64)
    avg = decay.decayed_average(xs, r)
    incr = decay.incremental_add(avg, len(xs), x_new, r)
    scratch = decay.decayed_average(np.append(xs, x_new), r)
    np.testing.assert_allclose(incr, scratch, rtol=1e-10, atol=1e-10)


@given(xs=st.lists(floats, min_size=2, max_size=40),
       r=st.floats(min_value=0.05, max_value=1.0),
       data=st.data())
@settings(max_examples=200, deadline=None)
def test_decremental_matches_scratch(xs, r, data):
    xs = np.asarray(xs, np.float64)
    n = len(xs)
    i = data.draw(st.integers(min_value=1, max_value=n))  # 1-based
    avg = decay.decayed_average(xs, r)
    # only the suffix [x_i .. x_n] is passed — the O(n-i) access property
    out = decay.decremental_delete(avg, n, xs[i - 1:], i, r)
    scratch = decay.decayed_average(np.delete(xs, i - 1), r)
    np.testing.assert_allclose(out, scratch, rtol=1e-8, atol=1e-8)


@given(xs=st.lists(floats, min_size=1, max_size=40),
       x_new=floats,
       r=st.floats(min_value=0.05, max_value=1.0),
       data=st.data())
@settings(max_examples=200, deadline=None)
def test_inplace_matches_scratch(xs, x_new, r, data):
    xs = np.asarray(xs, np.float64)
    n = len(xs)
    i = data.draw(st.integers(min_value=1, max_value=n))
    avg = decay.decayed_average(xs, r)
    out = decay.inplace_update(avg, n, xs[i - 1], x_new, i, r)
    xs2 = xs.copy()
    xs2[i - 1] = x_new
    np.testing.assert_allclose(out, decay.decayed_average(xs2, r),
                               rtol=1e-9, atol=1e-9)


@given(xs=st.lists(floats, min_size=3, max_size=30),
       r=st.floats(min_value=0.3, max_value=1.0))
@settings(max_examples=100, deadline=None)
def test_vector_series(xs, r):
    """The rules extend element-wise to vector series (paper §4.1 note)."""
    base = np.asarray(xs, np.float64)
    series = np.stack([base, 2 * base, base ** 2], axis=1)  # [n, 3]
    avg = decay.decayed_average(series, r)
    out = decay.decremental_delete(avg, len(xs), series[0:], 1, r)
    scratch = decay.decayed_average(series[1:], r)
    np.testing.assert_allclose(out, scratch, rtol=1e-7, atol=1e-7)


def test_suffix_coefficients_expand_the_dot_product(rng):
    """D(.)ᵀR(.) == Σ c_t x_t with the closed-form coefficients."""
    for _ in range(20):
        n = int(rng.integers(2, 30))
        i = int(rng.integers(1, n + 1))
        r = float(rng.uniform(0.1, 1.0))
        xs = rng.normal(size=n)
        avg = decay.decayed_average(xs, r)
        via_dot = decay.decremental_delete(avg, n, xs[i - 1:], i, r)
        coeff = decay.suffix_coefficients(n, i, r)
        via_coeff = (n * avg + coeff @ xs) / ((n - 1) * r)
        np.testing.assert_allclose(via_dot, via_coeff, rtol=1e-9)


def test_error_growth_factor_matches_paper():
    """§6.3: alpha = k/((k-1) r_g) > 1/r_g > 1."""
    a = decay.error_growth_factor(5, 0.7)
    assert a == pytest.approx(5 / (4 * 0.7))
    assert a > 1 / 0.7 > 1.0


def test_decremental_instability_is_real(rng):
    """Repeated deletions amplify an injected error by ~alpha^n (§6.3)."""
    r = 0.7
    n0 = 200
    xs = rng.normal(size=n0)
    avg = decay.decayed_average(xs, r)
    eps = 1e-9
    avg_bad = avg + eps
    xs_live = xs.copy()
    n_del = 30
    for _ in range(n_del):
        n = len(xs_live)
        avg = decay.decremental_delete(avg, n, xs_live[0:], 1, r)
        avg_bad = decay.decremental_delete(avg_bad, n, xs_live[0:], 1, r)
        xs_live = xs_live[1:]
    measured = abs(avg_bad - avg) / eps
    # predicted worst-case growth: prod over deletions of n/((n-1)r)
    predicted = np.prod([n / ((n - 1) * r)
                         for n in range(n0, n0 - n_del, -1)])
    assert measured == pytest.approx(predicted, rel=0.05)
