#!/usr/bin/env python3
"""Intra-repo markdown link checker (the CI docs job; no dependencies).

Validates every markdown link in the given files:

* relative file targets must exist on disk (resolved against the
  containing file; targets escaping the repo root are skipped — they
  address the GitHub web UI, e.g. CI badge links);
* ``file#anchor`` and ``#anchor`` targets must name a real heading in
  the target file, using GitHub's slugging rules (lowercase, strip
  punctuation, spaces → hyphens) or an explicit ``<a name="...">``;
* absolute URLs (http/https/mailto) are skipped — this is an
  *intra-repo* checker and CI must not flake on the network.

Exit code 1 lists every broken link as ``file:line: target (reason)``.

    python tools/check_links.py README.md DESIGN.md benchmarks/README.md
"""
from __future__ import annotations

import argparse
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
ANCHOR_RE = re.compile(r'<a\s+name="([^"]+)"')
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's heading → anchor slug (the subset we rely on)."""
    text = re.sub(r"<[^>]+>", "", heading)          # inline HTML tags
    text = re.sub(r"[*_`]|\[|\]|\([^)]*\)", "", text).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def collect_anchors(path: str) -> set:
    anchors = set()
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if m:
                anchors.add(github_slug(m.group(1)))
            for name in ANCHOR_RE.findall(line):
                anchors.add(name.lower())
    return anchors


def check_file(path: str, repo_root: str, anchor_cache: dict) -> list:
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                file_part, _, anchor = target.partition("#")
                if file_part:
                    resolved = os.path.normpath(
                        os.path.join(base, file_part))
                    if not resolved.startswith(
                            os.path.abspath(repo_root) + os.sep):
                        continue        # GitHub-web-relative (badges)
                    if not os.path.exists(resolved):
                        errors.append((path, lineno, target,
                                       "file not found"))
                        continue
                else:
                    resolved = os.path.abspath(path)
                if anchor:
                    if os.path.isdir(resolved) \
                            or not resolved.endswith((".md", ".markdown")):
                        errors.append((path, lineno, target,
                                       "anchor on non-markdown target"))
                        continue
                    if resolved not in anchor_cache:
                        anchor_cache[resolved] = collect_anchors(resolved)
                    if anchor.lower() not in anchor_cache[resolved]:
                        errors.append((path, lineno, target,
                                       "anchor not found"))
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="markdown files to check")
    ap.add_argument("--root", default=".",
                    help="repo root (targets escaping it are skipped)")
    args = ap.parse_args(argv)

    anchor_cache: dict = {}
    errors = []
    checked = 0
    for path in args.files:
        if not os.path.exists(path):
            errors.append((path, 0, path, "input file missing"))
            continue
        checked += 1
        errors.extend(check_file(path, args.root, anchor_cache))
    for path, lineno, target, reason in errors:
        print(f"{path}:{lineno}: {target} ({reason})")
    if errors:
        print(f"\n{len(errors)} broken link(s) in {checked} file(s)")
        return 1
    print(f"all intra-repo links OK in {checked} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
