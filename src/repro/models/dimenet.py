"""DimeNet (Klicpera et al., arXiv:2003.03123) — directional message
passing with radial (RBF) and spherical (SBF) bases over edge triplets.

Kernel regime (kernel_taxonomy §GNN): *triplet gather* — messages live on
edges; each interaction block aggregates over triplets (k→j, j→i) with an
angle-dependent bilinear transform, then scatters back to edges via
``jax.ops.segment_sum`` (JAX-native message passing — no sparse formats).

Graph inputs are precomputed index lists (the geometric frontend —
distances d_ji and angles α_kji — is computed by ``geometry_from_positions``
for molecular cells and *provided as inputs* for the non-geometric
benchmark graphs, where "distance" is a synthetic edge feature;
documented in DESIGN.md §4):

  z / node_feat  [N]         atomic numbers (or [N, d_feat] features)
  edge_src/dst   [E]         message direction j→i: src=j, dst=i
  dist           [E]         d_ji
  tri_kj/tri_ji  [T]         triplet edge indices into [E]
  angle          [T]         α(kj, ji)
  graph_id       [N]         molecule id for batched readout
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 95          # atomic-number embedding rows
    d_node_feat: int = 0         # >0: feature-input mode (non-geometric)
    n_targets: int = 1           # regression targets / classes
    dtype: Optional[object] = jnp.float32

    def n_params(self) -> int:
        d, b = self.d_hidden, self.n_bilinear
        nsb = self.n_spherical * self.n_radial
        emb = (self.n_species if not self.d_node_feat
               else self.d_node_feat) * d
        per_block = (d * d * 4            # msg MLPs
                     + self.n_radial * d  # rbf proj
                     + nsb * b            # sbf proj
                     + d * b + b * d      # bilinear down/up
                     + d * d * 2 + d * self.n_targets)  # output block
        return emb + self.n_radial * d + d * d \
            + self.n_blocks * per_block + d * self.n_targets


# -- bases -------------------------------------------------------------------

def rbf_basis(dist, n_radial, cutoff):
    """DimeNet radial Bessel basis: sin(n π d / c) / d, smoothed envelope."""
    d = jnp.maximum(dist, 1e-6)[..., None] / cutoff          # [E,1]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d) / d
    u = jnp.clip(d, 0, 1)
    env = 1 - 6 * u ** 5 + 15 * u ** 4 - 10 * u ** 3          # C2 envelope
    return basis * env


def sbf_basis(dist, angle, n_spherical, n_radial, cutoff):
    """Simplified spherical basis: cos(l·α) × radial Bessel products."""
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(angle[..., None] * (l + 1.0))               # [T,S]
    d = jnp.maximum(dist, 1e-6)[..., None] / cutoff
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    rad = jnp.sin(n * jnp.pi * d) / d                         # [T,R]
    return (ang[..., :, None] * rad[..., None, :]).reshape(
        angle.shape[0], n_spherical * n_radial)


def geometry_from_positions(pos, edge_src, edge_dst, tri_kj, tri_ji):
    """Molecular frontend: distances per edge + angles per triplet."""
    vec = pos[edge_dst] - pos[edge_src]                        # j→i vectors
    dist = jnp.linalg.norm(vec, axis=-1)
    v1 = -vec[tri_kj]                                          # j→k direction
    v2 = vec[tri_ji]
    cosang = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, -1) * jnp.linalg.norm(v2, -1), 1e-9)
    return dist, jnp.arccos(jnp.clip(cosang, -1 + 1e-7, 1 - 1e-7))


# -- params ------------------------------------------------------------------

def param_shapes(c: DimeNetConfig):
    d, b, nsb = c.d_hidden, c.n_bilinear, c.n_spherical * c.n_radial
    emb_rows = c.d_node_feat if c.d_node_feat else c.n_species
    blocks = {
        "w_msg1": (c.n_blocks, d, d), "w_msg2": (c.n_blocks, d, d),
        "w_rbf": (c.n_blocks, c.n_radial, d),
        "w_sbf": (c.n_blocks, nsb, b),
        "w_down": (c.n_blocks, d, b),
        "w_bilinear": (c.n_blocks, b, b, d),
        "w_out_edge": (c.n_blocks, d, d),
        "w_out_node": (c.n_blocks, d, d),
        "w_out_head": (c.n_blocks, d, c.n_targets),
    }
    return {
        "node_emb": (emb_rows, d),
        "rbf_emb": (c.n_radial, d),
        "w_edge_emb": (3 * d, d),
        "blocks": blocks,
        "head": (d, c.n_targets),
    }


def init_params(c: DimeNetConfig, key):
    shapes = param_shapes(c)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = [(jax.random.normal(k, s, jnp.float32)
               * np.sqrt(1.0 / max(s[-2] if len(s) > 1 else s[-1], 1))
               ).astype(c.dtype) for (p, s), k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(c: DimeNetConfig):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, c.dtype),
                        param_shapes(c), is_leaf=lambda x: isinstance(x, tuple))


def param_pspecs(c: DimeNetConfig, mesh, rules):
    """Params are tiny (~2M) — replicate everything; parallelism comes
    from sharding the edge/triplet axes of the *data* (activations)."""
    return jax.tree.map(lambda s: P(*([None] * len(s))), param_shapes(c),
                        is_leaf=lambda x: isinstance(x, tuple))


# -- model -------------------------------------------------------------------

def forward(params, batch, c: DimeNetConfig, axis_names=None):
    """Returns per-graph predictions [n_graphs, n_targets] (geometric
    mode) or per-node predictions (feature mode).

    ``axis_names``: when run inside shard_map with edge/triplet arrays
    partitioned (partition-local triplets — DESIGN.md §5), node
    aggregations are psum'd over these axes."""
    src, dst = batch["edge_src"], batch["edge_dst"]
    dist, angle = batch["dist"], batch["angle"]
    tri_kj, tri_ji = batch["tri_kj"], batch["tri_ji"]
    n_nodes = (batch["z"] if "z" in batch else batch["node_feat"]).shape[0]
    n_edges = src.shape[0]

    if c.d_node_feat:
        h = batch["node_feat"].astype(c.dtype) @ params["node_emb"]
    else:
        h = params["node_emb"][batch["z"]].astype(c.dtype)

    rbf = rbf_basis(dist, c.n_radial, c.cutoff).astype(c.dtype)    # [E,R]
    sbf = sbf_basis(dist[tri_ji], angle, c.n_spherical, c.n_radial,
                    c.cutoff).astype(c.dtype)                      # [T,SR]

    # embedding block: m_ji = W [h_j ; h_i ; rbf_emb]
    m = jnp.concatenate([h[src], h[dst], rbf @ params["rbf_emb"]],
                        axis=-1) @ params["w_edge_emb"]            # [E,D]
    m = jax.nn.silu(m)

    out_acc = jnp.zeros((n_nodes, c.n_targets), jnp.float32)

    def block(m, blk):
        # directional message: triplets k→j feeding edge j→i
        m2 = jax.nn.silu(m @ blk["w_msg1"])
        x_kj = m2[tri_kj]                                          # [T,D]
        x_kj = x_kj * (rbf[tri_kj] @ blk["w_rbf"])                 # radial gate
        t_down = x_kj @ blk["w_down"]                              # [T,b]
        s_proj = sbf @ blk["w_sbf"]                                # [T,b]
        tri_msg = jnp.einsum("tb,tf,bfd->td", t_down, s_proj,
                             blk["w_bilinear"])                    # bilinear
        agg = jax.ops.segment_sum(tri_msg, tri_ji, num_segments=n_edges)
        m_new = jax.nn.silu((m2 + agg) @ blk["w_msg2"]) + m        # residual
        # output block: edges → nodes (cross-partition: psum partials)
        e_out = jax.nn.silu(m_new @ blk["w_out_edge"])
        node = jax.ops.segment_sum(e_out, dst, num_segments=n_nodes)
        if axis_names:
            node = jax.lax.psum(node, axis_names)
        node = jax.nn.silu(node @ blk["w_out_node"])
        return m_new, (node @ blk["w_out_head"]).astype(jnp.float32)

    # remat: the [N, d_hidden] per-block node aggregates (2.4M × 128 × 6
    # blocks on ogb_products) are recomputed in backward, not saved
    m, outs = jax.lax.scan(jax.checkpoint(block), m, params["blocks"])
    out_acc = out_acc + jnp.sum(outs, axis=0)

    if c.d_node_feat:
        return out_acc                                   # per-node logits
    # molecular readout: sum per graph (n_graphs = labels length)
    return jax.ops.segment_sum(out_acc, batch["graph_id"],
                               num_segments=batch["labels"].shape[0])


def forward_sharded(params, batch, c: DimeNetConfig, mesh, rules):
    """Distributed forward: edge/triplet arrays sharded over the "graph"
    axes (data×model jointly); nodes replicated; triplet indices are
    LOCAL to their edge partition (partition-aware sampling — the data
    pipeline guarantees this; see data.graph_sampler)."""
    from jax.sharding import PartitionSpec as P
    graph_axes = tuple(a for a in ("data", "model")
                       if a in mesh.axis_names)
    e_spec, n_spec = P(graph_axes), P(None)
    specs = {
        "edge_src": e_spec, "edge_dst": e_spec, "dist": e_spec,
        "angle": e_spec, "tri_kj": e_spec, "tri_ji": e_spec,
    }
    in_specs = {k: specs.get(k, n_spec) for k in batch}

    def body(params, dyn):
        # inside the body, edge/triplet arrays are the LOCAL partition
        return forward(params, dyn, c, axis_names=graph_axes)

    dyn = dict(batch)
    pspecs = jax.tree.map(lambda s: P(*([None] * len(s.shape))),
                          params, is_leaf=lambda x: hasattr(x, "shape"))
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, in_specs), out_specs=n_spec,
        check_vma=False)(params, dyn)


def loss_fn(params, batch, c: DimeNetConfig, mesh=None, rules=None):
    if mesh is not None:
        pred = forward_sharded(params, batch, c, mesh, rules)
    else:
        pred = forward(params, batch, c)
    if c.n_targets == 1:
        return jnp.mean(jnp.square(pred[..., 0] - batch["labels"]))
    # node classification (full-graph cells)
    logits = pred
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None],
                               axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_train_step(c: DimeNetConfig, optimizer, mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, c, mesh, rules))(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}
    return train_step


def serve_step(params, batch, c: DimeNetConfig, mesh=None, rules=None):
    if mesh is not None:
        return forward_sharded(params, batch, c, mesh, rules)
    return forward(params, batch, c)
