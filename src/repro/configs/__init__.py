"""Architecture registry: ``--arch <id>`` resolution."""
from repro.configs import (bert4rec_cfg, command_r_plus_104b, deepfm_cfg,
                           deepseek_v3_671b, dimenet_cfg, dlrm_mlperf,
                           gemma3_27b, granite_3_2b, qwen2_moe_a2_7b,
                           tifu_knn, two_tower_retrieval)
from repro.configs.base import ArchDef, CellProgram

REGISTRY = {a.ARCH.name: a.ARCH for a in (
    qwen2_moe_a2_7b, deepseek_v3_671b, command_r_plus_104b, gemma3_27b,
    granite_3_2b, dimenet_cfg, dlrm_mlperf, deepfm_cfg, bert4rec_cfg,
    two_tower_retrieval, tifu_knn)}

ASSIGNED = [n for n in REGISTRY if n != "tifu-knn"]   # the 10 assigned archs


def get_arch(name: str) -> ArchDef:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_cells():
    """Every (arch, shape) pair — 40 assigned cells + 2 tifu-knn cells."""
    for name, arch in REGISTRY.items():
        for shape in arch.cells:
            yield name, shape
