"""Sharded state store for per-user TIFU-kNN state (paper §5, Fig. 1).

The Spark implementation keeps user vectors in a keyed state store; here
the store is a ``StreamState`` pytree whose user axis is sharded over the
``("pod", "data")`` mesh axes (user-level parallelism — paper: "each user
vector is calculated independently").  The item axis of ``user_vecs`` can
additionally be sharded over ``"model"`` for the kNN stage.

The store also owns the **serving corpus cache** (DESIGN.md §3.6): the
materialized ``[n_users, n_items]`` true-value corpus that kNN queries
read.  A micro-batch touches a handful of users; the engine marks those
rows dirty (``invalidate_users``) and ``corpus()`` refreshes only them —
high-QPS serving no longer pays a full scale×raw recompute per query.

Checkpointing + the idempotent update log give exactly-once semantics
across preemptions (DESIGN.md §5).  Every commit is checksummed (CRC32
of the state npz recorded in ``LATEST``, plus a self-CRC of the
metadata itself), the previous commit survives as ``LATEST.prev``, and
restore falls back to the last commit that verifies — so torn or
bit-flipped checkpoint files are *detected*, never silently installed
(DESIGN.md §9).  Store I/O retries transient failures with exponential
backoff under a bounded budget; the fault sites exercised by
``streaming.faults`` sit exactly on the commit/read path.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
import zipfile
import zlib
from typing import (TYPE_CHECKING, Any, Callable, Dict, Optional, Sequence,
                    Set, Tuple)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.types import StreamState, _pow2_pad
from repro.optim.compression import quantize_int8_rows
from repro.streaming import faults

if TYPE_CHECKING:  # type-only: the writer runs opaque commit closures
    from repro.streaming.async_checkpoint import AsyncCheckpointer


class CorruptCheckpointError(RuntimeError):
    """A checkpoint file failed its integrity check (torn or bit-flipped).

    Raised only when NO commit in the directory verifies — a corrupt
    newest commit with an intact ``LATEST.prev`` falls back silently
    (counted in :attr:`StateStore.restore_fallbacks`).
    """


@dataclasses.dataclass
class StoreConfig:
    """Shapes, placement and cache policy of one state store.

    In a sharded deployment its user rows are ONE shard's slice
    (DESIGN.md §7).
    """

    n_users: int
    n_items: int
    max_baskets: int
    max_basket_size: int
    max_groups: Optional[int] = None
    dtype: str = "float32"
    # mesh axis names: user axis and item axis sharding
    user_axes: tuple = ("data",)
    item_axes: tuple = ("model",)
    # corpus cache: once more than this fraction of user rows is dirty,
    # one full materialize beats a huge scattered row refresh (ROADMAP:
    # very high delete rates)
    corpus_rebuild_frac: float = 0.25
    # bounded I/O retry budget for checkpoint/restore file operations:
    # transient errors back off base·2^i and then surface (DESIGN.md §9)
    io_retries: int = 4
    io_retry_base_s: float = 0.005


def _fsync_dir(path: str) -> None:
    """Make a rename in ``path`` durable.

    The file fsync orders the DATA, the directory fsync orders the
    ENTRY — both are needed for the crash-anywhere guarantee.
    """
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def with_io_retries(fn: Callable, what: str, retries: int = 4,
                    base_delay_s: float = 0.005,
                    on_retry: Optional[Callable] = None) -> Any:
    """Run ``fn`` retrying transient OSErrors with exponential backoff.

    Bounded budget: ``retries`` re-attempts (delays ``base_delay_s · 2^i``)
    and then the last error propagates — a dead disk must surface, not
    spin.  ``FileNotFoundError`` is never retried (it is a *state*, not a
    transient), and injected crashes (``faults.InjectedCrash`` is a
    BaseException) pass straight through, exactly like a real SIGKILL.
    ``on_retry`` is called once per re-attempt (metrics hook).
    """
    for attempt in range(retries + 1):
        try:
            return fn()
        except FileNotFoundError:
            raise
        except OSError as e:
            if attempt == retries:
                raise OSError(
                    f"{what}: I/O retry budget exhausted "
                    f"({retries} retries): {e}") from e
            if on_retry is not None:
                on_retry()
            time.sleep(base_delay_s * (2 ** attempt))


def _meta_crc(payload: dict) -> int:
    """Self-CRC of a metadata payload (over canonical json, crc excluded)."""
    probe = {k: v for k, v in payload.items() if k != "meta_crc32"}
    return zlib.crc32(json.dumps(probe, sort_keys=True).encode())


def _file_crc(path: str) -> Tuple[int, int]:
    """``(crc32, n_bytes)`` of a file, read in chunks."""
    crc, n = 0, 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc, n
            crc = zlib.crc32(chunk, crc)
            n += len(chunk)


def atomic_write_json(path: str, payload: dict, retries: int = 4,
                      base_delay_s: float = 0.005,
                      on_retry: Optional[Callable] = None) -> None:
    """Write json atomically and durably (the commit-point primitive).

    Tmp-file + fsync + ``os.replace`` + directory fsync, so a crash —
    process OR system — leaves either the previous intact file or
    nothing, never a truncated one (the same contract as the state npz
    writes).  A self-CRC (``meta_crc32``) is stamped into the payload so
    *silent* corruption of the committed file (bit rot — a fault the
    rename protocol cannot prevent) is detected on read
    (:func:`load_json_checked`).  Transient I/O errors are retried under
    a bounded budget.
    """
    payload = dict(payload)
    payload["meta_crc32"] = _meta_crc(payload)
    base = os.path.basename(path)

    def write() -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        faults.trip(f"{base}.pre_replace")
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path) or ".")
        faults.trip(f"{base}.post_replace")

    with_io_retries(write, f"write {path}", retries, base_delay_s,
                    on_retry)


def load_json_checked(path: str, retries: int = 4,
                      base_delay_s: float = 0.005,
                      on_retry: Optional[Callable] = None) -> dict:
    """Read a json commit file, verifying its self-CRC when present.

    Raises :class:`CorruptCheckpointError` on undecodable json or a
    CRC mismatch (torn pre-atomic writers, bit flips); propagates
    ``FileNotFoundError`` untouched (absence is layout information, not
    corruption — the restore paths branch on it).  Legacy files without
    ``meta_crc32`` are accepted unverified.
    """
    base = os.path.basename(path)

    def read() -> bytes:
        faults.trip(f"{base}.read")
        # bytes, decoded below: a bit flip can produce invalid UTF-8,
        # which is corruption, not an I/O error to retry
        with open(path, "rb") as f:
            return f.read()

    raw = with_io_retries(read, f"read {path}", retries, base_delay_s,
                          on_retry)
    try:
        meta = json.loads(raw.decode("utf-8"))
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptCheckpointError(
            f"{path} is not valid json (torn write or bit flip?): "
            f"{e}") from e
    if not isinstance(meta, dict):
        raise CorruptCheckpointError(f"{path}: expected a json object")
    want = meta.get("meta_crc32")
    if want is not None and _meta_crc(meta) != want:
        raise CorruptCheckpointError(
            f"{path} failed its integrity check "
            f"(meta_crc32={want}, computed={_meta_crc(meta)}): "
            "bit-flipped or hand-edited")
    return meta


def _load_commit(directory: str, meta: dict) -> Dict[str, np.ndarray]:
    """Load + verify the state npz a commit's metadata names.

    Raises :class:`CorruptCheckpointError` when the npz misses the CRC
    recorded at commit time or cannot be parsed; legacy commits without
    ``npz_crc32`` skip the CRC check (their zip structure still has to
    parse).
    """
    step = meta["step"]
    path = os.path.join(directory, f"state_{step:010d}.npz")
    want = meta.get("npz_crc32")
    if want is not None:
        crc, n = with_io_retries(lambda: _file_crc(path), f"crc {path}")
        if crc != want:
            raise CorruptCheckpointError(
                f"{path} failed its CRC check (recorded {want}, computed "
                f"{crc} over {n} bytes): torn or bit-flipped")

    def read() -> Dict[str, np.ndarray]:
        faults.trip("npz.read")
        with np.load(path) as data:
            return {k: np.asarray(data[k]) for k in data.files}

    try:
        leaves = with_io_retries(read, f"read {path}")
    except FileNotFoundError:
        raise
    except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
        raise CorruptCheckpointError(f"{path} unreadable: {e}") from e
    for scale in ("uv_scale", "lgv_scale"):
        if scale not in leaves:
            leaves[scale] = np.ones(leaves["err_mult"].shape,
                                    leaves["err_mult"].dtype)
    return leaves


def load_checkpoint_arrays(
        directory: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Read the newest VERIFIED commit as host arrays: ``(meta, leaves)``.

    Reads the ``LATEST`` metadata (the atomic commit point), verifies
    its self-CRC and the recorded CRC of the state npz it names, and
    falls back to the previous commit (``LATEST.prev``, kept by
    :meth:`StateStore.checkpoint`) when the newest one is corrupt — the
    state and its exactly-once log always fall back *together*, so a
    replay re-applies exactly what the surviving commit has not seen
    (never a double-apply).  Pre-scaled-representation checkpoints (no
    ``uv_scale``/``lgv_scale`` leaves) migrate to scales of 1.  Shared
    by :meth:`StateStore.restore` and the resharding restore path
    (``streaming.engine.ShardedStreamingEngine.restore``, DESIGN.md §7).
    The chosen commit and any corruption skipped on the way are recorded
    under ``meta["_recovery"]``.  Cost: one O(state) read, no device
    work.
    """
    errors = []
    tried = False
    for name in ("LATEST", "LATEST.prev"):
        path = os.path.join(directory, name)
        if not os.path.exists(path):
            continue
        tried = True
        try:
            meta = load_json_checked(path)
            leaves = _load_commit(directory, meta)
        except (CorruptCheckpointError, OSError) as e:
            errors.append(f"{name}: {e}")
            continue
        meta["_recovery"] = {"source": name, "skipped": list(errors)}
        return meta, leaves
    if not tried:
        raise FileNotFoundError(
            f"no LATEST (or LATEST.prev) commit in {directory}")
    raise CorruptCheckpointError(
        f"no commit in {directory} passes its integrity checks: "
        + "; ".join(errors))


def state_shardings(cfg: StoreConfig, mesh: Any) -> StreamState:
    """PartitionSpecs for every leaf of the state pytree."""
    u = P(cfg.user_axes)
    ui = P(cfg.user_axes, cfg.item_axes)
    return StreamState(
        user_vecs=NamedSharding(mesh, ui),
        last_group_vecs=NamedSharding(mesh, ui),
        history=NamedSharding(mesh, P(cfg.user_axes, None, None)),
        group_sizes=NamedSharding(mesh, P(cfg.user_axes, None)),
        n_baskets=NamedSharding(mesh, u),
        n_groups=NamedSharding(mesh, u),
        err_mult=NamedSharding(mesh, u),
        uv_scale=NamedSharding(mesh, u),
        lgv_scale=NamedSharding(mesh, u),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _refresh_corpus_rows(corpus: jax.Array, user_vecs: jax.Array,
                         uv_scale: jax.Array,
                         rows: jax.Array) -> jax.Array:
    """Refresh ``corpus[rows] = uv_scale[rows] * user_vecs[rows]`` in place.

    ``rows`` may contain duplicates (pow2 padding repeats the first dirty
    row); duplicate writes carry identical values.
    """
    return corpus.at[rows].set(user_vecs[rows] * uv_scale[rows, None])


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _requantize_rows(corpus_q: jax.Array, scales: jax.Array,
                     corpus: jax.Array,
                     rows: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Re-quantize exactly the touched rows of the int8 serving corpus.

    ``corpus_q`` int8[M, I] / ``scales`` f32[M] are updated in place
    (donation — the refresh is O(dirty·I), not O(M·I)); per-row scaling
    means a row's quantization depends only on its own values, so
    touched rows re-quantize independently of the rest of the corpus.
    ``rows`` may contain pow2-padding duplicates (identical writes).
    """
    sub_q, sub_s = quantize_int8_rows(corpus[rows])
    return corpus_q.at[rows].set(sub_q), scales.at[rows].set(sub_s)


class StateStore:
    """Owns the StreamState, the serving corpus cache and persistence.

    On a real cluster the store's arrays are device-sharded via the
    shardings above; on the CPU test runner they are single-device.
    """

    def __init__(self, cfg: StoreConfig, mesh: Any = None) -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.state = StreamState.zeros(
            cfg.n_users, cfg.n_items, cfg.max_baskets, cfg.max_basket_size,
            cfg.max_groups)
        if mesh is not None:
            sh = state_shardings(cfg, mesh)
            self.state = jax.tree.map(jax.device_put, self.state,
                                      sh, is_leaf=lambda x: x is None)
        self._corpus: Optional[jax.Array] = None
        self._dirty: Set[int] = set()
        # int8 serving corpus cache (DESIGN.md §8.4): derived from the
        # fp32 cache, with its OWN dirty set — the two caches refresh on
        # independent schedules (a deployment may serve only one)
        self._corpus_q: Optional[jax.Array] = None
        self._corpus_qscale: Optional[jax.Array] = None
        self._q_dirty: Set[int] = set()
        # degraded-serving freeze (DESIGN.md §9): while frozen, corpus()
        # keeps answering from this snapshot and performs no refreshes
        self._frozen_corpus: Optional[jax.Array] = None
        self._frozen_quant: Optional[tuple] = None
        self.corpus_full_builds = 0
        self.corpus_rows_refreshed = 0
        self.corpus_threshold_rebuilds = 0
        self.quant_full_builds = 0
        self.quant_rows_refreshed = 0
        self.quant_threshold_rebuilds = 0
        # robustness counters (observability only)
        self.io_retries = 0
        self.restore_fallbacks = 0
        self.corruption_detected = 0
        self.last_restored_meta: dict = {}

    def _on_io_retry(self) -> None:
        self.io_retries += 1

    # -- serving corpus cache (DESIGN.md §3.6) --------------------------------

    def invalidate_users(self, users: Any) -> None:
        """Mark user rows of the serving corpus stale.

        The engine calls this after every micro-batch / stability
        refresh with the touched users; O(|users|) set inserts.
        """
        if self._corpus is None and self._corpus_q is None:
            return            # no cache yet: the first corpus() builds it
        rows = [int(x) for x in np.asarray(users).ravel()]
        if self._corpus is not None:
            self._dirty.update(rows)
        if self._corpus_q is not None:
            self._q_dirty.update(rows)

    def invalidate_all(self) -> None:
        """Drop the caches entirely (restore, out-of-band state edits)."""
        self._corpus = None
        self._dirty.clear()
        self._corpus_q = None
        self._corpus_qscale = None
        self._q_dirty.clear()

    def freeze_serving(self) -> None:
        """Enter degraded serving: pin the current corpus snapshot.

        While frozen, :meth:`corpus` answers from the pinned snapshot
        and performs NO refreshes or rebuilds — so ``recommend`` keeps
        working (on admittedly stale values) while this store's state is
        being recovered underneath it (restore, resharding).  If no
        corpus is cached yet, one is materialized first.  Idempotent.
        """
        if self._frozen_corpus is None:
            self._frozen_corpus = self.corpus()

    def thaw_serving(self) -> None:
        """Leave degraded serving: un-pin the snapshots.

        The next :meth:`corpus` / :meth:`quantized_corpus` call serves
        the live state again (restore paths invalidate the caches, so
        they rebuild fresh).
        """
        self._frozen_corpus = None
        self._frozen_quant = None

    @property
    def serving_degraded(self) -> bool:
        """True while :meth:`freeze_serving` is in effect."""
        return self._frozen_corpus is not None

    def corpus(self) -> jax.Array:
        """The materialized true-value corpus f32[n_users, n_items].

        First call (or after ``invalidate_all``) densifies everything;
        subsequent calls refresh only rows dirtied since the last call.
        The row list is padded to a pow2 bucket (duplicating one dirty
        row) so the refresh program compiles O(log n_users) times.

        LIFETIME: the refresh updates the cached buffer IN PLACE
        (donation keeps it O(dirty·I)), so the returned array is valid
        only until the next ``corpus()`` call that follows an
        invalidation.  Finish (or copy) a request batch before applying
        the next micro-batch's refresh — the serving loop here is
        synchronous, matching launch/serve.py.

        DEGRADED MODE: while :meth:`freeze_serving` is in effect the
        pinned snapshot is returned as-is (no refresh, no rebuild) —
        dirty rows keep accumulating and are reconciled at thaw.
        """
        if self._frozen_corpus is not None:
            return self._frozen_corpus
        if self._corpus is None:
            self._corpus = self.state.materialized_user_vecs()
            self._dirty.clear()
            self.corpus_full_builds += 1
        elif len(self._dirty) > self.cfg.corpus_rebuild_frac \
                * self.cfg.n_users:
            # past the crossover one full rebuild is cheaper than a
            # scattered refresh of most rows (and compiles exactly once)
            self._corpus = self.state.materialized_user_vecs()
            self._dirty.clear()
            self.corpus_full_builds += 1
            self.corpus_threshold_rebuilds += 1
        elif self._dirty:
            rows = np.fromiter(self._dirty, np.int32, len(self._dirty))
            self.corpus_rows_refreshed += rows.size
            pad = _pow2_pad(rows.size, self.cfg.n_users) - rows.size
            if pad:
                rows = np.concatenate([rows, np.full(pad, rows[0],
                                                     np.int32)])
            self._corpus = _refresh_corpus_rows(
                self._corpus, self.state.user_vecs, self.state.uv_scale,
                jnp.asarray(rows))
            self._dirty.clear()
        return self._corpus

    def quantized_corpus(self) -> tuple:
        """The int8 serving corpus: ``(q int8[M, I], scale f32[M])``.

        The cache entry behind `core.knn.recommend_for_users_quant`
        (DESIGN.md §8.4): per-row power-of-two-scale quantization
        (`optim.compression.quantize_int8_rows`) of the fp32 serving
        corpus.  Derived from :meth:`corpus` — the call refreshes the
        fp32 cache first, then re-quantizes ONLY the rows dirtied since
        the last ``quantized_corpus()`` call (its own dirty set: the
        two caches refresh on independent schedules).  Row-wise scaling
        is what makes this O(dirty·I): a touched row re-quantizes
        without looking at any other row.  Past
        ``corpus_rebuild_frac·n_users`` dirty rows one full re-quantize
        is cheaper (and compiles once), mirroring the fp32 policy.

        Same LIFETIME contract as :meth:`corpus` (in-place donated
        refresh), and the same DEGRADED MODE: while frozen, a pinned
        snapshot is served (quantized from the pinned fp32 snapshot on
        first use).
        """
        if self._frozen_corpus is not None:
            if self._frozen_quant is None:
                self._frozen_quant = quantize_int8_rows(self._frozen_corpus)
            return self._frozen_quant
        corpus = self.corpus()
        if self._corpus_q is None:
            self._corpus_q, self._corpus_qscale = quantize_int8_rows(corpus)
            self._q_dirty.clear()
            self.quant_full_builds += 1
        elif len(self._q_dirty) > self.cfg.corpus_rebuild_frac \
                * self.cfg.n_users:
            self._corpus_q, self._corpus_qscale = quantize_int8_rows(corpus)
            self._q_dirty.clear()
            self.quant_full_builds += 1
            self.quant_threshold_rebuilds += 1
        elif self._q_dirty:
            rows = np.fromiter(self._q_dirty, np.int32, len(self._q_dirty))
            self.quant_rows_refreshed += rows.size
            pad = _pow2_pad(rows.size, self.cfg.n_users) - rows.size
            if pad:
                rows = np.concatenate([rows, np.full(pad, rows[0],
                                                     np.int32)])
            self._corpus_q, self._corpus_qscale = _requantize_rows(
                self._corpus_q, self._corpus_qscale, corpus,
                jnp.asarray(rows))
            self._q_dirty.clear()
        return self._corpus_q, self._corpus_qscale

    # -- unlearning surface (DESIGN.md §11) -----------------------------------

    def scrub_rows(self, users: Sequence[int]) -> None:
        """Force the serving caches to drop residue for ``users`` now.

        The GDPR unlearning path: after the engine zeroes a forgotten
        user's state rows, the fp32/int8 cache rows still hold the
        pre-deletion values until the next natural refresh.  This marks
        the rows dirty and refreshes whichever caches exist, so the
        forgotten values are gone from every live serving buffer when
        the call returns.  Frozen degraded-serving snapshots are NOT
        touched — a forget while frozen shows up as residue in
        :meth:`row_residue` until ``thaw_serving`` (the honest answer:
        the pinned snapshot still serves the old values).  Cost: one
        O(|users| · n_items) row refresh per existing cache.
        """
        rows = np.asarray(list(users), np.int64)
        if rows.size == 0:
            return
        self.invalidate_users(rows)
        if self._frozen_corpus is not None:
            return
        if self._corpus_q is not None:
            self.quantized_corpus()   # refreshes the fp32 cache first
        elif self._corpus is not None:
            self.corpus()

    def row_residue(self, users: Sequence[int]) -> Dict[str, float]:
        """Residue of ``users`` rows in every live artifact, by name.

        Returns max-abs (or count) values over the given rows for the
        state leaves, the fp32/int8 serving caches, and any frozen
        degraded-serving snapshot — cache/snapshot keys appear only when
        that artifact exists.  A fully forgotten user reports 0.0
        everywhere: this is the machine-checkable no-trace predicate
        behind ``compliance.certify`` and ``forget_user`` receipts.
        Cost: O(|users| · n_items) host reads; no cache refresh.
        """
        rows = np.asarray(list(users), np.int64)
        st = self.state
        out: Dict[str, float] = {
            "user_vec_absmax": float(
                np.abs(np.asarray(st.user_vecs)[rows]).max(initial=0.0)),
            "last_group_absmax": float(
                np.abs(np.asarray(st.last_group_vecs)[rows])
                .max(initial=0.0)),
            "history_ids": float(
                (np.asarray(st.history)[rows] >= 0).sum()),
            "n_baskets": float(np.asarray(st.n_baskets)[rows]
                               .sum(initial=0)),
            "n_groups": float(np.asarray(st.n_groups)[rows]
                              .sum(initial=0)),
        }
        if self._corpus is not None:
            out["corpus_absmax"] = float(
                np.abs(np.asarray(self._corpus)[rows]).max(initial=0.0))
        if self._corpus_q is not None:
            out["quant_nonzero"] = float(
                (np.asarray(self._corpus_q)[rows] != 0).sum())
        if self._frozen_corpus is not None:
            out["frozen_absmax"] = float(
                np.abs(np.asarray(self._frozen_corpus)[rows])
                .max(initial=0.0))
        return out

    # -- persistence (exactly-once recovery substrate) -----------------------

    def _snapshot_leaves(self) -> Dict[str, np.ndarray]:
        """Copy every state leaf to host memory, owned by the caller.

        ``np.array(..., copy=True)`` rather than ``np.asarray`` on
        purpose: on the CPU backend a jax→numpy conversion can be a
        zero-copy *view* of the device buffer, and the engine's donated
        appliers invalidate that buffer on the very next micro-batch —
        a background writer holding a view would serialize garbage
        (read-after-free).  The deep copy is the "snapshot" half of
        snapshot-then-write (DESIGN.md §12) and the only O(state) cost
        that stays on the caller's hot path.
        """
        st = self.state
        return {
            "user_vecs": np.array(st.user_vecs, copy=True),
            "last_group_vecs": np.array(st.last_group_vecs, copy=True),
            "history": np.array(st.history, copy=True),
            "group_sizes": np.array(st.group_sizes, copy=True),
            "n_baskets": np.array(st.n_baskets, copy=True),
            "n_groups": np.array(st.n_groups, copy=True),
            "err_mult": np.array(st.err_mult, copy=True),
            "uv_scale": np.array(st.uv_scale, copy=True),
            "lgv_scale": np.array(st.lgv_scale, copy=True),
        }

    def checkpoint(self, directory: str, step: int,
                   extra_meta: Optional[dict] = None) -> str:
        """Write one atomic checkpoint commit; returns the npz path.

        Synchronous snapshot-then-write: :meth:`_snapshot_leaves` now,
        :meth:`_write_commit` inline.  The state npz is made durable
        FIRST; the ``LATEST`` metadata write (which carries
        ``extra_meta``, e.g. the engine's exactly-once log, plus the
        npz's CRC32) is the single atomic commit point.  The previous
        ``LATEST`` survives as ``LATEST.prev`` (byte-for-byte, its
        self-CRC stays valid), giving restore a verified fallback commit
        when the newest one is later found corrupted (DESIGN.md §9).
        Transient I/O errors retry under the config's bounded budget.
        Cost: one O(state) device fetch + compressed write.
        """
        return self._write_commit(directory, step, self._snapshot_leaves(),
                                  extra_meta)

    def checkpoint_async(self, checkpointer: "AsyncCheckpointer",
                         directory: str, step: int,
                         extra_meta: Optional[dict] = None) -> str:
        """Snapshot now, commit on the background writer; returns npz path.

        The caller-thread cost is one :meth:`_snapshot_leaves` copy; the
        serialize/fsync/atomic-replace sequence (identical bytes and
        identical fault sites to :meth:`checkpoint`) runs as a FIFO job
        on ``checkpointer``'s worker thread.  Exactly-once is preserved
        because the job *ends in* the atomic ``LATEST`` replace: until
        that replace lands, restore sees the previous commit, never a
        torn one.  A writer-thread failure (including an injected
        crash) surfaces at the checkpointer's next ``submit``/``flush``
        — callers must flush before trusting the returned path exists.
        """
        leaves = self._snapshot_leaves()
        path = os.path.join(directory, f"state_{step:010d}.npz")
        checkpointer.submit(
            lambda: self._write_commit(directory, step, leaves, extra_meta),
            label=f"{directory}@{step}")
        return path

    def _write_commit(self, directory: str, step: int,
                      leaves: Dict[str, np.ndarray],
                      extra_meta: Optional[dict] = None) -> str:
        """Serialize ``leaves`` and land the atomic ``LATEST`` commit.

        The write half of snapshot-then-write: runs inline for
        :meth:`checkpoint`, or as the background writer's job for
        :meth:`checkpoint_async`.  ``leaves`` must be host-owned copies
        (see :meth:`_snapshot_leaves`) — this function never touches
        ``self.state``, so the engine may keep donating buffers while
        it writes.
        """
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, f"state_{step:010d}.npz")
        tmp = path + ".tmp"

        def write_npz() -> Tuple[int, int]:
            faults.trip("npz.pre_write")
            with open(tmp, "wb") as f:
                np.savez_compressed(f, **leaves)
                f.flush()
                os.fsync(f.fileno())
            # CRC over the durable tmp bytes: recorded in LATEST, checked
            # on every restore — a tear or bit flip between now and then
            # cannot be installed silently
            crc, n = _file_crc(tmp)
            faults.trip("npz.pre_replace")
            os.replace(tmp, path)
            _fsync_dir(directory)
            faults.trip("npz.post_replace")
            return crc, n

        crc, n_bytes = with_io_retries(
            write_npz, f"write {path}", self.cfg.io_retries,
            self.cfg.io_retry_base_s, self._on_io_retry)
        self._retain_previous_commit(directory)
        meta = dict(step=step, **dataclasses.asdict(self.cfg))
        meta["user_axes"] = list(meta["user_axes"])
        meta["item_axes"] = list(meta["item_axes"])
        meta["npz_crc32"] = crc
        meta["npz_bytes"] = n_bytes
        if extra_meta:
            meta.update(extra_meta)
        # LATEST is the single commit point: the npz above is durable
        # before this replace lands, and any co-checkpointed metadata
        # (the engine's exactly-once log) rides in the SAME atomic write
        # — a crash anywhere leaves the previous checkpoint fully
        # consistent, never a new state with an old log.
        atomic_write_json(os.path.join(directory, "LATEST"), meta,
                          self.cfg.io_retries, self.cfg.io_retry_base_s,
                          self._on_io_retry)
        return path

    def _retain_previous_commit(self, directory: str) -> None:
        """Copy the current ``LATEST`` to ``LATEST.prev`` (atomically).

        Byte-for-byte, so the copied file's self-CRC stays valid; a
        crash between the copy and the new ``LATEST`` replace leaves
        ``LATEST == LATEST.prev`` — consistent.  The fallback depth is
        deliberately one: state and exactly-once log always travel
        together, and a two-commits-old state converges by replay.
        """
        cur = os.path.join(directory, "LATEST")
        if not os.path.exists(cur):
            return

        def copy() -> None:
            with open(cur, "rb") as f:
                raw = f.read()
            tmp = cur + ".prev.tmp"
            with open(tmp, "wb") as f:
                f.write(raw)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, cur + ".prev")
            _fsync_dir(directory)

        with_io_retries(copy, f"retain {cur}.prev", self.cfg.io_retries,
                        self.cfg.io_retry_base_s, self._on_io_retry)

    def _validate_meta(self, meta: dict) -> None:
        """Reject checkpoints written under different shape dimensions.

        Silently installing wrong-shaped state either fails later (shape
        error far from the cause) or — worse — runs with aliased
        user/item indices.
        """
        mismatches = []
        for field in ("n_users", "n_items", "max_baskets",
                      "max_basket_size"):
            want = getattr(self.cfg, field)
            got = meta.get(field)
            if got is not None and got != want:
                mismatches.append(f"{field}: checkpoint={got} store={want}")
        k_ckpt = meta.get("max_groups") or meta.get("max_baskets")
        k_cfg = self.cfg.max_groups or self.cfg.max_baskets
        if meta.get("max_baskets") is not None and k_ckpt != k_cfg:
            mismatches.append(
                f"max_groups (effective): checkpoint={k_ckpt} store={k_cfg}")
        if mismatches:
            raise ValueError(
                "checkpoint/store shape mismatch — refusing to restore: "
                + "; ".join(mismatches))

    def install_state(self, state: StreamState) -> None:
        """Replace the owned state out-of-band (resharding restore).

        Applies the store's device/mesh placement and drops the serving
        corpus cache — every row may have changed.  Callers are
        responsible for shape-validating ``state`` against the config
        (the resharding path does, via the checkpoint metadata).
        """
        if self.mesh is not None:
            sh = state_shardings(self.cfg, self.mesh)
            state = jax.tree.map(jax.device_put, state, sh)
        self.state = state
        self.invalidate_all()

    def restore(self, directory: str) -> int:
        """Install the checkpoint in ``directory``; returns its step.

        Reads the atomic ``LATEST`` commit, validates its shape metadata
        against this store's config (refusing mismatches loudly), keeps
        the parsed metadata in :attr:`last_restored_meta` for
        co-checkpointed payloads (the engine's exactly-once log rides in
        ``meta["engine"]`` — one reader, one parse), and drops the
        serving-corpus cache.  Cost: one O(state) read + device upload.
        """
        meta, leaves = load_checkpoint_arrays(directory)
        self._validate_meta(meta)
        rec = meta.get("_recovery", {})
        if rec.get("source") not in (None, "LATEST"):
            self.restore_fallbacks += 1
        self.corruption_detected += len(rec.get("skipped", ()))
        self.last_restored_meta = meta
        step = meta["step"]
        self.install_state(StreamState(
            **{k: jax.numpy.asarray(v) for k, v in leaves.items()}))
        return step
