"""Rule family (a): kernel contracts vs pallas_call sites (KC01–KC08).

Each check is a pure function over a parsed kernel module plus the
contracts registered for it, so the seeded-violation corpus
(tests/analysis_corpus/) can drive single files through the same code
path the repo-level linter uses.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis import astutil
from repro.analysis.contracts import OOB_WRITE, KernelContract
from repro.analysis.report import Finding
from repro.analysis.vmem import VMEM_BUDGET_BYTES

# Accumulation dtypes a kernel dot may declare (KC05): int8 operands
# accumulate exactly in int32, everything else in f32.
DOT_ACCUM_DTYPES = ("float32", "int32")

# Scratch accumulator dtypes allowed by KC08.
SCRATCH_DTYPES = ("float32", "int32")

# Callables whose results are approximate on TPU (or contraction-order
# dependent) and therefore banned from exact-parity kernel bodies
# (KC07) — the PR 7 exp2-scale bug class.
APPROX_TRANSCENDENTALS = frozenset({
    "exp", "exp2", "expm1", "log", "log2", "log1p", "logsumexp",
    "tanh", "sigmoid", "softmax", "erf", "erfc", "rsqrt",
})


def _f(rule: str, path: Path, line: int, msg: str) -> Finding:
    return Finding(rule=rule, path=str(path), line=line, message=msg)


def _check_grid_arity(site: astutil.PallasSite, c: KernelContract,
                      path: Path) -> List[Finding]:
    out: List[Finding] = []
    if not site.grid_parsed:
        out.append(_f("KC02", path, site.lineno,
                      f"{site.entry}: could not determine the grid "
                      "statically"))
        return out
    if len(site.grid) != c.grid_rank:
        out.append(_f("KC02", path, site.lineno,
                      f"{site.entry}: grid rank {len(site.grid)} != "
                      f"contract grid_rank {c.grid_rank}"))
    if site.scalar_prefetch != c.scalar_prefetch:
        out.append(_f("KC02", path, site.lineno,
                      f"{site.entry}: num_scalar_prefetch "
                      f"{site.scalar_prefetch} != contract "
                      f"scalar_prefetch {c.scalar_prefetch}"))
    want = len(site.grid) + site.scalar_prefetch
    for kind, specs in (("in_specs", site.in_specs),
                        ("out_specs", site.out_specs)):
        for i, spec in enumerate(specs):
            if spec.arity is None:
                out.append(_f("KC02", path, spec.lineno,
                              f"{site.entry}: {kind}[{i}] has no "
                              "statically-visible index-map lambda"))
            elif spec.arity != want:
                out.append(_f("KC02", path, spec.lineno,
                              f"{site.entry}: {kind}[{i}] index map "
                              f"takes {spec.arity} args, grid rank + "
                              f"scalar prefetch = {want}"))
    return out


def _check_vmem(site: astutil.PallasSite, c: KernelContract,
                path: Path) -> List[Finding]:
    if c.vmem_model is None or c.max_shapes is None:
        return [_f("KC03", path, site.lineno,
                   f"{site.entry}: contract declares no VMEM model / "
                   "max shapes")]
    try:
        used = c.vmem_model(**dict(c.max_shapes))
    except TypeError as e:
        return [_f("KC03", path, site.lineno,
                   f"{site.entry}: vmem_model does not accept the "
                   f"declared max_shapes ({e})")]
    if used > VMEM_BUDGET_BYTES:
        return [_f("KC03", path, site.lineno,
                   f"{site.entry}: model gives {used} bytes at max "
                   f"shapes {dict(c.max_shapes)} > budget "
                   f"{VMEM_BUDGET_BYTES}")]
    return []


def _check_tails(site: astutil.PallasSite, c: KernelContract,
                 body: Optional[ast.FunctionDef], src: str,
                 path: Path) -> List[Finding]:
    out: List[Finding] = []
    body_src = ast.get_source_segment(src, body) if body is not None else ""
    squashed = "".join((body_src or "").split())
    kinds = [astutil.grid_axis_kind(g) for g in site.grid]
    for axis, kind in enumerate(kinds):
        if kind == "cdiv":
            marker = dict(c.tail).get(axis)
            if marker is None:
                out.append(_f("KC04", path, site.lineno,
                              f"{site.entry}: cdiv grid axis {axis} has "
                              "no declared tail-mask entry"))
            elif marker != OOB_WRITE and \
                    "".join(marker.split()) not in squashed:
                out.append(_f("KC04", path, site.lineno,
                              f"{site.entry}: declared tail marker "
                              f"{marker!r} for axis {axis} not found in "
                              f"kernel body {c.body!r}"))
        elif kind == "floordiv" and not c.divisible:
            out.append(_f("KC04", path, site.lineno,
                          f"{site.entry}: exact-division grid axis "
                          f"{axis} but the contract does not declare "
                          "divisible=True"))
    for axis in dict(c.tail):
        if axis >= len(kinds) or kinds[axis] != "cdiv":
            out.append(_f("KC04", path, site.lineno,
                          f"{site.entry}: stale tail entry for axis "
                          f"{axis} (not a cdiv grid axis)"))
    if c.divisible and not astutil.has_mod_assert(site.entry_node):
        out.append(_f("KC04", path, site.lineno,
                      f"{site.entry}: divisible=True but no "
                      "divisibility assert (`%`) in the entry"))
    return out


def _check_dots(c: KernelContract, body: ast.FunctionDef,
                path: Path) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(body):
        if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                      ast.MatMult):
            out.append(_f("KC05", path, node.lineno,
                          f"{c.body}: `@` matmul in a kernel body has "
                          "no explicit accumulation dtype — use "
                          "dot_general(preferred_element_type=...)"))
        if not isinstance(node, ast.Call):
            continue
        name = node.func.attr if isinstance(node.func, ast.Attribute) \
            else getattr(node.func, "id", None)
        if name not in ("dot_general", "dot"):
            continue
        pet = None
        for kw in node.keywords:
            if kw.arg == "preferred_element_type":
                pet = kw.value
        if pet is None:
            out.append(_f("KC05", path, node.lineno,
                          f"{c.body}: {name} without "
                          "preferred_element_type"))
        else:
            dtype = pet.attr if isinstance(pet, ast.Attribute) \
                else getattr(pet, "id", None)
            if dtype not in DOT_ACCUM_DTYPES:
                out.append(_f("KC05", path, node.lineno,
                              f"{c.body}: {name} accumulates in "
                              f"{dtype!r}, expected one of "
                              f"{DOT_ACCUM_DTYPES}"))
    return out


def _check_transcendentals(c: KernelContract, body: ast.FunctionDef,
                           path: Path) -> List[Finding]:
    if not c.exact_parity:
        return []
    out: List[Finding] = []
    for node in ast.walk(body):
        if not isinstance(node, ast.Call):
            continue
        name = node.func.attr if isinstance(node.func, ast.Attribute) \
            else getattr(node.func, "id", None)
        if name in APPROX_TRANSCENDENTALS:
            out.append(_f("KC07", path, node.lineno,
                          f"{c.body}: approximate transcendental "
                          f"`{name}` in an exact-parity kernel body"))
    return out


def _check_scratch(site: astutil.PallasSite, c: KernelContract,
                   path: Path) -> List[Finding]:
    out: List[Finding] = []
    got = tuple(site.scratch_dtypes)
    if len(got) != len(c.accumulators):
        out.append(_f("KC08", path, site.lineno,
                      f"{site.entry}: {len(got)} scratch buffers, "
                      f"contract declares {len(c.accumulators)}"))
        return out
    for i, (g, want) in enumerate(zip(got, c.accumulators)):
        if g is None:
            out.append(_f("KC08", path, site.lineno,
                          f"{site.entry}: scratch[{i}] dtype not "
                          "statically resolvable"))
        elif g != want:
            out.append(_f("KC08", path, site.lineno,
                          f"{site.entry}: scratch[{i}] is {g}, "
                          f"contract declares {want}"))
        elif want not in SCRATCH_DTYPES:
            out.append(_f("KC08", path, site.lineno,
                          f"{site.entry}: scratch[{i}] dtype {want} is "
                          f"not an allowed accumulator ({SCRATCH_DTYPES})"))
    return out


def check_kernel_file(path: Path, tree: ast.Module, src: str,
                      file_contracts: Dict[str, KernelContract]
                      ) -> List[Finding]:
    """All KC rules over one parsed kernel file.

    ``file_contracts`` maps entry-function name -> contract for this
    file; entries without a contract are KC01, contracts without a
    surviving site are KC01 (stale), and KC06 (no f64) applies to the
    whole module.
    """
    findings: List[Finding] = []
    funcs = astutil.top_level_functions(tree)
    seen = set()
    for site in astutil.find_pallas_sites(tree):
        c = file_contracts.get(site.entry)
        if c is None:
            findings.append(_f("KC01", path, site.lineno,
                               f"pallas_call in `{site.entry}` has no "
                               "registered KernelContract"))
            continue
        seen.add(site.entry)
        body = funcs.get(c.body)
        if body is None:
            findings.append(_f("KC01", path, site.lineno,
                               f"{site.entry}: contract body "
                               f"{c.body!r} not found in module"))
            continue
        if site.kernel_body is not None and site.kernel_body != c.body:
            findings.append(_f("KC01", path, site.lineno,
                               f"{site.entry}: pallas_call body "
                               f"{site.kernel_body!r} != contract body "
                               f"{c.body!r}"))
        findings += _check_grid_arity(site, c, path)
        findings += _check_vmem(site, c, path)
        findings += _check_tails(site, c, body, src, path)
        findings += _check_dots(c, body, path)
        findings += _check_transcendentals(c, body, path)
        findings += _check_scratch(site, c, path)
    for entry, c in file_contracts.items():
        if entry not in seen:
            findings.append(_f("KC01", path, 1,
                               f"contract for `{entry}` has no "
                               "surviving pallas_call site"))
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in (
                "float64", "f64"):
            findings.append(_f("KC06", path, node.lineno,
                               "float64 reference in a kernel module"))
    return findings


def check_kernels(root: Path, registry) -> List[Finding]:
    """KC rules over every file in ``src/repro/kernels/``."""
    findings: List[Finding] = []
    kdir = root / "src" / "repro" / "kernels"
    by_module: Dict[str, Dict[str, KernelContract]] = {}
    for (module, entry), c in registry.items():
        by_module.setdefault(module, {})[entry] = c
    for path in sorted(kdir.glob("*.py")):
        sf = astutil.load(path)
        module = astutil.module_for(root, path)
        findings += check_kernel_file(path, sf.tree, sf.text,
                                      by_module.get(module, {}))
    return findings
