"""Rule family (b): dispatcher/oracle pairing (OR01–OR03).

Every public dispatcher in ``repro.kernels.ops`` must reach a reference
oracle in ``repro.kernels.ref`` (OR01), at least one test must exercise
the dispatcher (or its Pallas kernel) against that oracle in the same
file (OR02), and intentionally duplicated helper bodies must stay
AST-identical across modules (OR03).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import astutil
from repro.analysis.report import Finding


def _f(rule: str, path: Path, line: int, msg: str) -> Finding:
    return Finding(rule=rule, path=str(path), line=line, message=msg)


def _has_impl_arg(fn: ast.FunctionDef) -> bool:
    """True for an ``impl=None`` selector argument (the dispatcher
    signature convention — distinguishes dispatchers from helpers like
    ``default_impl(impl)`` that take a required impl string)."""
    args = fn.args
    for i, a in enumerate(args.args):
        if a.arg != "impl":
            continue
        j = i - (len(args.args) - len(args.defaults))
        return (0 <= j < len(args.defaults)
                and isinstance(args.defaults[j], ast.Constant)
                and args.defaults[j].value is None)
    for i, a in enumerate(args.kwonlyargs):
        if a.arg != "impl":
            continue
        d = args.kw_defaults[i]
        return isinstance(d, ast.Constant) and d.value is None
    return False


def public_dispatchers(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """Public top-level functions taking an ``impl`` argument."""
    return {name: fn
            for name, fn in astutil.top_level_functions(tree).items()
            if not name.startswith("_") and _has_impl_arg(fn)}


def kernel_import_aliases(tree: ast.Module) -> Dict[str, str]:
    """local-alias -> original name for ``repro.kernels.*`` imports
    (the ``ref``/``tile_plan`` helper modules themselves excluded)."""
    out: Dict[str, str] = {}
    for node in tree.body:
        if not isinstance(node, ast.ImportFrom):
            continue
        mod = node.module or ""
        if not mod.startswith("repro.kernels"):
            continue
        for alias in node.names:
            if alias.name in ("ref", "tile_plan"):
                continue
            out[alias.asname or alias.name] = alias.name
    return out


def _ops_closure(name: str, ops_funcs: Dict[str, ast.FunctionDef],
                 cache: Dict[str, Set[str]]) -> Set[str]:
    """Names referenced from ``name`` through ops-local helpers.

    Reference-based, not call-based: ``shard_topk_quant`` selects its
    helpers via a conditional expression, so plain Call edges miss it.
    """
    if name in cache:
        return cache[name]
    cache[name] = set()  # cycle guard
    refs = astutil.referenced_names(ops_funcs[name])
    out = set(refs)
    for r in refs:
        if r != name and r in ops_funcs:
            out |= _ops_closure(r, ops_funcs, cache)
    cache[name] = out
    return out


def _oracle_closure(start: Iterable[str],
                    ref_funcs: Dict[str, ast.FunctionDef]) -> Set[str]:
    """Oracles reachable from ``start`` through ref-module references —
    e.g. ``fused_recommend_quant_ref`` pulls in ``dtiled_topk_ref``."""
    seen: Set[str] = set()
    frontier = [s for s in start if s in ref_funcs]
    while frontier:
        cur = frontier.pop()
        if cur in seen:
            continue
        seen.add(cur)
        for r in astutil.referenced_names(ref_funcs[cur]):
            if r in ref_funcs and r not in seen:
                frontier.append(r)
    return seen


def check_dispatchers_in_tree(
        tree: ast.Module, path: Path, ref_names: Set[str],
        tests: Optional[Dict[Path, str]] = None,
        ref_funcs: Optional[Dict[str, ast.FunctionDef]] = None,
) -> List[Finding]:
    """OR01 (+OR02 when ``tests`` is given) over one ops-like module."""
    findings: List[Finding] = []
    ops_funcs = astutil.top_level_functions(tree)
    aliases = kernel_import_aliases(tree)
    cache: Dict[str, Set[str]] = {}
    for name, fn in sorted(public_dispatchers(tree).items()):
        refs = _ops_closure(name, ops_funcs, cache)
        oracles: Set[str] = set()
        for r in refs:
            if r.startswith("ref."):
                target = r[4:]
                if target in ref_names:
                    oracles.add(target)
                else:
                    findings.append(_f(
                        "OR01", path, fn.lineno,
                        f"{name}: references unknown oracle "
                        f"`ref.{target}`"))
        if not oracles:
            findings.append(_f(
                "OR01", path, fn.lineno,
                f"dispatcher `{name}` reaches no `ref.*` oracle"))
            continue
        if tests is None:
            continue
        if ref_funcs is not None:
            oracles = _oracle_closure(oracles, ref_funcs)
        kernel_names = {aliases[r] for r in refs if r in aliases}
        dispatch_side = {name} | kernel_names
        if not _covered_by_tests(dispatch_side, oracles, tests):
            findings.append(_f(
                "OR02", path, fn.lineno,
                f"no test references `{name}` (or its kernels "
                f"{sorted(kernel_names)}) together with an oracle in "
                f"{sorted(oracles)}"))
    return findings


def _covered_by_tests(dispatch_side: Set[str], oracles: Set[str],
                      tests: Dict[Path, str]) -> bool:
    for text in tests.values():
        if any(re.search(rf"\b{re.escape(n)}\b", text)
               for n in dispatch_side) and \
           any(re.search(rf"\b{re.escape(o)}\b", text)
               for o in oracles):
            return True
    return False


def check_oracle_pairing(root: Path) -> List[Finding]:
    """OR01/OR02 over the real ``ops.py`` / ``ref.py`` / ``tests/``."""
    ops_path = root / "src" / "repro" / "kernels" / "ops.py"
    ref_path = root / "src" / "repro" / "kernels" / "ref.py"
    ops_sf = astutil.load(ops_path)
    ref_funcs = astutil.top_level_functions(astutil.load(ref_path).tree)
    tests = {p: p.read_text()
             for p in sorted((root / "tests").glob("test_*.py"))}
    return check_dispatchers_in_tree(
        ops_sf.tree, ops_path, set(ref_funcs), tests=tests,
        ref_funcs=ref_funcs)


def check_duplicate_pair(
        a: Tuple[Path, str], b: Tuple[Path, str]) -> List[Finding]:
    """OR03 over one intentional-duplicate pair of (path, func name)."""
    dumps = []
    for path, name in (a, b):
        fn = astutil.top_level_functions(astutil.load(path).tree).get(name)
        if fn is None:
            return [_f("OR03", path, 1,
                       f"duplicate-pair function `{name}` not found")]
        dumps.append((path, fn.lineno, astutil.normalized_body_dump(fn)))
    if dumps[0][2] != dumps[1][2]:
        path, line, _ = dumps[1]
        return [_f("OR03", path, line,
                   f"body of `{b[1]}` has drifted from `{a[1]}` in "
                   f"{a[0]}")]
    return []


def check_duplicates(root: Path, pairs) -> List[Finding]:
    """OR03 over every registered intentional-duplicate pair."""
    findings: List[Finding] = []
    for (mod_a, fn_a), (mod_b, fn_b) in pairs:
        findings += check_duplicate_pair(
            (astutil.path_for(root, mod_a), fn_a),
            (astutil.path_for(root, mod_b), fn_b))
    return findings
