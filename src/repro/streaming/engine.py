"""Micro-batch streaming engine — the Spark Structured Streaming analog.

Implements Algorithm 1 of the paper (joint incremental/decremental state
updates) as a batched SPMD program:

  * incoming events (basket additions, basket/item deletion requests)
    are buffered in per-user pending queues and cut into micro-batches
    of at most one event per user (conflicting events for the same user
    wait for the next batch — this preserves per-user sequential
    semantics while letting independent users update in parallel,
    exactly the paper's user-level parallelism);

  * each micro-batch is **partitioned by event kind** into homogeneous
    ``AddBatch`` / ``DelBasketBatch`` / ``DelItemBatch`` sub-batches
    (DESIGN.md §4), so each compiled program runs exactly one update
    rule — the add path applies sparse deltas (O(basket) state traffic),
    the decremental paths pay their paper-given linear cost;

  * an idempotent update log (sequence numbers + processed watermark)
    makes recovery exactly-once: after restoring a checkpoint, events
    with seqno <= watermark are skipped on replay;

  * users whose numerical-error bound crossed the stability threshold
    are refreshed from scratch after the batch (core.stability), and
    users whose representation scale approaches SCALE_FLOOR are
    renormalized in place (core.updates.renormalize_users).
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import os
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stability
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, PAD_ID, AddBatch,
                              DelBasketBatch, DelItemBatch, TifuParams,
                              _pow2_pad)
from repro.core.updates import (SCALE_CEIL, SCALE_FLOOR,
                                apply_add_batch_counted,
                                apply_del_basket_batch, apply_del_item_batch,
                                refresh_users, renormalize_users)
from repro.streaming.state_store import StateStore


@dataclasses.dataclass(frozen=True)
class Event:
    """One streaming event. ``seqno`` is assigned by the engine."""
    kind: int
    user: int
    items: Optional[np.ndarray] = None   # for adds
    pos: int = 0                         # for deletes
    item: int = PAD_ID                   # for item deletes
    seqno: int = -1


@dataclasses.dataclass
class EngineMetrics:
    events_processed: int = 0
    batches: int = 0
    refreshes: int = 0
    renormalizations: int = 0
    # adds masked to no-ops by apply_add_batch's capacity guard
    dropped_adds: int = 0
    # pow2 sub-batch bucket transitions (each is a fresh compile unless
    # that bucket was seen before); shrinks are hysteresis-gated
    bucket_grows: int = 0
    bucket_shrinks: int = 0
    last_batch_seconds: float = 0.0


class StreamingEngine:
    """Joint incremental/decremental state maintenance (Algorithm 1)."""

    def __init__(self, store: StateStore, params: TifuParams,
                 batch_size: int = 256,
                 stability_target_rel_err: Optional[float] = 1e-2,
                 renorm_check_interval: int = 64,
                 bucket_hysteresis: int = 8):
        self.store = store
        self.params = params
        self.batch_size = batch_size
        # pow2 sub-batch bucket hysteresis (DESIGN.md §4.1): a kind's
        # bucket grows immediately (the rows exist, there is no choice)
        # but only shrinks after this many CONSECUTIVE micro-batches
        # whose sub-batch would fit the smaller bucket — kind counts that
        # straddle a pow2 boundary no longer flip-flop compiled shapes.
        self.bucket_hysteresis = max(1, bucket_hysteresis)
        self._kind_bucket: Dict[int, int] = {}
        self._below_bucket: Dict[int, int] = {}
        # The renormalization probe must fire before a scale that passed
        # the last probe can underflow f32 (raw rows scale as 1/scale).
        # A user gets at most one event per batch; the worst per-add
        # shrink factor is min(r_b, r_g)/2 (k=1 group opening / tau=1
        # append) and the worst per-delete growth factor is its inverse
        # 2/min(r_b, r_g) (Eq. 12 fold, k=2), so cap the interval I at
        # f^I >= 1e-14: a scale inside the probe bounds then stays
        # within a further 1e14 factor — raw magnitudes <= ~1e30/1e-30,
        # safely inside f32 range in both directions.
        f = min(params.r_b, params.r_g) / 2.0
        sound = int(np.floor(np.log(1e-14) / np.log(f))) if f < 1.0 else 64
        self.renorm_check_interval = max(1, min(renorm_check_interval,
                                                sound))
        # Per-user pending queues + a min-heap of (head seqno, user):
        # cutting a batch pops at most one event per user in seqno order
        # and costs O(taken·log users) — a hot user with a deep queue no
        # longer forces a rescan of the whole buffer every step.
        self._queues: Dict[int, deque] = {}
        self._heap: List[tuple] = []   # a user is in the heap iff its
        self._n_pending = 0            # queue exists in _queues
        # Exactly-once bookkeeping.  Conflict deferral (one event per user
        # per micro-batch) processes events OUT of seqno order, so a plain
        # high-watermark would drop deferred-but-unprocessed events on
        # replay.  We track the contiguous frontier + the sparse set of
        # processed seqnos above it, PLUS the seqnos currently sitting in
        # the pending queues: an at-least-once source may redeliver an
        # event before its first copy was ever processed, and without the
        # pending set that duplicate would be enqueued (and applied)
        # twice.
        self.watermark = -1                 # all seqnos <= this are done
        self._processed_above: set = set()
        self._pending_seqnos: set = set()
        self._next_seqno = 0
        self.metrics = EngineMetrics()
        if stability_target_rel_err is not None:
            self.err_threshold = stability.refresh_threshold(
                stability_target_rel_err, np.finfo(np.float32).eps)
        else:
            self.err_threshold = None

    # -- ingestion ------------------------------------------------------------

    @property
    def n_pending(self) -> int:
        """Number of buffered (not yet applied) events."""
        return self._n_pending

    def _enqueue(self, ev: Event) -> None:
        q = self._queues.get(ev.user)
        if q is None:
            q = self._queues[ev.user] = deque()
            heapq.heappush(self._heap, (ev.seqno, ev.user))
        q.append(ev)
        self._pending_seqnos.add(ev.seqno)
        self._n_pending += 1

    def submit(self, events: Iterable[Event]) -> None:
        for ev in events:
            if ev.seqno < 0:
                ev = dataclasses.replace(ev, seqno=self._next_seqno)
                self._next_seqno += 1
            elif ev.seqno <= self.watermark \
                    or ev.seqno in self._processed_above \
                    or ev.seqno in self._pending_seqnos:
                # replay of an event that was already processed OR is
                # still buffered: skip (at-least-once -> exactly-once)
                continue
            else:
                self._next_seqno = max(self._next_seqno, ev.seqno + 1)
            self._enqueue(ev)

    def add_basket(self, user: int, items: Sequence[int]) -> None:
        self.submit([Event(KIND_ADD_BASKET, user,
                           items=np.asarray(items, np.int32))])

    def delete_basket(self, user: int, pos: int) -> None:
        self.submit([Event(KIND_DEL_BASKET, user, pos=pos)])

    def delete_item(self, user: int, pos: int, item: int) -> None:
        self.submit([Event(KIND_DEL_ITEM, user, pos=pos, item=item)])

    # -- micro-batch processing -------------------------------------------------

    def _cut_batch(self) -> List[Event]:
        """Take up to batch_size events in seqno order, at most one per
        user; a user's later events stay queued for the next batch."""
        taken: List[Event] = []
        requeue = []
        while self._heap and len(taken) < self.batch_size:
            _, user = heapq.heappop(self._heap)
            q = self._queues[user]
            taken.append(q.popleft())
            if q:
                requeue.append((q[0].seqno, user))
            else:
                del self._queues[user]
        for entry in requeue:
            heapq.heappush(self._heap, entry)
        for ev in taken:
            self._pending_seqnos.discard(ev.seqno)
        self._n_pending -= len(taken)
        return taken

    def _bucket(self, kind: int, n: int) -> int:
        """Padded sub-batch size for ``n`` rows of ``kind``, with shrink
        hysteresis: growth is immediate, shrink waits for
        ``bucket_hysteresis`` consecutive under-boundary micro-batches."""
        want = _pow2_pad(n, self.batch_size)
        cur = self._kind_bucket.get(kind, 0)
        if want >= cur:
            if want > cur and cur:
                self.metrics.bucket_grows += 1
            self._kind_bucket[kind] = want
            self._below_bucket[kind] = 0
            return want
        self._below_bucket[kind] = self._below_bucket.get(kind, 0) + 1
        if self._below_bucket[kind] >= self.bucket_hysteresis:
            self._kind_bucket[kind] = want
            self._below_bucket[kind] = 0
            self.metrics.bucket_shrinks += 1
            return want
        return cur

    def _decay_absent_buckets(self, present) -> None:
        """Advance the shrink hysteresis of kinds ABSENT from this
        micro-batch.  Without this, a one-off burst (e.g. a GDPR delete
        wave) pins its large pow2 bucket forever: the kind never appears
        again, `_bucket` is never consulted, and the next singleton of
        that kind pads to the stale burst-sized bucket.  An absent batch
        counts as a zero-row batch, so after ``bucket_hysteresis``
        consecutive batches without the kind its bucket decays to the
        minimum (re-growth stays immediate, and previously compiled
        buckets are still cached)."""
        for kind in list(self._kind_bucket):
            if kind not in present and self._kind_bucket[kind] > 1:
                self._bucket(kind, 0)

    def _apply_events(self, events: List[Event]) -> None:
        """Partition a micro-batch by kind and run one homogeneous
        compiled program per kind present (users are disjoint across the
        sub-batches, so application order is irrelevant)."""
        adds = [ev for ev in events if ev.kind == KIND_ADD_BASKET]
        delb = [ev for ev in events if ev.kind == KIND_DEL_BASKET]
        deli = [ev for ev in events if ev.kind == KIND_DEL_ITEM]
        self._decay_absent_buckets({kind for kind, evs in
                                    ((KIND_ADD_BASKET, adds),
                                     (KIND_DEL_BASKET, delb),
                                     (KIND_DEL_ITEM, deli)) if evs})
        b = self.store.cfg.max_basket_size
        if adds:
            batch = AddBatch.build(
                [ev.user for ev in adds], [ev.items for ev in adds], b,
                pad_to=self._bucket(KIND_ADD_BASKET, len(adds)))
            # the counted variant surfaces capacity drops (masked to
            # no-ops by the guard) from the same fused program
            self.store.state, dropped = apply_add_batch_counted(
                self.store.state, batch, self.params)
            self.metrics.dropped_adds += int(dropped)
        if delb:
            batch = DelBasketBatch.build(
                [ev.user for ev in delb], [ev.pos for ev in delb],
                pad_to=self._bucket(KIND_DEL_BASKET, len(delb)))
            self.store.state = apply_del_basket_batch(self.store.state,
                                                      batch, self.params)
        if deli:
            batch = DelItemBatch.build(
                [ev.user for ev in deli], [ev.pos for ev in deli],
                [ev.item for ev in deli],
                pad_to=self._bucket(KIND_DEL_ITEM, len(deli)))
            self.store.state = apply_del_item_batch(self.store.state, batch,
                                                    self.params)
        # serving-corpus cache: only these rows changed (DESIGN.md §3.6)
        self.store.invalidate_users([ev.user for ev in events])

    def _maintain(self) -> None:
        """Stability refreshes + scale renormalization after a batch."""
        if self.err_threshold is not None:
            err = np.asarray(self.store.state.err_mult)
            bad = np.nonzero(err > self.err_threshold)[0]
            if bad.size:
                self.store.state = refresh_users(
                    self.store.state, jnp.asarray(bad, jnp.int32),
                    self.params)
                self.metrics.refreshes += int(bad.size)
                # a refresh changes the served values (it resets the
                # accumulated fp error), so those rows are stale too
                self.store.invalidate_users(bad)
        # Scales take thousands of events per user to approach either
        # bound (each group opening shrinks uv_scale by ~r_g, each Eq. 12
        # deletion grows it by ~1/r_g), so probe them only every Nth
        # batch — the gate itself is a blocking sync and must stay off
        # the per-step hot path.
        if self.metrics.batches % self.renorm_check_interval:
            return
        floor = SCALE_FLOOR * 1e2   # renormalize well before the bounds
        ceil = SCALE_CEIL * 1e-2
        uv = self.store.state.uv_scale
        lgv = self.store.state.lgv_scale
        lo, hi = jax.device_get((jnp.minimum(uv.min(), lgv.min()),
                                 jnp.maximum(uv.max(), lgv.max())))
        if lo < floor or hi > ceil:
            uv_h, lgv_h = np.asarray(uv), np.asarray(lgv)
            out = np.nonzero((uv_h < floor) | (lgv_h < floor)
                             | (uv_h > ceil) | (lgv_h > ceil))[0]
            self.store.state = renormalize_users(
                self.store.state, jnp.asarray(out, jnp.int32))
            self.metrics.renormalizations += int(out.size)

    def step(self) -> int:
        """Process one micro-batch. Returns number of events applied."""
        events = self._cut_batch()
        if not events:
            return 0
        t0 = time.perf_counter()
        self._apply_events(events)
        self._maintain()
        for ev in events:
            self._processed_above.add(ev.seqno)
        while self.watermark + 1 in self._processed_above:
            self.watermark += 1
            self._processed_above.discard(self.watermark)
        self.metrics.events_processed += len(events)
        self.metrics.batches += 1
        self.metrics.last_batch_seconds = time.perf_counter() - t0
        return len(events)

    def run_until_drained(self, max_batches: int = 10_000) -> int:
        total = 0
        for _ in range(max_batches):
            n = self.step()
            if n == 0:
                break
            total += n
        return total

    # -- recovery ---------------------------------------------------------------

    def checkpoint(self, directory: str, step: int) -> None:
        # The exactly-once log rides inside the store's LATEST metadata,
        # which is the checkpoint's single atomic commit point (fsync'd
        # tmp + os.replace): a crash anywhere — even between files —
        # can never pair a new state npz with an old/truncated log
        # (a torn pair would replay below the old watermark onto the
        # new state: double-apply).
        self.store.checkpoint(
            directory, step,
            extra_meta={"engine": {
                "watermark": self.watermark,
                "processed_above": sorted(self._processed_above),
                "next_seqno": self._next_seqno}})

    def restore(self, directory: str) -> None:
        self.store.restore(directory)
        meta = self.store.last_restored_meta.get("engine")
        if meta is None:
            # legacy checkpoint layout: separate ENGINE file
            with open(os.path.join(directory, "ENGINE")) as f:
                meta = json.load(f)
        self.watermark = meta["watermark"]
        self._processed_above = set(meta.get("processed_above", []))
        self._next_seqno = meta["next_seqno"]
        self._queues.clear()
        self._heap.clear()
        self._pending_seqnos.clear()
        self._n_pending = 0
