"""Jit'd dispatch wrappers: Pallas on TPU, interpret/reference on CPU.

The public entry points the rest of the system calls; each picks the
fastest implementation available for the current backend and is
guaranteed (by tests/test_kernels.py shape/dtype sweeps) to match the
ref.py oracles.

``default_impl`` overrides the per-call default process-wide — the
benchmark's ``--backend interpret`` arm and the interpret-mode stream
equivalence tests route the *whole* update pipeline through the Pallas
kernels on CPU with it.  The override is read at trace time, so entering
or leaving the context clears jax's compilation caches.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import vmem as _analysis_vmem
from repro.kernels import ref, tile_plan
from repro.kernels.decayed_scatter import (batched_decayed_scatter,
                                           decayed_scatter)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.knn_topk import knn_topk as _knn_pallas
from repro.kernels.knn_topk import knn_topk_dtiled as _knn_dtiled_pallas
from repro.kernels.serving_topn import (blend_topn_onehot as _blend_onehot,
                                        blend_topn_rows as _blend_rows)
from repro.kernels.serving_topn import \
    blend_topn_rows_quant as _blend_rows_quant_pallas
from repro.kernels.sparse_row_gather import \
    sparse_row_gather as _sparse_gather_pallas
from repro.kernels.sparse_row_scatter import \
    sparse_row_scatter as _sparse_scatter_pallas

_DEFAULT_IMPL = "auto"


@contextlib.contextmanager
def default_impl(impl: str) -> Iterator[None]:
    """Process-wide impl override (auto | pallas | interpret | ref).

    Jitted callers (core.updates) capture the dispatch decision at trace
    time, so both transitions clear the jit caches — this is a test /
    benchmark harness knob, not a serving-path switch.
    """
    global _DEFAULT_IMPL
    prev = _DEFAULT_IMPL
    _DEFAULT_IMPL = impl
    jax.clear_caches()
    try:
        yield
    finally:
        _DEFAULT_IMPL = prev
        jax.clear_caches()


def _resolve(impl: Optional[str]) -> str:
    return _DEFAULT_IMPL if impl is None else impl


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def knn_topk(queries: jax.Array, corpus: jax.Array, k: int,
             impl: str | None = None,
             **kw: Any) -> Tuple[jax.Array, jax.Array]:
    """Fused similarity + top-k (paper §2.2 neighbour search).

    O(Q·M·I) compute over corpus tiles with an on-chip [Q, k] running
    merge — never a [Q, M] score matrix in HBM (DESIGN.md §3.4).
    impl: auto | pallas | interpret | ref.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.knn_topk_ref(queries, corpus, k,
                                kw.get("metric", "euclidean"))
    return _knn_pallas(queries, corpus, k,
                       interpret=(impl == "interpret" or not _on_tpu()),
                       **kw)


def knn_topk_dtiled(queries: jax.Array, corpus: jax.Array, k: int,
                    bd: int = 512, impl: str | None = None,
                    **kw: Any) -> Tuple[jax.Array, jax.Array]:
    """D-tiled streaming top-k (DESIGN.md §8.4): VMEM flat in D.

    Same contract as :func:`knn_topk` (euclidean only) with the item
    axis tiled at width ``bd``; int8 ``queries``/``corpus`` take
    ``q_scale``/``c_scale`` (per-row, `optim.compression
    .quantize_int8_rows`) and are bitwise the `ref.dtiled_topk_ref`
    oracle on every impl.  impl: auto | pallas | interpret | ref.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.dtiled_topk_ref(queries, corpus, k, bd=bd, **kw)
    return _knn_dtiled_pallas(queries, corpus, k, bd=bd,
                              interpret=(impl == "interpret"
                                         or not _on_tpu()), **kw)


# ---------------------------------------------------------------------------
# Fused serving pipeline (DESIGN.md §8)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "topn", "metric"))
def _fused_recommend_ref(corpus: jax.Array, user_ids: jax.Array,
                         alpha: float, k: int, topn: int,
                         metric: str) -> jax.Array:
    return ref.fused_recommend_ref(corpus, user_ids, k, alpha, topn, metric)


@functools.partial(jax.jit,
                   static_argnames=("k", "alpha", "topn", "metric",
                                    "interpret"))
def _fused_recommend_pallas(corpus: jax.Array, user_ids: jax.Array,
                            k: int, alpha: float, topn: int, metric: str,
                            interpret: bool) -> jax.Array:
    queries = corpus[user_ids]
    _, idx = _knn_pallas(queries, corpus, k, metric=metric,
                         query_gids=user_ids, interpret=interpret)
    _, ids = _blend_onehot(corpus, user_ids, idx, alpha=alpha, topn=topn,
                           interpret=interpret)
    return ids


@functools.partial(jax.jit, static_argnames=("k", "topn", "bd"))
def _fused_recommend_dtiled_ref(corpus: jax.Array, user_ids: jax.Array,
                                alpha: float, k: int, topn: int,
                                bd: int) -> jax.Array:
    queries = corpus[user_ids]
    _, idx = ref.dtiled_topk_ref(queries, corpus, k, bd=bd,
                                 query_gids=user_ids)
    return ref.blend_topn_rows_ref(queries, corpus[idx], alpha, topn)


@functools.partial(jax.jit,
                   static_argnames=("k", "alpha", "topn", "bd",
                                    "interpret"))
def _fused_recommend_dtiled_pallas(corpus: jax.Array, user_ids: jax.Array,
                                   k: int, alpha: float, topn: int,
                                   bd: int, interpret: bool) -> jax.Array:
    queries = corpus[user_ids]
    _, idx = _knn_dtiled_pallas(queries, corpus, k, bd=bd,
                                query_gids=user_ids, interpret=interpret)
    _, ids = _blend_onehot(corpus, user_ids, idx, alpha=alpha, topn=topn,
                           interpret=interpret)
    return ids


@functools.partial(jax.jit, static_argnames=("k", "topn", "bd"))
def _fused_recommend_quant_ref(corpus_q: jax.Array, c_scale: jax.Array,
                               user_ids: jax.Array, alpha: float, k: int,
                               topn: int, bd: int) -> jax.Array:
    return ref.fused_recommend_quant_ref(corpus_q, c_scale, user_ids, k,
                                         alpha, topn, bd)


@functools.partial(jax.jit,
                   static_argnames=("k", "alpha", "topn", "bd",
                                    "interpret"))
def _fused_recommend_quant_pallas(corpus_q: jax.Array, c_scale: jax.Array,
                                  user_ids: jax.Array, k: int,
                                  alpha: float, topn: int, bd: int,
                                  interpret: bool) -> jax.Array:
    queries_q = corpus_q[user_ids]
    q_scale = c_scale[user_ids]
    _, idx = _knn_dtiled_pallas(queries_q, corpus_q, k, bd=bd,
                                query_gids=user_ids, q_scale=q_scale,
                                c_scale=c_scale, interpret=interpret)
    # stage B fetches only the selected k rows — and fetches them int8:
    # ¼ the HBM bytes of the fp32 gather (DESIGN.md §8.4)
    _, ids = _blend_rows_quant_pallas(queries_q, q_scale, corpus_q[idx],
                                      c_scale[idx], alpha=alpha,
                                      topn=topn, interpret=interpret)
    return ids


def fused_recommend(corpus: jax.Array, user_ids: jax.Array, k: int,
                    alpha: float, topn: int, metric: str = "euclidean",
                    impl: str | None = None,
                    bd: int | None = None) -> jax.Array:
    """Fused serving path: corpus rows → top-n item ids, one program.

    ``corpus`` f32[M, I] (the cached serving corpus), ``user_ids``
    i32[Q] corpus rows (self-excluded from their own neighbourhood) →
    i32[Q, topn].  The TPU path is the two-stage Pallas pipeline of
    DESIGN.md §8 (streaming top-k + one-hot blend/top-n: O(Q·k) HBM
    intermediates); the CPU path is the XLA reference — bitwise the
    historical `recommend_for_users` output.  ``k`` is clamped to M−1
    (see the comment at the clamp); cosine falls back to the reference
    (the kernels fuse the euclidean surrogate / dot only).
    ``bd`` (optional, euclidean only) routes stage A through the
    D-tiled kernel of DESIGN.md §8.4 — same results, VMEM flat in the
    item count; required beyond the monolithic kernel's ~64k-item wall.
    impl: auto | pallas | interpret | ref.
    """
    impl = _resolve(impl)
    q_n, m = user_ids.shape[0], corpus.shape[0]
    if topn > corpus.shape[1]:
        raise ValueError(f"topn={topn} > n_items={corpus.shape[1]}")
    if q_n == 0 or m == 0:
        return jnp.zeros((q_n, topn), jnp.int32)
    # clamp BELOW m: self-exclusion leaves m−1 finite candidates, and a
    # k that admits a −inf slot resolves it differently in the kernel
    # (accumulator-init index) than in the reference (the self row) —
    # keeping every selected candidate finite keeps the paths identical
    k = max(1, min(k, m - 1))
    if impl == "ref" or metric == "cosine" \
            or (impl == "auto" and not _on_tpu()):
        if bd is not None and metric != "cosine":
            return _fused_recommend_dtiled_ref(corpus, user_ids, alpha,
                                               k=k, topn=topn, bd=bd)
        return _fused_recommend_ref(corpus, user_ids, alpha, k=k,
                                    topn=topn, metric=metric)
    if bd is not None:
        return _fused_recommend_dtiled_pallas(
            corpus, user_ids, k=k, alpha=float(alpha), topn=topn, bd=bd,
            interpret=(impl == "interpret" or not _on_tpu()))
    return _fused_recommend_pallas(
        corpus, user_ids, k=k, alpha=float(alpha), topn=topn,
        metric=metric, interpret=(impl == "interpret" or not _on_tpu()))


def fused_recommend_quant(corpus_q: jax.Array, c_scale: jax.Array,
                          user_ids: jax.Array, k: int,
                          alpha: float, topn: int, bd: int = 512,
                          impl: str | None = None) -> jax.Array:
    """Int8 fused serving (DESIGN.md §8.4): quantized corpus → top-n ids.

    ``corpus_q`` int8[M, I] with per-row ``c_scale`` f32[M]
    (`optim.compression.quantize_int8_rows`; cached by
    `streaming.state_store.StateStore.quantized_corpus`).  Stage A runs
    the D-tiled int8 top-k (exact int32 MXU partials, scales applied at
    score-finish — bitwise `ref.fused_recommend_quant_ref` on every
    impl); stage B gathers only the selected k rows, int8 on the wire,
    and dequantizes in VMEM.  HBM traffic per query batch is
    O(Q/bq · M·I) int8 reads + O(Q·k·I) int8 + O(Q·n) out — ¼ the
    fp32 path's bytes.  Euclidean only.  impl: auto | pallas |
    interpret | ref.
    """
    impl = _resolve(impl)
    q_n, m = user_ids.shape[0], corpus_q.shape[0]
    if topn > corpus_q.shape[1]:
        raise ValueError(f"topn={topn} > n_items={corpus_q.shape[1]}")
    if q_n == 0 or m == 0:
        return jnp.zeros((q_n, topn), jnp.int32)
    k = max(1, min(k, m - 1))   # same −inf-slot reasoning as above
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _fused_recommend_quant_ref(corpus_q, c_scale, user_ids,
                                          alpha, k=k, topn=topn, bd=bd)
    return _fused_recommend_quant_pallas(
        corpus_q, c_scale, user_ids, k=k, alpha=float(alpha), topn=topn,
        bd=bd, interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("k", "shard", "n_shards",
                                             "metric"))
def _shard_topk_ref(queries: jax.Array, corpus: jax.Array,
                    query_gids: Optional[jax.Array], k: int, shard: int,
                    n_shards: int,
                    metric: str) -> Tuple[jax.Array, jax.Array]:
    return ref.shard_topk_ref(queries, corpus, k, shard, n_shards,
                              query_gids, metric)


@functools.partial(jax.jit, static_argnames=("k", "shard", "n_shards",
                                             "metric", "interpret"))
def _shard_topk_pallas(queries: jax.Array, corpus: jax.Array,
                       query_gids: jax.Array, k: int, shard: int,
                       n_shards: int, metric: str,
                       interpret: bool) -> Tuple[jax.Array, jax.Array]:
    vals, idx = _knn_pallas(queries, corpus, k, metric=metric,
                            query_gids=query_gids, col_offset=shard,
                            col_stride=n_shards, sub_qnorm=True,
                            interpret=interpret)
    gids = idx * n_shards + shard
    # k >= m_s on the owner shard admits the excluded self column as a
    # −inf candidate; the reference resolves its index to the self row
    # (the only −inf score), the kernel to the accumulator init — pin
    # the reference's answer so the cross-shard merge sees identical
    # (score, gid) lists
    return vals, jnp.where(jnp.isneginf(vals), query_gids[:, None], gids)


def shard_topk(queries: jax.Array, corpus: jax.Array, k: int, shard: int,
               n_shards: int, query_gids: jax.Array | None = None,
               metric: str = "euclidean",
               impl: str | None = None) -> Tuple[jax.Array, jax.Array]:
    """Per-shard neighbour candidates ``([Q, k'] scores, global ids)``.

    ``k' = min(k, M_s)``.  The TPU path streams corpus tiles through the
    fused top-k kernel with the shard's global-id mapping (column gid =
    ``row·n_shards + shard``) — the [Q, M_s] score matrix never reaches
    HBM; the CPU path is bitwise the historical
    `shard_topk_candidates`.  Cosine falls back to the reference.
    """
    impl = _resolve(impl)
    m_s = corpus.shape[0]
    q_n = queries.shape[0]
    if m_s == 0 or q_n == 0:
        kk = min(k, m_s)
        return (jnp.full((q_n, kk), -jnp.inf, jnp.float32),
                jnp.zeros((q_n, kk), jnp.int32))
    if impl == "ref" or metric == "cosine" \
            or (impl == "auto" and not _on_tpu()):
        return _shard_topk_ref(queries, corpus, query_gids, k=k,
                               shard=shard, n_shards=n_shards,
                               metric=metric)
    return _shard_topk_pallas(
        queries, corpus,
        (query_gids if query_gids is not None
         else jnp.full((q_n,), -1, jnp.int32)),
        k=min(k, m_s), shard=shard, n_shards=n_shards, metric=metric,
        interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("k", "shard", "n_shards",
                                             "bd"))
def _shard_topk_quant_ref(queries_q: jax.Array, q_scale: jax.Array,
                          corpus_q: jax.Array, c_scale: jax.Array,
                          query_gids: jax.Array, k: int, shard: int,
                          n_shards: int,
                          bd: int) -> Tuple[jax.Array, jax.Array]:
    vals, idx = ref.dtiled_topk_ref(queries_q, corpus_q, k, bd=bd,
                                    query_gids=query_gids,
                                    col_offset=shard, col_stride=n_shards,
                                    sub_qnorm=True, q_scale=q_scale,
                                    c_scale=c_scale)
    gids = idx * n_shards + shard
    return vals, jnp.where(jnp.isneginf(vals), query_gids[:, None], gids)


@functools.partial(jax.jit, static_argnames=("k", "shard", "n_shards",
                                             "bd", "interpret"))
def _shard_topk_quant_pallas(queries_q: jax.Array, q_scale: jax.Array,
                             corpus_q: jax.Array, c_scale: jax.Array,
                             query_gids: jax.Array, k: int, shard: int,
                             n_shards: int, bd: int,
                             interpret: bool
                             ) -> Tuple[jax.Array, jax.Array]:
    vals, idx = _knn_dtiled_pallas(queries_q, corpus_q, k, bd=bd,
                                   query_gids=query_gids,
                                   col_offset=shard, col_stride=n_shards,
                                   sub_qnorm=True, q_scale=q_scale,
                                   c_scale=c_scale, interpret=interpret)
    gids = idx * n_shards + shard
    # same −inf → self-gid pin as _shard_topk_pallas
    return vals, jnp.where(jnp.isneginf(vals), query_gids[:, None], gids)


def shard_topk_quant(queries_q: jax.Array, q_scale: jax.Array,
                     corpus_q: jax.Array, c_scale: jax.Array, k: int,
                     shard: int, n_shards: int,
                     query_gids: jax.Array | None = None,
                     bd: int = 512, impl: str | None = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Per-shard int8 neighbour candidates ``([Q, k'] scores, gids)``.

    The quantized twin of :func:`shard_topk` — D-tiled stage A over one
    shard's int8 corpus, ``sub_qnorm`` on so the emitted scores are the
    full −|q̂−ĉ|² on DEQUANTIZED values: per-row quantization is
    corpus-partition invariant (a row's (q, scale) is the same on any
    shard), so per-pair scores across shards are exactly the
    single-corpus int8 scores and the cross-shard merge stays
    bitwise-consistent (DESIGN.md §7.3/§8.4).  Bitwise the oracle on
    every impl.  impl: auto | pallas | interpret | ref.
    """
    impl = _resolve(impl)
    m_s = corpus_q.shape[0]
    q_n = queries_q.shape[0]
    if m_s == 0 or q_n == 0:
        kk = min(k, m_s)
        return (jnp.full((q_n, kk), -jnp.inf, jnp.float32),
                jnp.zeros((q_n, kk), jnp.int32))
    if query_gids is None:
        query_gids = jnp.full((q_n,), -1, jnp.int32)
    fn = (_shard_topk_quant_ref
          if impl == "ref" or (impl == "auto" and not _on_tpu())
          else functools.partial(
              _shard_topk_quant_pallas,
              interpret=(impl == "interpret" or not _on_tpu())))
    return fn(queries_q, q_scale, corpus_q, c_scale, query_gids,
              k=min(k, m_s), shard=shard, n_shards=n_shards, bd=bd)


@functools.partial(jax.jit, static_argnames=("topn",))
def _blend_rows_ref(queries: jax.Array, neighbor_rows: jax.Array,
                    alpha: float, topn: int) -> jax.Array:
    return ref.blend_topn_rows_ref(queries, neighbor_rows, alpha, topn)


@functools.partial(jax.jit, static_argnames=("alpha", "topn", "interpret"))
def _blend_rows_pallas(queries: jax.Array, neighbor_rows: jax.Array,
                       alpha: float, topn: int,
                       interpret: bool) -> jax.Array:
    return _blend_rows(queries, neighbor_rows, alpha=alpha, topn=topn,
                       interpret=interpret)[1]


def blend_topn_rows(queries: jax.Array, neighbor_rows: jax.Array,
                    alpha: float, topn: int,
                    impl: str | None = None) -> jax.Array:
    """Cross-shard final stage: fetched rows [Q, k, I] → top-n ids.

    Mean over k + alpha blend + top-n; the TPU path fuses them per item
    tile (no [Q, I] prediction intermediate), the CPU path is bitwise
    the historical ``_combine_neighbors``.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _blend_rows_ref(queries, neighbor_rows, alpha, topn=topn)
    return _blend_rows_pallas(
        queries, neighbor_rows, alpha=float(alpha), topn=topn,
        interpret=(impl == "interpret" or not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("topn",))
def _blend_rows_quant_ref(queries_q: jax.Array, q_scale: jax.Array,
                          neighbor_rows_q: jax.Array, n_scale: jax.Array,
                          alpha: float, topn: int) -> jax.Array:
    return ref.blend_topn_rows_quant_ref(queries_q, q_scale,
                                         neighbor_rows_q, n_scale, alpha,
                                         topn)


@functools.partial(jax.jit, static_argnames=("alpha", "topn", "interpret"))
def _blend_rows_quant_pallas_ids(queries_q: jax.Array, q_scale: jax.Array,
                                 neighbor_rows_q: jax.Array,
                                 n_scale: jax.Array, alpha: float,
                                 topn: int,
                                 interpret: bool) -> jax.Array:
    return _blend_rows_quant_pallas(queries_q, q_scale, neighbor_rows_q,
                                    n_scale, alpha=alpha, topn=topn,
                                    interpret=interpret)[1]


def blend_topn_rows_quant(queries_q: jax.Array, q_scale: jax.Array,
                          neighbor_rows_q: jax.Array, n_scale: jax.Array,
                          alpha: float, topn: int,
                          impl: str | None = None) -> jax.Array:
    """Quantized cross-shard final stage: int8 rows [Q, k, I] → top-n.

    The int8 twin of :func:`blend_topn_rows`: the k fetched rows cross
    the wire quantized (¼ the fp32 bytes) with per-row scales and are
    dequantized on-chip.  impl: auto | pallas | interpret | ref.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _blend_rows_quant_ref(queries_q, q_scale, neighbor_rows_q,
                                     n_scale, alpha, topn=topn)
    return _blend_rows_quant_pallas_ids(
        queries_q, q_scale, neighbor_rows_q, n_scale, alpha=float(alpha),
        topn=topn, interpret=(impl == "interpret" or not _on_tpu()))


def serving_cache_size() -> int:
    """Number of live compiled programs behind the serving entry points.

    One program per distinct (impl, request-batch bucket, corpus shape,
    static-arg) combination — the engine-side pow2 request bucketing
    (`StreamingEngine.recommend`) exists to keep this O(log Q);
    `launch/serve.py` prints it so a bucketing regression is visible
    from the CLI.
    """
    return sum(f._cache_size() for f in (
        _fused_recommend_ref, _fused_recommend_pallas,
        _fused_recommend_dtiled_ref, _fused_recommend_dtiled_pallas,
        _fused_recommend_quant_ref, _fused_recommend_quant_pallas,
        _shard_topk_ref, _shard_topk_pallas,
        _shard_topk_quant_ref, _shard_topk_quant_pallas,
        _blend_rows_ref, _blend_rows_pallas,
        _blend_rows_quant_ref, _blend_rows_quant_pallas_ids))


def stage_a_vmem_bytes(d: int, k: int, bq: int = 128, bm: int = 512,
                       bd: int | None = None,
                       itemsize: int = 4) -> int:
    """Analytic peak VMEM residency (bytes) of one stage-A grid step.

    Re-exported from :mod:`repro.analysis.vmem`, which owns this
    capacity-planning model alongside the exact per-kernel block models
    the contract linter budgets against (DESIGN.md §10.2); see
    :func:`repro.analysis.vmem.stage_a_vmem_bytes` for the full model
    notes.  Kept as a function (not an alias) so the signature stays in
    this module's API docs.
    """
    return _analysis_vmem.stage_a_vmem_bytes(d, k, bq=bq, bm=bm, bd=bd,
                                             itemsize=itemsize)


def multihot_scatter(ids: jax.Array, weights: jax.Array, n_items: int,
                     impl: str | None = None) -> jax.Array:
    """Weighted multi-hot scatter (the Eq. 1+2 from-scratch builder).

    One decayed-average user/group vector per call: O(N·B) input ids
    against an [n_items] output (DESIGN.md §3.1).
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.decayed_scatter_ref(ids, weights, n_items)
    if ids.ndim == 3:
        return batched_decayed_scatter(ids, weights, n_items,
                                       interpret=(impl == "interpret"
                                                  or not _on_tpu()))
    return decayed_scatter(ids, weights, n_items,
                           interpret=(impl == "interpret" or not _on_tpu()))


def plan_bi(n_items: int) -> int | None:
    """Item-tile width for the tile-planned kernels, or None.

    The largest lane-aligned tile (512/256/128) dividing ``n_items``;
    None means the Pallas path falls back to the XLA reference.  Public
    so hint producers (the streaming engine's host-measured ``T_max``,
    DESIGN.md §3.3) bucket ids with the same tile width the kernels use.
    """
    for bi in (512, 256, 128):
        if n_items % bi == 0:
            return bi
    return None


def _plan_dims(n_items: int, ids: jax.Array,
               t_max_cap: int = 0) -> Tuple[int, int] | None:
    """(bi, t_max) for the tile-planned kernels, or None → ref fallback.

    ``bi`` is the largest lane-aligned tile dividing ``n_items``;
    ``t_max`` is the static per-row touched-tile bound.  When ``ids`` is
    concrete (benchmark / direct calls outside jit) the true maximum is
    measured on host and pow2-bucketed — typical baskets touch only a
    few tiles, so the grid shrinks far below the ``min(W, I/bi)`` worst
    case that tracers must otherwise assume.  Under jit, a caller-
    supplied ``t_max_cap`` (the engine's host-measured bound, threaded
    through the batch appliers as a static arg) shrinks the tracer-side
    grid the same way; 0 means no hint.
    """
    bi = plan_bi(n_items)
    if bi is None:
        return None
    w = ids.shape[1]
    cap = max(1, min(w, n_items // bi))
    if isinstance(ids, jax.core.Tracer):
        return bi, (max(1, min(cap, t_max_cap)) if t_max_cap else cap)
    from repro.core.types import _pow2_pad
    return bi, min(_pow2_pad(tile_plan.max_touched_tiles(ids, bi)), cap)


def sparse_row_scatter(table: jax.Array, rows: jax.Array, ids: jax.Array,
                       vals: jax.Array, impl: str | None = None,
                       t_max_cap: int = 0) -> jax.Array:
    """Sparse per-row scatter-add into a [M, I] table (add-path deltas).

    XLA's native scatter is already O(U·W) on CPU/GPU; the tile-planned
    Pallas kernel is the TPU path (DMAs only the dirty tiles of the
    touched rows, in place — O(U·W) HBM traffic too).  ``t_max_cap``
    (optional, static) is a host-measured upper bound on per-row touched
    tiles that shrinks the kernel grid under jit; it MUST be sound (>=
    the true maximum) — the plan truncates beyond it.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.sparse_row_scatter_ref(table, rows, ids, vals)
    dims = _plan_dims(table.shape[1], ids, t_max_cap)
    if dims is None:
        return ref.sparse_row_scatter_ref(table, rows, ids, vals)
    bi, t_max = dims
    return _sparse_scatter_pallas(
        table, rows, ids, vals, bi=bi, t_max=t_max,
        interpret=(impl == "interpret" or not _on_tpu()))


def sparse_row_gather(table: jax.Array, rows: jax.Array, ids: jax.Array,
                      impl: str | None = None,
                      t_max_cap: int = 0) -> jax.Array:
    """Sparse per-row gather from a [M, I] table (update-path supports).

    XLA's native gather is already O(U·W) on CPU/GPU; the tile-planned
    Pallas kernel is the TPU path (DMAs only the touched rows' dirty
    tiles — O(U·W) HBM traffic too).  ``t_max_cap`` as in
    :func:`sparse_row_scatter`.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.sparse_row_gather_ref(table, rows, ids)
    dims = _plan_dims(table.shape[1], ids, t_max_cap)
    if dims is None:
        return ref.sparse_row_gather_ref(table, rows, ids)
    bi, t_max = dims
    return _sparse_gather_pallas(
        table, rows, ids, bi=bi, t_max=t_max,
        interpret=(impl == "interpret" or not _on_tpu()))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    impl: str | None = None, **kw: Any) -> jax.Array:
    """Blocked attention: [B,S,H,D] each → [B,S,H,D].

    O(S²·D) compute with O(S·D) memory (never an [S, S] score matrix in
    HBM); serves the LM stack, not the TIFU maintenance path.
    """
    impl = _resolve(impl)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal, window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                        interpret=(impl == "interpret" or not _on_tpu()),
                        **kw)
