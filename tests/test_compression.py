"""Gradient compression: quantization error bounds, error-feedback
unbiasedness over steps, hierarchical reduction parity."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (compress_with_feedback, decompress,
                                     dequantize_int8, init_error_feedback,
                                     quantize_int8)


def test_quantize_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(256,)) * 3.0, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.max(np.abs(np.asarray(dequantize_int8(q, scale) - x)))
    assert err <= float(scale) / 2 + 1e-7


def test_error_feedback_is_unbiased_over_steps(rng):
    """Σ decoded_t ≈ Σ g_t (the residual carries the rounding error)."""
    grads = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = init_error_feedback(grads)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for t in range(30):
        g = {"w": jnp.asarray(rng.normal(size=(64,)) * 0.01, jnp.float32)}
        q, scales, err = compress_with_feedback(g, err)
        dec = decompress(q, scales)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(dec["w"])
    # residual bound: remaining error ≤ last quantization step size
    resid = np.max(np.abs(total_true - total_sent))
    assert resid <= float(scales["w"]) + 1e-6


def test_hierarchical_psum_matches_plain():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
from repro import compat
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compression import hierarchical_psum_mean
mesh = jax.make_mesh((2, 4), ("pod", "data"))
x = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) * 0.01

def body(xl):
    out, _ = hierarchical_psum_mean(xl[0], "data", "pod", err=None)
    return out[None]

with compat.set_mesh(mesh):
    out = jax.jit(compat.shard_map(body, mesh=mesh,
                                in_specs=P(("pod", "data"), None),
                                out_specs=P(("pod", "data"), None),
                                check_vma=False))(x)
expect = np.mean(np.asarray(x), axis=0)
got = np.asarray(out)
for row in got:
    np.testing.assert_allclose(row, expect, rtol=2e-2, atol=1e-3)
print("HIER_OK")
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=580,
                       cwd="/root/repo")
    assert "HIER_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
