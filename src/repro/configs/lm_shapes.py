"""Shared LM shape-cell definitions (assignment: train_4k / prefill_32k /
decode_32k / long_500k)."""
from repro.configs.base import lm_decode_cell, lm_prefill_cell, lm_train_cell

TRAIN_4K = dict(seq=4096, global_batch=256)
PREFILL_32K = dict(seq=32768, global_batch=32)
DECODE_32K = dict(cache=32768, global_batch=128)
LONG_500K = dict(cache=524288, global_batch=1)


def standard_lm_cells(make_config, optimizer="adamw"):
    return {
        "train_4k": lm_train_cell(make_config, TRAIN_4K["global_batch"],
                                  TRAIN_4K["seq"], optimizer),
        "prefill_32k": lm_prefill_cell(make_config,
                                       PREFILL_32K["global_batch"],
                                       PREFILL_32K["seq"]),
        "decode_32k": lm_decode_cell(make_config, DECODE_32K["global_batch"],
                                     DECODE_32K["cache"]),
        # long_500k lowers ONE decode step against a 512k-token KV cache —
        # O(S), runnable for every arch. A 500k PREFILL would be quadratic
        # and is only feasible for sliding-window archs (gemma3); see
        # DESIGN.md §4 for the per-arch notes.
        "long_500k": lm_decode_cell(make_config, LONG_500K["global_batch"],
                                    LONG_500K["cache"]),
    }
