"""Batched JAX incremental/decremental updates (the TPU production path).

Kind-partitioned micro-batches (DESIGN.md §4): the streaming engine
splits each micro-batch into homogeneous sub-batches and each entry
point runs exactly one update rule:

  * ``apply_add_batch``        — Eq. 7-9, **sparse deltas**: O(batch·W)
    data touches the [M, I] state (W = (group_size+1)·max_basket_size),
    never an [n_items] temporary.  Matches the paper's O(1)-per-add
    asymptotic on the batched path (DESIGN.md §3.3).
  * ``apply_del_basket_batch`` — Eq. 10-12, **sparse deltas**: the
    suffix contractions expand to per-history-slot coefficients, so
    O(batch · N·B) data touches the [M, I] state (the history window),
    never an [n_items] temporary; the Eq. 12 whole-vector rescale folds
    into ``uv_scale`` (DESIGN.md §3.5).
  * ``apply_del_item_batch``   — Eq. 13 + basket-vanish fallback, same
    sparse treatment (the in-place branch touches ONE cell per table).

``apply_update_batch`` keeps the mixed-batch signature by partitioning
on the host; ``apply_update_batch_dense`` is the seed's
compute-all-kinds-and-select implementation, retained as the benchmark
baseline (benchmarks/bench_update_batch.py) and as a second oracle, and
``apply_del_*_batch_dense`` are the homogeneous dense decremental
baselines the sparse paths are validated and benchmarked against.

Design notes (DESIGN.md §3.2): the variable-length suffix contractions of
Eq. 10/12 are computed as *masked fixed-shape* weighted multi-hot
scatters using the closed-form coefficient expansion in
``decay.batched_suffix_coefficients`` — no data-dependent shapes, so one
compiled program serves every deletion position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decay
from repro.core.tifu import (last_group_vector_padded,
                             weighted_multihot_scatter, user_vector_padded)
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM,
                              KIND_NOOP, PAD_ID, AddBatch, DelBasketBatch,
                              DelItemBatch, StreamState, TifuParams,
                              UpdateBatch)
from repro.kernels.ops import sparse_row_gather, sparse_row_scatter

# Adds only shrink the scales (each new group multiplies uv_scale by
# k·r_g/(k+1), each append multiplies lgv_scale by tau·r_b/(tau+1));
# sparse Eq. 12 deletions GROW uv_scale by k/((k-1)·r_g) > 1.  Fold the
# scales back into the raw rows before float32 precision suffers on
# either side: 1e-18 keeps raw magnitudes <= ~1e18 (hit only after
# hundreds of group openings per user), SCALE_CEIL bounds the growth
# symmetrically (hundreds of single-basket-group deletions).
SCALE_FLOOR = 1e-18
SCALE_CEIL = 1e18


# ---------------------------------------------------------------------------
# Helpers on padded per-user state
# ---------------------------------------------------------------------------

def _multi_hot(items, n_items):
    """Multi-hot encode a basket: i32[B] (PAD_ID padded) → f32[I].

    Set semantics (duplicate ids count once), matching
    ``tifu.multi_hot`` and the sparse add path's first-occurrence dedup.
    """
    valid = items >= 0
    ids = jnp.where(valid, items, 0)
    return jnp.zeros((n_items,), jnp.float32).at[ids].max(
        valid.astype(jnp.float32))


def _row_group_geometry(group_sizes, max_baskets):
    """Locate every history row in its group.

    Returns per-row group index g (0-based), in-group position p
    (1-based) and group size tau, for fixed ``max_baskets`` rows.
    """
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    starts = ends - sizes
    t = jnp.arange(max_baskets)
    g = jnp.clip(jnp.searchsorted(ends, t, side="right"), 0,
                 sizes.shape[0] - 1)
    tau = sizes[g]
    p = t - starts[g] + 1
    return g, p, tau


def _locate(group_sizes, pos):
    """Locate a global basket index inside the group structure.

    Returns group index j (0-based) and in-group position i (1-based)
    of basket ``pos`` (traced).
    """
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    starts = ends - sizes
    j = jnp.clip(jnp.searchsorted(ends, pos, side="right"), 0,
                 sizes.shape[0] - 1)
    i = pos - starts[j] + 1
    return j, i


# ---------------------------------------------------------------------------
# Single-user updates (to be vmapped)
# ---------------------------------------------------------------------------

def _add_basket(user_vec, last_group_vec, history, group_sizes, n_baskets,
                n_groups, err_mult, items, params: TifuParams):
    n_items = user_vec.shape[0]
    v_b = _multi_hot(items, n_items).astype(user_vec.dtype)
    k = n_groups
    tau = jnp.where(k > 0, group_sizes[jnp.maximum(k - 1, 0)], 0)
    new_group = (k == 0) | (tau >= params.group_size)

    # Scenario 1 (Eq. 7): new single-basket group.
    user_new_a = (k * params.r_g * user_vec + v_b) / (k + 1)
    lgv_a = v_b
    sizes_a = group_sizes.at[jnp.minimum(k, group_sizes.shape[0] - 1)].set(1)
    err_a = jnp.maximum(
        err_mult * jnp.where(k > 0, decay.error_shrink_factor(k, params.r_g),
                             0.0), 1e-30)

    # Scenario 2 (Eq. 8 + Eq. 9): append to the last group.
    safe_tau = jnp.maximum(tau, 1)
    lgv_b = (safe_tau * params.r_b * last_group_vec + v_b) / (safe_tau + 1)
    user_new_b = user_vec + (lgv_b - last_group_vec) / jnp.maximum(k, 1)
    sizes_b = group_sizes.at[jnp.maximum(k - 1, 0)].add(1)
    err_b = err_mult

    user_vec = jnp.where(new_group, user_new_a, user_new_b)
    last_group_vec = jnp.where(new_group, lgv_a, lgv_b)
    group_sizes = jnp.where(new_group, sizes_a, sizes_b)
    err_mult = jnp.where(new_group, err_a, err_b)
    history = history.at[jnp.minimum(n_baskets, history.shape[0] - 1)].set(items)
    return (user_vec, last_group_vec, history, group_sizes, n_baskets + 1,
            n_groups + new_group.astype(jnp.int32), err_mult)


def _delete_basket(user_vec, last_group_vec, history, group_sizes, n_baskets,
                   n_groups, err_mult, pos, params: TifuParams):
    n_items = user_vec.shape[0]
    max_baskets = history.shape[0]
    k = n_groups
    j, i = _locate(group_sizes, pos)
    tau_j = group_sizes[j]
    g, p, tau = _row_group_geometry(group_sizes, max_baskets)
    t = jnp.arange(max_baskets)
    valid_row = t < n_baskets
    in_group_j = valid_row & (g == j)
    f32 = user_vec.dtype

    # ---- Scenario 1 (Eq. 10 + Eq. 11): tau_j > 1 -------------------------
    safe_tau = jnp.maximum(tau_j, 2)
    # recompute v_gj from the group's rows (O(tau) real work, masked here)
    w_gj = jnp.where(in_group_j,
                     jnp.asarray(params.r_b, f32) ** (tau_j - p)
                     / jnp.maximum(tau_j, 1).astype(f32), 0.0)
    v_gj = weighted_multihot_scatter(history, w_gj, n_items).astype(f32)
    # suffix coefficients inside group j, positions p >= i
    pow_tp = jnp.asarray(params.r_b, f32) ** (tau_j - p)
    c_row = jnp.where(p == i, -pow_tp, pow_tp * (params.r_b - 1.0))
    c_row = jnp.where(in_group_j & (p >= i), c_row, 0.0)
    suffix_g = weighted_multihot_scatter(history, c_row, n_items).astype(f32)
    v_gj_new = (tau_j * v_gj + suffix_g) / ((safe_tau - 1) * params.r_b)
    user_s1 = user_vec + (jnp.asarray(params.r_g, f32) ** (k - 1 - j)
                          * (v_gj_new - v_gj) / jnp.maximum(k, 1))
    sizes_s1 = group_sizes.at[j].add(-1)
    groups_s1 = k

    # ---- Scenario 2 (Eq. 12): tau_j == 1, k > 1 ---------------------------
    # suffix over group vectors j..k-1, expanded to per-basket weights:
    # coeff per group c_g (1-based group pos = g+1), times within-group
    # decayed-average weight r_b^(tau-p)/tau.
    cg = decay.batched_suffix_coefficients(k, j + 1,
                                           jnp.asarray(params.r_g, f32),
                                           group_sizes.shape[0]).astype(f32)
    w_row_s2 = jnp.where(valid_row,
                         cg[g] * jnp.asarray(params.r_b, f32) ** (tau - p)
                         / jnp.maximum(tau, 1).astype(f32), 0.0)
    suffix_u = weighted_multihot_scatter(history, w_row_s2, n_items).astype(f32)
    safe_k = jnp.maximum(k, 2)
    user_s2 = (k * user_vec + suffix_u) / ((safe_k - 1) * params.r_g)
    sizes_s2 = _remove_entry(group_sizes, j)
    groups_s2 = k - 1
    err_s2 = err_mult * decay.error_growth_factor(safe_k.astype(f32),
                                                  params.r_g)

    # ---- Scenario 3: tau_j == 1 and k == 1 → empty state ------------------
    user_s3 = jnp.zeros_like(user_vec)
    sizes_s3 = jnp.zeros_like(group_sizes)
    groups_s3 = jnp.zeros_like(k)

    single = tau_j == 1
    last = k == 1
    user_vec = jnp.where(single, jnp.where(last, user_s3, user_s2), user_s1)
    group_sizes = jnp.where(single, jnp.where(last, sizes_s3, sizes_s2),
                            sizes_s1)
    n_groups = jnp.where(single, jnp.where(last, groups_s3, groups_s2),
                         groups_s1)
    err_mult = jnp.where(single, jnp.where(last, jnp.ones_like(err_mult),
                                           err_s2), err_mult)

    # ---- history compaction: shift rows > pos up by one --------------------
    src = jnp.where(t >= pos, jnp.minimum(t + 1, max_baskets - 1), t)
    history = history[src]
    history = history.at[jnp.maximum(n_baskets - 1, 0)].set(
        jnp.full((history.shape[1],), PAD_ID, jnp.int32))
    n_baskets = n_baskets - 1

    # last_group_vec: recompute from the new geometry (cheap, masked).
    last_group_vec = last_group_vector_padded(
        history, group_sizes, n_groups,
        params).astype(f32)
    return (user_vec, last_group_vec, history, group_sizes, n_baskets,
            n_groups, err_mult)


def _remove_entry(sizes, j):
    """Remove entry j from a padded i32 vector (shift left, zero-fill)."""
    n = sizes.shape[0]
    t = jnp.arange(n)
    src = jnp.where(t >= j, jnp.minimum(t + 1, n - 1), t)
    out = sizes[src]
    return out.at[n - 1].set(jnp.where(j <= n - 1, 0, out[n - 1]))


def _delete_item(user_vec, last_group_vec, history, group_sizes, n_baskets,
                 n_groups, err_mult, pos, item, params: TifuParams):
    """Scenario 3 of §4.3 (Eq. 13 + Eq. 11) with basket-vanish fallback."""
    n_items = user_vec.shape[0]
    f32 = user_vec.dtype
    row = history[pos]
    present = jnp.any(row == item)
    blen = jnp.sum(row >= 0)
    vanish = present & (blen == 1)

    # --- Eq. 13 path: remove the item from the basket in place -------------
    j, i = _locate(group_sizes, pos)
    k = n_groups
    tau_j = jnp.maximum(group_sizes[j], 1)
    delta = -_multi_hot(jnp.array([item]), n_items).astype(f32)
    scale_g = jnp.asarray(params.r_b, f32) ** (tau_j - i) / tau_j
    dg = scale_g * delta                       # v'_gj - v_gj
    user_ip = user_vec + (jnp.asarray(params.r_g, f32) ** (k - 1 - j)
                          * dg / jnp.maximum(k, 1))
    lgv_ip = jnp.where(j == k - 1, last_group_vec + dg, last_group_vec)
    new_row = jnp.where(row == item, PAD_ID, row)
    hist_ip = history.at[pos].set(new_row)

    # --- fallback: basket vanishes → full basket deletion -------------------
    (user_db, lgv_db, hist_db, sizes_db, nb_db, ng_db, err_db) = \
        _delete_basket(user_vec, last_group_vec, history, group_sizes,
                       n_baskets, n_groups, err_mult, pos, params)

    apply_ip = present & ~vanish
    apply_db = vanish
    user_vec = jnp.where(apply_ip, user_ip,
                         jnp.where(apply_db, user_db, user_vec))
    last_group_vec = jnp.where(apply_ip, lgv_ip,
                               jnp.where(apply_db, lgv_db, last_group_vec))
    history = jnp.where(apply_ip, hist_ip,
                        jnp.where(apply_db, hist_db, history))
    group_sizes = jnp.where(apply_db, sizes_db, group_sizes)
    n_baskets = jnp.where(apply_db, nb_db, n_baskets)
    n_groups = jnp.where(apply_db, ng_db, n_groups)
    err_mult = jnp.where(apply_db, err_db, err_mult)
    return (user_vec, last_group_vec, history, group_sizes, n_baskets,
            n_groups, err_mult)


def _single_update(user_vec, last_group_vec, history, group_sizes, n_baskets,
                   n_groups, err_mult, kind, items, pos, item,
                   params: TifuParams):
    """Dispatch one update (Algorithm 1 generalised to 4 kinds)."""
    state = (user_vec, last_group_vec, history, group_sizes, n_baskets,
             n_groups, err_mult)
    add = _add_basket(*state, items, params)
    # guard delete positions for noop/add rows so gathers stay in-bounds
    safe_pos = jnp.clip(pos, 0, jnp.maximum(n_baskets - 1, 0))
    delb = _delete_basket(*state, safe_pos, params)
    deli = _delete_item(*state, safe_pos, item, params)

    def _sel(a, b, c, d):
        return jnp.where(kind == KIND_ADD_BASKET, b,
                         jnp.where(kind == KIND_DEL_BASKET, c,
                                   jnp.where(kind == KIND_DEL_ITEM, d, a)))

    # suppress deletes on empty histories (no-op)
    empty = n_baskets == 0
    kind = jnp.where(empty & ((kind == KIND_DEL_BASKET)
                              | (kind == KIND_DEL_ITEM)), KIND_NOOP, kind)
    return tuple(_sel(s, a, b, c)
                 for s, a, b, c in zip(state, add, delb, deli))


# ---------------------------------------------------------------------------
# Sparse-delta add path (Eq. 7-9, DESIGN.md §3.3)
# ---------------------------------------------------------------------------

def _capacity_mask(nb, k, tau, max_baskets, max_groups, group_size):
    """Mask adds that would overflow the padded history/group arrays.

    The single source of truth for apply_add_batch's no-op guard and
    the engine's dropped_adds metric.
    """
    new_group = (k == 0) | (tau >= group_size)
    return (nb >= max_baskets) | (new_group & (k >= max_groups))


def _first_occurrence(ids):
    """Pick one representative slot per distinct non-PAD id per row.

    Returns bool[U, W], True on exactly one slot per distinct id
    (set-semantics dedup inside the support window).  Sort-based —
    O(U·W·logW), no [U, W, W] pairwise intermediate; any representative
    slot works because every consumer scatters a value that depends only
    on the id, not the slot.
    """
    u, w = ids.shape
    order = jnp.argsort(ids, axis=1)
    sorted_ids = jnp.take_along_axis(ids, order, axis=1)
    first_sorted = jnp.concatenate(
        [jnp.ones((u, 1), bool),
         sorted_ids[:, 1:] != sorted_ids[:, :-1]], axis=1)
    first = jnp.zeros((u, w), bool).at[
        jnp.arange(u)[:, None], order].set(first_sorted)
    return (ids >= 0) & first


def _apply_add_batch(state: StreamState, batch: AddBatch,
                     params: TifuParams, t_max_cap: int = 0):
    """Apply a homogeneous basket-addition sub-batch with sparse deltas.

    The support of one addition is the new basket plus the last group's
    items (the only vectors Eq. 7-9 touch); everything else is a per-user
    *scalar*: the Eq. 7 rescale ``k·r_g/(k+1)`` and the Eq. 8 rescale
    ``tau·r_b/(tau+1)`` multiply ``uv_scale``/``lgv_scale`` instead of the
    [n_items] rows.  No [batch, n_items] gather or scatter anywhere —
    total state traffic is O(batch · (group_size+1) · max_basket_size).

    INVARIANT (streaming.engine): each user appears at most once among
    valid rows; padding rows carry zero deltas / unit factors and may
    alias any user.
    """
    u = batch.user
    n_bask, bh = state.max_baskets, state.max_basket_size
    kmax = state.max_groups
    m = params.group_size
    f32 = state.user_vecs.dtype

    # --- per-row scalars -----------------------------------------------------
    k = state.n_groups[u]                              # [U]
    nb = state.n_baskets[u]
    s = state.uv_scale[u]
    sig = state.lgv_scale[u]
    em = state.err_mult[u]
    tau = jnp.where(k > 0, state.group_sizes[u, jnp.maximum(k - 1, 0)], 0)
    new_group = (k == 0) | (tau >= m)
    # Capacity guard: a full history row is NOT all-PAD, so the sparse
    # history write below would corrupt it (and group_sizes at k == kmax).
    # Adds to full users are no-ops; the engine sizes N/K so real traffic
    # never hits this (deletions free rows) and surfaces drops via
    # apply_add_batch_counted.
    at_capacity = _capacity_mask(nb, k, tau, n_bask, kmax, m)
    valid = batch.valid & ~at_capacity
    items = jnp.where(valid[:, None], batch.items, PAD_ID)
    kf = jnp.maximum(k, 1).astype(f32)
    tauf = tau.astype(f32)
    r_b = jnp.asarray(params.r_b, f32)
    r_g = jnp.asarray(params.r_g, f32)

    # --- sparse support: last group's history rows + the new basket ----------
    start = nb - tau                                   # [U]
    row_t = jnp.arange(m)[None, :]                     # [1, m]
    rows_valid = (row_t < tau[:, None]) & (k > 0)[:, None] \
        & valid[:, None]
    grp_rows = jnp.clip(start[:, None] + row_t, 0, n_bask - 1)
    old_ids = state.history[u[:, None], grp_rows]      # [U, m, Bh]
    old_ids = jnp.where(rows_valid[:, :, None], old_ids,
                        PAD_ID).reshape(u.shape[0], m * bh)
    ids_all = jnp.concatenate([old_ids, items], axis=1)     # [U, W]
    first = _first_occurrence(ids_all)
    bfirst = _first_occurrence(items)                       # [U, Bb]
    zeros_old = jnp.zeros(old_ids.shape, f32)

    # gather the true last-group values on the support (O(U·W), sparse;
    # PAD ids read 0, which the `first` mask already zeroes downstream)
    lraw = sparse_row_gather(state.last_group_vecs, u, ids_all,
                             t_max_cap=t_max_cap)
    ltrue = lraw * sig[:, None]

    # --- scale updates (the dense part of Eq. 7/8, now scalar) ---------------
    s_ratio = jnp.where(new_group & (k > 0),
                        kf * r_g / (kf + 1.0), 1.0)    # k==0: s unchanged
    s_new = s * s_ratio
    sig_ratio = jnp.where(new_group, 1.0 / sig,        # reset sigma' = 1
                          tauf * r_b / (jnp.maximum(tauf, 1.0) + 1.0))
    sig_ratio = jnp.where(valid, sig_ratio, 1.0)
    sig_new = sig * sig_ratio

    # --- sparse deltas into the raw user rows --------------------------------
    # Scenario 2 (Eq. 8+9): u' = u + (lgv' - lgv)/k with
    # lgv' - lgv = (alpha-1)·lgv + beta·v_b, alpha = tau·r_b/(tau+1).
    alpha = tauf * r_b / (tauf + 1.0)
    beta = 1.0 / (tauf + 1.0)
    l_part = jnp.where(new_group[:, None], 0.0,
                       first * (alpha - 1.0)[:, None] * ltrue
                       / (kf * s)[:, None])
    # Scenario 1 (Eq. 7): u' = (k·r_g·u + v_b)/(k+1); the rescale lives in
    # s_new, the sparse part is v_b/((k+1)·s_new).
    b_coeff = jnp.where(new_group, 1.0 / ((kf * (k > 0) + 1.0) * s_new),
                        beta / (kf * s))
    user_vals = l_part + jnp.concatenate(
        [zeros_old, bfirst * b_coeff[:, None]], axis=1)

    # --- sparse deltas into the raw last-group rows --------------------------
    # Scenario 1 resets lgv to v_b: subtract the old raw values on their
    # support (exact zeroing) and add 1/sig_new at the basket ids.
    # Scenario 2 appends: add v_b/((tau+1)·sig_new) at the basket ids.
    lgv_reset = first * (-lraw) + jnp.concatenate(
        [zeros_old, bfirst / sig_new[:, None]], axis=1)
    lgv_append = jnp.concatenate(
        [zeros_old, bfirst / ((tauf + 1.0) * sig_new)[:, None]], axis=1)
    lgv_vals = jnp.where(new_group[:, None], lgv_reset, lgv_append)

    user_vecs = sparse_row_scatter(state.user_vecs, u, ids_all, user_vals,
                                   t_max_cap=t_max_cap)
    lg_vecs = sparse_row_scatter(state.last_group_vecs, u, ids_all, lgv_vals,
                                 t_max_cap=t_max_cap)

    # --- per-row scalar/bookkeeping scatters (no [batch, N, B] dense delta) --
    valid_i = valid.astype(jnp.int32)
    err_new = jnp.maximum(
        em * jnp.where(k > 0, decay.error_shrink_factor(kf, params.r_g),
                       0.0), 1e-30)
    err_ratio = jnp.where(valid & new_group, err_new / em, 1.0)
    gs_slot = jnp.where(new_group, jnp.minimum(k, kmax - 1),
                        jnp.maximum(k - 1, 0))
    hist_slot = jnp.minimum(nb, n_bask - 1)
    # the target history row is all PAD (-1); adding (item - PAD) writes
    # the basket without a dense [batch, N, B] delta block.
    hist_delta = jnp.where(valid[:, None], items - PAD_ID, 0)

    dropped = jnp.sum((at_capacity & batch.valid).astype(jnp.int32))
    return StreamState(
        user_vecs=user_vecs,
        last_group_vecs=lg_vecs,
        history=state.history.at[u, hist_slot].add(hist_delta),
        group_sizes=state.group_sizes.at[u, gs_slot].add(valid_i),
        n_baskets=state.n_baskets.at[u].add(valid_i),
        n_groups=state.n_groups.at[u].add(valid_i
                                          * new_group.astype(jnp.int32)),
        err_mult=state.err_mult.at[u].multiply(err_ratio),
        uv_scale=state.uv_scale.at[u].multiply(
            jnp.where(valid, s_ratio, 1.0)),
        lgv_scale=state.lgv_scale.at[u].multiply(sig_ratio),
    ), dropped


@functools.partial(jax.jit, static_argnames=("params", "t_max_cap"),
                   donate_argnums=(0,))
def apply_add_batch(state: StreamState, batch: AddBatch,
                    params: TifuParams, t_max_cap: int = 0) -> StreamState:
    """Apply a homogeneous basket-addition sub-batch with sparse deltas.

    Eq. 7–9 under the scaled representation: O(batch · W) state traffic
    (W = (group_size+1) · max_basket_size), never an [n_items]
    temporary — the paper's O(1)-per-add asymptotic on the batched path
    (see ``_apply_add_batch`` for the full derivation; the drop count is
    dead-code-eliminated here).  ``t_max_cap`` (static) is the engine's
    host-measured touched-tile bound, forwarded to the sparse kernels
    (DESIGN.md §3.3); 0 disables.
    """
    return _apply_add_batch(state, batch, params, t_max_cap)[0]


@functools.partial(jax.jit, static_argnames=("params", "t_max_cap"),
                   donate_argnums=(0,))
def apply_add_batch_counted(state: StreamState, batch: AddBatch,
                            params: TifuParams, t_max_cap: int = 0):
    """As ``apply_add_batch`` (Eq. 7–9, O(batch · W)), counting drops.

    Returns ``(state, dropped)`` where ``dropped`` is the number of
    valid rows the capacity guard masked to no-ops (i32 scalar) — one
    fused program, so the engine's dropped_adds metric costs no extra
    dispatch.
    """
    return _apply_add_batch(state, batch, params, t_max_cap)


# ---------------------------------------------------------------------------
# Dense masked decremental sub-batches (their support IS the history)
# ---------------------------------------------------------------------------

def _gather_true(state: StreamState, u):
    """Gather per-user state rows with scales folded in (true values)."""
    s = state.uv_scale[u]
    sig = state.lgv_scale[u]
    return (state.user_vecs[u] * s[:, None],
            state.last_group_vecs[u] * sig[:, None],
            state.history[u], state.group_sizes[u], state.n_baskets[u],
            state.n_groups[u], state.err_mult[u], s, sig)


def _scatter_del_deltas(state: StreamState, u, valid, old, new):
    """Write masked true-value deltas back into the scaled raw storage.

    Raw deltas are divided by the (unchanged) per-user scales; invalid
    rows carry zero deltas, so padding rows may alias any user.  The
    last-group raw row is *set* to new_true/sigma (its support after a
    deletion is recomputed from history, DESIGN.md §3.3 invariant).
    """
    uv, lgv, hist, gs, nb, ng, em, s, sig = old
    n_uv, n_lgv, n_hist, n_gs, n_nb, n_ng, n_em = new
    vf = valid[:, None]
    duv = jnp.where(vf, (n_uv - uv) / s[:, None], 0.0)
    # lgv raw' = new_true/sigma (support re-derived from history)
    dlgv = jnp.where(vf, n_lgv / sig[:, None] - state.last_group_vecs[u],
                     0.0)
    return StreamState(
        user_vecs=state.user_vecs.at[u].add(duv),
        last_group_vecs=state.last_group_vecs.at[u].add(dlgv),
        history=state.history.at[u].add(
            jnp.where(valid[:, None, None], n_hist - hist, 0)),
        group_sizes=state.group_sizes.at[u].add(
            jnp.where(valid[:, None], n_gs - gs, 0)),
        n_baskets=state.n_baskets.at[u].add(jnp.where(valid, n_nb - nb, 0)),
        n_groups=state.n_groups.at[u].add(jnp.where(valid, n_ng - ng, 0)),
        err_mult=state.err_mult.at[u].multiply(
            jnp.where(valid, n_em / em, 1.0)),
        uv_scale=state.uv_scale,
        lgv_scale=state.lgv_scale,
    )


@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def apply_del_basket_batch_dense(state: StreamState, batch: DelBasketBatch,
                                 params: TifuParams) -> StreamState:
    """Apply a homogeneous basket-deletion sub-batch (Eq. 10-12), densely.

    Dense masked per-user rows: gathers [batch, n_items] state rows and
    writes dense deltas — O(batch · n_items) state traffic.  Retained as
    the correctness baseline and the benchmark baseline for the sparse
    path (``apply_del_basket_batch``, DESIGN.md §3.5), which touches
    only the history-window support.
    """
    u = batch.user
    old = _gather_true(state, u)
    uv, lgv, hist, gs, nb, ng, em = old[:7]
    valid = batch.valid & (nb > 0)
    safe_pos = jnp.clip(batch.pos, 0, jnp.maximum(nb - 1, 0))
    new = jax.vmap(
        lambda *a: _delete_basket(*a, params))(uv, lgv, hist, gs, nb, ng,
                                               em, safe_pos)
    return _scatter_del_deltas(state, u, valid, old, new)


@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def apply_del_item_batch_dense(state: StreamState, batch: DelItemBatch,
                               params: TifuParams) -> StreamState:
    """Apply a homogeneous item-deletion sub-batch, densely.

    Eq. 13 + the basket-vanish fallback on dense [batch, n_items] rows —
    O(batch · n_items) state traffic; the correctness/benchmark baseline
    of the sparse path (``apply_del_item_batch``).
    """
    u = batch.user
    old = _gather_true(state, u)
    uv, lgv, hist, gs, nb, ng, em = old[:7]
    valid = batch.valid & (nb > 0)
    safe_pos = jnp.clip(batch.pos, 0, jnp.maximum(nb - 1, 0))
    new = jax.vmap(
        lambda *a: _delete_item(*a, params))(uv, lgv, hist, gs, nb, ng, em,
                                             safe_pos, batch.item)
    return _scatter_del_deltas(state, u, valid, old, new)


# ---------------------------------------------------------------------------
# Sparse decremental sub-batches (Eq. 10-13, DESIGN.md §3.5)
# ---------------------------------------------------------------------------
#
# The paper's decremental cost is linear in the surviving history, and the
# history's item support is at most N·B ids — orders of magnitude below
# n_items at production vocabularies.  These paths expand the Eq. 10-13
# suffix contractions into per-history-slot coefficients and apply them
# through the sparse row gather/scatter kernel pair, so (like the add
# path) no [batch, n_items] temporary ever materializes.  The Eq. 12
# whole-vector rescale k/((k-1)·r_g) folds into ``uv_scale`` — the scales
# can now also GROW; the engine renormalizes outside [SCALE_FLOOR·1e2,
# SCALE_CEIL] (see streaming.engine._maintain).


def _slots(c_row, bh):
    """Expand per-history-row coefficients to per-slot values.

    [U, N] → [U, N·B]: each valid id in row t carries weight c_row[t].
    """
    u, n = c_row.shape
    return jnp.broadcast_to(c_row[:, :, None], (u, n, bh)).reshape(u, -1)


def _del_basket_sparse_core(state: StreamState, u, hist, gs, nb, k, s, sig,
                            em, pos, valid, params: TifuParams,
                            t_max_cap: int = 0):
    """Shared sparse basket-deletion math (Eq. 10-12 on the support).

    Rows with ``valid`` False produce all-PAD support ids, zero scatter
    values and unit ratios, so padding rows may alias any user.  Returns
    ``(ids, u_vals, l_vals, s_ratio, em_ratio, new_hist, new_gs, d_nb,
    d_ng)`` — the caller assembles the StreamState (the item-deletion
    path merges these with its in-place Eq. 13 branch first).
    """
    f32 = state.user_vecs.dtype
    n_rows = u.shape[0]
    n_bask, bh = hist.shape[1], hist.shape[2]
    kmax = gs.shape[1]
    rb = jnp.asarray(params.r_b, f32)
    rg = jnp.asarray(params.r_g, f32)

    g, p, tau = jax.vmap(
        lambda sizes: _row_group_geometry(sizes, n_bask))(gs)   # [U, N]
    j, i = jax.vmap(_locate)(gs, pos)                           # [U]
    tau_j = jnp.take_along_axis(gs, j[:, None], axis=1)[:, 0]

    t = jnp.arange(n_bask)[None, :]
    valid_row = (t < nb[:, None]) & valid[:, None]
    in_gj = valid_row & (g == j[:, None])

    single = tau_j == 1
    last_g = k <= 1
    s1 = valid & ~single                  # Eq. 10+11: group j shrinks
    s2 = valid & single & ~last_g         # Eq. 12: group j vanishes
    s3 = valid & single & last_g          # last basket: state empties

    kf = jnp.maximum(k, 1).astype(f32)
    safe_k = jnp.maximum(k, 2).astype(f32)
    tjf = tau_j.astype(f32)
    safe_tau = jnp.maximum(tau_j, 2).astype(f32)
    tau_f = jnp.maximum(tau, 1).astype(f32)

    # --- support: the user's masked history window -------------------------
    ids = jnp.where(valid_row[:, :, None], hist,
                    PAD_ID).reshape(n_rows, n_bask * bh)
    first = _first_occurrence(ids).astype(f32)
    uraw = sparse_row_gather(state.user_vecs, u, ids, t_max_cap=t_max_cap)
    lraw = sparse_row_gather(state.last_group_vecs, u, ids,
                             t_max_cap=t_max_cap)

    # --- scenario 1: per-slot expansion of r_g^(k-1-j)·(v'_gj - v_gj)/k ----
    pow_tp = rb ** jnp.where(in_gj, tau_j[:, None] - p, 0)
    w_gj = jnp.where(in_gj, pow_tp / tau_f, 0.0)           # v_gj slots
    sc = jnp.where(p == i[:, None], -pow_tp, pow_tp * (rb - 1.0))
    sc = jnp.where(in_gj & (p >= i[:, None]), sc, 0.0)     # Eq. 10 suffix
    dvg = ((tjf - (tjf - 1.0) * rb)[:, None] * w_gj + sc) \
        / ((safe_tau - 1.0) * rb)[:, None]                 # (v'_gj - v_gj)
    cu1 = (rg ** jnp.maximum(k - 1 - j, 0) / kf)[:, None] * dvg

    # --- scenario 2: suffix over groups j..k-1; the k/((k-1)·r_g) rescale --
    # folds into uv_scale, leaving only the sparse suffix_u/(k·s) delta.
    cg = jax.vmap(lambda kk, jj: decay.batched_suffix_coefficients(
        kk, jj, params.r_g, kmax))(k, j + 1).astype(f32)   # [U, K]
    cu2 = jnp.where(valid_row,
                    jnp.take_along_axis(cg, g, axis=1)
                    * rb ** jnp.where(valid_row, tau - p, 0) / tau_f, 0.0)
    s_ratio = jnp.where(s2, kf / ((safe_k - 1.0) * rg), 1.0)

    # --- user-vector scatter values (raw storage) --------------------------
    u_vals = jnp.where(s1[:, None], _slots(cu1, bh) / s[:, None],
                       jnp.where(s2[:, None],
                                 _slots(cu2, bh) / (kf * s)[:, None],
                                 jnp.where(s3[:, None], -uraw * first, 0.0)))

    # --- last-group row: reset to the new true value on the support --------
    lgv_new_1 = s1 & (j == k - 1)         # last group shrank
    lgv_new_2 = s2 & (j == k - 1)         # last group removed → old k-2
    lgv_change = lgv_new_1 | lgv_new_2 | s3
    cl1 = w_gj + dvg                      # v'_gj slots
    cl2 = jnp.where(valid_row & (g == (k - 2)[:, None]),
                    rb ** jnp.where(valid_row, tau - p, 0) / tau_f, 0.0)
    cl = jnp.where(lgv_new_1[:, None], cl1,
                   jnp.where(lgv_new_2[:, None], cl2, 0.0))
    l_vals = jnp.where(lgv_change[:, None],
                       -lraw * first + _slots(cl, bh) / sig[:, None], 0.0)

    # --- history compaction + group-size bookkeeping (O(N·B), not O(I)) ----
    src = jnp.where(t >= pos[:, None], jnp.minimum(t + 1, n_bask - 1), t)
    new_hist = jnp.take_along_axis(hist, src[:, :, None], axis=1)
    new_hist = new_hist.at[jnp.arange(n_rows),
                           jnp.maximum(nb - 1, 0)].set(PAD_ID)
    gs_s1 = gs.at[jnp.arange(n_rows), j].add(-1)
    gs_s2 = jax.vmap(_remove_entry)(gs, j)
    new_gs = jnp.where(single[:, None],
                       jnp.where(last_g[:, None], jnp.zeros_like(gs), gs_s2),
                       gs_s1)

    em_ratio = jnp.where(s2, decay.error_growth_factor(safe_k, params.r_g),
                         1.0)
    em_ratio = jnp.where(s3, 1.0 / em, em_ratio)
    d_nb = jnp.where(valid, -1, 0)
    d_ng = jnp.where(valid & single, -1, 0)
    return (ids, u_vals, l_vals, s_ratio, em_ratio, new_hist, new_gs,
            d_nb, d_ng)


@functools.partial(jax.jit, static_argnames=("params", "t_max_cap"),
                   donate_argnums=(0,))
def apply_del_basket_batch(state: StreamState, batch: DelBasketBatch,
                           params: TifuParams,
                           t_max_cap: int = 0) -> StreamState:
    """Apply a homogeneous basket-deletion sub-batch with sparse deltas.

    Implements Eq. 10–12 (suffix contractions expanded to per-history-
    slot coefficients, DESIGN.md §3.5).  State traffic is O(batch · N·B)
    — the deleted user's history window — instead of the dense path's
    O(batch · n_items).  Semantics match ``apply_del_basket_batch_dense``
    and the RefEngine to ~1e-4 (tests/test_update_partition.py).
    ``t_max_cap`` as in :func:`apply_add_batch`.
    """
    u = batch.user
    hist = state.history[u]
    gs = state.group_sizes[u]
    nb = state.n_baskets[u]
    k = state.n_groups[u]
    s = state.uv_scale[u]
    sig = state.lgv_scale[u]
    em = state.err_mult[u]
    valid = batch.valid & (nb > 0)
    pos = jnp.clip(batch.pos, 0, jnp.maximum(nb - 1, 0))
    (ids, u_vals, l_vals, s_ratio, em_ratio, new_hist, new_gs, d_nb,
     d_ng) = _del_basket_sparse_core(state, u, hist, gs, nb, k, s, sig, em,
                                     pos, valid, params, t_max_cap)
    vf = valid[:, None]
    return StreamState(
        user_vecs=sparse_row_scatter(state.user_vecs, u, ids, u_vals,
                                     t_max_cap=t_max_cap),
        last_group_vecs=sparse_row_scatter(state.last_group_vecs, u, ids,
                                           l_vals, t_max_cap=t_max_cap),
        history=state.history.at[u].add(
            jnp.where(valid[:, None, None], new_hist - hist, 0)),
        group_sizes=state.group_sizes.at[u].add(
            jnp.where(vf, new_gs - gs, 0)),
        n_baskets=state.n_baskets.at[u].add(d_nb),
        n_groups=state.n_groups.at[u].add(d_ng),
        err_mult=state.err_mult.at[u].multiply(
            jnp.where(valid, em_ratio, 1.0)),
        uv_scale=state.uv_scale.at[u].multiply(
            jnp.where(valid, s_ratio, 1.0)),
        lgv_scale=state.lgv_scale,
    )


@functools.partial(jax.jit, static_argnames=("params", "t_max_cap"),
                   donate_argnums=(0,))
def apply_del_item_batch(state: StreamState, batch: DelItemBatch,
                         params: TifuParams,
                         t_max_cap: int = 0) -> StreamState:
    """Apply a homogeneous item-deletion sub-batch with sparse deltas.

    The Eq. 13 in-place branch touches a single (user, item) cell of each
    vector table — O(1) per event; the basket-vanish fallback reuses the
    sparse Eq. 10–12 core on the history window, O(N·B) per event.  One
    fused program serves both branches (the support is the window plus
    one appended item slot).  ``t_max_cap`` as in :func:`apply_add_batch`
    (the hint covers the appended item slot too).
    """
    u = batch.user
    hist = state.history[u]
    gs = state.group_sizes[u]
    nb = state.n_baskets[u]
    k = state.n_groups[u]
    s = state.uv_scale[u]
    sig = state.lgv_scale[u]
    em = state.err_mult[u]
    f32 = state.user_vecs.dtype
    n_rows = u.shape[0]
    valid = batch.valid & (nb > 0)
    pos = jnp.clip(batch.pos, 0, jnp.maximum(nb - 1, 0))

    row = hist[jnp.arange(n_rows), pos]                       # [U, B]
    present = valid & jnp.any(row == batch.item[:, None], axis=1)
    blen = jnp.sum(row >= 0, axis=1)
    apply_db = present & (blen == 1)                          # basket vanishes
    apply_ip = present & (blen > 1)                           # Eq. 13 in place

    (ids_db, u_db, l_db, s_ratio, em_ratio, hist_db, gs_db, d_nb,
     d_ng) = _del_basket_sparse_core(state, u, hist, gs, nb, k, s, sig, em,
                                     pos, apply_db, params, t_max_cap)

    # --- Eq. 13 in place: one cell per table -------------------------------
    j, i = jax.vmap(_locate)(gs, pos)
    tau_j = jnp.maximum(jnp.take_along_axis(gs, j[:, None], axis=1)[:, 0], 1)
    rb = jnp.asarray(params.r_b, f32)
    rg = jnp.asarray(params.r_g, f32)
    kf = jnp.maximum(k, 1).astype(f32)
    dg = -(rb ** jnp.maximum(tau_j - i, 0)) / tau_j.astype(f32)
    du_ip = jnp.where(apply_ip,
                      rg ** jnp.maximum(k - 1 - j, 0) * dg / (kf * s), 0.0)
    dl_ip = jnp.where(apply_ip & (j == k - 1), dg / sig, 0.0)

    ids = jnp.concatenate(
        [ids_db, jnp.where(apply_ip, batch.item, PAD_ID)[:, None]], axis=1)
    u_vals = jnp.concatenate([u_db, du_ip[:, None]], axis=1)
    l_vals = jnp.concatenate([l_db, dl_ip[:, None]], axis=1)

    # --- history/bookkeeping: in-place row edit vs fallback compaction -----
    row_ip = jnp.where(row == batch.item[:, None], PAD_ID, row)
    hist_ip = hist.at[jnp.arange(n_rows), pos].set(row_ip)
    new_hist = jnp.where(apply_db[:, None, None], hist_db,
                         jnp.where(apply_ip[:, None, None], hist_ip, hist))
    new_gs = jnp.where(apply_db[:, None], gs_db, gs)
    touched = apply_db | apply_ip
    return StreamState(
        user_vecs=sparse_row_scatter(state.user_vecs, u, ids, u_vals,
                                     t_max_cap=t_max_cap),
        last_group_vecs=sparse_row_scatter(state.last_group_vecs, u, ids,
                                           l_vals, t_max_cap=t_max_cap),
        history=state.history.at[u].add(
            jnp.where(touched[:, None, None], new_hist - hist, 0)),
        group_sizes=state.group_sizes.at[u].add(
            jnp.where(apply_db[:, None], new_gs - gs, 0)),
        n_baskets=state.n_baskets.at[u].add(d_nb),
        n_groups=state.n_groups.at[u].add(d_ng),
        err_mult=state.err_mult.at[u].multiply(
            jnp.where(apply_db, em_ratio, 1.0)),
        uv_scale=state.uv_scale.at[u].multiply(
            jnp.where(apply_db, s_ratio, 1.0)),
        lgv_scale=state.lgv_scale,
    )


# ---------------------------------------------------------------------------
# Mixed-batch entry points
# ---------------------------------------------------------------------------

def apply_update_batch(state: StreamState, batch: UpdateBatch,
                       params: TifuParams) -> StreamState:
    """Apply a mixed micro-batch through the partitioned pipeline.

    Compat shim: host-partitions the batch into homogeneous kind
    sub-batches, so each event pays its own kind's cost (adds
    O(batch·W), deletions O(batch·N·B) — Eq. 7–13 via the sparse
    appliers above).  INVARIANT (enforced by streaming.engine): within
    one batch each user appears at most once among non-noop rows; the
    sub-batches therefore touch disjoint users and can be applied in
    any order.  Requires concrete (non-traced) ``batch.kind``;
    fully-traced callers should build homogeneous sub-batches
    themselves (see configs/tifu_knn.py).
    """
    kind = np.asarray(jax.device_get(batch.kind))
    add_rows = np.nonzero(kind == KIND_ADD_BASKET)[0]
    delb_rows = np.nonzero(kind == KIND_DEL_BASKET)[0]
    deli_rows = np.nonzero(kind == KIND_DEL_ITEM)[0]
    cap = int(kind.shape[0])
    user = np.asarray(jax.device_get(batch.user))
    if add_rows.size:
        items = np.asarray(jax.device_get(batch.basket_items))
        state = apply_add_batch(
            state, AddBatch.build(user[add_rows], items[add_rows],
                                  items.shape[1], pad_cap=cap), params)
    if delb_rows.size:
        pos = np.asarray(jax.device_get(batch.basket_pos))
        state = apply_del_basket_batch(
            state, DelBasketBatch.build(user[delb_rows], pos[delb_rows],
                                        pad_cap=cap), params)
    if deli_rows.size:
        pos = np.asarray(jax.device_get(batch.basket_pos))
        item = np.asarray(jax.device_get(batch.item))
        state = apply_del_item_batch(
            state, DelItemBatch.build(user[deli_rows], pos[deli_rows],
                                      item[deli_rows], pad_cap=cap), params)
    return state


@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def apply_update_batch_dense(state: StreamState, batch: UpdateBatch,
                             params: TifuParams) -> StreamState:
    """Apply a mixed micro-batch via the seed's dense path.

    Gathers [batch, n_items] rows, computes ALL update rules (Eq. 7–13)
    per row, selects one, scatters dense deltas — ~4x redundant compute
    and O(batch · n_items) traffic regardless of kind mix.  Retained as
    the benchmark baseline (bench_update_batch.py measures the
    partitioned pipeline against it) and as a second oracle.
    """
    u = batch.user
    *gathered, s, sig = _gather_true(state, u)
    gathered = tuple(gathered)
    updated = jax.vmap(
        lambda uv, lgv, h, gs, nb, ng, em, kind, items, pos, item:
        _single_update(uv, lgv, h, gs, nb, ng, em, kind, items, pos, item,
                       params))(
        *gathered, batch.kind, batch.basket_items, batch.basket_pos,
        batch.item)
    deltas = tuple(new - old for new, old in zip(updated, gathered))
    return StreamState(
        user_vecs=state.user_vecs.at[u].add(deltas[0] / s[:, None]),
        last_group_vecs=state.last_group_vecs.at[u].add(
            deltas[1] / sig[:, None]),
        history=state.history.at[u].add(deltas[2]),
        group_sizes=state.group_sizes.at[u].add(deltas[3]),
        n_baskets=state.n_baskets.at[u].add(deltas[4]),
        n_groups=state.n_groups.at[u].add(deltas[5]),
        err_mult=state.err_mult.at[u].add(deltas[6]),
        uv_scale=state.uv_scale,
        lgv_scale=state.lgv_scale,
    )


# ---------------------------------------------------------------------------
# Maintenance passes
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def refresh_users(state: StreamState, users, params: TifuParams) -> StreamState:
    """Recompute selected users from scratch (stability tracker).

    The exact Eq. 1+2 closed-form rebuild on the padded history —
    O(|users| · (N·B + n_items)) — resetting the per-user error
    trackers and scales to 1 (the fresh rows are true values).
    """
    h = state.history[users]
    gs = state.group_sizes[users]
    ng = state.n_groups[users]
    fresh = jax.vmap(lambda hh, gg, nn: user_vector_padded(hh, gg, nn, params))(
        h, gs, ng).astype(state.user_vecs.dtype)
    lgv = jax.vmap(lambda hh, gg, nn: last_group_vector_padded(
        hh, gg, nn, params))(h, gs, ng).astype(state.user_vecs.dtype)
    return StreamState(
        user_vecs=state.user_vecs.at[users].set(fresh),
        last_group_vecs=state.last_group_vecs.at[users].set(lgv),
        history=state.history,
        group_sizes=state.group_sizes,
        n_baskets=state.n_baskets,
        n_groups=state.n_groups,
        err_mult=state.err_mult.at[users].set(1.0),
        uv_scale=state.uv_scale.at[users].set(1.0),
        lgv_scale=state.lgv_scale.at[users].set(1.0),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def renormalize_users(state: StreamState, users) -> StreamState:
    """Fold the per-user scales back into the raw rows (scale -> 1).

    Dense per selected user — O(|users| · n_items) — but
    value-preserving and rare: the engine triggers it only when a scale
    approaches SCALE_FLOOR/SCALE_CEIL (hundreds of group openings or
    Eq. 12 deletions per user between triggers).
    """
    s = state.uv_scale[users]
    sig = state.lgv_scale[users]
    return StreamState(
        user_vecs=state.user_vecs.at[users].multiply(s[:, None]),
        last_group_vecs=state.last_group_vecs.at[users].multiply(
            sig[:, None]),
        history=state.history,
        group_sizes=state.group_sizes,
        n_baskets=state.n_baskets,
        n_groups=state.n_groups,
        err_mult=state.err_mult,
        uv_scale=state.uv_scale.at[users].set(1.0),
        lgv_scale=state.lgv_scale.at[users].set(1.0),
    )
