"""Fused similarity × streaming top-k Pallas kernel (TPU target).

Serves TIFU-kNN neighbour search (paper §2.2) and the two-tower /
bert4rec ``retrieval_cand`` cells: Q queries against M corpus rows,
returning per-query top-k WITHOUT materializing the [Q, M] score matrix
in HBM — the win over the reference path at M = 10⁶.

Design (DESIGN.md §3.4 / §8):
  grid = (⌈Q/bq⌉, ⌈M/bm⌉), M innermost (sequential).  Per step the MXU
  computes a [bq, bm] score tile in VMEM (2·q@cᵀ − |c|², the monotone
  euclidean surrogate); a running [bq, k] top-k buffer lives in VMEM
  scratch and is merged tile-by-tile; only [Q, k] leaves the chip.

  Neither Q nor M needs to divide its block size: tail blocks are
  masked inside the kernel (out-of-range corpus columns score −inf,
  out-of-range query rows are write-masked by Pallas), so prime-sized
  request batches and corpora run the same schedule — no host-side
  padding copy of the corpus.

  Self-exclusion is fused into the scan: when ``query_gids`` is given,
  a column whose GLOBAL id equals the query's global id is masked to
  −inf in its score tile.  Column global ids are
  ``local_idx · col_stride + col_offset`` — identity for a single
  corpus, ``(row · n_shards + shard)`` for one shard of a user-axis
  sharded corpus (DESIGN.md §7.1), so a query user is excluded only on
  its owner shard.

  The merge uses lax.top_k on the concatenated [bq, k+bm] tile, which
  preserves lax.top_k's tie-break (lowest index wins): the running
  buffer holds candidates from earlier (lower-id) tiles and sits first
  in the concat, so an equal-score later column never displaces an
  earlier one (pinned by tests/test_serving_pipeline.py).

D-tiled variant (DESIGN.md §8.4): ``knn_topk`` holds full [bq, D] /
[bm, D] blocks in VMEM — an O(bq·D) residency that walls out at
D ≈ 64k items.  ``knn_topk_dtiled`` adds a third (innermost) grid axis
over D-tiles: per (qi, mi) the q·cᵀ contraction accumulates into a
running [bq, bm] f32 block accumulator over ⌈D/bd⌉ steps, and only at
the LAST D-tile are the scores finished (norm terms, tail mask, fused
self-exclusion — the same contracts as the monolithic kernel) and
merged into the running [bq, k] top-k.  VMEM residency is O(bq·bd +
bm·bd + bq·bm), flat in D.  The same kernel serves an int8 per-row
quantized corpus (DESIGN.md §8.4): each D-tile's partial dot runs on
the MXU in int8→int32 (exact for bd ≤ 1024: |Σ| ≤ bd·127² < 2²⁴, so
the per-tile partial converts to f32 exactly), the cross-tile f32
accumulation is order-fixed, and the per-row scales are applied once
at score-finish time — which makes the int8 scores bit-for-bit
reproducible against the XLA oracle (``kernels.ref.dtiled_topk_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem as _avmem
from repro.analysis.contracts import OOB_WRITE, KernelContract, register


def _kernel(qid_ref, q_ref, c_ref, cn_ref, vals_ref, idx_ref, acc_vals,
            acc_idx, *, k: int, bm: int, metric: str, m: int,
            col_offset: int, col_stride: int, sub_qnorm: bool):
    mi = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        acc_vals[...] = jnp.full_like(acc_vals, -jnp.inf)
        acc_idx[...] = jnp.zeros_like(acc_idx)

    q = q_ref[...]                                   # [bq, D]
    c = c_ref[...]                                   # [bm, D]
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [bq, bm]
    if metric == "euclidean":
        scores = 2.0 * scores - cn_ref[...][None, :]
        if sub_qnorm:
            # full −|q−c|²: the shard-candidate path emits these scores
            # into the cross-shard merge, where they must be the same
            # per-pair values the reference path computes (§7.3); the
            # per-query constant is rank-irrelevant, so the single-
            # corpus path skips it.
            qf = q.astype(jnp.float32)
            scores = scores - jnp.sum(qf * qf, axis=1, keepdims=True)
    tile_idx = mi * bm + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    # tail mask: columns past the corpus end carry garbage (the block
    # read is out of bounds); they must never win the merge
    scores = jnp.where(tile_idx >= m, -jnp.inf, scores)
    # fused self-exclusion on GLOBAL ids (qid = -1 disables: gids >= 0)
    col_gid = tile_idx * col_stride + col_offset
    scores = jnp.where(col_gid == qid_ref[...][:, None], -jnp.inf, scores)

    merged_vals = jnp.concatenate([acc_vals[...], scores], axis=1)
    merged_idx = jnp.concatenate([acc_idx[...], tile_idx], axis=1)
    top_vals, top_pos = jax.lax.top_k(merged_vals, k)
    acc_vals[...] = top_vals
    acc_idx[...] = jnp.take_along_axis(merged_idx, top_pos, axis=1)

    @pl.when(mi == nm - 1)
    def _done():
        vals_ref[...] = acc_vals[...]
        idx_ref[...] = acc_idx[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bm", "metric", "interpret",
                                    "col_offset", "col_stride",
                                    "sub_qnorm"))
def knn_topk(queries, corpus, k: int, bq: int = 128, bm: int = 512,
             metric: str = "euclidean", interpret: bool = False,
             query_gids=None, col_offset: int = 0, col_stride: int = 1,
             sub_qnorm: bool = False):
    """queries [Q, D] × corpus [M, D] → (vals [Q, k], idx [Q, k]).

    ``idx`` are LOCAL corpus row indices; ``query_gids`` (i32[Q],
    optional) excludes the column whose global id
    ``idx·col_stride + col_offset`` equals the query's global id.
    Q and M need not divide ``bq``/``bm`` (masked tail blocks).  When
    ``k > M`` the trailing entries are −inf with unspecified indices —
    callers clamp (``ops.fused_recommend`` does).  ``sub_qnorm`` makes
    the euclidean scores the full −|q−c|² (the shard-candidate merge
    needs comparable values); off, they are the monotone surrogate
    2qc − |c|².
    """
    qn, d = queries.shape
    m = corpus.shape[0]
    if qn == 0 or m == 0:
        return (jnp.full((qn, k), -jnp.inf, jnp.float32),
                jnp.zeros((qn, k), jnp.int32))
    bq = min(bq, qn)
    bm = min(bm, m)
    if query_gids is None:
        query_gids = jnp.full((qn,), -1, jnp.int32)
    cnorm = jnp.sum(corpus.astype(jnp.float32) ** 2, axis=-1)
    grid = (pl.cdiv(qn, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel, k=k, bm=bm, metric=metric, m=m,
                               col_offset=col_offset, col_stride=col_stride,
                               sub_qnorm=sub_qnorm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda qi, mi: (qi,)),
            pl.BlockSpec((bq, d), lambda qi, mi: (qi, 0)),
            pl.BlockSpec((bm, d), lambda qi, mi: (mi, 0)),
            pl.BlockSpec((bm,), lambda qi, mi: (mi,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qi, mi: (qi, 0)),
            pl.BlockSpec((bq, k), lambda qi, mi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),   # running top-k vals
            pltpu.VMEM((bq, k), jnp.int32),     # running top-k idx
        ],
        interpret=interpret,
    )(query_gids.astype(jnp.int32), queries, corpus, cnorm)


# ---------------------------------------------------------------------------
# D-tiled stage A (DESIGN.md §8.4): O(bq·bd) VMEM residency, int8 corpus
# ---------------------------------------------------------------------------

def tiled_sqnorm(x, bd: int):
    """Per-row squared norm Σᵢ x[r, i]², accumulated in D-tile order.

    Returns f32[M].  int8 rows sum each bd-wide tile exactly in int32
    (bd ≤ 1024 keeps the per-tile partial below 2²⁴, so the f32 convert
    is exact); f32 rows sum per tile in f32.  The cross-tile f32
    accumulation order is fixed (tile 0 first), matching the kernel's
    block accumulator — ``kernels.ref`` duplicates this function
    verbatim so the oracle stays import-free (parity pinned by
    tests/test_quantized_serving.py).
    """
    m, d = x.shape
    bd = max(1, min(bd, d))
    nt = pl.cdiv(d, bd)
    pad = nt * bd - d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    xt = x.reshape(m, nt, bd)
    if x.dtype == jnp.int8:
        per_tile = jnp.sum(xt.astype(jnp.int32) ** 2,
                           axis=-1).astype(jnp.float32)
    else:
        xf = xt.astype(jnp.float32)
        per_tile = jnp.sum(xf * xf, axis=-1)
    return jnp.cumsum(per_tile, axis=1)[:, -1]


def _dtiled_kernel(qid_ref, q_ref, c_ref, cn_ref, qn_ref, qs_ref, cs_ref,
                   vals_ref, idx_ref, acc, acc_vals, acc_idx, *, k: int,
                   bm: int, bd: int, m: int, d: int, col_offset: int,
                   col_stride: int, sub_qnorm: bool, quantized: bool):
    mi = pl.program_id(1)
    di = pl.program_id(2)
    nm = pl.num_programs(1)
    nd = pl.num_programs(2)

    @pl.when((mi == 0) & (di == 0))
    def _init_topk():
        acc_vals[...] = jnp.full_like(acc_vals, -jnp.inf)
        acc_idx[...] = jnp.zeros_like(acc_idx)

    @pl.when(di == 0)
    def _init_acc():
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[...]                                   # [bq, bd]
    c = c_ref[...]                                   # [bm, bd]
    # tail D-lanes carry garbage (OOB block read): zero BOTH operands so
    # the contraction contributes exactly 0 (0·NaN would poison f32)
    lane = di * bd + jax.lax.broadcasted_iota(jnp.int32, (1, bd), 1)
    q = jnp.where(lane < d, q, jnp.zeros_like(q))
    c = jnp.where(lane < d, c, jnp.zeros_like(c))
    if quantized:
        # exact int32 partial per tile; f32 convert exact for bd <= 1024
        part = jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc[...] += part.astype(jnp.float32)
    else:
        acc[...] += jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _merge():
        qs = qs_ref[...]                             # [bq]
        cs = cs_ref[...]                             # [bm]
        scores = (2.0 * (qs[:, None] * cs[None, :]) * acc[...]
                  - (cs * cs)[None, :] * cn_ref[...][None, :])
        if sub_qnorm:
            scores = scores - (qs * qs * qn_ref[...])[:, None]
        tile_idx = mi * bm + jax.lax.broadcasted_iota(jnp.int32,
                                                      scores.shape, 1)
        scores = jnp.where(tile_idx >= m, -jnp.inf, scores)
        col_gid = tile_idx * col_stride + col_offset
        scores = jnp.where(col_gid == qid_ref[...][:, None], -jnp.inf,
                           scores)
        merged_vals = jnp.concatenate([acc_vals[...], scores], axis=1)
        merged_idx = jnp.concatenate([acc_idx[...], tile_idx], axis=1)
        top_vals, top_pos = jax.lax.top_k(merged_vals, k)
        acc_vals[...] = top_vals
        acc_idx[...] = jnp.take_along_axis(merged_idx, top_pos, axis=1)

    @pl.when((mi == nm - 1) & (di == nd - 1))
    def _done():
        vals_ref[...] = acc_vals[...]
        idx_ref[...] = acc_idx[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bm", "bd", "interpret",
                                    "col_offset", "col_stride",
                                    "sub_qnorm"))
def knn_topk_dtiled(queries, corpus, k: int, bq: int = 128, bm: int = 512,
                    bd: int = 512, interpret: bool = False,
                    query_gids=None, col_offset: int = 0,
                    col_stride: int = 1, sub_qnorm: bool = False,
                    q_scale=None, c_scale=None):
    """D-tiled streaming top-k for million-item corpora (§8.4).

    queries [Q, D] × corpus [M, D] →
    (vals f32[Q, k], local idx i32[Q, k]).  The D axis is the third (innermost) grid dimension: VMEM residency
    is O(bq·bd + bm·bd + bq·bm) instead of the monolithic kernel's
    O(bq·D) — flat in D (DESIGN.md §8.4).  Scoring, tail-mask,
    self-exclusion (``query_gids``/``col_offset``/``col_stride``),
    ``sub_qnorm`` and the lowest-index tie-break follow :func:`knn_topk`
    exactly.  When ``queries``/``corpus`` are int8 (per-row symmetric
    quantization), ``q_scale`` f32[Q] / ``c_scale`` f32[M] are required:
    each D-tile's partial dot accumulates exactly in int32 on the MXU
    and the scales are applied once at score-finish, so the euclidean
    surrogate is ``2·s_q·s_c·(q₈·c₈) − s_c²·|c₈|²`` — bit-for-bit the
    XLA oracle's value (``ref.dtiled_topk_ref``; the scales must be the
    power-of-two ones of ``optim.compression.quantize_int8_rows``,
    which make every scale application an exact exponent shift and the
    score FMA-contraction-invariant).  Euclidean only; k >
    M leaves trailing −inf entries with unspecified indices (callers
    clamp, as in :func:`knn_topk`).  ``bd`` must stay ≤ 1024 on the
    int8 path (exact f32 convert of the per-tile int32 partial).
    """
    qn_, d = queries.shape
    m = corpus.shape[0]
    quantized = corpus.dtype == jnp.int8
    if quantized and (q_scale is None or c_scale is None):
        raise ValueError("int8 corpus requires q_scale and c_scale")
    if quantized and bd > 1024:
        raise ValueError(f"bd={bd} > 1024 breaks the exact int8 "
                         "per-tile f32 convert (DESIGN.md §8.4)")
    if qn_ == 0 or m == 0:
        return (jnp.full((qn_, k), -jnp.inf, jnp.float32),
                jnp.zeros((qn_, k), jnp.int32))
    bq = min(bq, qn_)
    bm = min(bm, m)
    bd = min(bd, d)
    if query_gids is None:
        query_gids = jnp.full((qn_,), -1, jnp.int32)
    cnorm = tiled_sqnorm(corpus, bd)
    qnorm = (tiled_sqnorm(queries, bd) if sub_qnorm
             else jnp.zeros((qn_,), jnp.float32))
    if q_scale is None:
        q_scale = jnp.ones((qn_,), jnp.float32)
        c_scale = jnp.ones((m,), jnp.float32)
    grid = (pl.cdiv(qn_, bq), pl.cdiv(m, bm), pl.cdiv(d, bd))
    kernel = functools.partial(_dtiled_kernel, k=k, bm=bm, bd=bd, m=m,
                               d=d, col_offset=col_offset,
                               col_stride=col_stride, sub_qnorm=sub_qnorm,
                               quantized=quantized)
    acc_dtype = jnp.float32
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda qi, mi, di: (qi,)),
            pl.BlockSpec((bq, bd), lambda qi, mi, di: (qi, di)),
            pl.BlockSpec((bm, bd), lambda qi, mi, di: (mi, di)),
            pl.BlockSpec((bm,), lambda qi, mi, di: (mi,)),
            pl.BlockSpec((bq,), lambda qi, mi, di: (qi,)),
            pl.BlockSpec((bq,), lambda qi, mi, di: (qi,)),
            pl.BlockSpec((bm,), lambda qi, mi, di: (mi,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qi, mi, di: (qi, 0)),
            pl.BlockSpec((bq, k), lambda qi, mi, di: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn_, k), jnp.float32),
            jax.ShapeDtypeStruct((qn_, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, bm), acc_dtype),    # running q·cᵀ partial
            pltpu.VMEM((bq, k), jnp.float32),   # running top-k vals
            pltpu.VMEM((bq, k), jnp.int32),     # running top-k idx
        ],
        interpret=interpret,
    )(query_gids.astype(jnp.int32), queries, corpus, cnorm, qnorm,
      q_scale, c_scale)


# Kernel contracts (DESIGN.md §10.1): what tools/lint_kernels.py holds
# the pallas_call sites above to.  Grid axis 0 tails via Pallas OOB
# write masking; the corpus/D axes tail via the in-kernel masks quoted.
register(KernelContract(
    module="repro.kernels.knn_topk",
    entry="knn_topk",
    body="_kernel",
    grid_rank=2,
    tail={0: OOB_WRITE, 1: "tile_idx >= m"},
    accumulators=("float32", "int32"),
    vmem_model=_avmem.knn_topk_block_bytes,
    max_shapes={"d": 4096, "k": 512, "bq": 128, "bm": 512},
))
register(KernelContract(
    module="repro.kernels.knn_topk",
    entry="knn_topk_dtiled",
    body="_dtiled_kernel",
    grid_rank=3,
    tail={0: OOB_WRITE, 1: "tile_idx >= m", 2: "lane < d"},
    accumulators=("float32", "float32", "int32"),
    vmem_model=_avmem.knn_topk_dtiled_block_bytes,
    max_shapes={"d": 1 << 20, "k": 512, "bq": 128, "bm": 512,
                "bd": 512},
))
