"""Blocked online-softmax attention Pallas kernel (TPU target).

The TPU fast path for the LM archs' train/prefill attention: grid =
(batch·heads, Q blocks, KV blocks) with the KV dimension innermost;
running (max, sum, acc) live in VMEM scratch, so the [S, S] score matrix
never exists in HBM.  Supports causal and sliding-window masks (the
gemma3 5:1 pattern passes ``window``).

The portable lowering used by the dry-run is
``models.transformer.flash_attention`` (same schedule via lax.scan);
this kernel is validated against ``ref.flash_attention_ref`` in
interpret mode (tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem as _avmem
from repro.analysis.contracts import KernelContract, register

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_i, l_i, acc,
            *, bq: int, bk: int, scale: float, causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0]                                   # [bq, d]
    k = k_ref[0]                                   # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_i[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1)
    acc[...] = acc[...] * alpha[:, None] \
        + jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    m_i[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q,k,v: [B, S, H, D] (H == KV heads) → [B, S, H, D]."""
    b, s, h, d = q.shape
    sk = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, sk)
    assert s % bq == 0 and sk % bk == 0
    scale = 1.0 / (d ** 0.5)
    # fold batch × heads into the leading grid dim
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    grid = (b * h, s // bq, sk // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                          window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


# Kernel contract (DESIGN.md §10.1).  The S/KV grid axes are exact
# divisions guarded by the entry assert; exact_parity=False because the
# online softmax uses jnp.exp — its oracle (ref.flash_attention_ref)
# compares allclose, not bitwise.
register(KernelContract(
    module="repro.kernels.flash_attention",
    entry="flash_attention",
    body="_kernel",
    grid_rank=3,
    divisible=True,
    exact_parity=False,
    accumulators=("float32", "float32", "float32"),
    vmem_model=_avmem.flash_attention_block_bytes,
    max_shapes={"d": 256, "bq": 256, "bk": 256},
))
