"""Benchmark harness — one entry per paper table/figure (+ system
benchmarks).  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import sys
import time

import numpy as np


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_table2(quick: bool):
    """Paper Table 2: ranking metrics baseline vs incr vs decr."""
    from benchmarks.table2_predictive import run
    scale = 0.04 if quick else 0.12
    for ds in ("tafeng", "instacart", "valuedshopper"):
        t0 = time.perf_counter()
        rows, vec_diff = run(ds, scale=scale if ds != "valuedshopper"
                             else scale / 2)
        dt = (time.perf_counter() - t0) * 1e6
        for r in rows:
            _row(f"table2.{ds}.{r[1]}", dt / len(rows),
                 f"base={r[2]:.4f};incr={r[3]:.4f};decr={r[4]:.4f}")
        assert vec_diff < 1e-10, f"incremental not exact: {vec_diff}"


def bench_fig2a(quick: bool):
    from benchmarks.fig2_updates import fig2a_additions
    rows = fig2a_additions(n_max=1000 if quick else 3000,
                           sample_every=500)
    first, last = rows[0], rows[-1]
    _row("fig2a.incremental_update", last[1],
         f"t(n={first[0]})={first[1]:.1f}us;t(n={last[0]})={last[1]:.1f}us;"
         f"constant")
    _row("fig2a.baseline_retrain", last[2],
         f"grows {first[2]:.1f}->{last[2]:.1f}us")


def bench_fig2b(quick: bool):
    from benchmarks.fig2_updates import fig2b_deletions
    rows = fig2b_deletions(n0=600 if quick else 1500,
                           n_del=400 if quick else 1000, sample_every=200)
    med = rows[len(rows) // 2]
    _row("fig2b.delete_from_end", med[1], "near-constant")
    _row("fig2b.delete_from_start", med[2], "suffix-linear (spiky)")
    _row("fig2b.delete_random", med[3], "between")
    _row("fig2b.baseline_retrain", med[4], "O(n)")


def bench_fig2c(quick: bool):
    from benchmarks.fig2c_error import deletions_to, run
    for dtype in (np.float64, np.float32):
        t0 = time.perf_counter()
        rows = run(dtype, n0=420, n_del=200 if quick else 400)
        us = (time.perf_counter() - t0) * 1e6 / len(rows)
        d1 = deletions_to(rows, 1e-2)
        _row(f"fig2c.error_growth.{np.dtype(dtype).name}", us,
             f"deletions_to_1pct={d1}")


def bench_streaming(quick: bool):
    from benchmarks.streaming_throughput import run
    for bs in ((256,) if quick else (64, 256, 1024)):
        n, dt, _ = run(bs, n_events=1024 if quick else 4096)
        _row(f"streaming.batch{bs}", dt / max(n, 1) * 1e6,
             f"{n/dt:,.0f} events/s")


def bench_kernels(quick: bool):
    """Kernel schedules (portable paths; Pallas targets TPU)."""
    import jax.numpy as jnp
    from repro.core.knn import streaming_topk
    from repro.kernels.ref import knn_topk_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(65536 if quick else 262144, 128)),
                    jnp.float32)
    for name, fn in (
            ("knn.materialized", lambda: knn_topk_ref(q, c, 100)),
            ("knn.streaming", lambda: streaming_topk(q, c, 100,
                                                     chunk=16384))):
        fn()[0].block_until_ready()          # compile
        t0 = time.perf_counter()
        fn()[0].block_until_ready()
        _row(f"kernel.{name}", (time.perf_counter() - t0) * 1e6,
             f"Q=256xM={c.shape[0]}")


def bench_roofline(quick: bool):
    import json
    import os
    path = "results/dryrun_single_pod.json"
    if not os.path.exists(path):
        _row("roofline.missing", 0, "run launch/dryrun.py --all first")
        return
    with open(path) as f:
        cells = [r for r in json.load(f) if "error" not in r]
    for r in cells:
        rt = r["roofline"]
        dom = max(rt["t_compute_s"], rt["t_memory_s"], rt["t_collective_s"])
        _row(f"roofline.{r['arch']}.{r['shape']}", dom * 1e6,
             f"bound={rt['bottleneck']};fits={r['fits_16GiB_adjusted']}")


def main() -> None:
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    for bench in (bench_fig2a, bench_fig2b, bench_fig2c, bench_table2,
                  bench_streaming, bench_kernels, bench_roofline):
        bench(quick)


if __name__ == "__main__":
    main()
