"""Unlearning no-op property: ``delete(add(x)) == identity`` (ISSUE 9).

On randomized interleaved add/delete streams, inserting an add event
immediately followed by the deletion that cancels it (``del_basket`` of
the new basket, or ``del_item`` of each of its items — both deletion
kinds) must leave the engine in the same state as the stream without
the pair, across 1/2/4-shard configurations:

* integer leaves (history, group sizes, basket/group counts) bitwise;
* materialized float values allclose (the raw/scale FACTORING of
  ``last_group_vecs`` is path-dependent even when the value is not);
* every leaf bitwise after ``refresh_users`` — the renormalization
  pass the engine itself runs — proving the factoring is the ONLY
  difference ("bitwise on the scaled representation after renorm").

The seeded sweep always runs; a hypothesis-driven variant widens the
search when hypothesis is installed.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, TifuParams)
from repro.core.updates import refresh_users
from repro.parallel.sharding import UserShardSpec
from repro.streaming import (Event, ShardedStreamingEngine, StateStore,
                             StoreConfig, StreamingEngine)

P = TifuParams(n_items=23, group_size=3)
M, N, B = 6, 16, 5

INT_LEAVES = ("history", "group_sizes", "n_baskets", "n_groups")
FLOAT_LEAVES = ("user_vecs", "uv_scale", "last_group_vecs", "lgv_scale",
                "err_mult")


def build(n_shards):
    """Single or sharded engine at the module-level geometry."""
    if n_shards == 1:
        store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                       max_baskets=N, max_basket_size=B))
        return StreamingEngine(store, P, batch_size=8)
    return ShardedStreamingEngine.create(
        UserShardSpec(M, n_shards), P, max_baskets=N, max_basket_size=B,
        batch_size=8)


def stores_of(eng):
    """The per-shard StateStores of either engine flavour."""
    if isinstance(eng, StreamingEngine):
        return [eng.store]
    return [sh.store for sh in eng.shards]


def gen_stream(rng, n_events):
    """Randomized interleaved add/del_basket/del_item stream."""
    events, nb = [], [0] * M
    for _ in range(n_events):
        u = int(rng.integers(0, M))
        r = rng.random()
        if nb[u] > 0 and r < 0.3:
            pos = int(rng.integers(0, nb[u]))
            if r < 0.18:
                events.append(Event(KIND_DEL_BASKET, u, pos=pos))
                nb[u] -= 1
            else:
                events.append(Event(KIND_DEL_ITEM, u, pos=pos,
                                    item=int(rng.integers(0, P.n_items))))
        else:
            items = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                               replace=False)
            events.append(Event(KIND_ADD_BASKET, u, items=items.tolist()))
            nb[u] = min(nb[u] + 1, N - 2)
    return events, nb


def cancelled_pair(u, nb_u, items, cancel_kind):
    """An add for ``u`` plus the deletion event(s) that cancel it.

    The add appends at position ``nb_u`` (the end), so the cancelling
    deletions target that position and every LATER event of the stream
    sees the exact pre-pair history — the insertion point is free.
    """
    pair = [Event(KIND_ADD_BASKET, u, items=list(items))]
    if cancel_kind == KIND_DEL_BASKET:
        pair.append(Event(KIND_DEL_BASKET, u, pos=nb_u))
    else:
        for item in items:
            pair.append(Event(KIND_DEL_ITEM, u, pos=nb_u,
                              item=int(item)))
    return pair


def run_engine(events):
    """Drained engines for the event list at 1/2/4 shards."""
    engines = {}
    for n_shards in (1, 2, 4):
        eng = build(n_shards)
        eng.submit(events)
        eng.run_until_drained()
        engines[n_shards] = eng
    return engines


def assert_noop(seed, cancel_kind, n_events=60):
    """Assert delete(add(x)) == identity for one seeded stream."""
    rng = np.random.default_rng(seed)
    base, nb = gen_stream(rng, n_events)
    u = int(rng.integers(0, M))
    cut = int(rng.integers(0, len(base) + 1))
    items = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                       replace=False)
    # u's basket count at the insertion point, derived exactly by
    # replaying the stream prefix (item deletes can vanish baskets, so
    # counting events is not enough)
    probe = build(1)
    probe.submit(base[:cut])
    probe.run_until_drained()
    nb_u = int(np.asarray(probe.store.state.n_baskets)[u])
    if nb_u >= N - 2:
        return                      # capacity edge: pair add would drop
    pair = cancelled_pair(u, nb_u, items, cancel_kind)
    with_pair = base[:cut] + pair + base[cut:]

    for n_shards in (1, 2, 4):
        eng_a = build(n_shards)
        eng_a.submit(with_pair)
        eng_a.run_until_drained()
        eng_b = build(n_shards)
        eng_b.submit(base)
        eng_b.run_until_drained()
        for sa, sb in zip(stores_of(eng_a), stores_of(eng_b)):
            for name in INT_LEAVES:
                np.testing.assert_array_equal(
                    np.asarray(getattr(sa.state, name)),
                    np.asarray(getattr(sb.state, name)),
                    err_msg=f"{name} seed={seed} kind={cancel_kind} "
                            f"shards={n_shards}")
            np.testing.assert_allclose(
                np.asarray(sa.state.materialized_user_vecs()),
                np.asarray(sb.state.materialized_user_vecs()),
                atol=1e-5,
                err_msg=f"materialized seed={seed} shards={n_shards}")
            # after the renorm/refresh pass the factoring is canonical:
            # EVERY leaf must be bitwise identical
            rows = jnp.arange(sa.cfg.n_users, dtype=jnp.int32)
            ra = refresh_users(sa.state, rows, P)
            rb = refresh_users(sb.state, rows, P)
            for name in INT_LEAVES + FLOAT_LEAVES:
                np.testing.assert_array_equal(
                    np.asarray(getattr(ra, name)),
                    np.asarray(getattr(rb, name)),
                    err_msg=f"post-renorm {name} seed={seed} "
                            f"kind={cancel_kind} shards={n_shards}")


@pytest.mark.parametrize("cancel_kind", [KIND_DEL_BASKET, KIND_DEL_ITEM],
                         ids=["del_basket", "del_item"])
@pytest.mark.parametrize("seed", range(4))
def test_delete_add_noop_seeded(seed, cancel_kind):
    """Always-on seeded sweep of the cancellation property."""
    assert_noop(seed, cancel_kind)


# hypothesis-driven widening. NOT importorskip: that would skip the
# whole module, and the seeded sweep above is the always-on floor of
# this property in environments without hypothesis.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           kind=st.sampled_from([KIND_DEL_BASKET, KIND_DEL_ITEM]),
           n_events=st.integers(min_value=5, max_value=80))
    @settings(max_examples=15, deadline=None)
    def test_delete_add_noop_hypothesis(seed, kind, n_events):
        """Property-based widening of the seeded sweep."""
        assert_noop(seed, kind, n_events=n_events)
