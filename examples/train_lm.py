"""End-to-end LM training driver (deliverable (b)): ~100M-parameter
transformer trained for a few hundred steps with checkpoint/restart.

    # quick demo (~2 min on CPU):
    PYTHONPATH=src python examples/train_lm.py --quick
    # the full 100M × 300 steps run:
    PYTHONPATH=src python examples/train_lm.py
"""
import argparse
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args, rest = ap.parse_known_args()
    if args.quick:
        sys.argv = [sys.argv[0], "--arch", "granite-3-2b", "--smoke",
                    "--steps", "30", "--batch", "8", "--seq", "64",
                    "--log-every", "5"] + rest
    else:
        sys.argv = [sys.argv[0], "--arch", "lm100m", "--steps", "300",
                    "--batch", "2", "--seq", "256",
                    "--ckpt", "/tmp/lm100m_ckpt"] + rest
    raise SystemExit(train_main())
