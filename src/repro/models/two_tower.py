"""Two-tower retrieval (Yi et al., RecSys'19; Covington RecSys'16).

User tower: embedding-bag over the user's interaction history (this is
where the paper's decayed-average maintenance plugs in — the bag IS a
TIFU-style user vector over item embeddings, maintained under
additions/deletions with Eq. 3/4) + id embeddings → MLP → e_u [256].
Item tower: id/category embeddings → MLP → e_i [256].
Training: in-batch sampled softmax with logQ correction.
Retrieval: e_u against 10⁶ candidate embeddings (kernels.knn_topk).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_mlp, init_mlp, mlp_shapes
from repro.models.embedding import (TableSpec, embedding_bag,
                                    embedding_lookup, init_table)


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_users: int = 5_000_000
    n_items: int = 2_000_000
    n_item_cats: int = 10_000
    hist_len: int = 50
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    dtype: Optional[object] = jnp.float32

    @property
    def user_table(self) -> TableSpec:
        return TableSpec((self.n_users,), self.embed_dim)

    @property
    def item_table(self) -> TableSpec:
        return TableSpec((self.n_items,), self.embed_dim)

    @property
    def cat_table(self) -> TableSpec:
        return TableSpec((self.n_item_cats,), self.embed_dim)

    def n_params(self) -> int:
        n = (self.user_table.padded_rows() + self.item_table.padded_rows()
             + self.cat_table.padded_rows()) * self.embed_dim
        for dims in ([2 * self.embed_dim, *self.tower_mlp],
                     [2 * self.embed_dim, *self.tower_mlp]):
            n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


def init_params(c: TwoTowerConfig, key):
    ks = jax.random.split(key, 5)
    return {
        "user_emb": init_table(ks[0], c.user_table, c.dtype),
        "item_emb": init_table(ks[1], c.item_table, c.dtype),
        "cat_emb": init_table(ks[2], c.cat_table, c.dtype),
        "user_mlp": init_mlp(ks[3], [2 * c.embed_dim, *c.tower_mlp], c.dtype),
        "item_mlp": init_mlp(ks[4], [2 * c.embed_dim, *c.tower_mlp], c.dtype),
    }


def abstract_params(c: TwoTowerConfig):
    shapes = {
        "user_emb": (c.user_table.padded_rows(), c.embed_dim),
        "item_emb": (c.item_table.padded_rows(), c.embed_dim),
        "cat_emb": (c.cat_table.padded_rows(), c.embed_dim),
        "user_mlp": mlp_shapes([2 * c.embed_dim, *c.tower_mlp]),
        "item_mlp": mlp_shapes([2 * c.embed_dim, *c.tower_mlp]),
    }
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, c.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_pspecs(c: TwoTowerConfig, mesh, rules):
    n_dev = int(np.prod(mesh.devices.shape))
    tp = rules.tensor if rules.tensor in mesh.axis_names else None

    def rows(spec):
        return tuple(mesh.axis_names) \
            if spec.padded_rows() % n_dev == 0 else tp

    mlp = lambda dims: [{k: P(*([None] * len(s))) for k, s in l.items()}
                        for l in mlp_shapes(dims)]
    return {
        "user_emb": P(rows(c.user_table), None),
        "item_emb": P(rows(c.item_table), None),
        "cat_emb": P(rows(c.cat_table), None),
        "user_mlp": mlp([2 * c.embed_dim, *c.tower_mlp]),
        "item_mlp": mlp([2 * c.embed_dim, *c.tower_mlp]),
    }


def user_tower(params, batch, c: TwoTowerConfig):
    """batch: {"user_id": [B], "history": [B, hist_len] (-1 padded)}."""
    uid = embedding_lookup(params["user_emb"], batch["user_id"][:, None],
                           c.user_table)[:, 0, :]
    hist = embedding_bag(params["item_emb"], batch["history"][:, None, :],
                         c.item_table, mode="mean")[:, 0, :]
    e = apply_mlp(params["user_mlp"], jnp.concatenate([uid, hist], -1))
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-6)


def item_tower(params, batch, c: TwoTowerConfig):
    """batch: {"item_id": [B], "item_cat": [B]}."""
    iid = embedding_lookup(params["item_emb"], batch["item_id"][:, None],
                           c.item_table)[:, 0, :]
    cat = embedding_lookup(params["cat_emb"], batch["item_cat"][:, None],
                           c.cat_table)[:, 0, :]
    e = apply_mlp(params["item_mlp"], jnp.concatenate([iid, cat], -1))
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-6)


def sampled_softmax_loss(params, batch, c: TwoTowerConfig,
                         temperature: float = 0.05):
    """In-batch softmax with logQ correction (batch["logq"]: [B])."""
    eu = user_tower(params, batch, c)
    ei = item_tower(params, batch, c)
    logits = (eu @ ei.T).astype(jnp.float32) / temperature
    if "logq" in batch:
        logits = logits - batch["logq"][None, :]
    labels = jnp.arange(logits.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_step(c: TwoTowerConfig, optimizer, mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: sampled_softmax_loss(p, batch, c))(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}
    return train_step


def serve_step(params, batch, c: TwoTowerConfig, mesh=None, rules=None):
    """Online scoring: user × item pairs → dot scores."""
    return jnp.sum(user_tower(params, batch, c)
                   * item_tower(params, batch, c), axis=-1)


def retrieval_step(params, batch, c: TwoTowerConfig, top_n: int = 100,
                   mesh=None, rules=None):
    """retrieval_cand: 1 query vs n_candidates item embeddings [N, D]."""
    eu = user_tower(params, batch, c)                 # [1, D]
    scores = (eu @ batch["candidates"].T).astype(jnp.float32)
    return jax.lax.top_k(scores, top_n)
