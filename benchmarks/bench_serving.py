"""Serving latency/throughput vs corpus size, fused vs baseline.

The update path has been benchmarked since PR 1; this is the READ path
(ISSUE 5): batched recommendation requests against a materialized
corpus, measuring

  * ``fused``    — the live serving path (`core.knn.recommend_for_users`
                   → ``kernels.ops.fused_recommend``): on CPU the XLA
                   reference (bitwise the historical output), on TPU /
                   interpret the two-stage Pallas pipeline of
                   DESIGN.md §8 (streaming top-k + one-hot blend/top-n,
                   O(Q·k) HBM intermediates);
  * ``baseline`` — the pre-fusion unfused computation pinned in-line
                   here (full [Q, M] score materialization, [Q, k, I]
                   neighbour gather, [Q, I] prediction, separate
                   top-n), always through XLA.

On a CPU host the two arms run the same math, so the speedup sits at
~1x BY CONSTRUCTION (the fused CPU path is pinned bitwise to the
baseline); the enforceable CPU signals are the latency trend, the
queries/s / p50 / p99 numbers, and the REQUEST-BUCKETING gate: a sweep
of ragged request sizes through `StreamingEngine.recommend` must
compile only the pow2 bucket count of programs
(``serving_compiled_programs``, enforced as an upper bound by
``bench_trend.py`` — "compiled" metrics must never increase).  The
fused-vs-baseline speedup becomes meaningful on the TPU arm (ROADMAP:
needs a real-TPU run, like the update kernels' ``--backend tpu`` arm).

``--backend`` as in bench_update_batch.py: ``cpu`` pins the XLA
reference path, ``tpu`` natural dispatch on a TPU host, ``interpret``
drives the Pallas serving kernels in interpret mode (plumbing numbers;
only allowed with ``--smoke``).

``--scale`` runs the million-item sweep instead (ISSUE 7 / DESIGN.md
§8.4): corpus ITEM counts 64k → 1M through three serving paths —
monolithic fp32 (the §8 kernel, whose [bq, D] + [bm, D] VMEM blocks
grow linearly in D and blow the 16 MiB budget long before 1M),
D-tiled fp32, and D-tiled int8 over the per-row-quantized corpus.
Per sweep point it records latency, the analytic per-query-block VMEM
model (``kernels.ops.stage_a_vmem_bytes``), corpus HBM bytes, and the
int8-vs-fp32 top-n overlap, then ASSERTS the tentpole claim: D-tiled
VMEM stays flat (within 10%) across the sweep while monolithic no
longer fits VMEM at the top size.

Entries merge into BENCH_updates.json under ``arm="serving"`` (or
``arm="serving_scale"`` for ``--scale``) — schema:
benchmarks/README.md.  Scale-summary keys follow the non-gated
parity-key convention (no "compiled"/"speedup" substrings), so
``bench_trend.py`` records but never gates them.

    PYTHONPATH=src python benchmarks/bench_serving.py
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke  # CI
    PYTHONPATH=src python benchmarks/bench_serving.py --scale
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TifuParams, knn
from repro.kernels import ops
from repro.streaming import StateStore, StoreConfig, StreamingEngine

from bench_update_batch import BACKEND_IMPL, merge_runs


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_items: int = 2048
    q_batch: int = 256
    k: int = 64
    topn: int = 10
    alpha: float = 0.7
    corpus_grid: tuple = (1_024, 8_192, 32_768)
    iters: int = 30
    warmup: int = 3
    # request-bucketing sweep (through a real engine)
    bucket_users: int = 512
    bucket_requests: int = 32


SMOKE = ServeConfig(n_items=192, q_batch=48, k=8, topn=5,
                    corpus_grid=(160, 320), iters=3, warmup=1,
                    bucket_users=64, bucket_requests=8)


@dataclasses.dataclass(frozen=True)
class ScaleConfig:
    """Million-item sweep (``--scale``): the D axis grows, Q/M stay
    fixed, so every trend in the output is an item-count trend."""
    m_users: int = 256
    q_batch: int = 32
    k: int = 16
    topn: int = 10
    alpha: float = 0.7
    items_grid: tuple = (65_536, 262_144, 1_048_576)
    bd: int = 1024
    iters: int = 3
    warmup: int = 1


SCALE_SMOKE = ScaleConfig(m_users=48, q_batch=8, k=4, topn=5,
                          items_grid=(768, 1_536), bd=256, iters=2,
                          warmup=1)

# v4/v5-class VMEM per core; the budget stage_a_vmem_bytes is judged
# against (DESIGN.md §8.2)
VMEM_BUDGET = 16 * 2**20


@functools.partial(jax.jit, static_argnames=("k", "topn"))
def baseline_recommend(corpus, user_ids, k, alpha, topn):
    """The pre-fusion serving computation, pinned here as the baseline:
    [Q, M] scores in HBM, [Q, k, I] neighbour gather, [Q, I] prediction,
    then top-n — compiled as ONE program, exactly like the historical
    ``recommend_for_users`` jit, so the fused-vs-baseline ratio compares
    kernel paths, not dispatch overheads."""
    queries = corpus[user_ids]
    pred = knn.predict(queries, corpus, k=k, alpha=alpha,
                       exclude_self=True, query_ids=user_ids)
    return knn.recommend_topn(pred, topn)


def bench_path(path: str, corpus, cfg: ServeConfig, rng, backend: str):
    m = corpus.shape[0]
    users = jnp.asarray(rng.choice(m, size=min(cfg.q_batch, m),
                                   replace=False).astype(np.int32))
    if path == "fused":
        def run():
            return knn.recommend_for_users(corpus, users, k=cfg.k,
                                           alpha=cfg.alpha, topn=cfg.topn)
    else:
        def run():
            return baseline_recommend(corpus, users, cfg.k, cfg.alpha,
                                      cfg.topn)
    for _ in range(cfg.warmup):
        jax.block_until_ready(run())
    times = []
    for _ in range(cfg.iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    q_n = int(users.shape[0])
    return {"path": path, "backend": backend, "m_users": m,
            "n_items": cfg.n_items, "q_batch": q_n, "k": cfg.k,
            "topn": cfg.topn, "iters": cfg.iters,
            "mean_ms": float(times.mean() * 1e3),
            "p50_ms": float(np.median(times) * 1e3),
            "p99_ms": float(np.quantile(times, 0.99) * 1e3),
            "min_ms": float(times.min() * 1e3),
            "queries_per_s": float(q_n / times.mean())}


def make_corpus(m: int, n_items: int, rng) -> jnp.ndarray:
    """A dense random corpus stands in for materialized user vectors —
    serving cost depends only on shapes, not values."""
    return jnp.asarray(rng.random((m, n_items), np.float32))


def bench_bucketing(cfg: ServeConfig, rng) -> dict:
    """Ragged request sizes through the engine-side batcher: the
    compiled-shape count must track the pow2 BUCKETS, not the sizes."""
    p = TifuParams(n_items=cfg.n_items, group_size=3, k_neighbors=cfg.k,
                   alpha=cfg.alpha)
    store = StateStore(StoreConfig(n_users=cfg.bucket_users,
                                   n_items=cfg.n_items, max_baskets=4,
                                   max_basket_size=8))
    eng = StreamingEngine(store, p, batch_size=cfg.bucket_users)
    for u in range(cfg.bucket_users):
        eng.add_basket(u, rng.choice(cfg.n_items, size=4, replace=False))
    eng.run_until_drained()
    sizes = sorted(int(rng.integers(1, cfg.bucket_users + 1))
                   for _ in range(cfg.bucket_requests))
    for q_n in sizes:
        eng.recommend(rng.choice(cfg.bucket_users, size=q_n,
                                 replace=False), topn=cfg.topn)
    buckets = {1 << max(0, (s - 1).bit_length()) for s in sizes}
    return {"request_sizes": len(set(sizes)),
            "pow2_buckets": len(buckets),
            "compiled_shapes": eng.metrics.serve_compiled_shapes}


def _time_runs(run, iters: int, warmup: int) -> np.ndarray:
    for _ in range(warmup):
        jax.block_until_ready(run())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    return np.asarray(times)


def _topn_overlap(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.mean([len(set(x) & set(y)) / len(x)
                          for x, y in zip(a, b)]))


def bench_scale_point(n_items: int, cfg: ScaleConfig, backend: str) -> list:
    """One sweep point: the three serving paths over the same corpus.

    The VMEM numbers come from the analytic per-grid-step model
    (``ops.stage_a_vmem_bytes``) — the quantity the Pallas grid actually
    holds resident; latency is wall clock on whatever backend runs."""
    from repro.optim.compression import quantize_int8_rows

    rng = np.random.default_rng(0)
    corpus = make_corpus(cfg.m_users, n_items, rng)
    corpus_q, c_scale = quantize_int8_rows(corpus)
    users = jnp.asarray(rng.choice(cfg.m_users, size=cfg.q_batch,
                                   replace=False).astype(np.int32))
    paths = {
        "fp32_mono": (
            lambda: knn.recommend_for_users(corpus, users, k=cfg.k,
                                            alpha=cfg.alpha,
                                            topn=cfg.topn),
            ops.stage_a_vmem_bytes(n_items, cfg.k),
            int(corpus.size) * 4),
        "fp32_dtiled": (
            lambda: ops.fused_recommend(corpus, users, k=cfg.k,
                                        alpha=cfg.alpha, topn=cfg.topn,
                                        bd=cfg.bd),
            ops.stage_a_vmem_bytes(n_items, cfg.k, bd=cfg.bd),
            int(corpus.size) * 4),
        "int8_dtiled": (
            lambda: knn.recommend_for_users_quant(corpus_q, c_scale,
                                                  users, k=cfg.k,
                                                  alpha=cfg.alpha,
                                                  topn=cfg.topn,
                                                  bd=cfg.bd),
            ops.stage_a_vmem_bytes(n_items, cfg.k, bd=cfg.bd,
                                   itemsize=1),
            int(corpus_q.size) + int(c_scale.size) * 4),
    }
    out, recs = [], {}
    for path, (run, vmem, hbm) in paths.items():
        times = _time_runs(run, cfg.iters, cfg.warmup)
        recs[path] = np.asarray(run())
        out.append({"path": path, "backend": backend, "n_items": n_items,
                    "m_users": cfg.m_users, "q_batch": cfg.q_batch,
                    "k": cfg.k, "topn": cfg.topn, "bd": cfg.bd,
                    "iters": cfg.iters,
                    "mean_ms": float(times.mean() * 1e3),
                    "p50_ms": float(np.median(times) * 1e3),
                    "min_ms": float(times.min() * 1e3),
                    "stage_a_vmem_bytes": int(vmem),
                    "fits_vmem": bool(vmem <= VMEM_BUDGET),
                    "corpus_hbm_bytes": int(hbm)})
    overlap = _topn_overlap(recs["fp32_mono"], recs["int8_dtiled"])
    for r in out:
        r["int8_fp32_topn_overlap"] = overlap
    del corpus, corpus_q
    return out


def summarize_scale(results: list, cfg: ScaleConfig) -> dict:
    """Scale-arm summary.  Keys deliberately avoid the "compiled" and
    "speedup" substrings so ``bench_trend.py`` records but never gates
    them (CPU/interpret latencies here are plumbing numbers, and the
    VMEM claims are asserted below, not trend-gated)."""
    def pick(path, n):
        return next(r for r in results if r["path"] == path
                    and r["n_items"] == n)

    d_lo, d_hi = cfg.items_grid[0], cfg.items_grid[-1]
    dtiled = [pick("fp32_dtiled", n) for n in cfg.items_grid]
    vmems = [r["stage_a_vmem_bytes"] for r in dtiled]
    mono_lo, mono_hi = pick("fp32_mono", d_lo), pick("fp32_mono", d_hi)
    int8_hi, fp32_hi = pick("int8_dtiled", d_hi), pick("fp32_dtiled", d_hi)
    summary = {
        "scale_max_items": d_hi,
        "scale_dtiled_vmem_mib_at_max_items":
            vmems[-1] / 2**20,
        "scale_dtiled_vmem_growth_across_sweep":
            max(vmems) / min(vmems),
        "scale_mono_vmem_mib_at_max_items":
            mono_hi["stage_a_vmem_bytes"] / 2**20,
        "scale_mono_vmem_growth_across_sweep":
            mono_hi["stage_a_vmem_bytes"] / mono_lo["stage_a_vmem_bytes"],
        "scale_mono_fits_vmem_at_max_items": int(mono_hi["fits_vmem"]),
        "scale_int8_hbm_reduction_vs_fp32":
            fp32_hi["corpus_hbm_bytes"] / int8_hi["corpus_hbm_bytes"],
        "scale_int8_fp32_topn_overlap_at_max_items":
            int8_hi["int8_fp32_topn_overlap"],
        "scale_int8_p50_ms_at_max_items": int8_hi["p50_ms"],
        "scale_fp32_dtiled_p50_ms_at_max_items": fp32_hi["p50_ms"],
    }
    # The tentpole claims, enforced at bench time (ISSUE 7 acceptance):
    # per-query-block serving memory flat (within 10%) across the sweep
    # for the D-tiled paths, while the monolithic kernel's grows with D
    # and — at full scale — no longer fits VMEM at all.
    assert summary["scale_dtiled_vmem_growth_across_sweep"] <= 1.10, vmems
    assert all(r["fits_vmem"] for r in dtiled), vmems
    assert summary["scale_mono_vmem_growth_across_sweep"] > 1.10
    if d_hi >= 1_000_000:
        assert not mono_hi["fits_vmem"], mono_hi["stage_a_vmem_bytes"]
    return summary


def run_scale(cfg: ScaleConfig, backend: str) -> tuple:
    results = []
    with ops.default_impl(BACKEND_IMPL[backend]):
        for n_items in cfg.items_grid:
            for r in bench_scale_point(n_items, cfg, backend):
                results.append(r)
                fits = "fits" if r["fits_vmem"] else "EXCEEDS VMEM"
                print(f"{r['path']:12s} I={n_items:>9,d} "
                      f"mean={r['mean_ms']:9.2f} ms "
                      f"vmem={r['stage_a_vmem_bytes'] / 2**20:8.2f} MiB "
                      f"({fits}) hbm={r['corpus_hbm_bytes'] / 2**20:8.1f}"
                      f" MiB")
    return results, summarize_scale(results, cfg)


def summarize(results: list, bucketing: dict, cfg: ServeConfig,
              backend: str) -> dict:
    def pick(path, m):
        return next(r for r in results if r["path"] == path
                    and r["m_users"] == m)

    m_lo, m_hi = cfg.corpus_grid[0], cfg.corpus_grid[-1]
    fused_lo, fused_hi = pick("fused", m_lo), pick("fused", m_hi)
    base_hi = pick("baseline", m_hi)
    ratio = base_hi["mean_ms"] / fused_hi["mean_ms"]
    # On cpu the two arms run the SAME math (the fused cpu path is
    # bitwise-pinned to the baseline), so the ratio is a parity check
    # around 1x, not a speedup — name it so the trend gate (which
    # enforces "*speedup*" keys) never gates on dispatch noise.  The
    # Pallas backends keep the speedup name: there the paths differ.
    ratio_key = ("serving_fused_baseline_parity_at_max_corpus"
                 if backend == "cpu"
                 else "serving_fused_speedup_vs_baseline_at_max_corpus")
    return {
        "max_corpus_users": m_hi,
        "serving_qps_at_max_corpus": fused_hi["queries_per_s"],
        "serving_p50_ms_at_max_corpus": fused_hi["p50_ms"],
        "serving_p99_ms_at_max_corpus": fused_hi["p99_ms"],
        "serving_latency_growth_to_max_corpus":
            fused_hi["mean_ms"] / fused_lo["mean_ms"],
        ratio_key: ratio,
        "serving_compiled_programs": bucketing["compiled_shapes"],
        "serving_request_sizes_swept": bucketing["request_sizes"],
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI: validates the harness, not "
                         "perf)")
    ap.add_argument("--scale", action="store_true",
                    help="million-item sweep: mono vs D-tiled vs int8 "
                         "(arm=serving_scale)")
    ap.add_argument("--backend", choices=sorted(BACKEND_IMPL),
                    default=None,
                    help="serving kernel path (default: tpu on a TPU "
                         "host, else cpu)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_updates.json"))
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else ServeConfig()
    backend = args.backend or ("tpu" if jax.default_backend() == "tpu"
                               else "cpu")
    if backend == "tpu" and jax.default_backend() != "tpu":
        ap.error("--backend tpu requires a TPU host "
                 f"(jax.default_backend() == {jax.default_backend()!r})")
    if backend == "interpret" and not args.smoke:
        ap.error("--backend interpret is interpret-mode Pallas (orders "
                 "of magnitude slower): only allowed with --smoke")

    if args.scale:
        scfg = SCALE_SMOKE if args.smoke else ScaleConfig()
        results, summary = run_scale(scfg, backend)
        print(f"\nsummary [serving_scale/{backend}]:")
        for key, v in summary.items():
            print(f"  {key}: {v:.3f}" if isinstance(v, float)
                  else f"  {key}: {v}")
        entry = {
            "backend": backend,
            "jax_backend": jax.default_backend(),
            "mode": "smoke" if args.smoke else "full",
            "arm": "serving_scale",
            "config": dataclasses.asdict(scfg),
            "summary": summary,
            "results": results,
        }
        out = os.path.abspath(args.out)
        payload = merge_runs(out, entry)
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {out} ({len(payload['runs'])} run entries)")
        return 0

    results = []
    with ops.default_impl(BACKEND_IMPL[backend]):
        for m in cfg.corpus_grid:
            rng = np.random.default_rng(0)
            corpus = make_corpus(m, cfg.n_items, rng)
            for path in ("fused", "baseline"):
                r = bench_path(path, corpus, cfg, rng, backend)
                results.append(r)
                print(f"{path:9s} M={m:>7d} I={cfg.n_items} "
                      f"Q={r['q_batch']} mean={r['mean_ms']:8.2f} ms "
                      f"p99={r['p99_ms']:8.2f} ms "
                      f"({r['queries_per_s']:,.0f} q/s)")
            del corpus
        bucketing = bench_bucketing(cfg, np.random.default_rng(1))
    print(f"bucketing: {bucketing['request_sizes']} request sizes → "
          f"{bucketing['compiled_shapes']} compiled shapes "
          f"({bucketing['pow2_buckets']} pow2 buckets)")
    summary = summarize(results, bucketing, cfg, backend)
    print(f"\nsummary [{backend}]:")
    for key, v in summary.items():
        note = ""
        if key == "serving_fused_baseline_parity_at_max_corpus":
            note = ("  (~1x by construction — bitwise-pinned paths; "
                    "the TPU arm is the perf claim)")
        elif key == "serving_compiled_programs":
            note = "  (gated: must not increase)"
        print(f"  {key}: {v:.2f}{note}" if isinstance(v, float)
              else f"  {key}: {v}{note}")

    entry = {
        "backend": backend,
        "jax_backend": jax.default_backend(),
        "mode": "smoke" if args.smoke else "full",
        "arm": "serving",
        "config": dataclasses.asdict(cfg),
        "summary": summary,
        "results": results,
    }
    out = os.path.abspath(args.out)
    payload = merge_runs(out, entry)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out} ({len(payload['runs'])} run entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
