"""Seeded-violation corpus runner (DESIGN.md §10.5).

Each file under ``tests/analysis_corpus/`` is a known-bad snippet —
parsed, never imported — and ``manifest.json`` maps it to the checker
that must flag it plus the rule ids expected.  A case passes when the
expected rules are a subset of what the checker reports; the corpus is
the analyzer's own regression suite (``lint_kernels.py --selftest``),
so a rule that silently stops firing fails CI the same way a kernel
regression would.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List

from repro.analysis import astutil, engine_rules, kernel_rules, \
    oracle_rules
from repro.analysis.contracts import OOB_WRITE, KernelContract

# Closed fault-site registry the corpus `faults` checker validates
# against (a fixed stand-in for the real faults.py registries).
CORPUS_FAULT_SITES = frozenset({"npz.pre_write", "LATEST.pre_replace"})


def _small_model(bq: int = 128, bm: int = 256) -> int:
    return 3 * bq * bm * 4


def _blowup_model(d: int, bq: int = 128) -> int:
    return bq * d * 4


def _contract(**kw) -> KernelContract:
    base: Dict[str, object] = dict(
        module="corpus", entry="thing", body="_kernel", grid_rank=2,
        tail={0: OOB_WRITE, 1: "tile >= m"}, accumulators=("float32",),
        vmem_model=_small_model, max_shapes={"bq": 128, "bm": 256})
    base.update(kw)
    return KernelContract(**base)  # type: ignore[arg-type]


# Contracts the `kernel` checker pairs with each corpus file — written
# so only the seeded defect (plus its knock-ons) fires.
CORPUS_CONTRACTS: Dict[str, Dict[str, KernelContract]] = {
    "kc01_unregistered.py": {},
    "kc02_grid_arity.py": {"thing": _contract()},
    "kc02_prefetch_arity.py": {"thing": _contract()},
    "kc03_vmem_blowup.py": {"thing": _contract(
        vmem_model=_blowup_model, max_shapes={"d": 1 << 20, "bq": 128})},
    "kc04_missing_tailmask.py": {"thing": _contract(
        tail={0: OOB_WRITE})},
    "kc04_undeclared_cdiv.py": {"thing": _contract(tail={})},
    "kc05_implicit_dot.py": {"thing": _contract()},
    "kc05_f16_dot.py": {"thing": _contract()},
    "kc06_float64.py": {"thing": _contract()},
    "kc07_exp_in_parity.py": {"thing": _contract()},
    "kc08_accum_dtype.py": {"thing": _contract()},
}


@dataclasses.dataclass
class CaseResult:
    """One corpus case: expected rule ids vs what the checker found."""

    name: str
    expected: List[str]
    found: List[str]

    @property
    def ok(self) -> bool:
        """True when every expected rule id was reported."""
        return set(self.expected) <= set(self.found)

    def __str__(self) -> str:
        mark = "ok  " if self.ok else "FAIL"
        return (f"{mark} {self.name}: expected {sorted(self.expected)}, "
                f"found {sorted(set(self.found))}")


def run_case(path: Path, spec: Dict) -> CaseResult:
    """Run the checker named by ``spec['checker']`` over one case file."""
    checker = spec["checker"]
    if checker == "bench":
        findings = engine_rules.check_bench_keys(path)
    else:
        sf = astutil.load(path)
        if checker == "kernel":
            findings = kernel_rules.check_kernel_file(
                path, sf.tree, sf.text,
                CORPUS_CONTRACTS.get(path.name, {}))
        elif checker == "ops":
            findings = oracle_rules.check_dispatchers_in_tree(
                sf.tree, path, ref_names=set())
        elif checker == "duplicate":
            a, b = spec["pair"]
            findings = oracle_rules.check_duplicate_pair(
                (path, a), (path, b))
        elif checker == "store":
            findings = engine_rules.check_commit_paths_in_tree(
                sf.tree, path)
        elif checker == "faults":
            findings, _ = engine_rules.check_trip_calls_in_tree(
                sf.tree, path, set(CORPUS_FAULT_SITES))
        else:
            raise ValueError(f"{path.name}: unknown checker {checker!r}")
    return CaseResult(name=path.name, expected=list(spec["rules"]),
                      found=[f.rule for f in findings])


def run_corpus(corpus_dir: Path) -> List[CaseResult]:
    """Run every case listed in ``corpus_dir/manifest.json``."""
    manifest = json.loads((corpus_dir / "manifest.json").read_text())
    results = []
    for name in sorted(manifest):
        results.append(run_case(corpus_dir / name, manifest[name]))
    return results
