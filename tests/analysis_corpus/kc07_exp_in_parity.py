"""Corpus case: approximate transcendental in an exact-parity kernel
body (expected KC07).

jnp.exp lowers to a polynomial approximation on TPU; a kernel whose
oracle is compared bitwise cannot use it (the flash-attention kernel
opts out via exact_parity=False — this contract does not).
"""
import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref, *, m):
    tile = pl.program_id(1)
    vals = jnp.exp(x_ref[...])
    vals = jnp.where(tile >= m, 0.0, vals)
    acc_ref[...] = vals
    o_ref[...] = acc_ref[...]


def thing(x, n, m, bq=128, bm=256):
    grid = (pl.cdiv(n, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi))],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )(x)
