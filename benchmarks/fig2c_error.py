"""Paper Fig. 2c: numeric error growth under repeated decremental
updates; hyper-parameters fixed to the paper's m=2, r_g=0.7, r_b=0.9.

Reports the fitted exponential base (theory: k/((k-1)·r_g) per group-
vanish deletion) and the deletion budget to 1% relative error — in BOTH
f64 (paper's JVM doubles) and f32 (TPU-native), plus the stability
tracker's predicted budget (core.stability, beyond-paper).
"""
from __future__ import annotations

import numpy as np

from repro.core import RefEngine, TifuParams, stability
from repro.core.tifu import user_vector_ragged


def run(dtype, n0=420, n_del=400, seed=0):
    p = TifuParams(n_items=8, group_size=2, r_b=0.9, r_g=0.7)
    rng = np.random.default_rng(seed)
    eng = RefEngine(p, dtype=dtype)
    hist = []
    for _ in range(n0):
        b = rng.choice(p.n_items, size=2, replace=False)
        eng.add_basket(0, b)
        hist.append(b)
    sizes = list(eng.state(0).group_sizes)
    rows = []
    for k in range(1, n_del + 1):
        eng.delete_basket(0, 0)
        # mirror bookkeeping for the true value
        if sizes[0] > 1:
            sizes[0] -= 1
        else:
            sizes.pop(0)
        del hist[0]
        truth = user_vector_ragged(hist, sizes, p)
        denom = max(np.max(np.abs(truth)), 1e-30)
        rel = float(np.max(np.abs(eng.state(0).user_vec - truth)) / denom)
        rows.append((k, rel))
    return rows


def deletions_to(rows, target):
    for k, rel in rows:
        if rel >= target:
            return k
    return None


def main():
    print("# fig2c: dtype,k,rel_err")
    for dtype in (np.float64, np.float32):
        rows = run(dtype)
        for k, rel in rows[:: max(len(rows) // 10, 1)]:
            print(f"fig2c,{np.dtype(dtype).name},{k},{rel:.3e}")
        d1 = deletions_to(rows, 1e-2)
        print(f"# {np.dtype(dtype).name}: deletions to 1% rel err: {d1}")
        # fitted growth base vs theory
        ks = np.array([k for k, r in rows if 1e-12 < r < 1e-2])
        rs = np.array([r for k, r in rows if 1e-12 < r < 1e-2])
        if len(ks) > 5:
            base = np.exp(np.polyfit(ks, np.log(rs), 1)[0])
            print(f"# {np.dtype(dtype).name}: fitted per-deletion error "
                  f"base {base:.4f} (theory ~ k/((k-1)*0.7) for group "
                  f"deletes)")
    budget = stability.deletion_budget(
        k_groups=210, r_g=0.7, target_rel_err=1e-2,
        eps=float(np.finfo(np.float32).eps))
    print(f"# stability-tracker predicted f32 budget (k=210): {budget}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
