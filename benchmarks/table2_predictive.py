"""Paper Table 2: Recall@{10,20} / NDCG@{10,20} for baseline (retrain
from scratch) vs incremental vs decremental maintenance, on synthetic
datasets matching TaFeng/Instacart/ValuedShopper statistics.

Claim under test: incremental == baseline EXACTLY; decremental shows no
significant regression (paper: differences ≤ ~3e-4 absolute).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import RefEngine, knn
from repro.data import synthetic


def evaluate(user_vecs: np.ndarray, users, test, params, ks=(10, 20)):
    corpus = jnp.asarray(user_vecs, jnp.float32)
    pred = knn.predict(corpus, corpus, k=params.k_neighbors,
                       alpha=params.alpha, exclude_self=True)
    recs = np.asarray(knn.recommend_topn(pred, max(ks)))
    truth = [test[u] for u in users]
    out = {}
    for k in ks:
        out[f"recall@{k}"] = knn.recall_at_k(recs, truth, k)
        out[f"ndcg@{k}"] = knn.ndcg_at_k(recs, truth, k)
    return out


def run(dataset="tafeng", scale=0.15, seed=0, deletion_user_rate=1e-3,
        deletion_frac=0.10):
    ds = synthetic.generate(dataset, scale=scale, seed=seed)
    p = ds.params
    train, test = ds.train_test_split()
    users = sorted(train)
    rng = np.random.default_rng(seed + 1)

    # --- baseline: full from-scratch training --------------------------------
    base = RefEngine(p)
    for u in users:
        base.fit_from_scratch(u, train[u])
    m_base = evaluate(base.user_matrix(users), users, test, p)

    # --- incremental: basket-by-basket online learning -----------------------
    incr = RefEngine(p)
    for u in users:
        for b in train[u]:
            incr.add_basket(u, b)
    m_incr = evaluate(incr.user_matrix(users), users, test, p)
    max_vec_diff = max(
        float(np.max(np.abs(incr.state(u).user_vec - base.state(u).user_vec)))
        for u in users)

    # --- decremental: paper §6.1 — ~1/1000 users delete 10% of baskets ------
    decr = RefEngine(p)
    for u in users:
        decr.fit_from_scratch(u, train[u])
    n_del_users = max(1, int(len(users) * max(deletion_user_rate, 1e-3)))
    for u in rng.choice(users, size=n_del_users, replace=False):
        st = decr.state(int(u))
        n_del = max(1, int(st.n_baskets * deletion_frac))
        for _ in range(n_del):
            if st.n_baskets == 0:
                break
            decr.delete_basket(int(u), int(rng.integers(0, st.n_baskets)))
    m_decr = evaluate(decr.user_matrix(users), users, test, p)

    rows = []
    for metric in ("recall@10", "ndcg@10", "recall@20", "ndcg@20"):
        rows.append((dataset, metric, m_base[metric], m_incr[metric],
                     m_decr[metric]))
    return rows, max_vec_diff


def main(scale=0.15):
    print("dataset,metric,baseline,incremental,decremental")
    for ds in ("tafeng", "instacart", "valuedshopper"):
        sc = scale if ds != "valuedshopper" else scale / 2  # 57 b/user
        rows, vec_diff = run(ds, scale=sc)
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]:.4f},{r[3]:.4f},{r[4]:.4f}")
        assert vec_diff < 1e-10, \
            f"incremental not exact on {ds}: {vec_diff}"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
