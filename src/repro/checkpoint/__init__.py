from repro.checkpoint.pytree import (AsyncCheckpointer, latest_step,
                                     restore_pytree, save_pytree)

__all__ = ["AsyncCheckpointer", "latest_step", "restore_pytree",
           "save_pytree"]
