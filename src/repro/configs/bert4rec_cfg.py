"""bert4rec [arXiv:1904.06690] — embed_dim=64 2 blocks 2 heads seq=200,
bidirectional cloze training; 1M-item catalogue (padded)."""
from repro.configs import recsys_shapes as rs
from repro.configs.base import ArchDef, recsys_cell
from repro.models import bert4rec


def make_config():
    return bert4rec.Bert4RecConfig()


def smoke_config():
    return bert4rec.Bert4RecConfig(n_items=500, embed_dim=32, n_blocks=2,
                                   n_heads=2, seq_len=16, d_ff=64)


def _flops_train(c):
    per_tok = c.n_blocks * (4 * c.embed_dim ** 2 + 2 * c.embed_dim * c.d_ff)
    return 6.0 * per_tok * rs.TRAIN_BATCH * c.seq_len


ARCH = ArchDef(
    name="bert4rec", family="recsys",
    cells={
        "train_batch": recsys_cell(
            bert4rec, make_config, rs.bert4rec_batch(rs.TRAIN_BATCH),
            "sampled-cloze train B=65536", train=True, pass_mesh=True,
            train_kwargs={"sampled": True}, flops_fn=_flops_train),
        "serve_p99": recsys_cell(
            bert4rec, make_config,
            rs.bert4rec_batch(rs.SERVE_P99, train=False), "serve B=512", pass_mesh=True),
        "serve_bulk": recsys_cell(
            bert4rec, make_config,
            rs.bert4rec_batch(rs.SERVE_BULK, train=False), "serve B=262144", pass_mesh=True),
        "retrieval_cand": recsys_cell(
            bert4rec, make_config, rs.bert4rec_retrieval_batch(),
            "1 query vs 1M candidates", serve_fn="retrieval_step", pass_mesh=True),
    },
    make_smoke=smoke_config,
    notes="encoder-only (no decode shapes); paper's closed-form unlearning "
          "does NOT apply (learned seq model) — DESIGN.md §4.")
