"""Host/jit tile plans for the sparse row kernels (DESIGN.md §3.3/§3.5).

The sparse scatter/gather pair addresses O(U·W) table *elements*, but a
TPU grid step moves whole ``[1, bi]`` item tiles: without a plan the
kernels sweep every tile of every touched row — O(U·I) HBM traffic, the
one place the TPU path used to be asymptotically worse than the XLA
reference.  A ``TilePlan`` fixes that: it enumerates, per batch row, the
sorted deduplicated list of item tiles the row's ids actually touch
(``row_tiles``; PAD = −1), then flattens those ``(batch, target row,
tile)`` work items into a static ``U·T_max`` step sequence whose
scalar-prefetched arrays drive the kernels' block index maps.  A grid
step DMAs only a genuinely dirty tile; padding steps repeat the previous
step's block (the pipeline skips the fetch when the block index does not
change) and are ``pl.when``-guarded out of the compute, so HBM traffic
is O(U·W) regardless of vocabulary size.

Two step orders serve the two kernels:

* ``order="target"`` (scatter): work items are sorted by
  ``(target row, tile)``, so every visit to one output block — including
  visits contributed by *duplicate* target rows — lands on consecutive
  grid steps.  That is the only order under which the scatter's
  load/accumulate/store-per-run contract is safe: duplicate rows with
  differing supports would otherwise revisit a block non-consecutively,
  which Pallas leaves undefined.  Padding steps clone the last real work
  item and sort to the end.
* ``order="batch"`` (gather): work items stay grouped by batch row
  (reads commute, duplicates need no merging), so each output ``[1, W]``
  row block is resident for exactly its row's tile run.  Padding steps
  repeat the row's last real tile (tile 0 for all-PAD rows).

``plan_dma_tiles`` counts the table tiles a plan actually DMAs (block
index changes + 1) — the quantity the acceptance tests pin to the
touched-tile count rather than ``I/bi``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_SENTINEL = np.int32(np.iinfo(np.int32).max)


class TilePlan(NamedTuple):
    """Flattened step sequence for a ``(U, T_max)`` kernel grid.

    All arrays are i32[U·T_max]; step ``s = r·T_max + t``.  ``batch[s]``
    is the batch row whose ids/vals the step reads, ``row[s]``/``tile[s]``
    the table block it maps (always safe to index — padding steps clone a
    real block), ``valid[s]`` 1 for real work items and 0 for padding.
    """
    batch: jax.Array
    row: jax.Array
    tile: jax.Array
    valid: jax.Array


def row_tiles(ids, bi: int):
    """Per-row sorted deduplicated touched item tiles, PAD = −1.

    ids: i32[U, W] (PAD = −1) → i32[U, W] with each row's unique tiles
    ascending first and −1 padding after (at most W uniques per row).
    """
    u, w = ids.shape
    t = jnp.where(ids >= 0, ids // bi, _SENTINEL)
    t = jnp.sort(t, axis=1)
    dup = jnp.concatenate([jnp.zeros((u, 1), bool), t[:, 1:] == t[:, :-1]],
                          axis=1)
    t = jnp.sort(jnp.where(dup, _SENTINEL, t), axis=1)
    return jnp.where(t == _SENTINEL, -1, t).astype(jnp.int32)


def row_tile_counts(ids, bi: int):
    """Per-row touched-tile counts, floored at 1: i32[U].

    ``ids i32[U, W]`` (PAD = −1) → number of distinct item tiles each
    row touches (an all-PAD row counts 1: every plan reserves at least
    one guarded step).  Traceable under jit — this is the device half
    of the ``T_max`` measurement that :func:`max_touched_tiles` does on
    host, used by the streaming engine's fused step summary so the
    bound rides the single per-step transfer instead of forcing its
    own history fetch (DESIGN.md §12).
    """
    t = row_tiles(ids, bi)
    return jnp.maximum(jnp.sum((t >= 0).astype(jnp.int32), axis=1),
                       1).astype(jnp.int32)


def history_support_tile_bound(history, n_baskets, extra_ids, valid,
                               *, bi: int):
    """Scalar touched-tile bound for delete supports, on device.

    The delete appliers' support for user row ``r`` is the whole live
    history window ``history[r, :n_baskets[r]]`` plus (for item
    deletes) the deleted id itself — passed as ``extra_ids i32[U]``
    with −1 for "none".  ``valid bool[U]`` masks padding rows (their
    count is forced to 1, never 0, so the max stays a sound plan
    size).  Returns the i32[] max over rows; jit-traceable with static
    ``bi``.
    """
    u, n, b = history.shape
    live = jnp.arange(n, dtype=jnp.int32)[None, :, None] \
        < n_baskets[:, None, None]
    ids = jnp.where(live, history, -1).reshape(u, n * b)
    ids = jnp.concatenate([ids, extra_ids[:, None].astype(jnp.int32)],
                          axis=1)
    counts = jnp.where(valid, row_tile_counts(ids, bi), 1)
    return jnp.max(counts)


def add_support_tile_bound(history, group_sizes, n_baskets, n_groups,
                           new_ids, valid, *, bi: int):
    """Scalar touched-tile bound for the add support, on device.

    The add applier touches the new basket's ids (``new_ids i32[U, W]``,
    PAD = −1) plus the user's LAST group window
    ``history[r, n−tau : n]`` where ``tau`` is the last group's size —
    the rows Eq. 8's group-vector update re-reads.  Same masking
    contract as :func:`history_support_tile_bound`; returns the i32[]
    max over valid rows.
    """
    u, n, b = history.shape
    rows = jnp.arange(u, dtype=jnp.int32)
    tau = jnp.where(
        n_groups > 0,
        group_sizes[rows, jnp.maximum(n_groups - 1, 0)], 0)
    lo = jnp.maximum(n_baskets - tau, 0)
    pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    live = (pos >= lo[:, None]) & (pos < n_baskets[:, None])
    ids = jnp.where(live[:, :, None], history, -1).reshape(u, n * b)
    ids = jnp.concatenate([ids, new_ids.astype(jnp.int32)], axis=1)
    counts = jnp.where(valid, row_tile_counts(ids, bi), 1)
    return jnp.max(counts)


def build_plan(rows, ids, *, bi: int, t_max: int,
               order: str = "target") -> TilePlan:
    """Build the step plan for ``rows i32[U]``, ``ids i32[U, W]``.

    ``t_max`` is static and must be >= the largest per-row touched-tile
    count (``min(W, I/bi)`` is always safe; the ops dispatcher measures
    the true maximum when the inputs are concrete).  Traceable under jit.
    """
    u, w = ids.shape
    tiles = row_tiles(ids, bi)[:, :t_max]                    # [U, T]
    rows = jnp.clip(rows, 0, None).astype(jnp.int32)
    batch = jnp.broadcast_to(
        jnp.arange(u, dtype=jnp.int32)[:, None], (u, t_max))
    trow = jnp.broadcast_to(rows[:, None], (u, t_max))
    valid = tiles >= 0

    if order == "batch":
        # padding repeats the row's last real tile -> no block change, no
        # DMA; all-PAD rows fall back to tile 0 (guarded to a no-op).
        safe = jnp.maximum(jax.lax.cummax(tiles, axis=1), 0)
        return TilePlan(batch.ravel(), trow.ravel(), safe.ravel(),
                        valid.ravel().astype(jnp.int32))
    if order != "target":
        raise ValueError(order)

    fb, fr = batch.ravel(), trow.ravel()
    ft, fv = tiles.ravel(), valid.ravel()
    # lexicographic (target row, tile) via two stable passes; padding
    # sorts to the very end of the step sequence
    o1 = jnp.argsort(jnp.where(fv, ft, _SENTINEL), stable=True)
    fb, fr, ft, fv = fb[o1], fr[o1], ft[o1], fv[o1]
    o2 = jnp.argsort(jnp.where(fv, fr, _SENTINEL), stable=True)
    fb, fr, ft, fv = fb[o2], fr[o2], ft[o2], fv[o2]
    # padding clones the last real work item (guarded no-op on the same
    # block, extending its run); an all-PAD batch falls back to block
    # (rows[0], 0) which is loaded and stored back unchanged.
    n_valid = jnp.sum(fv.astype(jnp.int32))
    last = jnp.maximum(n_valid - 1, 0)

    def fill(x, default):
        filler = jnp.where(n_valid > 0, x[last], default)
        return jnp.where(fv, x, filler)

    return TilePlan(fill(fb, 0), fill(fr, rows[0]), fill(ft, 0),
                    fv.astype(jnp.int32))


def plan_dma_tiles(plan: TilePlan) -> int:
    """Number of table tiles the plan DMAs (block-index changes + 1).

    Consecutive steps mapping the same ``(row, tile)`` block share one
    fetch, so the DMA count is the number of block-index changes + 1.
    The acceptance contract pins it to the touched-tile count (never
    ``U · I/bi``).
    """
    r, t = np.asarray(plan.row), np.asarray(plan.tile)
    if r.size == 0:
        return 0
    return int(np.sum((r[1:] != r[:-1]) | (t[1:] != t[:-1]))) + 1


def max_touched_tiles(ids, bi: int) -> int:
    """Largest per-row touched-tile count (host-side, concrete ids only).

    The ops dispatcher uses this to shrink ``T_max`` below the static
    ``min(W, I/bi)`` worst case when the batch is available on host.
    """
    t = np.asarray(ids)
    t = np.where(t >= 0, t // bi, -1)
    best = 1
    for row in t:
        row = row[row >= 0]
        if row.size:
            best = max(best, int(np.unique(row).size))
    return best
