"""Pin the analytic VMEM models to real pallas_call block allocations.

The KC03 lint rule budgets each kernel contract by evaluating its
``repro.analysis.vmem`` model at the declared max shapes — which is
only meaningful if the models count exactly the bytes the kernels
allocate.  These tests intercept ``pl.pallas_call`` and recompute the
per-grid-step block residency (every in/out BlockSpec at its block
shape × operand itemsize, plus every VMEM scratch buffer) from the
specs the kernel actually passes, over sampled (d, k, bq, bm, bd)
configurations, and require EXACT equality with the model.  The
stage-A capacity-planning model (``stage_a_vmem_bytes``) is pinned to
the exact models through closed-form deltas.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import vmem as avmem
from repro.analysis.contracts import REGISTRY
from repro.analysis.linter import load_contracts
from repro.kernels import ops
from repro.kernels.knn_topk import knn_topk, knn_topk_dtiled
from repro.kernels.serving_topn import (blend_topn_rows,
                                        blend_topn_rows_quant)


def _block_bytes(kw, operands) -> int:
    """Recompute one grid step's block residency from a pallas_call."""
    total = 0
    for spec, op in zip(kw["in_specs"], operands):
        total += int(np.prod(spec.block_shape)) \
            * np.dtype(op.dtype).itemsize
    out_specs, out_shapes = kw["out_specs"], kw["out_shape"]
    if not isinstance(out_specs, (list, tuple)):
        out_specs, out_shapes = [out_specs], [out_shapes]
    for spec, osh in zip(out_specs, out_shapes):
        total += int(np.prod(spec.block_shape)) \
            * np.dtype(osh.dtype).itemsize
    for scratch in kw.get("scratch_shapes", []):
        total += int(np.prod(scratch.shape)) \
            * np.dtype(scratch.dtype).itemsize
    return total


@pytest.fixture
def captured_bytes(monkeypatch):
    """Intercept pl.pallas_call; record each site's block bytes."""
    captured: list = []
    real = pl.pallas_call

    def spy(kernel, **kw):
        inner = real(kernel, **kw)

        def wrapped(*operands):
            captured.append(_block_bytes(kw, operands))
            return inner(*operands)

        return wrapped

    monkeypatch.setattr(pl, "pallas_call", spy)
    jax.clear_caches()  # jit caches would skip the retrace (and the spy)
    yield captured
    jax.clear_caches()


@pytest.mark.parametrize("d,k,bq,bm", [(32, 4, 8, 16), (48, 8, 16, 16),
                                       (64, 4, 8, 32)])
def test_knn_topk_model_matches_blocks(captured_bytes, d, k, bq, bm):
    rng = np.random.default_rng(0)
    q = rng.normal(size=(bq, d)).astype(np.float32)
    c = rng.normal(size=(3 * bm + 5, d)).astype(np.float32)
    knn_topk(q, c, k, bq=bq, bm=bm, interpret=True)
    assert captured_bytes == [avmem.knn_topk_block_bytes(
        d=d, k=k, bq=bq, bm=bm, itemsize=4)]


@pytest.mark.parametrize("d,k,bq,bm,bd", [(64, 4, 8, 16, 32),
                                          (96, 8, 8, 16, 48)])
def test_knn_topk_dtiled_model_matches_blocks(captured_bytes, d, k, bq,
                                              bm, bd):
    rng = np.random.default_rng(1)
    q = rng.normal(size=(bq, d)).astype(np.float32)
    c = rng.normal(size=(2 * bm + 3, d)).astype(np.float32)
    knn_topk_dtiled(q, c, k, bq=bq, bm=bm, bd=bd, interpret=True)
    assert captured_bytes == [avmem.knn_topk_dtiled_block_bytes(
        d=d, k=k, bq=bq, bm=bm, bd=bd, itemsize=4)]


def test_knn_topk_dtiled_int8_model_matches_blocks(captured_bytes):
    d, k, bq, bm, bd = 64, 4, 8, 16, 32
    rng = np.random.default_rng(2)
    q = rng.integers(-127, 128, size=(bq, d), dtype=np.int8)
    c = rng.integers(-127, 128, size=(2 * bm, d), dtype=np.int8)
    knn_topk_dtiled(q, c, k, bq=bq, bm=bm, bd=bd, interpret=True,
                    q_scale=np.ones(bq, np.float32),
                    c_scale=np.ones(2 * bm, np.float32))
    assert captured_bytes == [avmem.knn_topk_dtiled_block_bytes(
        d=d, k=k, bq=bq, bm=bm, bd=bd, itemsize=1)]


@pytest.mark.parametrize("k,topn,bq,bi", [(4, 8, 4, 32), (8, 16, 8, 64)])
def test_blend_topn_rows_model_matches_blocks(captured_bytes, k, topn,
                                              bq, bi):
    rng = np.random.default_rng(3)
    queries = rng.normal(size=(bq, 2 * bi)).astype(np.float32)
    nbrs = rng.normal(size=(bq, k, 2 * bi)).astype(np.float32)
    blend_topn_rows(queries, nbrs, 0.7, topn, bq=bq, bi=bi,
                    interpret=True)
    assert captured_bytes == [avmem.blend_topn_rows_block_bytes(
        k=k, topn=topn, bq=bq, bi=bi)]


def test_blend_topn_rows_quant_model_matches_blocks(captured_bytes):
    k, topn, bq, bi = 4, 8, 4, 32
    rng = np.random.default_rng(4)
    qq = rng.integers(-127, 128, size=(bq, 2 * bi), dtype=np.int8)
    nq = rng.integers(-127, 128, size=(bq, k, 2 * bi), dtype=np.int8)
    blend_topn_rows_quant(qq, np.ones(bq, np.float32), nq,
                          np.ones((bq, k), np.float32), 0.7, topn,
                          bq=bq, bi=bi, interpret=True)
    assert captured_bytes == [avmem.blend_topn_rows_quant_block_bytes(
        k=k, topn=topn, bq=bq, bi=bi)]


@pytest.mark.parametrize("d,k,bq,bm,bd", [(256, 16, 128, 512, 128),
                                          (1024, 64, 64, 256, 512),
                                          (4096, 300, 128, 512, 512)])
def test_stage_a_delta_identities(d, k, bq, bm, bd):
    # the planning model drops exactly the O(bq + bm) side vectors the
    # exact models count; the closed-form deltas pin that relationship
    mono_delta = (avmem.knn_topk_block_bytes(d=d, k=k, bq=bq, bm=bm)
                  - avmem.stage_a_vmem_bytes(d, k, bq=bq, bm=bm))
    assert mono_delta == bq * 4 + bm * 4 + bq * k * 8 - bq * bm * 4
    dt_delta = (avmem.knn_topk_dtiled_block_bytes(d=d, k=k, bq=bq,
                                                  bm=bm, bd=bd)
                - avmem.stage_a_vmem_bytes(d, k, bq=bq, bm=bm, bd=bd))
    assert dt_delta == 3 * bq * 4 + 2 * bm * 4 + bq * k * 8


def test_ops_stage_a_delegates():
    for args in ((256, 16), (65536, 300), (1 << 20, 300)):
        for bd in (None, 512):
            assert ops.stage_a_vmem_bytes(*args, bd=bd) \
                == avmem.stage_a_vmem_bytes(*args, bd=bd)


def test_all_contracts_under_budget():
    load_contracts()
    assert len(REGISTRY) >= 9
    for (module, entry), c in REGISTRY.items():
        used = c.max_vmem_bytes()
        assert 0 < used <= avmem.VMEM_BUDGET_BYTES, (module, entry, used)
