"""Streaming engine (Algorithm 1): parity with the ref engine, per-user
ordering under conflicts, exactly-once recovery, stability refresh."""
import dataclasses

import numpy as np

from repro.core import RefEngine, TifuParams, KIND_ADD_BASKET
from repro.data import stream, synthetic
from repro.streaming import Event, StateStore, StoreConfig, StreamingEngine

P = TifuParams(n_items=29, group_size=3)


def make_engine(n_users=8, batch_size=16, **kw):
    store = StateStore(StoreConfig(n_users=n_users, n_items=P.n_items,
                                   max_baskets=24, max_basket_size=6))
    return StreamingEngine(store, P, batch_size=batch_size, **kw), store


def test_engine_matches_ref(rng):
    eng, store = make_engine()
    ref = RefEngine(P, dtype=np.float32)
    for _ in range(120):
        u = int(rng.integers(0, 8))
        nb = ref.state(u).n_baskets
        if nb == 0 or (rng.random() < 0.7 and nb < 22):
            items = rng.choice(P.n_items, size=int(rng.integers(1, 5)),
                               replace=False)
            eng.add_basket(u, items)
            ref.add_basket(u, items)
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            eng.delete_basket(u, pos)
            ref.delete_basket(u, pos)
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(ref.state(u).history[pos]))
            eng.delete_item(u, pos, item)
            ref.delete_item(u, pos, item)
    eng.run_until_drained()
    for u in range(8):
        np.testing.assert_allclose(
            np.asarray(store.state.materialized_user_vecs()[u]),
            ref.state(u).user_vec.astype(np.float32), atol=1e-4)


def test_per_user_order_preserved_under_conflicts(rng):
    """Many events for ONE user in a single submit: the engine must apply
    them sequentially (one per micro-batch) in order."""
    eng, store = make_engine(batch_size=4)
    ref = RefEngine(P, dtype=np.float32)
    baskets = [rng.choice(P.n_items, size=3, replace=False)
               for _ in range(10)]
    for b in baskets:
        eng.add_basket(3, b)
        ref.add_basket(3, b)
    eng.delete_basket(3, 0)
    ref.delete_basket(3, 0)
    eng.run_until_drained()
    np.testing.assert_allclose(np.asarray(store.state.materialized_user_vecs()[3]),
                               ref.state(3).user_vec.astype(np.float32),
                               atol=1e-4)
    assert int(store.state.n_baskets[3]) == 9


def test_exactly_once_recovery(rng, tmp_path):
    """Process half the stream, checkpoint, replay everything from the
    start against the restored engine: already-processed seqnos must be
    skipped and the final state must equal the single-pass run."""
    events = []
    for t in range(40):
        u = int(rng.integers(0, 8))
        items = rng.choice(P.n_items, size=3, replace=False)
        events.append(Event(KIND_ADD_BASKET, u, items=items))

    # single-pass reference run
    eng1, store1 = make_engine()
    eng1.submit(events)
    eng1.run_until_drained()

    # half-run + crash + restore + full replay
    eng2, store2 = make_engine()
    eng2.submit(events)
    for _ in range(2):
        eng2.step()
    eng2.checkpoint(str(tmp_path), 1)
    processed = eng2.metrics.events_processed

    eng3, store3 = make_engine()
    eng3.restore(str(tmp_path))
    # replay the FULL stream with original seqnos (at-least-once delivery)
    replay = [dataclasses.replace(ev, seqno=i)
              for i, ev in enumerate(events)]
    eng3.submit(replay)
    assert eng3.n_pending == len(events) - processed  # dups skipped
    eng3.run_until_drained()
    np.testing.assert_allclose(np.asarray(store3.state.materialized_user_vecs()),
                               np.asarray(store1.state.materialized_user_vecs()),
                               atol=1e-5)


def test_redelivered_pending_event_is_not_double_applied(rng):
    """At-least-once sources may redeliver an event whose first copy is
    still BUFFERED (not yet processed): the duplicate must be dropped at
    submit time, not enqueued and applied twice (regression: submit only
    deduped against processed seqnos)."""
    eng, store = make_engine()
    ref = RefEngine(P, dtype=np.float32)
    baskets = [rng.choice(P.n_items, size=3, replace=False)
               for _ in range(4)]
    events = [Event(KIND_ADD_BASKET, 2, items=b, seqno=i)
              for i, b in enumerate(baskets)]
    for b in baskets:
        ref.add_basket(2, b)
    eng.submit(events)
    assert eng.n_pending == 4
    # redelivery before ANY processing: all four still pending
    eng.submit(events)
    assert eng.n_pending == 4
    eng.step()          # conflict deferral: one event applied, 3 pending
    assert eng.n_pending == 3
    # redelivery straddling processed AND pending copies
    eng.submit(events)
    assert eng.n_pending == 3
    eng.run_until_drained()
    assert int(store.state.n_baskets[2]) == 4    # not 8
    np.testing.assert_allclose(
        np.asarray(store.state.materialized_user_vecs()[2]),
        ref.state(2).user_vec.astype(np.float32), atol=1e-4)


def test_interrupted_engine_checkpoint_write_is_not_picked_up(rng, tmp_path):
    """The exactly-once log commits atomically WITH the state inside
    LATEST (fsync'd tmp + os.replace): a crash mid-write leaves a stray
    partial .tmp next to the intact previous commit, and restore must
    read the intact one (regression: ENGINE was a second, separately
    written file, so a crash could tear the state/log pair — a torn log
    replays below the old watermark onto the new state)."""
    import json
    import os
    eng, store = make_engine()
    for t in range(8):
        eng.add_basket(t % 4, rng.choice(P.n_items, size=3, replace=False))
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 1)
    assert not os.path.exists(os.path.join(str(tmp_path), "LATEST.tmp"))
    watermark = eng.watermark
    assert watermark >= 0
    # simulate a crash mid-way through the NEXT checkpoint's commit
    with open(os.path.join(str(tmp_path), "LATEST.tmp"), "w") as f:
        f.write('{"step": 2, "engine": {"watermark": 99999, "proc')
    eng2, _ = make_engine()
    eng2.restore(str(tmp_path))
    assert eng2.watermark == watermark            # intact commit won
    # legacy layout (separate ENGINE file, pre-fold checkpoints) still
    # restores through the fallback path
    latest = os.path.join(str(tmp_path), "LATEST")
    with open(latest) as f:
        meta = json.load(f)
    legacy_engine = meta.pop("engine")
    # legacy files predate the integrity fields: drop them too (keeping
    # a stale meta_crc32 would — correctly — read as corruption)
    meta.pop("meta_crc32", None)
    meta.pop("npz_crc32", None)
    meta.pop("npz_bytes", None)
    with open(latest, "w") as f:
        json.dump(meta, f)
    with open(os.path.join(str(tmp_path), "ENGINE"), "w") as f:
        json.dump(legacy_engine, f)
    eng3, _ = make_engine()
    eng3.restore(str(tmp_path))
    assert eng3.watermark == watermark


def test_restore_rejects_mismatched_shapes(rng, tmp_path):
    """Restoring a checkpoint whose LATEST meta disagrees with the
    store's shape config must raise, not silently install wrong-shaped
    (or index-aliased) state."""
    import pytest
    eng, store = make_engine(n_users=8)
    eng.add_basket(1, rng.choice(P.n_items, size=3, replace=False))
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), 0)
    for bad in [dict(n_users=16), dict(n_items=P.n_items + 1),
                dict(max_baskets=99), dict(max_basket_size=2)]:
        cfg = StoreConfig(n_users=8, n_items=P.n_items, max_baskets=24,
                          max_basket_size=6)
        for k, v in bad.items():
            setattr(cfg, k, v)
        store2 = StateStore(cfg)
        with pytest.raises(ValueError, match="shape mismatch"):
            store2.restore(str(tmp_path))
        with pytest.raises(ValueError, match=next(iter(bad))):
            store2.restore(str(tmp_path))
    # matching config still restores
    ok = StateStore(StoreConfig(n_users=8, n_items=P.n_items,
                                max_baskets=24, max_basket_size=6))
    assert ok.restore(str(tmp_path)) == 0


def test_paper_deletion_scenario(rng):
    """§6.1 setup: 1/1000 users delete 10% of baskets; engine stays
    consistent with from-scratch on the surviving history."""
    ds = synthetic.generate("tafeng", scale=0.004, seed=1)
    p = ds.params
    n_users = len(ds.histories)
    store = StateStore(StoreConfig(
        n_users=n_users, n_items=p.n_items,
        max_baskets=max(len(h) for h in ds.histories.values()) + 4,
        max_basket_size=max((len(b) for h in ds.histories.values()
                             for b in h), default=8) + 2))
    eng = StreamingEngine(store, p, batch_size=64)
    events = stream.make_stream(ds.histories, deletion_user_rate=0.1,
                                deletion_basket_frac=0.3, seed=2)
    eng.submit(events)
    n = eng.run_until_drained()
    assert n == len(events)
    # spot-check a few users against from-scratch on the engine's history
    from repro.core.tifu import user_vector_padded
    for u in list(ds.histories)[:5]:
        vec = np.asarray(store.state.materialized_user_vecs()[u])
        fresh = np.asarray(user_vector_padded(
            store.state.history[u], store.state.group_sizes[u],
            store.state.n_groups[u], p))
        np.testing.assert_allclose(vec, fresh, atol=1e-3)
