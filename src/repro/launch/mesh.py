"""Production meshes. v5e-256 per pod: single-pod (16,16) = 256 chips,
multi-pod (2,16,16) = 512 chips.  A FUNCTION so importing this module
never touches jax device state (dryrun sets the device-count env first).
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh=None) -> ShardingRules:
    """Default sharding rules: batch/FSDP over (pod,data), TP over model."""
    return ShardingRules(batch=("pod", "data"), fsdp=("pod", "data"),
                         tensor="model", expert="model", context="model")


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CI-size dry-runs (subprocess tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~per chip per direction)
HBM_PER_CHIP = 16 * 2 ** 30    # 16 GiB
