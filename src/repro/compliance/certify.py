"""Retained-equivalence certification (GDPR unlearning, DESIGN.md §11).

Given an engine and the event log it processed, prove the unlearning
property the deletion paths exist for: the maintained state must be
equivalent to a model that was fit on the **retained** data only, and a
forgotten user must leave **no trace** in any live or persisted
artifact.  The paper's §4.3 varying-group-size relaxation makes exact
retrain-equivalence unattainable after a deletion — the maintained
group structure is path-dependent — so the certificate is layered:

* **structural** — the engine's stored history must contain exactly the
  retained baskets (an event-by-event semantic replay of the log), for
  every user.  A skipped or phantom deletion fails here.
* **pure-add bitwise** — users never touched by a deletion must match a
  fresh engine replay of their add events bit for bit, on every state
  leaf (the add path is deterministic and row-independent).
* **path fit** — deletion-bearing users must match the Eq. 1+2 closed
  form evaluated on (retained history, *maintained* group structure)
  within a small float envelope: the float state is a function of the
  retained data alone.
* **canonical envelope** — against the from-scratch retained-only fit
  (canonical ``default_group_sizes`` regrouping) the divergence is
  bounded by the per-user envelope of :func:`divergence_envelope`,
  derived in DESIGN.md §11.2.
* **top-n overlap** — serving from the maintained corpus and from the
  canonical retained-only corpus must agree on at least
  ``overlap_floor`` of each top-n list on average.
* **no trace** — a forgotten user's rows are exactly zero in the state,
  the fp32 and int8 serving caches, and a checkpoint round-trip; the
  dead-letter queues hold none of their events.

``certify`` works on both :class:`~repro.streaming.StreamingEngine` and
:class:`~repro.streaming.ShardedStreamingEngine` and is the check behind
``forget_user`` receipts, ``tests/test_compliance.py`` and the
``arm="compliance"`` benchmark (benchmarks/bench_compliance.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.tifu import default_group_sizes, user_vector_ragged
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, TifuParams)
from repro.core import knn
from repro.streaming import (Event, ShardedStreamingEngine, StateStore,
                             StoreConfig, StreamingEngine,
                             load_checkpoint_arrays)

# Float envelope for the path-fit check: the f32 engine accumulates
# roundoff relative to the exact closed form; existing parity suites pin
# it at 1e-4 against the f32 RefEngine over comparable stream lengths
# (tests/test_streaming.py, tests/test_serving_under_updates.py).
DEFAULT_PATH_ATOL = 2e-4


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One named certification check: pass/fail plus a human detail."""

    name: str
    ok: bool
    detail: str


@dataclasses.dataclass
class ComplianceReport:
    """The typed outcome of :func:`certify`.

    ``checks`` carries one :class:`CheckResult` per certification layer;
    ``envelope_slack`` is the worst observed margin of the canonical
    comparison below its derived bound (negative = inside the bound),
    and ``overlap_mean`` the measured top-n agreement.
    """

    n_users: int
    n_events: int
    n_deletion_events: int
    pure_add_users: List[int]
    deletion_users: List[int]
    forgotten_users: List[int]
    checks: List[CheckResult]
    envelope_slack: float = float("-inf")
    overlap_mean: float = 1.0

    @property
    def compliant(self) -> bool:
        """True when every certification check passed."""
        return all(c.ok for c in self.checks)

    @property
    def violations(self) -> List[CheckResult]:
        """The failed checks (empty for a compliant engine)."""
        return [c for c in self.checks if not c.ok]

    def summary(self) -> str:
        """One line per check, for logs and assertion messages."""
        lines = [f"compliant={self.compliant} users={self.n_users} "
                 f"events={self.n_events} "
                 f"deletions={self.n_deletion_events}"]
        for c in self.checks:
            lines.append(f"  [{'ok' if c.ok else 'FAIL'}] "
                         f"{c.name}: {c.detail}")
        return "\n".join(lines)


def retained_histories(events: Iterable[Event],
                       n_users: int) -> List[List[np.ndarray]]:
    """Semantic replay of the event log: each user's retained baskets.

    Applies the log's events in order with the same guards the engine
    enforces at apply time (a delete position at or beyond the current
    history length is a quarantined no-op; deleting an absent item is a
    no-op), so the result is the per-user basket list a compliant engine
    must hold.  Per-user order is the log order — the engine's one-event
    -per-user-per-micro-batch cut preserves exactly that.
    """
    hist: List[List[np.ndarray]] = [[] for _ in range(n_users)]
    for ev in events:
        h = hist[ev.user]
        if ev.kind == KIND_ADD_BASKET:
            h.append(np.unique(np.asarray(ev.items, np.int64).ravel()))
        elif ev.kind == KIND_DEL_BASKET:
            if 0 <= ev.pos < len(h):
                del h[ev.pos]
        elif ev.kind == KIND_DEL_ITEM:
            if 0 <= ev.pos < len(h):
                b = h[ev.pos]
                if ev.item in b:
                    b = b[b != ev.item]
                    if b.size:
                        h[ev.pos] = b
                    else:
                        del h[ev.pos]
    return hist


def basket_weights(group_sizes: Sequence[int], r_b: float,
                   r_g: float) -> np.ndarray:
    """Per-basket scalar weight of Eq. 1+2 under a given partition.

    The user vector is linear in the basket multi-hots: ``v_u = sum_t
    w(t) * mh(b_t)`` where basket ``t`` sits at in-group position ``i``
    (1-based) of group ``j`` (0-based) of ``k`` groups and

        ``w(t) = r_g^(k-1-j) / k  *  r_b^(tau_j - i) / tau_j``.

    The partition fully determines the weights — this is the scalar
    footprint the §4.3 path dependence acts on (DESIGN.md §11.2).
    """
    k = len(group_sizes)
    w = []
    for j, tau in enumerate(group_sizes):
        for i in range(1, tau + 1):
            w.append((r_g ** (k - 1 - j)) / k * (r_b ** (tau - i)) / tau)
    return np.asarray(w, np.float64)


def divergence_envelope(maintained_sizes: Sequence[int],
                        canonical_sizes: Sequence[int], r_b: float,
                        r_g: float) -> float:
    """The §4.3 path-dependence bound ``E_u`` (DESIGN.md §11.2).

    Both the maintained (path-dependent) and the canonical retained-only
    fit are weighted sums of the SAME basket multi-hots, so with
    ``w_path``/``w_canon`` from :func:`basket_weights`:

        ``||v_path - v_canon||_inf <= sum_t |w_path(t) - w_canon(t)|``

    because every multi-hot entry is 0 or 1.  The bound is tight (met
    when all baskets share an item) and computable per user in
    O(n_baskets).
    """
    wp = basket_weights(maintained_sizes, r_b, r_g)
    wc = basket_weights(canonical_sizes, r_b, r_g)
    if wp.size != wc.size:
        raise ValueError(f"partitions cover {wp.size} vs {wc.size} "
                         "baskets — not the same history")
    return float(np.abs(wp - wc).sum())


# ---------------------------------------------------------------------------
# Engine introspection (single-engine and sharded)
# ---------------------------------------------------------------------------

def _engines(engine) -> List[Tuple[StreamingEngine, np.ndarray]]:
    """(shard engine, global user ids of its rows) pairs."""
    if isinstance(engine, ShardedStreamingEngine):
        out = []
        for s, sh in enumerate(engine.shards):
            rows = np.arange(sh.store.cfg.n_users, dtype=np.int64)
            out.append((sh, rows * engine.spec.n_shards + s))
        return out
    return [(engine,
             np.arange(engine.store.cfg.n_users, dtype=np.int64))]


def _n_users(engine) -> int:
    if isinstance(engine, ShardedStreamingEngine):
        return engine.spec.n_users
    return engine.store.cfg.n_users


def _global_leaves(engine) -> Dict[str, np.ndarray]:
    """Assemble global per-user views of every state leaf + the corpus."""
    n = _n_users(engine)
    out: Dict[str, np.ndarray] = {}
    leaf_names = ("user_vecs", "last_group_vecs", "history", "group_sizes",
                  "n_baskets", "n_groups", "err_mult", "uv_scale",
                  "lgv_scale")
    for sh, gids in _engines(engine):
        st = sh.store.state
        mat = np.asarray(st.materialized_user_vecs())
        for name in leaf_names:
            a = np.asarray(getattr(st, name))
            if name not in out:
                out[name] = np.zeros((n,) + a.shape[1:], a.dtype)
            out[name][gids] = a
        if "corpus" not in out:
            out["corpus"] = np.zeros((n, mat.shape[1]), mat.dtype)
        out["corpus"][gids] = mat
    return out


def _dead_letter_users(engine) -> set:
    """Global user ids present in any dead-letter queue."""
    out = set()
    if isinstance(engine, ShardedStreamingEngine):
        for ev, _ in engine.dead_letter:
            out.add(int(ev.user))
        for s, sh in enumerate(engine.shards):
            for ev, _ in sh.dead_letter:
                out.add(int(ev.user) * engine.spec.n_shards + s)
    else:
        for ev, _ in engine.dead_letter:
            out.add(int(ev.user))
    return out


def _store_cfg(engine) -> StoreConfig:
    if isinstance(engine, ShardedStreamingEngine):
        return engine.shards[0].store.cfg
    return engine.store.cfg


# ---------------------------------------------------------------------------
# The certifier
# ---------------------------------------------------------------------------

def _structural_check(hist, leaves) -> CheckResult:
    """Stored history == retained baskets, per user, exactly."""
    bad = []
    for u, retained in enumerate(hist):
        nb = int(leaves["n_baskets"][u])
        if nb != len(retained):
            bad.append(f"user {u}: {nb} stored vs {len(retained)} "
                       "retained basket(s)")
            continue
        for t, basket in enumerate(retained):
            row = leaves["history"][u, t]
            stored = np.sort(row[row >= 0])
            if not np.array_equal(stored, np.sort(basket)):
                bad.append(f"user {u} basket {t}: stored "
                           f"{stored.tolist()} != retained "
                           f"{np.sort(basket).tolist()}")
                break
        k = int(leaves["n_groups"][u])
        if int(leaves["group_sizes"][u, :k].sum()) != len(retained):
            bad.append(f"user {u}: group sizes do not cover the "
                       "retained history")
    return CheckResult(
        "structural-retained-equivalence", not bad,
        bad[0] if bad else "stored history == retained events, all users")


def _pure_add_bitwise_check(engine, events, pure_add, leaves,
                            params) -> CheckResult:
    """Fresh replay of pure-add users' events must match bit for bit."""
    if not pure_add:
        return CheckResult("pure-add-bitwise", True, "no pure-add users")
    cfg = _store_cfg(engine)
    store = StateStore(StoreConfig(
        n_users=_n_users(engine), n_items=cfg.n_items,
        max_baskets=cfg.max_baskets, max_basket_size=cfg.max_basket_size,
        max_groups=cfg.max_groups))
    fresh = StreamingEngine(store, params)
    keep = set(pure_add)
    fresh.submit([Event(ev.kind, ev.user, items=ev.items)
                  for ev in events if ev.user in keep])
    fresh.run_until_drained()
    ref = _global_leaves(fresh)
    rows = np.asarray(pure_add, np.int64)
    for name in ("user_vecs", "uv_scale", "last_group_vecs", "lgv_scale",
                 "history", "group_sizes", "n_baskets", "n_groups",
                 "err_mult"):
        if not np.array_equal(leaves[name][rows], ref[name][rows]):
            return CheckResult(
                "pure-add-bitwise", False,
                f"leaf {name!r} differs from a fresh replay for at "
                f"least one of {len(pure_add)} pure-add user(s)")
    return CheckResult(
        "pure-add-bitwise", True,
        f"{len(pure_add)} user(s) bitwise-equal to a fresh replay")


def _deletion_checks(hist, leaves, deletion_users, params,
                     path_atol) -> Tuple[List[CheckResult], float,
                                         np.ndarray]:
    """Path-fit and canonical-envelope checks for deletion users.

    Returns the two checks, the worst envelope slack, and the canonical
    retained-only corpus rows for the overlap comparison.
    """
    canon = np.array(leaves["corpus"], np.float32, copy=True)
    if not deletion_users:
        return ([CheckResult("path-fit", True, "no deletion-bearing "
                             "users"),
                 CheckResult("canonical-envelope", True,
                             "no deletion-bearing users")],
                float("-inf"), canon)
    path_bad: List[str] = []
    env_bad: List[str] = []
    worst_slack = float("-inf")
    for u in deletion_users:
        retained = hist[u]
        k = int(leaves["n_groups"][u])
        sizes = [int(x) for x in leaves["group_sizes"][u, :k]]
        if sum(sizes) != len(retained):
            # the structural check reports this divergence; the float
            # comparisons are meaningless against a wrong basket count
            path_bad.append(f"user {u}: maintained partition covers "
                            f"{sum(sizes)} basket(s), retained history "
                            f"has {len(retained)} — skipped float "
                            "comparison")
            continue
        v_m = leaves["corpus"][u].astype(np.float64)
        # (a) the maintained float row is the Eq. 1+2 closed form on
        # (retained history, maintained partition) up to f32 roundoff
        v_path = user_vector_ragged(retained, sizes, params)
        d_path = float(np.abs(v_m - v_path).max()) if len(retained) \
            else float(np.abs(v_m).max())
        if d_path > path_atol:
            path_bad.append(f"user {u}: |maintained - path fit| = "
                            f"{d_path:.2e} > {path_atol:.0e}")
        # (b) against the canonical retained-only fit the divergence is
        # bounded by the derived envelope E_u (DESIGN.md §11.2)
        canon_sizes = default_group_sizes(len(retained),
                                          params.group_size)
        v_canon = user_vector_ragged(retained, canon_sizes, params)
        canon[u] = v_canon.astype(np.float32)
        env = divergence_envelope(sizes, canon_sizes, params.r_b,
                                  params.r_g)
        d_canon = float(np.abs(v_m - v_canon).max())
        slack = d_canon - (env + path_atol)
        worst_slack = max(worst_slack, slack)
        if slack > 0:
            env_bad.append(f"user {u}: |maintained - canonical| = "
                           f"{d_canon:.2e} > envelope {env:.2e} + "
                           f"{path_atol:.0e}")
    checks = [
        CheckResult("path-fit", not path_bad,
                    path_bad[0] if path_bad else
                    f"{len(deletion_users)} deletion-bearing user(s) "
                    f"within {path_atol:.0e} of the retained path fit"),
        CheckResult("canonical-envelope", not env_bad,
                    env_bad[0] if env_bad else
                    f"max envelope slack {worst_slack:.2e} (<= 0 is "
                    "inside the derived bound)"),
    ]
    return checks, worst_slack, canon


def _overlap_check(leaves, canon, params, topn, overlap_floor
                   ) -> Tuple[CheckResult, float]:
    """Top-n agreement between maintained and canonical serving."""
    active = np.nonzero(leaves["n_baskets"] > 0)[0]
    if active.size < 2:
        return (CheckResult("topn-overlap", True,
                            "fewer than 2 active users"), 1.0)
    k = min(params.k_neighbors, active.size - 1)

    def _topn(corpus):
        import jax.numpy as jnp
        sub = jnp.asarray(corpus[active])
        pred = knn.predict(sub, sub, k=k, alpha=params.alpha,
                           exclude_self=True)
        return np.asarray(knn.recommend_topn(pred, topn))

    recs_m = _topn(leaves["corpus"])
    recs_c = _topn(canon)
    overlaps = [len(set(a.tolist()) & set(b.tolist())) / topn
                for a, b in zip(recs_m, recs_c)]
    mean = float(np.mean(overlaps))
    return (CheckResult(
        "topn-overlap", mean >= overlap_floor,
        f"mean top-{topn} overlap {mean:.3f} vs floor "
        f"{overlap_floor:.2f} over {active.size} active user(s)"),
        mean)


def _no_trace_checks(engine, hist, leaves, forgotten,
                     checkpoint_dir) -> List[CheckResult]:
    """A forgotten user leaves no residue in any live/persisted artifact."""
    checks: List[CheckResult] = []
    bad: List[str] = []
    for u in forgotten:
        if hist[u]:
            bad.append(f"user {u}: event log retains {len(hist[u])} "
                       "basket(s) — deletion sequence incomplete")
        if int(leaves["n_baskets"][u]) or int(leaves["n_groups"][u]):
            bad.append(f"user {u}: bookkeeping not empty")
        if (leaves["history"][u] >= 0).any():
            bad.append(f"user {u}: history rows hold item ids")
        for name in ("user_vecs", "last_group_vecs", "corpus"):
            r = float(np.abs(leaves[name][u]).max())
            if r != 0.0:
                bad.append(f"user {u}: {name} residue |max| = {r:.2e}")
    # serving-cache + frozen-snapshot residue via the store helper
    for sh, gids in _engines(engine):
        local = [int(np.nonzero(gids == u)[0][0]) for u in forgotten
                 if u in gids]
        if not local:
            continue
        residue = sh.store.row_residue(local)
        for key, val in residue.items():
            if val != 0.0 and key not in ("user_vec_absmax",
                                          "last_group_absmax",
                                          "history_ids", "n_baskets",
                                          "n_groups"):
                bad.append(f"shard store: {key} residue {val:.2e} for "
                           f"local rows {local}")
    dl = _dead_letter_users(engine)
    for u in forgotten:
        if u in dl:
            bad.append(f"user {u}: event(s) still in a dead-letter "
                       "queue")
    checks.append(CheckResult(
        "no-trace-live", not bad,
        bad[0] if bad else f"{len(forgotten)} forgotten user(s) leave "
        "no live residue"))
    if checkpoint_dir is not None:
        checks.append(_checkpoint_round_trip_check(
            engine, forgotten, checkpoint_dir))
    return checks


def _checkpoint_round_trip_check(engine, forgotten,
                                 directory) -> CheckResult:
    """Save -> reload from disk: persisted leaves hold no residue."""
    engine.checkpoint(directory, step=1)
    bad: List[str] = []
    for s, (sh, gids) in enumerate(_engines(engine)):
        d = directory if isinstance(engine, StreamingEngine) \
            else os.path.join(directory, f"shard_{s:03d}")
        meta, leaves = load_checkpoint_arrays(d)
        for u in forgotten:
            hit = np.nonzero(gids == u)[0]
            if not hit.size:
                continue
            r = int(hit[0])
            for name in ("user_vecs", "last_group_vecs"):
                resid = float(np.abs(leaves[name][r]).max())
                if resid != 0.0:
                    bad.append(f"user {u}: persisted {name} residue "
                               f"{resid:.2e}")
            if (leaves["history"][r] >= 0).any() \
                    or int(leaves["n_baskets"][r]):
                bad.append(f"user {u}: persisted history not empty")
        # the persisted exactly-once log must carry only seqnos — any
        # event payload in the commit metadata would be residue
        eng_meta = meta.get("engine", {})
        extra = set(eng_meta) - {"watermark", "processed_above",
                                 "delivered", "next_seqno"}
        if extra:
            bad.append(f"commit metadata carries unexpected log "
                       f"fields {sorted(extra)}")
    return CheckResult(
        "checkpoint-round-trip", not bad,
        bad[0] if bad else "persisted commit holds no forgotten-user "
        "residue")


def certify(engine, events: Sequence[Event], *,
            params: Optional[TifuParams] = None,
            forgotten_users: Sequence[int] = (),
            topn: int = 5,
            overlap_floor: float = 0.5,
            path_atol: float = DEFAULT_PATH_ATOL,
            checkpoint_dir: Optional[str] = None) -> ComplianceReport:
    """Certify ``engine`` against its event log (DESIGN.md §11).

    ``events`` is the as-delivered log in order (quarantined deletions
    are re-derived by the same apply-time guards, so passing them is
    harmless); ``forgotten_users`` are global user ids whose entire
    history the log deletes (e.g. via ``forget_user``) — they
    additionally get the no-trace checks, including a checkpoint
    round-trip when ``checkpoint_dir`` is given.  Returns a
    :class:`ComplianceReport`; a deliberately skipped (or phantom)
    deletion fails the structural check, so tampering is detectable.
    Cost: one semantic log replay, one fresh replay of the pure-add
    users, and O(deletion users · history) closed-form fits.
    """
    params = engine.params if params is None else params
    n = _n_users(engine)
    events = list(events)
    hist = retained_histories(events, n)
    leaves = _global_leaves(engine)

    deletion_users = sorted(
        {ev.user for ev in events
         if ev.kind in (KIND_DEL_BASKET, KIND_DEL_ITEM)}
        | set(int(u) for u in forgotten_users))
    touched = {ev.user for ev in events}
    pure_add = sorted(touched - set(deletion_users))
    n_del = sum(ev.kind in (KIND_DEL_BASKET, KIND_DEL_ITEM)
                for ev in events)

    checks = [_structural_check(hist, leaves),
              _pure_add_bitwise_check(engine, events, pure_add, leaves,
                                      params)]
    del_checks, slack, canon = _deletion_checks(
        hist, leaves, deletion_users, params, path_atol)
    checks.extend(del_checks)
    overlap_check, overlap_mean = _overlap_check(
        leaves, canon, params, topn, overlap_floor)
    checks.append(overlap_check)
    if forgotten_users:
        checks.extend(_no_trace_checks(
            engine, hist, leaves, [int(u) for u in forgotten_users],
            checkpoint_dir))
    return ComplianceReport(
        n_users=n, n_events=len(events), n_deletion_events=n_del,
        pure_add_users=pure_add, deletion_users=deletion_users,
        forgotten_users=sorted(int(u) for u in forgotten_users),
        checks=checks, envelope_slack=slack, overlap_mean=overlap_mean)
