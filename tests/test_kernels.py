"""Pallas kernels vs their pure-jnp oracles — shape/dtype sweeps in
interpret mode (kernel bodies execute on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decayed_scatter import (batched_decayed_scatter,
                                           decayed_scatter)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.knn_topk import knn_topk
from repro.kernels.sparse_row_gather import sparse_row_gather
from repro.kernels.sparse_row_scatter import sparse_row_scatter


@pytest.mark.parametrize("q,m,d,k,bq,bm", [
    (128, 1024, 64, 8, 64, 256),
    (256, 2048, 128, 32, 128, 512),
    (64, 512, 32, 300, 64, 128),     # k > block
    (128, 768, 48, 16, 128, 256),    # non-pow2 dims
])
@pytest.mark.parametrize("metric", ["euclidean", "dot"])
def test_knn_topk_matches_ref(rng, q, m, d, k, bq, bm, metric):
    qs = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    cs = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    v, i = knn_topk(qs, cs, k=min(k, m), bq=bq, bm=bm, metric=metric,
                    interpret=True)
    rv, ri = ref.knn_topk_ref(qs, cs, min(k, m), metric)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), atol=1e-3,
                               rtol=1e-4)
    for a, b in zip(np.asarray(i), np.asarray(ri)):
        assert set(map(int, a)) == set(map(int, b))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_knn_topk_dtypes(rng, dtype):
    qs = jnp.asarray(rng.normal(size=(64, 64)), dtype)
    cs = jnp.asarray(rng.normal(size=(512, 64)), dtype)
    v, i = knn_topk(qs, cs, k=8, bq=64, bm=128, interpret=True)
    rv, ri = ref.knn_topk_ref(qs, cs, 8)
    if dtype == jnp.bfloat16:
        # bf16 rounding can flip near-tie selections (discrete-boundary
        # regime): check set recall ≥ 75% + value proximity instead
        overlap = np.mean([len(set(map(int, a)) & set(map(int, b))) / 8
                           for a, b in zip(np.asarray(i), np.asarray(ri))])
        assert overlap >= 0.75, overlap
        np.testing.assert_allclose(np.asarray(v)[:, 0],
                                   np.asarray(rv)[:, 0], atol=1.0)
    else:
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                                   atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("n,b,items,bi,bn", [
    (256, 8, 512, 128, 64),
    (512, 16, 1024, 512, 256),
    (128, 4, 2048, 256, 128),
    (64, 32, 640, 128, 64),          # wide baskets, non-pow2 items
])
def test_decayed_scatter_matches_ref(rng, n, b, items, bi, bn):
    ids = jnp.asarray(rng.integers(-1, items, (n, b)), jnp.int32)
    w = jnp.asarray(rng.random(n), jnp.float32)
    out = decayed_scatter(ids, w, items, bi=bi, bn=bn, interpret=True)
    exp = ref.decayed_scatter_ref(ids, w, items)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


def test_decayed_scatter_batched(rng):
    ids = jnp.asarray(rng.integers(-1, 256, (3, 128, 8)), jnp.int32)
    w = jnp.asarray(rng.random((3, 128)), jnp.float32)
    out = batched_decayed_scatter(ids, w, 256, interpret=True)
    for u in range(3):
        exp = ref.decayed_scatter_ref(ids[u], w[u], 256)
        np.testing.assert_allclose(np.asarray(out[u]), np.asarray(exp),
                                   atol=1e-4)


def test_decayed_scatter_builds_tifu_user_vector(rng):
    """End-to-end: kernel output == TIFU closed-form user vector."""
    from repro.core import TifuParams
    from repro.core.tifu import (closed_form_basket_weights,
                                 default_group_sizes, user_vector_ragged)
    p = TifuParams(n_items=512, group_size=3)
    baskets = [rng.choice(p.n_items, size=4, replace=False)
               for _ in range(10)]
    sizes = default_group_sizes(10, 3)
    ids = np.full((16, 8), -1, np.int32)
    for i, b_ in enumerate(baskets):
        ids[i, :len(b_)] = b_
    w = np.asarray(closed_form_basket_weights(
        jnp.asarray(sizes + [0] * (16 - len(sizes)), jnp.int32),
        len(sizes), p.r_b, p.r_g, 16))
    out = decayed_scatter(jnp.asarray(ids), jnp.asarray(w, jnp.float32),
                          p.n_items, interpret=True)
    oracle = user_vector_ragged(baskets, sizes, p)
    np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-5)


@pytest.mark.parametrize("m,items,u,w,bi", [
    (64, 512, 16, 24, 128),
    (128, 1024, 32, 64, 512),
    (16, 640, 8, 8, 128),            # non-pow2 items
    (256, 2048, 1, 48, 512),         # single-row batch
])
def test_sparse_row_scatter_matches_ref(rng, m, items, u, w, bi):
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, m, u), jnp.int32)
    ids = jnp.asarray(rng.integers(-1, items, (u, w)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(u, w)), jnp.float32)
    out = sparse_row_scatter(table, rows, ids, vals, bi=bi, interpret=True)
    exp = ref.sparse_row_scatter_ref(table, rows, ids, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


def test_sparse_row_scatter_duplicate_rows_accumulate(rng):
    """Padding rows alias real target rows (the engine's noop-row
    contract) and duplicate (row, id) pairs must accumulate."""
    m, items, u, w = 8, 512, 6, 16
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray([3, 3, 5, 3, 0, 5], jnp.int32)
    ids = jnp.asarray(rng.integers(-1, items, (u, w)), jnp.int32)
    ids = ids.at[0, :4].set(7).at[1, :4].set(7)     # same (row, id) repeated
    vals = jnp.asarray(rng.normal(size=(u, w)), jnp.float32)
    vals = vals.at[3].set(0.0)                       # a zero (padding) row
    out = sparse_row_scatter(table, rows, ids, vals, bi=128, interpret=True)
    exp = ref.sparse_row_scatter_ref(table, rows, ids, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


def test_sparse_row_scatter_all_pad_is_identity(rng):
    table = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    rows = jnp.zeros((3,), jnp.int32)
    ids = jnp.full((3, 8), -1, jnp.int32)
    vals = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    out = sparse_row_scatter(table, rows, ids, vals, bi=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(table))


@pytest.mark.parametrize("m,items,u,w,bi", [
    (64, 512, 16, 24, 128),
    (128, 1024, 32, 64, 512),
    (16, 640, 8, 8, 128),            # non-pow2 items
    (256, 2048, 1, 48, 512),         # single-row batch
])
def test_sparse_row_gather_matches_ref(rng, m, items, u, w, bi):
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray(rng.integers(0, m, u), jnp.int32)
    ids = jnp.asarray(rng.integers(-1, items, (u, w)), jnp.int32)
    out = sparse_row_gather(table, rows, ids, bi=bi, interpret=True)
    exp = ref.sparse_row_gather_ref(table, rows, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


def test_sparse_row_gather_duplicate_rows_and_ids(rng):
    """Duplicate target rows and repeated ids within a row read the same
    cells independently (no sort/accumulate needed, unlike the scatter)."""
    m, items = 8, 512
    table = jnp.asarray(rng.normal(size=(m, items)), jnp.float32)
    rows = jnp.asarray([3, 3, 5, 0], jnp.int32)
    ids = jnp.asarray(rng.integers(-1, items, (4, 16)), jnp.int32)
    ids = ids.at[0, :4].set(7).at[1, :4].set(7)
    out = sparse_row_gather(table, rows, ids, bi=128, interpret=True)
    exp = ref.sparse_row_gather_ref(table, rows, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


def test_sparse_row_gather_all_pad_is_zero(rng):
    table = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    out = sparse_row_gather(table, jnp.zeros((3,), jnp.int32),
                            jnp.full((3, 8), -1, jnp.int32), bi=128,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((3, 8)))


def test_gather_scatter_round_trip(rng):
    """scatter(gather) with negated vals zeroes exactly the support —
    the reset idiom the sparse delete paths rely on (DESIGN.md §3.5)."""
    m, items, u, w = 8, 512, 4, 12
    table = jnp.zeros((m, items), jnp.float32)
    rows = jnp.asarray([1, 2, 5, 7], jnp.int32)
    ids = jnp.asarray(rng.choice(items, size=(u, w), replace=False),
                      jnp.int32)
    vals = jnp.asarray(rng.normal(size=(u, w)), jnp.float32)
    table = ref.sparse_row_scatter_ref(table, rows, ids, vals)
    got = sparse_row_gather(table, rows, ids, bi=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(vals), atol=1e-6)
    wiped = sparse_row_scatter(table, rows, ids, -got, bi=128,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(wiped), np.zeros((m, items)),
                               atol=1e-6)


@pytest.mark.parametrize("b,s,h,d,win,bq,bk", [
    (2, 256, 2, 64, 0, 64, 64),
    (1, 128, 4, 32, 32, 64, 32),
    (2, 256, 2, 64, 64, 128, 64),
    (1, 512, 1, 128, 0, 128, 128),
])
def test_flash_attention_matches_ref(rng, b, s, h, d, win, bq, bk):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=win, bq=bq, bk=bk,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    exp = ref.flash_attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp), atol=3e-2)
