"""Pallas TPU kernels (+ pure-jnp oracles and jit dispatchers).

knn_topk           — fused similarity × streaming top-k with in-kernel
                     tail masks and global-id self-exclusion (serving
                     stage A, DESIGN.md §8.1; retrieval_cand cells)
serving_topn       — one-hot neighbour-blend + top-n kernels (serving
                     stage B and the cross-shard blend, DESIGN.md §8)
decayed_scatter    — one-hot-matmul weighted multi-hot scatter (TIFU
                     user vectors; EmbeddingBag substrate)
sparse_row_scatter — sparse per-row scatter-add into the [M, I] state
                     (batched add/delete-path deltas, DESIGN.md §3.3/§3.5)
sparse_row_gather  — sparse per-row gather of the [M, I] state (the read
                     half of the pair: update-path supports)
tile_plan          — host/jit touched-tile plans driving the sparse pair's
                     block index maps (O(U·W) TPU HBM traffic)
flash_attention    — blocked online-softmax attention (LM train/prefill)
"""
from repro.kernels import ops, ref, serving_topn, tile_plan
from repro.kernels.ops import (blend_topn_rows, default_impl,
                               flash_attention, fused_recommend, knn_topk,
                               multihot_scatter, shard_topk,
                               sparse_row_gather, sparse_row_scatter)

__all__ = ["ops", "ref", "serving_topn", "tile_plan", "blend_topn_rows",
           "default_impl", "flash_attention", "fused_recommend",
           "knn_topk", "multihot_scatter", "shard_topk",
           "sparse_row_gather", "sparse_row_scatter"]
