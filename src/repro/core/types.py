"""Core datatypes for the TIFU-kNN maintenance system.

Two state representations coexist (see DESIGN.md §3):

* ``RaggedUserState`` — per-user ragged numpy state, used by the
  paper-faithful reference engine (``core.ref_engine``).  Updates touch
  exactly the suffix the paper's algorithms touch, so latency benchmarks
  reproduce the paper's asymptotics (Fig. 2a/2b).

* ``StreamState`` — struct-of-arrays padded JAX state for ``M`` users,
  used by the batched SPMD streaming engine (``streaming.engine``).  Its
  vector tables are stored *scaled* (DESIGN.md §3.3) so basket additions
  apply sparse deltas; kind-partitioned sub-batches (``AddBatch``,
  ``DelBasketBatch``, ``DelItemBatch``, DESIGN.md §4.1) carry one
  homogeneous micro-batch each.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD_ID = -1  # padding value for item ids in basket arrays


@dataclasses.dataclass(frozen=True)
class TifuParams:
    """TIFU-kNN hyper-parameters (Table 1 of the paper).

    Attributes:
      n_items: vocabulary size ``|I|``.
      group_size: nominal group size ``m``.
      r_b: within-group (basket) time-decay rate, ``0 < r_b <= 1``.
      r_g: across-group time-decay rate, ``0 < r_g <= 1``.
      k_neighbors: number of nearest neighbours for the CF component.
      alpha: weight of the personal component in the final prediction.
    """

    n_items: int
    group_size: int = 7
    r_b: float = 0.9
    r_g: float = 0.7
    k_neighbors: int = 300
    alpha: float = 0.7

    def __post_init__(self) -> None:
        if not (0.0 < self.r_b <= 1.0):
            raise ValueError(f"r_b must be in (0, 1], got {self.r_b}")
        if not (0.0 < self.r_g <= 1.0):
            raise ValueError(f"r_g must be in (0, 1], got {self.r_g}")
        if self.group_size < 1:
            raise ValueError("group_size must be >= 1")


# Hyper-parameters used in the paper's experiments (Table 1):
#   [m, r_b, r_g, k, alpha]
PAPER_HYPERPARAMS = {
    "tafeng": TifuParams(n_items=11997, group_size=7, r_b=0.9, r_g=0.7,
                         k_neighbors=300, alpha=0.7),
    "instacart": TifuParams(n_items=7999, group_size=3, r_b=0.9, r_g=0.7,
                            k_neighbors=900, alpha=0.9),
    "valuedshopper": TifuParams(n_items=7874, group_size=7, r_b=1.0, r_g=0.6,
                                k_neighbors=300, alpha=0.7),
}


@dataclasses.dataclass
class RaggedUserState:
    """Paper-faithful per-user state (ragged, numpy).

    ``history`` is a list of baskets, each basket a 1-D int array of item
    ids.  ``group_sizes[j]`` is the number of baskets in group ``j`` under
    the *varying group size* relaxation (paper §4.3).  ``user_vec`` and
    ``last_group_vec`` are dense ``|I|`` vectors.  ``err_mult`` tracks the
    worst-case multiplicative error factor accumulated by decremental
    updates (beyond-paper stability tracker, see core.stability).
    """

    history: List[np.ndarray]
    group_sizes: List[int]
    user_vec: np.ndarray
    last_group_vec: np.ndarray
    err_mult: float = 1.0

    @property
    def n_baskets(self) -> int:
        return len(self.history)

    @property
    def n_groups(self) -> int:
        return len(self.group_sizes)

    @staticmethod
    def empty(n_items: int) -> "RaggedUserState":
        return RaggedUserState(
            history=[],
            group_sizes=[],
            user_vec=np.zeros(n_items, dtype=np.float64),
            last_group_vec=np.zeros(n_items, dtype=np.float64),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StreamState:
    """Padded struct-of-arrays state for ``M`` users (JAX path).

    Shapes (``M`` users, ``N`` max baskets, ``B`` max basket size,
    ``K`` max groups, ``I`` items):

      user_vecs:       f32[M, I]   raw (scaled) storage, see below
      last_group_vecs: f32[M, I]   raw (scaled) storage, see below
      history:         i32[M, N, B]   (PAD_ID padded)
      group_sizes:     i32[M, K]
      n_baskets:       i32[M]
      n_groups:        i32[M]
      err_mult:        f32[M]
      uv_scale:        f32[M]
      lgv_scale:       f32[M]

    Scaled representation (DESIGN.md §3.3): the *true* TIFU vectors are

        user_vec(u)       = uv_scale[u]  * user_vecs[u]
        last_group_vec(u) = lgv_scale[u] * last_group_vecs[u]

    Basket additions (Eq. 7-9) rescale the whole user/group vector by a
    per-user scalar; storing that scalar separately turns every addition
    into a *sparse* delta whose support is only the touched items, so the
    batched add path never reads or writes an ``[n_items]`` temporary.
    Use :meth:`materialized_user_vecs` for serving / kNN / comparisons.
    Scales only shrink; ``core.updates.renormalize_users`` folds them back
    into the raw rows before they underflow (SCALE_FLOOR).
    """

    user_vecs: jax.Array
    last_group_vecs: jax.Array
    history: jax.Array
    group_sizes: jax.Array
    n_baskets: jax.Array
    n_groups: jax.Array
    err_mult: jax.Array
    uv_scale: jax.Array
    lgv_scale: jax.Array

    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], None]:
        children = (self.user_vecs, self.last_group_vecs, self.history,
                    self.group_sizes, self.n_baskets, self.n_groups,
                    self.err_mult, self.uv_scale, self.lgv_scale)
        return children, None

    @classmethod
    def tree_unflatten(cls, aux: None,
                       children: Tuple[jax.Array, ...]) -> "StreamState":
        return cls(*children)

    # -- true-value accessors -------------------------------------------------

    def materialized_user_vecs(self) -> jax.Array:
        """True user vectors f32[M, I] (raw rows × per-user scale)."""
        return self.user_vecs * self.uv_scale[:, None]

    def materialized_last_group_vecs(self) -> jax.Array:
        """True last-group vectors f32[M, I]."""
        return self.last_group_vecs * self.lgv_scale[:, None]

    @property
    def n_users(self) -> int:
        return self.user_vecs.shape[0]

    @property
    def n_items(self) -> int:
        return self.user_vecs.shape[1]

    @property
    def max_baskets(self) -> int:
        return self.history.shape[1]

    @property
    def max_basket_size(self) -> int:
        return self.history.shape[2]

    @property
    def max_groups(self) -> int:
        return self.group_sizes.shape[1]

    @staticmethod
    def zeros(n_users: int, n_items: int, max_baskets: int,
              max_basket_size: int, max_groups: int | None = None,
              dtype: Any = jnp.float32) -> "StreamState":
        if max_groups is None:
            max_groups = max_baskets  # worst case: all groups of size 1
        return StreamState(
            user_vecs=jnp.zeros((n_users, n_items), dtype),
            last_group_vecs=jnp.zeros((n_users, n_items), dtype),
            history=jnp.full((n_users, max_baskets, max_basket_size), PAD_ID,
                             jnp.int32),
            group_sizes=jnp.zeros((n_users, max_groups), jnp.int32),
            n_baskets=jnp.zeros((n_users,), jnp.int32),
            n_groups=jnp.zeros((n_users,), jnp.int32),
            err_mult=jnp.ones((n_users,), dtype),
            uv_scale=jnp.ones((n_users,), dtype),
            lgv_scale=jnp.ones((n_users,), dtype),
        )


# Update kinds for the streaming engine (Algorithm 1 generalised).
KIND_NOOP = 0
KIND_ADD_BASKET = 1
KIND_DEL_BASKET = 2
KIND_DEL_ITEM = 3


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class UpdateBatch:
    """A fixed-shape micro-batch of updates (adds and deletes mixed).

    kind:         i32[U]    one of KIND_*
    user:         i32[U]    target user row
    basket_items: i32[U, B] item ids for adds (PAD_ID padded)
    basket_pos:   i32[U]    global basket index for deletions
    item:         i32[U]    item id for item deletions
    """

    kind: jax.Array
    user: jax.Array
    basket_items: jax.Array
    basket_pos: jax.Array
    item: jax.Array

    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], None]:
        return (self.kind, self.user, self.basket_items, self.basket_pos,
                self.item), None

    @classmethod
    def tree_unflatten(cls, aux: None,
                       children: Tuple[jax.Array, ...]) -> "UpdateBatch":
        return cls(*children)

    @property
    def size(self) -> int:
        return self.kind.shape[0]

    @staticmethod
    def noop(batch: int, max_basket_size: int) -> "UpdateBatch":
        return UpdateBatch(
            kind=jnp.zeros((batch,), jnp.int32),
            user=jnp.zeros((batch,), jnp.int32),
            basket_items=jnp.full((batch, max_basket_size), PAD_ID, jnp.int32),
            basket_pos=jnp.zeros((batch,), jnp.int32),
            item=jnp.full((batch,), PAD_ID, jnp.int32),
        )


# ---------------------------------------------------------------------------
# Kind-partitioned homogeneous sub-batches (DESIGN.md §4)
# ---------------------------------------------------------------------------
#
# A mixed UpdateBatch forces one compiled program to evaluate every update
# rule per row and select (4x redundant work).  The streaming engine instead
# partitions each micro-batch by event kind into these fixed-shape
# sub-batches, so each compiled program runs exactly one rule.  Rows beyond
# the real event count have valid=False and zero effect; they may alias any
# user because every state write is a masked delta (scatter-add / multiply
# by 1), never an unmasked set.

def _pow2_pad(n: int, cap: int = 0) -> int:
    """Pad a sub-batch length to the next power of two (bounded bucketing
    keeps the number of compiled shapes at log2(cap) per kind).  ``cap``
    (the engine batch size) bounds the padding; 0 means uncapped."""
    if n <= 0:
        return 1
    p = 1 << (n - 1).bit_length()
    return min(p, max(cap, n)) if cap else p


def _resolve_pad(n: int, pad_cap: int, pad_to: int) -> int:
    """Padded row count for a sub-batch build: an explicit ``pad_to``
    (the engine's hysteresis-held bucket) wins over the pow2 default."""
    if pad_to:
        if pad_to < n:
            raise ValueError(f"pad_to={pad_to} < sub-batch size {n}")
        return pad_to
    return _pow2_pad(n, pad_cap)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AddBatch:
    """Homogeneous basket-addition sub-batch (the paper's O(1) case).

    user:  i32[U]     target user row
    items: i32[U, B]  item ids of the new basket (PAD_ID padded)
    valid: bool[U]    False for padding rows (zero effect)
    """

    user: jax.Array
    items: jax.Array
    valid: jax.Array

    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], None]:
        return (self.user, self.items, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux: None,
                       children: Tuple[jax.Array, ...]) -> "AddBatch":
        return cls(*children)

    @property
    def size(self) -> int:
        return self.user.shape[0]

    @staticmethod
    def build(users: Sequence[int], baskets: Sequence[Any],
              max_basket_size: int, pad_cap: int = 0,
              pad_to: int = 0) -> "AddBatch":
        """From parallel host lists of user ids and item-id sequences.

        ``pad_to`` (engine bucket hysteresis, DESIGN.md §4.1) overrides
        the pow2 bucket with an explicit row count >= len(users)."""
        n = len(users)
        u = _resolve_pad(n, pad_cap, pad_to)
        user = np.zeros(u, np.int32)
        items = np.full((u, max_basket_size), PAD_ID, np.int32)
        valid = np.zeros(u, bool)
        for r, (uu, b) in enumerate(zip(users, baskets)):
            user[r] = uu
            # baskets are item SETS: dedup + drop PADs here so duplicate
            # ids never reach history (recompute paths would double-count)
            ids = np.unique(np.asarray(b, np.int32))
            ids = ids[ids >= 0][:max_basket_size]
            items[r, :len(ids)] = ids
            valid[r] = True
        return AddBatch(user=jnp.asarray(user), items=jnp.asarray(items),
                        valid=jnp.asarray(valid))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DelBasketBatch:
    """Homogeneous basket-deletion sub-batch (linear decremental cost).

    user: i32[U]   target user row
    pos:  i32[U]   global basket index to delete
    valid: bool[U]
    """

    user: jax.Array
    pos: jax.Array
    valid: jax.Array

    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], None]:
        return (self.user, self.pos, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux: None,
                       children: Tuple[jax.Array, ...]) -> "DelBasketBatch":
        return cls(*children)

    @property
    def size(self) -> int:
        return self.user.shape[0]

    @staticmethod
    def build(users: Sequence[int], positions: Sequence[int],
              pad_cap: int = 0, pad_to: int = 0) -> "DelBasketBatch":
        n = len(users)
        u = _resolve_pad(n, pad_cap, pad_to)
        user = np.zeros(u, np.int32)
        pos = np.zeros(u, np.int32)
        valid = np.zeros(u, bool)
        user[:n] = np.asarray(users, np.int32)
        pos[:n] = np.asarray(positions, np.int32)
        valid[:n] = True
        return DelBasketBatch(user=jnp.asarray(user), pos=jnp.asarray(pos),
                              valid=jnp.asarray(valid))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DelItemBatch:
    """Homogeneous item-deletion sub-batch (Eq. 13 with vanish fallback).

    user: i32[U]   target user row
    pos:  i32[U]   global basket index holding the item
    item: i32[U]   item id to delete
    valid: bool[U]
    """

    user: jax.Array
    pos: jax.Array
    item: jax.Array
    valid: jax.Array

    def tree_flatten(self) -> Tuple[Tuple[jax.Array, ...], None]:
        return (self.user, self.pos, self.item, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux: None,
                       children: Tuple[jax.Array, ...]) -> "DelItemBatch":
        return cls(*children)

    @property
    def size(self) -> int:
        return self.user.shape[0]

    @staticmethod
    def build(users: Sequence[int], positions: Sequence[int],
              items: Sequence[int], pad_cap: int = 0,
              pad_to: int = 0) -> "DelItemBatch":
        n = len(users)
        u = _resolve_pad(n, pad_cap, pad_to)
        user = np.zeros(u, np.int32)
        pos = np.zeros(u, np.int32)
        item = np.full(u, PAD_ID, np.int32)
        valid = np.zeros(u, bool)
        user[:n] = np.asarray(users, np.int32)
        pos[:n] = np.asarray(positions, np.int32)
        item[:n] = np.asarray(items, np.int32)
        valid[:n] = True
        return DelItemBatch(user=jnp.asarray(user), pos=jnp.asarray(pos),
                            item=jnp.asarray(item), valid=jnp.asarray(valid))
