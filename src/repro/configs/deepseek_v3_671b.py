"""deepseek-v3-671b [arXiv:2412.19437]
61L d_model=7168 128H MLA, 1 shared + 256 routed experts top-8
(per-expert d_ff=2048), first 3 layers dense (d_ff=18432),
vocab=129280, MTP head.  MLA: q_lora=1536, kv_lora=512, nope=128,
rope=64, v=128."""
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.lm_shapes import standard_lm_cells
from repro.models.transformer import TransformerConfig


def make_config():
    return TransformerConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, d_head=128, d_ff=18432, vocab_size=129280,
        moe=True, n_experts=256, n_shared_experts=1, top_k=8,
        moe_d_ff=2048, first_dense_layers=3,
        mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128,
        mtp=True, tie_embeddings=False, dtype=jnp.bfloat16)


def smoke_config():
    return TransformerConfig(
        name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=192, vocab_size=256,
        moe=True, n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=32,
        first_dense_layers=1, mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, mtp=True,
        capacity_factor=2.0, tie_embeddings=False, q_block=8,
        dtype=jnp.float32)


# 671B with Adam m+v would not fit 16 GB/chip at 256-way sharding —
# use Adafactor (factored second moments), the standard choice here.
ARCH = ArchDef(
    name="deepseek-v3-671b", family="lm",
    cells=standard_lm_cells(make_config, optimizer="adafactor"),
    make_smoke=smoke_config,
    notes="MLA latent KV cache (decode uses the absorbed-matmul path); "
          "MTP auxiliary head; adafactor optimizer; bf16 params.")
