"""Shared recsys shape cells: train_batch=65536, serve_p99=512,
serve_bulk=262144, retrieval_cand: batch=1 × 1M candidates."""
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import batch_spec, sds

TRAIN_BATCH = 65536
SERVE_P99 = 512
SERVE_BULK = 262144
N_CANDIDATES = 1_000_000


def dlrm_batch(n, train=True):
    def build(c, mesh, rules):
        ax = batch_spec(mesh, rules, n)
        batch = {"dense": sds((n, c.n_dense), jnp.float32),
                 "sparse": sds((n, c.n_sparse), jnp.int32)}
        shard = {"dense": NamedSharding(mesh, P(ax, None)),
                 "sparse": NamedSharding(mesh, P(ax, None))}
        if train:
            batch["labels"] = sds((n,), jnp.float32)
            shard["labels"] = NamedSharding(mesh, P(ax))
        return batch, shard
    return build


def deepfm_batch(n, train=True):
    def build(c, mesh, rules):
        ax = batch_spec(mesh, rules, n)
        batch = {"sparse": sds((n, c.n_fields), jnp.int32)}
        shard = {"sparse": NamedSharding(mesh, P(ax, None))}
        if train:
            batch["labels"] = sds((n,), jnp.float32)
            shard["labels"] = NamedSharding(mesh, P(ax))
        return batch, shard
    return build


def bert4rec_batch(n, train=True, n_masked=20, n_negatives=8192):
    def build(c, mesh, rules):
        ax = batch_spec(mesh, rules, n)
        batch = {"ids": sds((n, c.seq_len), jnp.int32)}
        shard = {"ids": NamedSharding(mesh, P(ax, None))}
        if train:
            batch.update({"mask_pos": sds((n, n_masked), jnp.int32),
                          "targets": sds((n, n_masked), jnp.int32),
                          "negatives": sds((n_negatives,), jnp.int32)})
            shard.update({"mask_pos": NamedSharding(mesh, P(ax, None)),
                          "targets": NamedSharding(mesh, P(ax, None)),
                          "negatives": NamedSharding(mesh, P(None))})
        return batch, shard
    return build


def bert4rec_retrieval_batch(n_cand=N_CANDIDATES):
    def build(c, mesh, rules):
        tp = rules.tensor if rules.tensor in mesh.axis_names else None
        batch = {"ids": sds((1, c.seq_len), jnp.int32),
                 "candidates": sds((n_cand, c.embed_dim), jnp.float32)}
        shard = {"ids": NamedSharding(mesh, P(None, None)),
                 "candidates": NamedSharding(mesh, P(tp, None))}
        return batch, shard
    return build


def two_tower_batch(n, train=True):
    def build(c, mesh, rules):
        ax = batch_spec(mesh, rules, n)
        batch = {"user_id": sds((n,), jnp.int32),
                 "history": sds((n, c.hist_len), jnp.int32),
                 "item_id": sds((n,), jnp.int32),
                 "item_cat": sds((n,), jnp.int32)}
        shard = {k: NamedSharding(mesh, P(ax, None) if len(v.shape) == 2
                                  else P(ax)) for k, v in batch.items()}
        if train:
            batch["logq"] = sds((n,), jnp.float32)
            shard["logq"] = NamedSharding(mesh, P(ax))
        return batch, shard
    return build


def two_tower_retrieval_batch(n_cand=N_CANDIDATES):
    def build(c, mesh, rules):
        tp = rules.tensor if rules.tensor in mesh.axis_names else None
        batch = {"user_id": sds((1,), jnp.int32),
                 "history": sds((1, c.hist_len), jnp.int32),
                 "candidates": sds((n_cand, c.tower_mlp[-1]), jnp.float32)}
        shard = {"user_id": NamedSharding(mesh, P(None)),
                 "history": NamedSharding(mesh, P(None, None)),
                 "candidates": NamedSharding(mesh, P(tp, None))}
        return batch, shard
    return build
