"""Repo-level driver: run every rule family and collect a Report.

This is the only analysis module that imports repo code (the kernel
modules, to populate the contract registry) — the rule modules stay
pure-AST so the corpus can exercise known-bad snippets without
importing them.
"""
from __future__ import annotations

import importlib
from pathlib import Path
from typing import Optional

from repro.analysis import engine_rules, kernel_rules, oracle_rules
from repro.analysis.contracts import (DUPLICATE_PAIRS, KERNEL_MODULES,
                                      REGISTRY)
from repro.analysis.report import Report


def default_root() -> Path:
    """The repo root, resolved from this file (src/repro/analysis/)."""
    return Path(__file__).resolve().parents[3]


def load_contracts() -> None:
    """Import every kernel module so its register() block runs."""
    for module in KERNEL_MODULES:
        importlib.import_module(module)


def lint_repo(root: Optional[Path] = None) -> Report:
    """Run all KC/OR/EN rules over the repo at ``root``."""
    root = Path(root) if root is not None else default_root()
    load_contracts()
    findings = []
    findings += kernel_rules.check_kernels(root, REGISTRY)
    findings += oracle_rules.check_oracle_pairing(root)
    findings += oracle_rules.check_duplicates(root, DUPLICATE_PAIRS)
    findings += engine_rules.check_commit_paths(root)
    findings += engine_rules.check_fault_registry(root)
    findings += engine_rules.check_bench_keys(root / "BENCH_updates.json")
    return Report(findings=sorted(findings))
