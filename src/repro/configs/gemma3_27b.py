"""gemma3-27b [hf:google/gemma-3-*]
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144,
5:1 local:global sliding-window attention (window 1024), 128k context."""
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.lm_shapes import standard_lm_cells
from repro.models.transformer import TransformerConfig


def make_config():
    return TransformerConfig(
        name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32,
        n_kv_heads=16, d_head=128, d_ff=21504, vocab_size=262144,
        sliding_window=1024, global_every=6,   # layers 6,12,... are global
        tie_embeddings=True, dtype=jnp.bfloat16)


def smoke_config():
    return TransformerConfig(
        name="gemma3-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256,
        sliding_window=4, global_every=6, q_block=8, dtype=jnp.float32)


ARCH = ArchDef(
    name="gemma3-27b", family="lm",
    cells=standard_lm_cells(make_config),
    make_smoke=smoke_config,
    notes="5:1 local:global; the ONLY assigned LM arch whose 500k PREFILL "
          "is sub-quadratic (window=1024); long_500k decode runs for all.")
