"""Micro-batch streaming engine — the Spark Structured Streaming analog.

Implements Algorithm 1 of the paper (joint incremental/decremental state
updates) as a batched SPMD program:

  * incoming events (basket additions, basket/item deletion requests)
    are buffered and cut into fixed-shape ``UpdateBatch`` micro-batches;

  * within a micro-batch each user appears at most once (conflicting
    events for the same user stay in the buffer for the next batch —
    this preserves per-user sequential semantics while letting
    independent users update in parallel, exactly the paper's
    user-level parallelism);

  * an idempotent update log (sequence numbers + processed watermark)
    makes recovery exactly-once: after restoring a checkpoint, events
    with seqno <= watermark are skipped on replay;

  * users whose numerical-error bound crossed the stability threshold
    are refreshed from scratch after the batch (core.stability).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Iterable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import stability
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, PAD_ID, TifuParams, UpdateBatch)
from repro.core.updates import apply_update_batch, refresh_users
from repro.streaming.state_store import StateStore


@dataclasses.dataclass(frozen=True)
class Event:
    """One streaming event. ``seqno`` is assigned by the engine."""
    kind: int
    user: int
    items: Optional[np.ndarray] = None   # for adds
    pos: int = 0                         # for deletes
    item: int = PAD_ID                   # for item deletes
    seqno: int = -1


@dataclasses.dataclass
class EngineMetrics:
    events_processed: int = 0
    batches: int = 0
    refreshes: int = 0
    last_batch_seconds: float = 0.0


class StreamingEngine:
    """Joint incremental/decremental state maintenance (Algorithm 1)."""

    def __init__(self, store: StateStore, params: TifuParams,
                 batch_size: int = 256,
                 stability_target_rel_err: Optional[float] = 1e-2):
        self.store = store
        self.params = params
        self.batch_size = batch_size
        self.buffer: deque[Event] = deque()
        # Exactly-once bookkeeping.  Conflict deferral (one event per user
        # per micro-batch) processes events OUT of seqno order, so a plain
        # high-watermark would drop deferred-but-unprocessed events on
        # replay.  We track the contiguous frontier + the sparse set of
        # processed seqnos above it.
        self.watermark = -1                 # all seqnos <= this are done
        self._processed_above: set[int] = set()
        self._next_seqno = 0
        self.metrics = EngineMetrics()
        if stability_target_rel_err is not None:
            self.err_threshold = stability.refresh_threshold(
                stability_target_rel_err, np.finfo(np.float32).eps)
        else:
            self.err_threshold = None

    # -- ingestion ------------------------------------------------------------

    def submit(self, events: Iterable[Event]) -> None:
        for ev in events:
            if ev.seqno < 0:
                ev = dataclasses.replace(ev, seqno=self._next_seqno)
                self._next_seqno += 1
            elif ev.seqno <= self.watermark \
                    or ev.seqno in self._processed_above:
                continue  # replay of an already-processed event: skip
            else:
                self._next_seqno = max(self._next_seqno, ev.seqno + 1)
            self.buffer.append(ev)

    def add_basket(self, user: int, items: Sequence[int]) -> None:
        self.submit([Event(KIND_ADD_BASKET, user,
                           items=np.asarray(items, np.int32))])

    def delete_basket(self, user: int, pos: int) -> None:
        self.submit([Event(KIND_DEL_BASKET, user, pos=pos)])

    def delete_item(self, user: int, pos: int, item: int) -> None:
        self.submit([Event(KIND_DEL_ITEM, user, pos=pos, item=item)])

    # -- micro-batch processing -------------------------------------------------

    def _cut_batch(self) -> List[Event]:
        """Take up to batch_size events, at most one per user, preserving
        per-user order (later events for a busy user stay buffered)."""
        taken, skipped, users = [], [], set()
        while self.buffer and len(taken) < self.batch_size:
            ev = self.buffer.popleft()
            if ev.user in users:
                skipped.append(ev)
            else:
                users.add(ev.user)
                taken.append(ev)
        # NOTE: extendleft reverses; re-insert in original order.
        for ev in reversed(skipped):
            self.buffer.appendleft(ev)
        return taken

    def _to_update_batch(self, events: List[Event]) -> UpdateBatch:
        u = self.batch_size
        b = self.store.cfg.max_basket_size
        kind = np.zeros(u, np.int32)
        user = np.zeros(u, np.int32)
        items = np.full((u, b), PAD_ID, np.int32)
        pos = np.zeros(u, np.int32)
        item = np.full(u, PAD_ID, np.int32)
        for r, ev in enumerate(events):
            kind[r] = ev.kind
            user[r] = ev.user
            pos[r] = ev.pos
            item[r] = ev.item
            if ev.items is not None:
                ids = np.asarray(ev.items, np.int32)[:b]
                items[r, :len(ids)] = ids
        return UpdateBatch(kind=jnp.asarray(kind), user=jnp.asarray(user),
                           basket_items=jnp.asarray(items),
                           basket_pos=jnp.asarray(pos),
                           item=jnp.asarray(item))

    def step(self) -> int:
        """Process one micro-batch. Returns number of events applied."""
        events = self._cut_batch()
        if not events:
            return 0
        t0 = time.perf_counter()
        batch = self._to_update_batch(events)
        self.store.state = apply_update_batch(self.store.state, batch,
                                              self.params)
        if self.err_threshold is not None:
            err = np.asarray(self.store.state.err_mult)
            bad = np.nonzero(err > self.err_threshold)[0]
            if bad.size:
                self.store.state = refresh_users(
                    self.store.state, jnp.asarray(bad, jnp.int32),
                    self.params)
                self.metrics.refreshes += int(bad.size)
        for ev in events:
            self._processed_above.add(ev.seqno)
        while self.watermark + 1 in self._processed_above:
            self.watermark += 1
            self._processed_above.discard(self.watermark)
        self.metrics.events_processed += len(events)
        self.metrics.batches += 1
        self.metrics.last_batch_seconds = time.perf_counter() - t0
        return len(events)

    def run_until_drained(self, max_batches: int = 10_000) -> int:
        total = 0
        for _ in range(max_batches):
            n = self.step()
            if n == 0:
                break
            total += n
        return total

    # -- recovery ---------------------------------------------------------------

    def checkpoint(self, directory: str, step: int) -> None:
        self.store.checkpoint(directory, step)
        with open(os.path.join(directory, "ENGINE"), "w") as f:
            json.dump({"watermark": self.watermark,
                       "processed_above": sorted(self._processed_above),
                       "next_seqno": self._next_seqno}, f)

    def restore(self, directory: str) -> None:
        self.store.restore(directory)
        with open(os.path.join(directory, "ENGINE")) as f:
            meta = json.load(f)
        self.watermark = meta["watermark"]
        self._processed_above = set(meta.get("processed_above", []))
        self._next_seqno = meta["next_seqno"]
        self.buffer.clear()
