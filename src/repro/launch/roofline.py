"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = Σ per-collective (bytes / chips) / link_bw

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis — we parse the optimized HLO text and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[2,1024,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s(" + "|".join(_COLLECTIVES)
    + r")[\s(]")
# tuple-result collectives:  = (f32[..], f32[..]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s*("
    + "|".join(_COLLECTIVES) + r")[\s(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total result bytes per collective kind (result size ≈ moved bytes
    order; all-gather result = gathered size, all-reduce = tensor size)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-start" in line or "-done" in line:
            # async pairs: count only the -start to avoid double counting
            if "-done" in line:
                continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    coll_detail: Dict[str, int]

    # NOTE: compiled.cost_analysis() and the partitioned HLO are PER-DEVICE
    # quantities (verified against a hand-computed sharded matmul), so the
    # roofline terms divide by per-chip peaks directly.

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_detail": {k: v for k, v in self.coll_detail.items()
                            if k != "_counts"},
            "coll_counts": self.coll_detail.get("_counts", {}),
        }


def analyze(compiled, hlo_text: str, chips: int) -> RooflineTerms:
    """Roofline terms from the compiled artifact.

    FLOPs/bytes/collectives come from the HLO-walking cost model
    (launch.hlo_cost) which multiplies while bodies by their trip count —
    ``compiled.cost_analysis()`` counts loop bodies once and under-reports
    scan-heavy models ~26× (see hlo_cost docstring).  cost_analysis values
    are kept as a cross-check in ``coll_detail['_xla_flops']``.
    """
    from repro.launch.hlo_cost import HloCostModel
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    model = HloCostModel(hlo_text)
    costs = model.cost()
    coll = dict(costs.coll)
    coll["_xla_flops"] = xla_flops
    coll["_xla_bytes"] = xla_bytes
    return RooflineTerms(flops=max(costs.flops, xla_flops),
                         hbm_bytes=max(costs.bytes, xla_bytes),
                         coll_bytes=costs.coll_bytes,
                         chips=chips, coll_detail=coll)
