from repro.parallel.sharding import (ShardingRules, logical_to_physical,
                                     shard_params_pytree, zero_like_sharded,
                                     pick_fsdp_dim)

__all__ = ["ShardingRules", "logical_to_physical", "shard_params_pytree",
           "zero_like_sharded", "pick_fsdp_dim"]
