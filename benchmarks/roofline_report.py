"""§Roofline report: read the dry-run JSON and print the full per-cell
table (three terms, bottleneck, useful-FLOPs ratio, memory fit)."""
from __future__ import annotations

import json
import os

RESULTS = ["results/dryrun_single_pod.json", "results/dryrun_multi_pod.json"]


def fmt(r):
    rt = r.get("roofline", {})
    uf = r.get("useful_flops_ratio")
    return (f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{rt.get('t_compute_s', 0):.3e},{rt.get('t_memory_s', 0):.3e},"
            f"{rt.get('t_collective_s', 0):.3e},{rt.get('bottleneck','-')},"
            f"{(uf if uf is not None else 0):.3f},"
            f"{r.get('peak_adjusted_bytes', 0)/2**30:.2f},"
            f"{r.get('fits_16GiB_adjusted', False)}")


def main():
    print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
          "bottleneck,useful_flops_ratio,peak_adj_GiB,fits")
    for path in RESULTS:
        if not os.path.exists(path):
            print(f"# missing {path} — run launch/dryrun.py first")
            continue
        with open(path) as f:
            for r in json.load(f):
                if "error" in r:
                    print(f"{r['arch']},{r['shape']},{r['mesh']},ERROR,,,,,,")
                else:
                    print(fmt(r))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
