"""Analytic per-kernel VMEM block-residency models (DESIGN.md §10.2).

Each ``<kernel>_block_bytes`` function returns the EXACT number of bytes
of one grid step's block-resident state: every ``in_specs``/``out_specs``
block at its block shape and operand itemsize, plus every VMEM scratch
buffer.  Scalar-prefetch operands live in SMEM and are excluded.  The
property test (tests/test_vmem_model.py) pins these against the specs an
interpret-mode ``pallas_call`` actually receives, and the KC03 lint rule
evaluates each registered contract's model at its declared max shapes
against :data:`VMEM_BUDGET_BYTES`.

:func:`stage_a_vmem_bytes` is the coarser *capacity-planning* model the
serving benchmark sweeps record (operand blocks + the score tile +
top-k, dropping O(bq + bm) vectors); it lives here so the kernel models
and the planning model share one module, and ``kernels.ops`` re-exports
it unchanged.
"""
from __future__ import annotations

# Per-core VMEM capacity the contracts budget against (TPU v4/v5e class).
VMEM_BUDGET_BYTES = 16 * 2 ** 20


def stage_a_vmem_bytes(d: int, k: int, bq: int = 128, bm: int = 512,
                       bd: int | None = None,
                       itemsize: int = 4) -> int:
    """Analytic peak VMEM residency (bytes) of one stage-A grid step.

    Monolithic (``bd=None``): the [bq, D] query and [bm, D] corpus
    blocks dominate — linear in the item count D, the ~64k-item wall
    (16 MiB VMEM / (bq+bm)·4 B).  D-tiled: [bq, bd] + [bm, bd] operand
    blocks (``itemsize`` bytes: 4 fp32, 1 int8) + the f32 [bq, bm]
    accumulator — flat in D.  Both include the f32+i32 [bq, k] running
    top-k.  This is the model `benchmarks/bench_serving.py --scale`
    records per sweep point (DESIGN.md §8.2's table is generated from
    it); it counts double-buffered operand blocks once, so real
    residency is ≤ 2× for the streamed inputs.
    """
    topk = bq * k * (4 + 4)
    if bd is None:
        return (bq * d + bm * d) * itemsize + bq * bm * 4 + topk
    bd = min(bd, d)
    return (bq * bd + bm * bd) * itemsize + bq * bm * 4 + topk


def knn_topk_block_bytes(d: int, k: int, bq: int = 128, bm: int = 512,
                         itemsize: int = 4) -> int:
    """Monolithic stage A: qid[bq] + q[bq,d] + c[bm,d] + cnorm[bm] in,
    2×[bq,k] out, 2×[bq,k] scratch."""
    return (bq * 4 + (bq + bm) * d * itemsize + bm * 4
            + 2 * bq * k * 4 + 2 * bq * k * 4)


def knn_topk_dtiled_block_bytes(d: int, k: int, bq: int = 128,
                                bm: int = 512, bd: int = 512,
                                itemsize: int = 4) -> int:
    """D-tiled stage A: qid/qn/qs[bq] + cn/cs[bm] + q[bq,bd] + c[bm,bd]
    in, 2×[bq,k] out, [bq,bm] f32 accumulator + 2×[bq,k] scratch."""
    bd = min(bd, d)
    return (3 * bq * 4 + 2 * bm * 4 + (bq + bm) * bd * itemsize
            + 2 * bq * k * 4 + bq * bm * 4 + 2 * bq * k * 4)


def blend_topn_onehot_block_bytes(k: int, topn: int, bq: int = 128,
                                  bm: int = 512, bi: int = 512) -> int:
    """One-hot stage B: uid[bq] + idx[bq,k] + corpus[bm,bi] in,
    2×[bq,topn] out, 2×[bq,bi] + 2×[bq,topn] scratch."""
    return (bq * 4 + bq * k * 4 + bm * bi * 4
            + 2 * bq * topn * 4 + 2 * bq * bi * 4 + 2 * bq * topn * 4)


def blend_topn_rows_block_bytes(k: int, topn: int, bq: int = 8,
                                bi: int = 512) -> int:
    """Cross-shard stage B: q[bq,bi] + nbr[bq,k,bi] in, 2×[bq,topn]
    out, 2×[bq,topn] scratch.  The [bq,k,bi] block dominates — bq
    defaults low accordingly."""
    return (bq * bi * 4 + bq * k * bi * 4
            + 2 * bq * topn * 4 + 2 * bq * topn * 4)


def blend_topn_rows_quant_block_bytes(k: int, topn: int, bq: int = 8,
                                      bi: int = 512) -> int:
    """Quantized stage B: int8 q[bq,bi] + nbr[bq,k,bi] (itemsize 1) +
    f32 scales qs[bq] + ns[bq,k] in, 2×[bq,topn] out + scratch."""
    return (bq * bi + bq * k * bi + bq * 4 + bq * k * 4
            + 2 * bq * topn * 4 + 2 * bq * topn * 4)


def sparse_row_scatter_block_bytes(w: int, bi: int = 512) -> int:
    """Planned scatter: ids[1,w] + vals[1,w] + table tile[1,bi] in,
    [1,bi] out, [bi] f32 scratch (plan arrays are scalar-prefetch)."""
    return w * 4 + w * 4 + bi * 4 + bi * 4 + bi * 4


def sparse_row_gather_block_bytes(w: int, bi: int = 512) -> int:
    """Planned gather: ids[1,w] + table tile[1,bi] in, [1,w] out."""
    return w * 4 + bi * 4 + w * 4


def decayed_scatter_block_bytes(b: int, bn: int = 256,
                                bi: int = 512) -> int:
    """Multi-hot scatter: ids[bn,b] + w[bn] in, [bi] out, [bi] scratch."""
    return bn * b * 4 + bn * 4 + bi * 4 + bi * 4


def flash_attention_block_bytes(d: int, bq: int = 128, bk: int = 128,
                                itemsize: int = 4) -> int:
    """Attention: q[1,bq,d] + k/v[1,bk,d] in, [1,bq,d] out, f32
    (max[bq], denom[bq], acc[bq,d]) scratch."""
    return ((bq + 2 * bk + bq) * d * itemsize
            + 2 * bq * 4 + bq * d * 4)
