"""Multi-device parity tests (subprocess with 8 fake host devices):
the §Perf-optimized distributed paths must equal their single-device
references exactly."""
import subprocess
import sys



def _run(script: str) -> str:
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=580,
                       cwd="/root/repo")
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    return r.stdout


HEADER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
from repro import compat
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.sharding import ShardingRules
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(batch=("data",), fsdp=("data",))
rng = np.random.default_rng(0)
"""


def test_distributed_predict_matches_reference():
    _run(HEADER + r"""
from repro.core import knn
q = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
c = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
ref = knn.predict(q, c, k=5, alpha=0.7, exclude_self=False)
with compat.set_mesh(mesh):
    cd = jax.device_put(c, NamedSharding(mesh, P(("data","model"), None)))
    out = jax.jit(lambda q, c: knn.distributed_predict(
        q, c, 5, 0.7, mesh, rules))(q, cd)
assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
print("OK")
""")


def test_sharded_moe_matches_local():
    _run(HEADER + r"""
from repro.models.transformer import TransformerConfig, moe_block
import repro.models.transformer as T
c = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_head=16, d_ff=128, vocab_size=97, q_block=4,
                      moe=True, n_experts=8, n_shared_experts=0, top_k=2,
                      moe_d_ff=32, capacity_factor=4.0, dtype=jnp.float32)
shapes = T._dense_layer_shapes(c, False)
layer = {k: jax.random.normal(jax.random.PRNGKey(i), v, jnp.float32)*0.1
         for i, (k, v) in enumerate(shapes.items())
         if k.startswith(("router", "we_"))}
x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64), jnp.float32)
out_local = moe_block(x, layer, c, None, None)
with compat.set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ls = {"router": jax.device_put(layer["router"],
                                   NamedSharding(mesh, P(None, None))),
          "we_gate": jax.device_put(layer["we_gate"],
                                    NamedSharding(mesh, P("model", "data", None))),
          "we_up": jax.device_put(layer["we_up"],
                                  NamedSharding(mesh, P("model", "data", None))),
          "we_down": jax.device_put(layer["we_down"],
                                    NamedSharding(mesh, P("model", None, "data")))}
    out_sh = jax.jit(lambda x, l: moe_block(x, l, c, mesh, rules))(xs, ls)
assert float(jnp.max(jnp.abs(out_local - out_sh))) < 1e-4
print("OK")
""")


def test_bert4rec_shardmap_serve_matches_fallback():
    _run(HEADER + r"""
from repro.models import bert4rec
c = bert4rec.Bert4RecConfig(n_items=1000, embed_dim=32, n_blocks=2,
                            n_heads=2, seq_len=16, d_ff=64)
params = bert4rec.init_params(c, jax.random.PRNGKey(0))
ids = jnp.asarray(rng.integers(2, 900, (8, 16)), jnp.int32)
v0, i0 = bert4rec.serve_step(params, {"ids": ids}, c, top_n=10)
with compat.set_mesh(mesh):
    v1, i1 = jax.jit(lambda p, b: bert4rec.serve_step(
        p, b, c, top_n=10, mesh=mesh, rules=rules))(params, {"ids": ids})
np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), atol=1e-4)
for a, b in zip(np.asarray(i0), np.asarray(i1)):
    assert set(map(int, a)) == set(map(int, b))
print("OK")
""")


def test_lm_train_step_runs_sharded():
    """A real (executed, not just compiled) sharded MoE train step."""
    _run(HEADER + r"""
from repro.models import transformer as T
from repro.optim import adamw, adamw_state_pspecs
from repro.configs.base import named
c = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_head=16, d_ff=128, vocab_size=256, moe=True,
                        n_experts=8, n_shared_experts=1, top_k=2,
                        moe_d_ff=32, first_dense_layers=1, q_block=8,
                        capacity_factor=2.0, dtype=jnp.float32)
params = T.init_params(c, jax.random.PRNGKey(0))
pspecs = T.param_pspecs(c, mesh, rules)
opt = adamw(total_steps=5)
batch = {"tokens": jnp.ones((8, 32), jnp.int32),
         "labels": jnp.ones((8, 32), jnp.int32)}
with compat.set_mesh(mesh):
    params = jax.tree.map(lambda x, s: jax.device_put(
        x, NamedSharding(mesh, s)), params, pspecs,
        is_leaf=lambda x: isinstance(x, jax.Array))
    opt_state = opt.init(params)
    step = jax.jit(T.make_train_step(c, opt, mesh, rules),
                   donate_argnums=(0, 1))
    for _ in range(3):
        params, opt_state, m = step(params, opt_state, batch)
    loss = float(m["loss"])
assert np.isfinite(loss) and loss > 0
print("OK", loss)
""")
