"""Sharded state store for per-user TIFU-kNN state (paper §5, Fig. 1).

The Spark implementation keeps user vectors in a keyed state store; here
the store is a ``StreamState`` pytree whose user axis is sharded over the
``("pod", "data")`` mesh axes (user-level parallelism — paper: "each user
vector is calculated independently").  The item axis of ``user_vecs`` can
additionally be sharded over ``"model"`` for the kNN stage.

The store also owns the **serving corpus cache** (DESIGN.md §3.6): the
materialized ``[n_users, n_items]`` true-value corpus that kNN queries
read.  A micro-batch touches a handful of users; the engine marks those
rows dirty (``invalidate_users``) and ``corpus()`` refreshes only them —
high-QPS serving no longer pays a full scale×raw recompute per query.

Checkpointing + the idempotent update log give exactly-once semantics
across preemptions (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Optional, Set

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.types import StreamState, _pow2_pad


@dataclasses.dataclass
class StoreConfig:
    """Shapes, placement and cache policy of one state store.

    In a sharded deployment its user rows are ONE shard's slice
    (DESIGN.md §7).
    """

    n_users: int
    n_items: int
    max_baskets: int
    max_basket_size: int
    max_groups: Optional[int] = None
    dtype: str = "float32"
    # mesh axis names: user axis and item axis sharding
    user_axes: tuple = ("data",)
    item_axes: tuple = ("model",)
    # corpus cache: once more than this fraction of user rows is dirty,
    # one full materialize beats a huge scattered row refresh (ROADMAP:
    # very high delete rates)
    corpus_rebuild_frac: float = 0.25


def _fsync_dir(path: str) -> None:
    """Make a rename in ``path`` durable.

    The file fsync orders the DATA, the directory fsync orders the
    ENTRY — both are needed for the crash-anywhere guarantee.
    """
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_json(path: str, payload: dict) -> None:
    """Write json atomically and durably (the commit-point primitive).

    Tmp-file + fsync + ``os.replace`` + directory fsync, so a crash —
    process OR system — leaves either the previous intact file or
    nothing, never a truncated one (the same contract as the state npz
    writes).
    """
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


def load_checkpoint_arrays(directory: str):
    """Read one checkpoint commit as host arrays: ``(meta, leaves)``.

    Reads the ``LATEST`` metadata (the atomic commit point) and the state
    npz it names, migrating pre-scaled-representation checkpoints (no
    ``uv_scale``/``lgv_scale`` leaves) to scales of 1.  Shared by
    :meth:`StateStore.restore` and the resharding restore path
    (``streaming.engine.ShardedStreamingEngine.restore``, DESIGN.md §7),
    which reassembles N shard checkpoints without installing them into a
    same-shape store first.  Cost: one O(state) read, no device work.
    """
    with open(os.path.join(directory, "LATEST")) as f:
        meta = json.load(f)
    step = meta["step"]
    path = os.path.join(directory, f"state_{step:010d}.npz")
    data = np.load(path)
    leaves = {k: np.asarray(data[k]) for k in data.files}
    for scale in ("uv_scale", "lgv_scale"):
        if scale not in leaves:
            leaves[scale] = np.ones(leaves["err_mult"].shape,
                                    leaves["err_mult"].dtype)
    return meta, leaves


def state_shardings(cfg: StoreConfig, mesh) -> StreamState:
    """PartitionSpecs for every leaf of the state pytree."""
    u = P(cfg.user_axes)
    ui = P(cfg.user_axes, cfg.item_axes)
    return StreamState(
        user_vecs=NamedSharding(mesh, ui),
        last_group_vecs=NamedSharding(mesh, ui),
        history=NamedSharding(mesh, P(cfg.user_axes, None, None)),
        group_sizes=NamedSharding(mesh, P(cfg.user_axes, None)),
        n_baskets=NamedSharding(mesh, u),
        n_groups=NamedSharding(mesh, u),
        err_mult=NamedSharding(mesh, u),
        uv_scale=NamedSharding(mesh, u),
        lgv_scale=NamedSharding(mesh, u),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _refresh_corpus_rows(corpus, user_vecs, uv_scale, rows):
    """Refresh ``corpus[rows] = uv_scale[rows] * user_vecs[rows]`` in place.

    ``rows`` may contain duplicates (pow2 padding repeats the first dirty
    row); duplicate writes carry identical values.
    """
    return corpus.at[rows].set(user_vecs[rows] * uv_scale[rows, None])


class StateStore:
    """Owns the StreamState, the serving corpus cache and persistence.

    On a real cluster the store's arrays are device-sharded via the
    shardings above; on the CPU test runner they are single-device.
    """

    def __init__(self, cfg: StoreConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.state = StreamState.zeros(
            cfg.n_users, cfg.n_items, cfg.max_baskets, cfg.max_basket_size,
            cfg.max_groups)
        if mesh is not None:
            sh = state_shardings(cfg, mesh)
            self.state = jax.tree.map(jax.device_put, self.state,
                                      sh, is_leaf=lambda x: x is None)
        self._corpus: Optional[jax.Array] = None
        self._dirty: Set[int] = set()
        self.corpus_full_builds = 0
        self.corpus_rows_refreshed = 0
        self.corpus_threshold_rebuilds = 0
        self.last_restored_meta: dict = {}

    # -- serving corpus cache (DESIGN.md §3.6) --------------------------------

    def invalidate_users(self, users) -> None:
        """Mark user rows of the serving corpus stale.

        The engine calls this after every micro-batch / stability
        refresh with the touched users; O(|users|) set inserts.
        """
        if self._corpus is None:
            return            # no cache yet: the first corpus() builds it
        self._dirty.update(int(x) for x in np.asarray(users).ravel())

    def invalidate_all(self) -> None:
        """Drop the cache entirely (restore, out-of-band state edits)."""
        self._corpus = None
        self._dirty.clear()

    def corpus(self) -> jax.Array:
        """The materialized true-value corpus f32[n_users, n_items].

        First call (or after ``invalidate_all``) densifies everything;
        subsequent calls refresh only rows dirtied since the last call.
        The row list is padded to a pow2 bucket (duplicating one dirty
        row) so the refresh program compiles O(log n_users) times.

        LIFETIME: the refresh updates the cached buffer IN PLACE
        (donation keeps it O(dirty·I)), so the returned array is valid
        only until the next ``corpus()`` call that follows an
        invalidation.  Finish (or copy) a request batch before applying
        the next micro-batch's refresh — the serving loop here is
        synchronous, matching launch/serve.py.
        """
        if self._corpus is None:
            self._corpus = self.state.materialized_user_vecs()
            self._dirty.clear()
            self.corpus_full_builds += 1
        elif len(self._dirty) > self.cfg.corpus_rebuild_frac \
                * self.cfg.n_users:
            # past the crossover one full rebuild is cheaper than a
            # scattered refresh of most rows (and compiles exactly once)
            self._corpus = self.state.materialized_user_vecs()
            self._dirty.clear()
            self.corpus_full_builds += 1
            self.corpus_threshold_rebuilds += 1
        elif self._dirty:
            rows = np.fromiter(self._dirty, np.int32, len(self._dirty))
            self.corpus_rows_refreshed += rows.size
            pad = _pow2_pad(rows.size, self.cfg.n_users) - rows.size
            if pad:
                rows = np.concatenate([rows, np.full(pad, rows[0],
                                                     np.int32)])
            self._corpus = _refresh_corpus_rows(
                self._corpus, self.state.user_vecs, self.state.uv_scale,
                jnp.asarray(rows))
            self._dirty.clear()
        return self._corpus

    # -- persistence (exactly-once recovery substrate) -----------------------

    def checkpoint(self, directory: str, step: int,
                   extra_meta: Optional[dict] = None) -> str:
        """Write one atomic checkpoint commit; returns the npz path.

        The state npz is made durable FIRST; the ``LATEST`` metadata
        write (which carries ``extra_meta``, e.g. the engine's
        exactly-once log) is the single atomic commit point — see the
        comment at the write below.  Cost: one O(state) device fetch +
        compressed write.
        """
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, f"state_{step:010d}.npz")
        tmp = path + ".tmp"
        leaves = {
            "user_vecs": np.asarray(self.state.user_vecs),
            "last_group_vecs": np.asarray(self.state.last_group_vecs),
            "history": np.asarray(self.state.history),
            "group_sizes": np.asarray(self.state.group_sizes),
            "n_baskets": np.asarray(self.state.n_baskets),
            "n_groups": np.asarray(self.state.n_groups),
            "err_mult": np.asarray(self.state.err_mult),
            "uv_scale": np.asarray(self.state.uv_scale),
            "lgv_scale": np.asarray(self.state.lgv_scale),
        }
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **leaves)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(directory)
        meta = dict(step=step, **dataclasses.asdict(self.cfg))
        meta["user_axes"] = list(meta["user_axes"])
        meta["item_axes"] = list(meta["item_axes"])
        if extra_meta:
            meta.update(extra_meta)
        # LATEST is the single commit point: the npz above is durable
        # before this replace lands, and any co-checkpointed metadata
        # (the engine's exactly-once log) rides in the SAME atomic write
        # — a crash anywhere leaves the previous checkpoint fully
        # consistent, never a new state with an old log.
        atomic_write_json(os.path.join(directory, "LATEST"), meta)
        return path

    def _validate_meta(self, meta: dict) -> None:
        """Reject checkpoints written under different shape dimensions.

        Silently installing wrong-shaped state either fails later (shape
        error far from the cause) or — worse — runs with aliased
        user/item indices.
        """
        mismatches = []
        for field in ("n_users", "n_items", "max_baskets",
                      "max_basket_size"):
            want = getattr(self.cfg, field)
            got = meta.get(field)
            if got is not None and got != want:
                mismatches.append(f"{field}: checkpoint={got} store={want}")
        k_ckpt = meta.get("max_groups") or meta.get("max_baskets")
        k_cfg = self.cfg.max_groups or self.cfg.max_baskets
        if meta.get("max_baskets") is not None and k_ckpt != k_cfg:
            mismatches.append(
                f"max_groups (effective): checkpoint={k_ckpt} store={k_cfg}")
        if mismatches:
            raise ValueError(
                "checkpoint/store shape mismatch — refusing to restore: "
                + "; ".join(mismatches))

    def install_state(self, state: StreamState) -> None:
        """Replace the owned state out-of-band (resharding restore).

        Applies the store's device/mesh placement and drops the serving
        corpus cache — every row may have changed.  Callers are
        responsible for shape-validating ``state`` against the config
        (the resharding path does, via the checkpoint metadata).
        """
        if self.mesh is not None:
            sh = state_shardings(self.cfg, self.mesh)
            state = jax.tree.map(jax.device_put, state, sh)
        self.state = state
        self.invalidate_all()

    def restore(self, directory: str) -> int:
        """Install the checkpoint in ``directory``; returns its step.

        Reads the atomic ``LATEST`` commit, validates its shape metadata
        against this store's config (refusing mismatches loudly), keeps
        the parsed metadata in :attr:`last_restored_meta` for
        co-checkpointed payloads (the engine's exactly-once log rides in
        ``meta["engine"]`` — one reader, one parse), and drops the
        serving-corpus cache.  Cost: one O(state) read + device upload.
        """
        meta, leaves = load_checkpoint_arrays(directory)
        self._validate_meta(meta)
        self.last_restored_meta = meta
        step = meta["step"]
        self.install_state(StreamState(
            **{k: jax.numpy.asarray(v) for k, v in leaves.items()}))
        return step
