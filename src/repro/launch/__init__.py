"""Launch: production meshes, the multi-pod dry-run, training and
serving drivers, roofline analysis."""
from repro.launch.mesh import (make_production_mesh, make_rules,
                               make_test_mesh)

__all__ = ["make_production_mesh", "make_rules", "make_test_mesh"]
