"""Batched JAX incremental/decremental updates (the TPU production path).

Fixed-shape, mask-driven implementations of the paper's update rules,
``vmap``-able over a micro-batch of users.  Semantics are validated
against ``core.ref_engine`` (the paper-faithful oracle) in
``tests/test_updates_jax.py``.

Design notes (DESIGN.md §3.2): the variable-length suffix contractions of
Eq. 10/12 are computed as *masked fixed-shape* weighted multi-hot
scatters using the closed-form coefficient expansion in
``decay.batched_suffix_coefficients`` — no data-dependent shapes, so one
compiled program serves every deletion position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import decay
from repro.core.tifu import (closed_form_basket_weights,
                             last_group_vector_padded,
                             weighted_multihot_scatter, user_vector_padded)
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM,
                              KIND_NOOP, PAD_ID, StreamState, TifuParams,
                              UpdateBatch)


# ---------------------------------------------------------------------------
# Helpers on padded per-user state
# ---------------------------------------------------------------------------

def _multi_hot(items, n_items):
    """items: i32[B] (PAD_ID padded) → f32[I]."""
    valid = items >= 0
    ids = jnp.where(valid, items, 0)
    return jnp.zeros((n_items,), jnp.float32).at[ids].add(
        valid.astype(jnp.float32))


def _row_group_geometry(group_sizes, max_baskets):
    """Per-history-row group index g (0-based), in-group pos p (1-based),
    group size tau, for fixed max_baskets rows."""
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    starts = ends - sizes
    t = jnp.arange(max_baskets)
    g = jnp.clip(jnp.searchsorted(ends, t, side="right"), 0,
                 sizes.shape[0] - 1)
    tau = sizes[g]
    p = t - starts[g] + 1
    return g, p, tau


def _locate(group_sizes, pos):
    """Group index j (0-based) and in-group position i (1-based) of a
    global basket index ``pos`` (traced)."""
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    starts = ends - sizes
    j = jnp.clip(jnp.searchsorted(ends, pos, side="right"), 0,
                 sizes.shape[0] - 1)
    i = pos - starts[j] + 1
    return j, i


# ---------------------------------------------------------------------------
# Single-user updates (to be vmapped)
# ---------------------------------------------------------------------------

def _add_basket(user_vec, last_group_vec, history, group_sizes, n_baskets,
                n_groups, err_mult, items, params: TifuParams):
    n_items = user_vec.shape[0]
    v_b = _multi_hot(items, n_items).astype(user_vec.dtype)
    k = n_groups
    tau = jnp.where(k > 0, group_sizes[jnp.maximum(k - 1, 0)], 0)
    new_group = (k == 0) | (tau >= params.group_size)

    # Scenario 1 (Eq. 7): new single-basket group.
    user_new_a = (k * params.r_g * user_vec + v_b) / (k + 1)
    lgv_a = v_b
    sizes_a = group_sizes.at[jnp.minimum(k, group_sizes.shape[0] - 1)].set(1)
    err_a = jnp.maximum(
        err_mult * jnp.where(k > 0, decay.error_shrink_factor(k, params.r_g),
                             0.0), 1e-30)

    # Scenario 2 (Eq. 8 + Eq. 9): append to the last group.
    safe_tau = jnp.maximum(tau, 1)
    lgv_b = (safe_tau * params.r_b * last_group_vec + v_b) / (safe_tau + 1)
    user_new_b = user_vec + (lgv_b - last_group_vec) / jnp.maximum(k, 1)
    sizes_b = group_sizes.at[jnp.maximum(k - 1, 0)].add(1)
    err_b = err_mult

    user_vec = jnp.where(new_group, user_new_a, user_new_b)
    last_group_vec = jnp.where(new_group, lgv_a, lgv_b)
    group_sizes = jnp.where(new_group, sizes_a, sizes_b)
    err_mult = jnp.where(new_group, err_a, err_b)
    history = history.at[jnp.minimum(n_baskets, history.shape[0] - 1)].set(items)
    return (user_vec, last_group_vec, history, group_sizes, n_baskets + 1,
            n_groups + new_group.astype(jnp.int32), err_mult)


def _delete_basket(user_vec, last_group_vec, history, group_sizes, n_baskets,
                   n_groups, err_mult, pos, params: TifuParams):
    n_items = user_vec.shape[0]
    max_baskets = history.shape[0]
    k = n_groups
    j, i = _locate(group_sizes, pos)
    tau_j = group_sizes[j]
    g, p, tau = _row_group_geometry(group_sizes, max_baskets)
    t = jnp.arange(max_baskets)
    valid_row = t < n_baskets
    in_group_j = valid_row & (g == j)
    f32 = user_vec.dtype

    # ---- Scenario 1 (Eq. 10 + Eq. 11): tau_j > 1 -------------------------
    safe_tau = jnp.maximum(tau_j, 2)
    # recompute v_gj from the group's rows (O(tau) real work, masked here)
    w_gj = jnp.where(in_group_j,
                     jnp.asarray(params.r_b, f32) ** (tau_j - p)
                     / jnp.maximum(tau_j, 1).astype(f32), 0.0)
    v_gj = weighted_multihot_scatter(history, w_gj, n_items).astype(f32)
    # suffix coefficients inside group j, positions p >= i
    pow_tp = jnp.asarray(params.r_b, f32) ** (tau_j - p)
    c_row = jnp.where(p == i, -pow_tp, pow_tp * (params.r_b - 1.0))
    c_row = jnp.where(in_group_j & (p >= i), c_row, 0.0)
    suffix_g = weighted_multihot_scatter(history, c_row, n_items).astype(f32)
    v_gj_new = (tau_j * v_gj + suffix_g) / ((safe_tau - 1) * params.r_b)
    user_s1 = user_vec + (jnp.asarray(params.r_g, f32) ** (k - 1 - j)
                          * (v_gj_new - v_gj) / jnp.maximum(k, 1))
    sizes_s1 = group_sizes.at[j].add(-1)
    groups_s1 = k

    # ---- Scenario 2 (Eq. 12): tau_j == 1, k > 1 ---------------------------
    # suffix over group vectors j..k-1, expanded to per-basket weights:
    # coeff per group c_g (1-based group pos = g+1), times within-group
    # decayed-average weight r_b^(tau-p)/tau.
    cg = decay.batched_suffix_coefficients(k, j + 1,
                                           jnp.asarray(params.r_g, f32),
                                           group_sizes.shape[0]).astype(f32)
    w_row_s2 = jnp.where(valid_row,
                         cg[g] * jnp.asarray(params.r_b, f32) ** (tau - p)
                         / jnp.maximum(tau, 1).astype(f32), 0.0)
    suffix_u = weighted_multihot_scatter(history, w_row_s2, n_items).astype(f32)
    safe_k = jnp.maximum(k, 2)
    user_s2 = (k * user_vec + suffix_u) / ((safe_k - 1) * params.r_g)
    sizes_s2 = _remove_entry(group_sizes, j)
    groups_s2 = k - 1
    err_s2 = err_mult * decay.error_growth_factor(safe_k.astype(f32),
                                                  params.r_g)

    # ---- Scenario 3: tau_j == 1 and k == 1 → empty state ------------------
    user_s3 = jnp.zeros_like(user_vec)
    sizes_s3 = jnp.zeros_like(group_sizes)
    groups_s3 = jnp.zeros_like(k)

    single = tau_j == 1
    last = k == 1
    user_vec = jnp.where(single, jnp.where(last, user_s3, user_s2), user_s1)
    group_sizes = jnp.where(single, jnp.where(last, sizes_s3, sizes_s2),
                            sizes_s1)
    n_groups = jnp.where(single, jnp.where(last, groups_s3, groups_s2),
                         groups_s1)
    err_mult = jnp.where(single, jnp.where(last, jnp.ones_like(err_mult),
                                           err_s2), err_mult)

    # ---- history compaction: shift rows > pos up by one --------------------
    src = jnp.where(t >= pos, jnp.minimum(t + 1, max_baskets - 1), t)
    history = history[src]
    history = history.at[jnp.maximum(n_baskets - 1, 0)].set(
        jnp.full((history.shape[1],), PAD_ID, jnp.int32))
    n_baskets = n_baskets - 1

    # last_group_vec: recompute from the new geometry (cheap, masked).
    last_group_vec = last_group_vector_padded(
        history, group_sizes, n_groups,
        params).astype(f32)
    return (user_vec, last_group_vec, history, group_sizes, n_baskets,
            n_groups, err_mult)


def _remove_entry(sizes, j):
    """Remove entry j from a padded i32 vector (shift left, zero-fill)."""
    n = sizes.shape[0]
    t = jnp.arange(n)
    src = jnp.where(t >= j, jnp.minimum(t + 1, n - 1), t)
    out = sizes[src]
    return out.at[n - 1].set(jnp.where(j <= n - 1, 0, out[n - 1]))


def _delete_item(user_vec, last_group_vec, history, group_sizes, n_baskets,
                 n_groups, err_mult, pos, item, params: TifuParams):
    """Scenario 3 of §4.3 (Eq. 13 + Eq. 11) with basket-vanish fallback."""
    n_items = user_vec.shape[0]
    f32 = user_vec.dtype
    row = history[pos]
    present = jnp.any(row == item)
    blen = jnp.sum(row >= 0)
    vanish = present & (blen == 1)

    # --- Eq. 13 path: remove the item from the basket in place -------------
    j, i = _locate(group_sizes, pos)
    k = n_groups
    tau_j = jnp.maximum(group_sizes[j], 1)
    delta = -_multi_hot(jnp.array([item]), n_items).astype(f32)
    scale_g = jnp.asarray(params.r_b, f32) ** (tau_j - i) / tau_j
    dg = scale_g * delta                       # v'_gj - v_gj
    user_ip = user_vec + (jnp.asarray(params.r_g, f32) ** (k - 1 - j)
                          * dg / jnp.maximum(k, 1))
    lgv_ip = jnp.where(j == k - 1, last_group_vec + dg, last_group_vec)
    new_row = jnp.where(row == item, PAD_ID, row)
    hist_ip = history.at[pos].set(new_row)

    # --- fallback: basket vanishes → full basket deletion -------------------
    (user_db, lgv_db, hist_db, sizes_db, nb_db, ng_db, err_db) = \
        _delete_basket(user_vec, last_group_vec, history, group_sizes,
                       n_baskets, n_groups, err_mult, pos, params)

    apply_ip = present & ~vanish
    apply_db = vanish
    user_vec = jnp.where(apply_ip, user_ip,
                         jnp.where(apply_db, user_db, user_vec))
    last_group_vec = jnp.where(apply_ip, lgv_ip,
                               jnp.where(apply_db, lgv_db, last_group_vec))
    history = jnp.where(apply_ip, hist_ip,
                        jnp.where(apply_db, hist_db, history))
    group_sizes = jnp.where(apply_db, sizes_db, group_sizes)
    n_baskets = jnp.where(apply_db, nb_db, n_baskets)
    n_groups = jnp.where(apply_db, ng_db, n_groups)
    err_mult = jnp.where(apply_db, err_db, err_mult)
    return (user_vec, last_group_vec, history, group_sizes, n_baskets,
            n_groups, err_mult)


def _single_update(user_vec, last_group_vec, history, group_sizes, n_baskets,
                   n_groups, err_mult, kind, items, pos, item,
                   params: TifuParams):
    """Dispatch one update (Algorithm 1 generalised to 4 kinds)."""
    state = (user_vec, last_group_vec, history, group_sizes, n_baskets,
             n_groups, err_mult)
    add = _add_basket(*state, items, params)
    # guard delete positions for noop/add rows so gathers stay in-bounds
    safe_pos = jnp.clip(pos, 0, jnp.maximum(n_baskets - 1, 0))
    delb = _delete_basket(*state, safe_pos, params)
    deli = _delete_item(*state, safe_pos, item, params)

    def sel(a, b, c, d):
        return jnp.where(kind == KIND_ADD_BASKET, b,
                         jnp.where(kind == KIND_DEL_BASKET, c,
                                   jnp.where(kind == KIND_DEL_ITEM, d, a)))

    # suppress deletes on empty histories (no-op)
    empty = n_baskets == 0
    kind = jnp.where(empty & ((kind == KIND_DEL_BASKET)
                              | (kind == KIND_DEL_ITEM)), KIND_NOOP, kind)
    return tuple(sel(s, a, b, c)
                 for s, a, b, c in zip(state, add, delb, deli))


# ---------------------------------------------------------------------------
# Micro-batch application
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("params",), donate_argnums=(0,))
def apply_update_batch(state: StreamState, batch: UpdateBatch,
                       params: TifuParams) -> StreamState:
    """Apply a micro-batch of updates (one per distinct user).

    INVARIANT (enforced by streaming.engine): within one batch each user
    appears at most once among non-noop rows.  Results are written back
    as *deltas* with scatter-add, so noop rows (delta 0) may alias any
    user.
    """
    u = batch.user
    gathered = (state.user_vecs[u], state.last_group_vecs[u],
                state.history[u], state.group_sizes[u], state.n_baskets[u],
                state.n_groups[u], state.err_mult[u])
    updated = jax.vmap(
        lambda uv, lgv, h, gs, nb, ng, em, kind, items, pos, item:
        _single_update(uv, lgv, h, gs, nb, ng, em, kind, items, pos, item,
                       params))(
        *gathered, batch.kind, batch.basket_items, batch.basket_pos,
        batch.item)
    deltas = tuple(new - old for new, old in zip(updated, gathered))
    return StreamState(
        user_vecs=state.user_vecs.at[u].add(deltas[0]),
        last_group_vecs=state.last_group_vecs.at[u].add(deltas[1]),
        history=state.history.at[u].add(deltas[2]),
        group_sizes=state.group_sizes.at[u].add(deltas[3]),
        n_baskets=state.n_baskets.at[u].add(deltas[4]),
        n_groups=state.n_groups.at[u].add(deltas[5]),
        err_mult=state.err_mult.at[u].add(deltas[6]),
    )


@functools.partial(jax.jit, static_argnames=("params",))
def refresh_users(state: StreamState, users, params: TifuParams) -> StreamState:
    """Exact from-scratch refresh of selected users (stability tracker)."""
    h = state.history[users]
    gs = state.group_sizes[users]
    ng = state.n_groups[users]
    fresh = jax.vmap(lambda hh, gg, nn: user_vector_padded(hh, gg, nn, params))(
        h, gs, ng).astype(state.user_vecs.dtype)
    lgv = jax.vmap(lambda hh, gg, nn: last_group_vector_padded(
        hh, gg, nn, params))(h, gs, ng).astype(state.user_vecs.dtype)
    return StreamState(
        user_vecs=state.user_vecs.at[users].set(fresh),
        last_group_vecs=state.last_group_vecs.at[users].set(lgv),
        history=state.history,
        group_sizes=state.group_sizes,
        n_baskets=state.n_baskets,
        n_groups=state.n_groups,
        err_mult=state.err_mult.at[users].set(1.0),
    )
