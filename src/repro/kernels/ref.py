"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's tests sweep shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_topk_ref(queries, corpus, k: int, metric: str = "euclidean"):
    """Top-k (scores, indices) of each query against the corpus.

    euclidean uses the monotone surrogate 2qc − |c|² (per-query |q|² is
    rank-irrelevant and omitted, matching the kernel).
    """
    if metric == "euclidean":
        scores = (2.0 * queries @ corpus.T
                  - jnp.sum(corpus * corpus, axis=-1)[None, :])
    elif metric == "dot":
        scores = queries @ corpus.T
    else:
        raise ValueError(metric)
    return jax.lax.top_k(scores.astype(jnp.float32), k)


def _pairwise_scores(queries, corpus, metric: str):
    """Mirror of ``core.knn.pairwise_scores`` (duplicated: kernels must
    not import core).  Bitwise parity with the core version is pinned by
    tests/test_serving_pipeline.py."""
    if metric == "euclidean":
        qc = queries @ corpus.T
        qn = jnp.sum(queries * queries, axis=-1, keepdims=True)
        cn = jnp.sum(corpus * corpus, axis=-1)[None, :]
        return 2.0 * qc - qn - cn
    if metric == "cosine":
        qn = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-12)
        cn = corpus / jnp.maximum(
            jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-12)
        return qn @ cn.T
    if metric == "dot":
        return queries @ corpus.T
    raise ValueError(f"unknown metric {metric}")


def fused_recommend_ref(corpus, user_ids, k: int, alpha, topn: int,
                        metric: str = "euclidean"):
    """Oracle for the fused serving pipeline (DESIGN.md §8).

    Computes EXACTLY what the pre-fusion `core.knn.recommend_for_users`
    computed — row gather, full-score nearest neighbours with self
    exclusion, [Q, k, I] neighbour gather + mean, alpha blend, top-n —
    in the same operation order, so the dispatcher's CPU path stays
    bitwise-identical to the historical serving output.
    """
    queries = corpus[user_ids]
    scores = _pairwise_scores(queries, corpus, metric)
    scores = scores.at[jnp.arange(queries.shape[0]), user_ids].set(-jnp.inf)
    _, idx = jax.lax.top_k(scores, k)
    neighbors = jnp.mean(corpus[idx], axis=1)
    pred = alpha * queries + (1.0 - alpha) * neighbors
    return jax.lax.top_k(pred, topn)[1]


def shard_topk_ref(queries, corpus, k: int, shard: int, n_shards: int,
                   query_gids=None, metric: str = "euclidean"):
    """Oracle for the per-shard candidate stage (DESIGN.md §7.3).

    One shard's local corpus scored in full; self-exclusion compares
    GLOBAL ids (``local_row · n_shards + shard``) so a query user is
    masked only on its owner shard.  Returns ``([Q, k'] scores, global
    ids)`` with ``k' = min(k, M_s)`` — the exact math the pre-fusion
    `core.knn.shard_topk_candidates` ran.
    """
    m_s = corpus.shape[0]
    scores = _pairwise_scores(queries, corpus, metric).astype(jnp.float32)
    col_gid = jnp.arange(m_s, dtype=jnp.int32) * n_shards + shard
    if query_gids is not None:
        scores = jnp.where(col_gid[None, :] == query_gids[:, None],
                           -jnp.inf, scores)
    vals, idx = jax.lax.top_k(scores, min(k, m_s))
    return vals, col_gid[idx]


def blend_topn_rows_ref(queries, neighbor_rows, alpha, topn: int):
    """Oracle for the cross-shard blend: mean over the fetched rows,
    alpha blend, top-n — the pre-fusion ``_combine_neighbors`` math."""
    neighbors = jnp.mean(neighbor_rows, axis=1)
    pred = alpha * queries + (1.0 - alpha) * neighbors
    return jax.lax.top_k(pred, topn)[1]


def tiled_sqnorm_ref(x, bd: int):
    """Per-row squared norm in D-tile accumulation order (f32[M]).

    Duplicate of ``kernels.knn_topk.tiled_sqnorm`` (the oracle must not
    import kernel modules); both call sites see the same shapes, so the
    two are bitwise identical — pinned by tests/test_quantized_serving.py.
    int8 rows: exact int32 per-tile sums, f32 cross-tile accumulation.
    """
    m, d = x.shape
    bd = max(1, min(bd, d))
    nt = -(-d // bd)
    pad = nt * bd - d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    xt = x.reshape(m, nt, bd)
    if x.dtype == jnp.int8:
        per_tile = jnp.sum(xt.astype(jnp.int32) ** 2,
                           axis=-1).astype(jnp.float32)
    else:
        xf = xt.astype(jnp.float32)
        per_tile = jnp.sum(xf * xf, axis=-1)
    return jnp.cumsum(per_tile, axis=1)[:, -1]


def dtiled_topk_ref(queries, corpus, k: int, bd: int = 512,
                    query_gids=None, col_offset: int = 0,
                    col_stride: int = 1, sub_qnorm: bool = False,
                    q_scale=None, c_scale=None):
    """Oracle for the D-tiled stage A (DESIGN.md §8.4).

    Mirrors ``knn_topk_dtiled``'s accumulation schedule exactly: the
    q·cᵀ contraction is a ``lax.scan`` over ⌈D/bd⌉ D-tiles (a scan, not
    a Python loop — at I = 10⁶ an unrolled jaxpr would have ~2000 dot
    ops), each tile's partial computed in int32 (int8 inputs, exact) or
    f32, accumulated cross-tile in f32 in tile order.  On the int8 path
    this makes the oracle BITWISE the kernel's output for any (bq, bm)
    blocking — the acceptance contract of ISSUE 7.  Scores, masks and
    scale application use the identical expression tree as the kernel.
    Requires k ≤ M (callers clamp, as for ``knn_topk_ref``).
    """
    qn, d = queries.shape
    m = corpus.shape[0]
    quantized = corpus.dtype == jnp.int8
    bd = max(1, min(bd, d))
    nt = -(-d // bd)
    cn = tiled_sqnorm_ref(corpus, bd)
    qnorm = (tiled_sqnorm_ref(queries, bd) if sub_qnorm
             else jnp.zeros((qn,), jnp.float32))
    pad = nt * bd - d
    qp, cp = queries, corpus
    if pad:
        qp = jnp.pad(qp, ((0, 0), (0, pad)))
        cp = jnp.pad(cp, ((0, 0), (0, pad)))
    qt = jnp.moveaxis(qp.reshape(qn, nt, bd), 1, 0)   # [nt, Q, bd]
    ct = jnp.moveaxis(cp.reshape(m, nt, bd), 1, 0)    # [nt, M, bd]

    def step(acc, qc):
        q, c = qc
        if quantized:
            part = jax.lax.dot_general(
                q, c, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
            return acc + part.astype(jnp.float32), None
        return acc + jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32), None

    acc, _ = jax.lax.scan(step, jnp.zeros((qn, m), jnp.float32), (qt, ct))
    if q_scale is None:
        q_scale = jnp.ones((qn,), jnp.float32)
        c_scale = jnp.ones((m,), jnp.float32)
    scores = (2.0 * (q_scale[:, None] * c_scale[None, :]) * acc
              - (c_scale * c_scale)[None, :] * cn[None, :])
    if sub_qnorm:
        scores = scores - (q_scale * q_scale * qnorm)[:, None]
    if query_gids is not None:
        col_gid = (jnp.arange(m, dtype=jnp.int32) * col_stride
                   + col_offset)
        scores = jnp.where(col_gid[None, :] == query_gids[:, None],
                           -jnp.inf, scores)
    return jax.lax.top_k(scores, k)


def blend_topn_rows_quant_ref(queries_q, q_scale, neighbor_rows_q,
                              n_scale, alpha, topn: int):
    """Oracle for the quantized cross-shard blend (stage B, §8.4).

    queries_q int8[Q, I] / q_scale f32[Q]; neighbor_rows_q
    int8[Q, k, I] / n_scale f32[Q, k].  Dequantizes (exact elementwise
    f32 multiplies, the kernel's in-VMEM operands bitwise), then the
    same mean + alpha blend + top-n as ``blend_topn_rows_ref``.
    """
    queries = queries_q.astype(jnp.float32) * q_scale[:, None]
    nbr = neighbor_rows_q.astype(jnp.float32) * n_scale[:, :, None]
    neighbors = jnp.mean(nbr, axis=1)
    pred = alpha * queries + (1.0 - alpha) * neighbors
    return jax.lax.top_k(pred, topn)[1]


def fused_recommend_quant_ref(corpus_q, c_scale, user_ids, k: int, alpha,
                              topn: int, bd: int = 512):
    """Oracle for the int8 fused serving pipeline (DESIGN.md §8.4).

    The query IS the user's quantized corpus row (q_scale =
    c_scale[user]); stage A is the D-tiled int8 top-k with fused
    self-exclusion, stage B gathers only the selected k int8 rows
    (the 4×-smaller HBM fetch that motivates the path) and blends
    dequantized.  Requires k ≤ M − 1 (dispatcher clamps).
    """
    queries_q = corpus_q[user_ids]
    q_scale = c_scale[user_ids]
    _, idx = dtiled_topk_ref(queries_q, corpus_q, k, bd=bd,
                             query_gids=user_ids, q_scale=q_scale,
                             c_scale=c_scale)
    return blend_topn_rows_quant_ref(queries_q, q_scale, corpus_q[idx],
                                     c_scale[idx], alpha, topn)


def decayed_scatter_ref(ids, weights, n_items: int):
    """Weighted multi-hot scatter: out[i] = Σ_{n,b} w[n]·[ids[n,b] == i].

    ids: i32[N, B] (PAD=-1), weights: f32[N] → f32[n_items].
    This is the TIFU-kNN user-vector builder AND the EmbeddingBag-grad
    shape (one-hot-matmul on TPU).
    """
    flat = ids.reshape(-1)
    w = jnp.repeat(weights, ids.shape[1])
    valid = flat >= 0
    return jnp.zeros((n_items,), jnp.float32).at[
        jnp.where(valid, flat, 0)].add(jnp.where(valid, w, 0.0))


def sparse_row_scatter_ref(table, rows, ids, vals):
    """Sparse per-row scatter-add into a [M, I] table.

    table: f32[M, I]; rows: i32[U]; ids: i32[U, W] (PAD=-1 skipped);
    vals: f32[U, W].  Returns table with

        out[rows[r], ids[r, w]] += vals[r, w]      for ids[r, w] >= 0.

    Duplicate (row, id) pairs accumulate.  Only O(U·W) elements of the
    table are addressed — this is the batched add path's delta applier
    (DESIGN.md §3.3).
    """
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    v = jnp.where(valid, vals, 0.0)
    return table.at[rows[:, None], safe].add(v)


def sparse_row_gather_ref(table, rows, ids):
    """Sparse per-row gather from a [M, I] table.

    table: f32[M, I]; rows: i32[U]; ids: i32[U, W] (PAD=-1 → 0.0).
    Returns f32[U, W] with out[r, w] = table[rows[r], ids[r, w]].

    The read half of the sparse_row_scatter pair: the decremental paths
    gather the raw values on an event's support before computing the
    reset/delta terms (DESIGN.md §3.5).  O(U·W) elements addressed.
    """
    m = table.shape[0]
    valid = ids >= 0
    safe_rows = jnp.clip(rows, 0, m - 1)
    vals = table[safe_rows[:, None], jnp.where(valid, ids, 0)]
    return jnp.where(valid, vals, 0.0).astype(table.dtype)


def replay_scatter_plan_ref(table, ids, vals, plan, bi: int):
    """Plan-consistency oracle: replay a scatter TilePlan step-by-step
    under the TPU pipeline's semantics and return the resulting table.

    Models exactly what the hardware observes: a maximal run of
    consecutive steps mapping the same ``(row, tile)`` block loads the
    block ONCE from the pre-pass table, accumulates the valid steps'
    contributions, and flushes once at the run's end.  A plan that maps
    one block into two separate runs (the non-consecutive-revisit bug the
    (row, tile) sort exists to prevent) trips the assertion instead of
    silently losing the first run's update.  ``ids``/``vals`` must be the
    row-sorted arrays the plan was built from (numpy or jax).
    """
    import numpy as np
    tab = np.array(table, np.float32)       # HBM after all flushes
    src = tab.copy()                         # what a run's load observes
    ids = np.asarray(ids)
    vals = np.asarray(vals)
    pb, pr = np.asarray(plan.batch), np.asarray(plan.row)
    pt, pv = np.asarray(plan.tile), np.asarray(plan.valid)
    flushed = set()
    acc = None
    for s in range(pr.size):
        row, tile = int(pr[s]), int(pt[s])
        if s == 0 or (row, tile) != (int(pr[s - 1]), int(pt[s - 1])):
            assert (row, tile) not in flushed, \
                f"block {(row, tile)} revisited in a second run"
            acc = src[row, tile * bi:(tile + 1) * bi].copy()
        if pv[s]:
            b = int(pb[s])
            for i, v in zip(ids[b], vals[b]):
                if tile * bi <= i < (tile + 1) * bi:
                    acc[int(i) - tile * bi] += float(v)
        if s == pr.size - 1 or (row, tile) != (int(pr[s + 1]),
                                               int(pt[s + 1])):
            tab[row, tile * bi:(tile + 1) * bi] = acc
            flushed.add((row, tile))
    return tab


def replay_gather_plan_ref(table, ids, plan, bi: int):
    """Plan-consistency oracle for the gather: replay the plan's valid
    steps and return f32[U, W] (PAD ids → 0).  Asserts each step reads
    ids from the batch row that owns the output block (``order="batch"``
    keeps pbatch[s] == s // T_max)."""
    import numpy as np
    tab = np.asarray(table)
    ids = np.asarray(ids)
    u, w = ids.shape
    t_max = np.asarray(plan.row).size // u
    out = np.zeros((u, w), tab.dtype)
    pb, pr = np.asarray(plan.batch), np.asarray(plan.row)
    pt, pv = np.asarray(plan.tile), np.asarray(plan.valid)
    for s in range(pr.size):
        if not pv[s]:
            continue
        b, row, tile = int(pb[s]), int(pr[s]), int(pt[s])
        assert b == s // t_max, (b, s, t_max)
        for wi, i in enumerate(ids[b]):
            if tile * bi <= i < (tile + 1) * bi:
                out[b, wi] = tab[row, int(i)]
    return out


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """Plain attention oracle. q,k,v: [B,S,H,D] (H == KV heads here)."""
    b, s, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
