"""Streaming: micro-batch state maintenance (Spark Structured Streaming
analog — paper §5), exactly-once recovery, stability-triggered refresh,
and the user-axis sharded deployment (DESIGN.md §7)."""
from repro.streaming.engine import (Event, ShardedStreamingEngine,
                                    StreamingEngine)
from repro.streaming.state_store import (StateStore, StoreConfig,
                                         load_checkpoint_arrays,
                                         state_shardings)

__all__ = ["Event", "StreamingEngine", "ShardedStreamingEngine",
           "StateStore", "StoreConfig", "state_shardings",
           "load_checkpoint_arrays"]
