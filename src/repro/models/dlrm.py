"""DLRM (Naumov et al., arXiv:1906.00091) — MLPerf Criteo-1TB config.

dense [B,13] → bottom MLP → [B,128]
sparse ids [B,26] → row-sharded embedding lookup → [B,26,128]
dot-interaction over the 27 vectors → lower triangle (351) ++ dense
→ top MLP → CTR logit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_mlp, bce_with_logits, init_mlp, mlp_shapes
from repro.models.embedding import TableSpec, embedding_lookup, init_table

# Public Criteo-Terabyte per-feature cardinalities (facebookresearch/dlrm).
CRITEO_1TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    vocab_sizes: tuple = CRITEO_1TB_VOCABS
    embed_dim: int = 128
    bot_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    dtype: Optional[object] = jnp.float32

    @property
    def n_sparse(self):
        return len(self.vocab_sizes)

    @property
    def table(self) -> TableSpec:
        return TableSpec(self.vocab_sizes, self.embed_dim)

    @property
    def n_interactions(self):
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    def n_params(self) -> int:
        n = self.table.padded_rows() * self.embed_dim
        dims_b = [self.n_dense, *self.bot_mlp]
        dims_t = [self.n_interactions + self.embed_dim, *self.top_mlp]
        for d in (dims_b, dims_t):
            n += sum(a * b + b for a, b in zip(d[:-1], d[1:]))
        return n


def init_params(c: DLRMConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "table": init_table(k1, c.table, c.dtype),
        "bot": init_mlp(k2, [c.n_dense, *c.bot_mlp], c.dtype),
        "top": init_mlp(k3, [c.n_interactions + c.embed_dim, *c.top_mlp],
                        c.dtype),
    }


def abstract_params(c: DLRMConfig):
    shapes = {
        "table": (c.table.padded_rows(), c.embed_dim),
        "bot": mlp_shapes([c.n_dense, *c.bot_mlp]),
        "top": mlp_shapes([c.n_interactions + c.embed_dim, *c.top_mlp]),
    }
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, c.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_pspecs(c: DLRMConfig, mesh, rules):
    """Embedding rows sharded over EVERY mesh axis (the classic DLRM
    model-parallel-embeddings split, extended across pods); MLPs are
    small → replicated (data-parallel)."""
    n_dev = int(np.prod(mesh.devices.shape))
    rows = tuple(mesh.axis_names) if c.table.padded_rows() % n_dev == 0 \
        else (rules.tensor if rules.tensor in mesh.axis_names else None)
    mlp_spec = lambda layers: [{k: P(*([None] * len(s)))
                                for k, s in l.items()} for l in layers]
    return {
        "table": P(rows, None),
        "bot": mlp_spec(mlp_shapes([c.n_dense, *c.bot_mlp])),
        "top": mlp_spec(mlp_shapes([c.n_interactions + c.embed_dim,
                                    *c.top_mlp])),
    }


def dot_interaction(vectors):
    """vectors [B, F, D] → lower-triangle pairwise dots [B, F(F-1)/2]."""
    b, f, d = vectors.shape
    z = jnp.einsum("bfd,bgd->bfg", vectors, vectors)
    iu, ju = np.tril_indices(f, k=-1)
    return z[:, iu, ju]


def _constrain_batchwise(x, mesh, rules, batch_size):
    """Pin the batch dim to the (pod,data) axes — GSPMD otherwise
    replicates gather outputs from the row-sharded table."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    from repro.parallel.sharding import batch_axes
    import numpy as np
    ax = batch_axes(mesh, rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = int(np.prod([sizes[a] for a in ax])) if ax else 1
    if n <= 1 or batch_size % n:
        return x
    spec = P(ax, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def forward(params, batch, c: DLRMConfig, mesh=None, rules=None):
    """batch: {"dense": f32[B,13], "sparse": i32[B,26]} → logits [B]."""
    b = batch["dense"].shape[0]
    dense = apply_mlp(params["bot"], batch["dense"].astype(c.dtype))
    sparse = embedding_lookup(params["table"], batch["sparse"], c.table)
    sparse = _constrain_batchwise(sparse, mesh, rules, b)
    feats = jnp.concatenate([dense[:, None, :], sparse], axis=1)  # [B,27,D]
    inter = dot_interaction(feats)
    top_in = jnp.concatenate([dense, inter], axis=-1)
    return apply_mlp(params["top"], top_in)[..., 0]


def loss_fn(params, batch, c: DLRMConfig, mesh=None, rules=None):
    return bce_with_logits(forward(params, batch, c, mesh, rules),
                           batch["labels"])


def make_train_step(c: DLRMConfig, optimizer, mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, c, mesh, rules))(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}
    return train_step


def serve_step(params, batch, c: DLRMConfig, mesh=None, rules=None):
    return jax.nn.sigmoid(forward(params, batch, c, mesh, rules))
