"""Prefill+decode must agree with the teacher-forced full forward for
every attention variant (GQA / sliding-window / MLA-absorbed / MoE)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.transformer import (TransformerConfig, _unembed,
                                      decode_step, forward, init_params,
                                      prefill)

CASES = {
    "dense_gqa": TransformerConfig(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=97, q_block=4, dtype=jnp.float32),
    "sliding_5to1": TransformerConfig(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=97, q_block=4, sliding_window=4,
        global_every=6, dtype=jnp.float32),
    "mla_absorbed": TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=97, q_block=4, mla=True, q_lora_rank=32,
        kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        dtype=jnp.float32),
    "moe_shared_mtp": TransformerConfig(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=97, q_block=4, moe=True, n_experts=8,
        n_shared_experts=1, top_k=2, moe_d_ff=32, first_dense_layers=1,
        mtp=True, capacity_factor=2.0, dtype=jnp.float32),
}


@pytest.mark.parametrize("name", list(CASES))
def test_prefill_decode_vs_full(name):
    c = CASES[name]
    key = jax.random.PRNGKey(1)
    params = init_params(c, key)
    toks = jax.random.randint(key, (2, 12), 0, c.vocab_size)
    x, _ = forward(params, toks, c)
    full_logits = (x @ _unembed(params, c)).astype(jnp.float32)
    lg, caches = prefill(params, toks[:, :8], c, max_len=16)
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, 7, :])))]
    for t in range(8, 12):
        lg, caches = decode_step(params, caches, toks[:, t:t + 1], t, c)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t, :]))))
    assert max(errs) < 2e-3, errs


def test_flash_equals_naive_attention():
    """The portable flash lowering == plain masked softmax attention."""
    import numpy as np
    from repro.models.transformer import flash_attention
    from repro.kernels.ref import flash_attention_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    out = flash_attention(q, k, v, 0, jnp.asarray(2 ** 30), 0.25, 16)
    exp = flash_attention_ref(q, k, v, causal=True, window=0, scale=0.25)
    assert float(jnp.max(jnp.abs(out - exp))) < 1e-4


def test_loss_decreases_with_training(rng=None):
    """Short LM training run: the loss must actually go down."""
    import numpy as np
    from repro.models.transformer import make_train_step
    from repro.optim import adamw
    c = CASES["dense_gqa"]
    params = init_params(c, jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3, warmup_steps=2, total_steps=40)
    step = jax.jit(make_train_step(c, opt))
    state = opt.init(params)
    g = np.random.default_rng(0)
    toks = g.integers(0, c.vocab_size, (4, 17))
    toks[:, 1::2] = toks[:, 0:-1:2]      # learnable copy structure
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    losses = []
    for _ in range(30):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]
