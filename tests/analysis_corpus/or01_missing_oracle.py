"""Corpus case: dispatcher with no reference oracle (expected OR01).

A public dispatcher that never consults `ref.*` has no ground truth —
nothing can catch its kernel silently drifting.
"""
from repro.kernels.knn_topk import knn_topk as _knn_pallas


def thing(queries, corpus, k, impl=None):
    return _knn_pallas(queries, corpus, k)
