"""Static analysis for kernel contracts and engine invariants.

An AST-driven lint pass (DESIGN.md §10) that checks the repo's declared
contracts against its code, in three rule families:

* ``KC*`` — kernel contracts: every ``pl.pallas_call`` site carries a
  registered :class:`~repro.analysis.contracts.KernelContract` whose
  grid rank, BlockSpec index-map arities, tail masks, dtype rules and
  analytic VMEM model (``repro.analysis.vmem``) match the code.
* ``OR*`` — oracle pairing: every dispatcher in ``kernels/ops.py``
  reaches a ``kernels/ref.py`` oracle, some test imports both, and the
  intentionally duplicated function pairs stay AST-identical.
* ``EN*`` — engine invariants: ``state_store`` write paths reach the
  atomic commit primitive, fault sites form a closed registry with
  ``streaming/faults.py``, and BENCH summary keys follow the
  gated/parity naming convention (``repro.analysis.bench_schema``).

Everything here is stdlib-only (``ast`` + ``json``): importing this
package never pulls in jax, so kernel modules can register contracts at
import time without cost.  The repo-level driver is
``repro.analysis.linter`` (CLI: ``tools/lint_kernels.py``).
"""
from repro.analysis import bench_schema, contracts, report, vmem
from repro.analysis.contracts import KernelContract, register
from repro.analysis.report import Finding, Report
from repro.analysis.vmem import VMEM_BUDGET_BYTES, stage_a_vmem_bytes

__all__ = [
    "bench_schema", "contracts", "report", "vmem",
    "KernelContract", "register", "Finding", "Report",
    "VMEM_BUDGET_BYTES", "stage_a_vmem_bytes",
]
