"""HLO-text cost model with correct while-loop accounting.

``compiled.cost_analysis()`` counts each while body ONCE — for scan-based
models (layers, flash blocks, CE chunks) this under-reports FLOPs by the
trip count (measured 26× on granite train_4k).  This module re-derives
per-device cost by walking the optimized HLO:

  * builds the computation call graph (fusion ``calls=``, while
    ``body=/condition=``, ``to_apply=``);
  * multiplies while bodies by ``known_trip_count`` from backend_config;
  * FLOPs: 2 × prod(result dims) × prod(lhs contracting dims) per dot;
  * HBM bytes: operand + result bytes of every top-level (unfused) op —
    fusion internals excluded, views (bitcast/gte/tuple) excluded;
  * collective bytes: result bytes per collective op kind.

Everything is per-device (the HLO is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f4e2m1fn": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERANDS_RE = re.compile(r"\(((?:%[\w\.\-]+(?:,\s*)?)+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# ops whose results are views / free
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "custom-call", "partition-id",
             "replica-id"}


def _parse_shapes(text: str):
    """All array shapes in a type string → list of (dtype, [dims])."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] or [1]
        out.append((dt, d))
    return out


def _nbytes(text: str) -> int:
    return sum(_DTYPE_BYTES[dt] * _prod(d) for dt, d in _parse_shapes(text))


def _prod(dims):
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = defaultdict(float)

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k,
                     defaultdict(float, {a: b * k for a, b in
                                         self.coll.items()}))

    def add(self, other: "Costs"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] += v

    @property
    def coll_bytes(self):
        return sum(self.coll.values())


_CONVERT_RE = re.compile(
    r"=\s*f32\[([0-9,]+)\][^=]*convert\(%([\w\.\-]+)\)")


def estimate_f32_shadow_bytes(hlo_text: str, min_bytes: int = 1 << 26):
    """Estimate CPU-only float-normalization overhead.

    XLA's CPU backend has no native bf16 FMA: a float-normalization pass
    rewrites bf16 dots to f32 and materializes f32 copies of bf16 weight/
    activation stacks (hoisted out of while loops).  A TPU build never
    creates these.  We detect large ``f32 = convert(bf16)`` results and
    report their total as the upper-bound correction to peak memory
    (dryrun reports BOTH raw and adjusted peaks).
    """
    sym = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sym[m.group(1)] = m.group(2)
    total = 0
    seen_ops = set()
    for line in hlo_text.splitlines():
        m = _CONVERT_RE.search(line)
        if not m:
            continue
        dm = _DEF_RE.match(line)
        name = dm.group(1) if dm else line
        if name in seen_ops:
            continue
        seen_ops.add(name)
        dims, operand = m.groups()
        src = sym.get(operand, "")
        if not src.startswith("bf16["):
            continue
        size = 4 * _prod([int(x) for x in dims.split(",") if x])
        if size >= min_bytes:
            total += size
    return total


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: Dict[str, Costs] = {}

    @staticmethod
    def _split(text: str):
        comps, cur, name = {}, None, None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    name, cur = m.group(1), []
            else:
                if line.strip() == "}":
                    comps[name] = cur
                    cur, name = None, None
                else:
                    cur.append(line)
        return comps

    @staticmethod
    def _find_entry(text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    return m.group(1)
        raise ValueError("no ENTRY computation found")

    def cost(self) -> Costs:
        return self._cost_of(self.entry)

    # -- internals ----------------------------------------------------------

    def _cost_of(self, comp: str) -> Costs:
        if comp in self._memo:
            return self._memo[comp]
        total = Costs()
        lines = self.computations.get(comp, [])
        # symbol table: var -> full type text (for operand byte/shape lookup)
        sym = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                sym[m.group(1)] = m.group(2)
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            opm = re.match(r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                           r"([a-z0-9\-]+)", rhs)
            if not opm:
                continue
            result_type, op = opm.group(1), opm.group(2)
            if op == "while":
                body = _BODY_RE.search(rhs)
                cond = _COND_RE.search(rhs)
                trip = 1
                tm = _TRIP_RE.search(rhs)
                if tm:
                    trip = int(tm.group(1))
                inner = Costs()
                if body:
                    inner.add(self._cost_of(body.group(1)))
                if cond:
                    inner.add(self._cost_of(cond.group(1)))
                total.add(inner.scaled(max(trip, 1)))
                continue
            if op in ("fusion", "call", "conditional", "async-start"):
                cm = _CALLS_RE.search(rhs)
                if cm:
                    total.add(self._cost_of(cm.group(1)))
                if op == "fusion":
                    total.bytes += self._io_bytes(rhs, result_type, sym)
                continue
            if op.startswith(tuple(_COLLECTIVES)):
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                if op.endswith("-done"):
                    continue  # counted at -start
                total.coll[kind] += _nbytes(result_type)
                total.bytes += self._io_bytes(rhs, result_type, sym)
                continue
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            if op == "convert":
                # bf16↔f32 converts are CPU float-normalization artifacts;
                # TPU reads bf16 natively — exclude from memory traffic.
                continue
            if op in ("dot", "convolution"):
                total.flops += self._dot_flops(rhs, result_type, sym)
            # reductions called via to_apply: flops ≈ result+operand elems
            total.bytes += self._io_bytes(rhs, result_type, sym)
        self._memo[comp] = total
        return total

    def _dot_flops(self, rhs: str, result_type: str, sym) -> float:
        shapes = _parse_shapes(result_type)
        if not shapes:
            return 0.0
        res_elems = sum(_prod(d) for _, d in shapes)
        ops = _OPERANDS_RE.search(rhs)
        contract = 1
        cm = _LHS_CONTRACT_RE.search(rhs)
        if ops and cm:
            lhs_name = ops.group(1).split(",")[0].strip().lstrip("%")
            lhs_type = sym.get(lhs_name, "")
            lhs_shapes = _parse_shapes(lhs_type)
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        contract *= dims[int(idx)]
        return 2.0 * res_elems * contract

    def _io_bytes(self, rhs: str, result_type: str, sym) -> float:
        b = _nbytes(result_type)
        ops = _OPERANDS_RE.search(rhs)
        if ops:
            for name in ops.group(1).split(","):
                t = sym.get(name.strip().lstrip("%"))
                if t:
                    b += _nbytes(t.split(" ")[0])
        return float(b)
