"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]
40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155 (padded to 49408)."""
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.lm_shapes import standard_lm_cells
from repro.models.transformer import TransformerConfig


def make_config():
    return TransformerConfig(
        name="granite-3-2b", n_layers=40, d_model=2048, n_heads=32,
        n_kv_heads=8, d_head=64, d_ff=8192,
        vocab_size=49408,   # 49155 padded to a multiple of 256 (TP)
        tie_embeddings=True, dtype=jnp.bfloat16)


def smoke_config():
    return TransformerConfig(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=128, vocab_size=128,
        tie_embeddings=True, q_block=8, dtype=jnp.float32)


ARCH = ArchDef(
    name="granite-3-2b", family="lm",
    cells=standard_lm_cells(make_config),
    make_smoke=smoke_config,
    notes="dense GQA; kv=8 < model axis → attention params FSDP-only "
          "(see transformer.param_pspecs); vocab padded 49155→49408.")
