import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (architecture × input shape)
on the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The XLA_FLAGS line above MUST run before any other import: jax locks the
device count on first init.  512 host devices back both the (16,16)
single-pod and the (2,16,16) multi-pod meshes (the single-pod run uses
the first 256).
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import compat                                # noqa: E402

from repro.configs import REGISTRY, get_arch            # noqa: E402
from repro.launch.mesh import (HBM_PER_CHIP, make_production_mesh,
                               make_rules)              # noqa: E402
from repro.launch import roofline                       # noqa: E402


def run_cell(arch_name: str, shape: str, multi_pod: bool,
             extract_hlo: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh)
    chips = mesh.devices.size
    arch = get_arch(arch_name)
    builder = arch.cells[shape]
    t0 = time.time()
    prog = builder(mesh, rules)
    with compat.set_mesh(mesh):
        jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                         donate_argnums=prog.donate_argnums)
        lowered = jitted.lower(*prog.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    per_dev = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        "generated_code_bytes": int(
            getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    # peak live bytes per device (args + outputs + temps − donated aliases)
    peak = (per_dev["argument_bytes"] + per_dev["output_bytes"]
            + per_dev["temp_bytes"] - per_dev["alias_bytes"])
    hlo = compiled.as_text() if extract_hlo else ""
    terms = roofline.analyze(compiled, hlo, chips)
    # CPU float-normalization shadows (f32 copies of bf16 stacks) do not
    # exist on TPU — report an adjusted peak too (see hlo_cost docstring).
    from repro.launch.hlo_cost import estimate_f32_shadow_bytes
    shadow = estimate_f32_shadow_bytes(hlo) if hlo else 0
    peak_adj = max(peak - shadow, per_dev["argument_bytes"])
    mf = prog.model_flops_per_step          # GLOBAL model flops per step
    mf_dev = mf / chips if chips else mf    # per-device share
    result = {
        "arch": arch_name, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "description": prog.description,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_per_device": per_dev,
        "peak_bytes_per_device": int(peak),
        "cpu_f32_shadow_bytes": int(shadow),
        "peak_adjusted_bytes": int(peak_adj),
        "fits_16GiB": bool(peak <= HBM_PER_CHIP),
        "fits_16GiB_adjusted": bool(peak_adj <= HBM_PER_CHIP),
        "roofline": terms.as_dict(),
        "model_flops": mf,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": (mf_dev / terms.flops) if terms.flops else None,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for name, arch in REGISTRY.items():
            for shape in arch.cells:
                cells.append((name, shape))
    else:
        arch = get_arch(args.arch)
        shapes = [args.shape] if args.shape else list(arch.cells)
        cells = [(args.arch, s) for s in shapes]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for name, shape in cells:
        for mp in meshes:
            tag = f"{name} × {shape} × {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(name, shape, mp)
                rt = r["roofline"]
                print(f"[OK] {tag}: peak={r['peak_bytes_per_device']/2**30:.2f}GiB "
                      f"adj={r['peak_adjusted_bytes']/2**30:.2f}GiB "
                      f"fits={r['fits_16GiB_adjusted']} "
                      f"t_comp={rt['t_compute_s']:.2e}s "
                      f"t_mem={rt['t_memory_s']:.2e}s "
                      f"t_coll={rt['t_collective_s']:.2e}s "
                      f"bound={rt['bottleneck']} "
                      f"(compile {r['compile_s']}s)", flush=True)
            except Exception as e:  # noqa: BLE001
                r = {"arch": name, "shape": shape,
                     "mesh": "2x16x16" if mp else "16x16",
                     "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            results.append(r)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {args.out}")
    n_ok = sum(1 for r in results if "error" not in r)
    print(f"{n_ok}/{len(results)} cells passed")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
