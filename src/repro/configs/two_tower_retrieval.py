"""two-tower-retrieval [RecSys'19 YouTube] — embed_dim=256,
towers 1024-512-256, dot interaction, in-batch sampled softmax."""
from repro.configs import recsys_shapes as rs
from repro.configs.base import ArchDef, recsys_cell
from repro.models import two_tower


def make_config():
    return two_tower.TwoTowerConfig()


def smoke_config():
    return two_tower.TwoTowerConfig(n_users=1000, n_items=500,
                                    n_item_cats=20, hist_len=8,
                                    embed_dim=16, tower_mlp=(32, 16))


def _flops_train(c):
    tower = sum(a * b for a, b in zip([2 * c.embed_dim, *c.tower_mlp[:-1]],
                                      c.tower_mlp))
    # two towers fwd+bwd + BxB in-batch logits fwd+bwd
    return (6.0 * 2 * tower * rs.TRAIN_BATCH
            + 6.0 * rs.TRAIN_BATCH ** 2 * c.tower_mlp[-1])


ARCH = ArchDef(
    name="two-tower-retrieval", family="recsys",
    cells={
        "train_batch": recsys_cell(
            two_tower, make_config, rs.two_tower_batch(rs.TRAIN_BATCH),
            "in-batch softmax B=65536", train=True, pass_mesh=True, flops_fn=_flops_train),
        "serve_p99": recsys_cell(
            two_tower, make_config,
            rs.two_tower_batch(rs.SERVE_P99, train=False),
            "pair scoring B=512", pass_mesh=True),
        "serve_bulk": recsys_cell(
            two_tower, make_config,
            rs.two_tower_batch(rs.SERVE_BULK, train=False),
            "pair scoring B=262144", pass_mesh=True),
        "retrieval_cand": recsys_cell(
            two_tower, make_config, rs.two_tower_retrieval_batch(),
            "1 query vs 1M candidates", serve_fn="retrieval_step", pass_mesh=True),
    },
    make_smoke=smoke_config,
    notes="CLOSEST match to the paper: user tower = embedding-bag user "
          "vector (decayed-average maintenance applies); retrieval_cand "
          "uses the kNN/top-k kernel shape (DESIGN.md §4).")
