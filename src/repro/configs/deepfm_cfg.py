"""deepfm [arXiv:1703.04247] — 39 fields, embed_dim=10, FM + 400-400-400."""
from repro.configs import recsys_shapes as rs
from repro.configs.base import ArchDef, recsys_cell
from repro.models import deepfm


def make_config():
    return deepfm.DeepFMConfig()


def smoke_config():
    return deepfm.DeepFMConfig(vocab_sizes=tuple([32] * 39), embed_dim=10,
                               mlp=(32, 32))


def _flops_train(c):
    mlp = c.n_params() - c.table.padded_rows() * (c.embed_dim + 1)
    return 6.0 * mlp * rs.TRAIN_BATCH


ARCH = ArchDef(
    name="deepfm", family="recsys",
    cells={
        "train_batch": recsys_cell(deepfm, make_config,
                                   rs.deepfm_batch(rs.TRAIN_BATCH),
                                   "train B=65536", train=True, pass_mesh=True,
                                   flops_fn=_flops_train),
        "serve_p99": recsys_cell(deepfm, make_config,
                                 rs.deepfm_batch(rs.SERVE_P99, train=False),
                                 "serve B=512", pass_mesh=True),
        "serve_bulk": recsys_cell(deepfm, make_config,
                                  rs.deepfm_batch(rs.SERVE_BULK, train=False),
                                  "serve B=262144", pass_mesh=True),
        "retrieval_cand": recsys_cell(
            deepfm, make_config,
            rs.deepfm_batch(rs.N_CANDIDATES, train=False),
            "score 1M candidates", pass_mesh=True),
    },
    make_smoke=smoke_config,
    notes="FM sum-square identity; embedding bag maintenance per paper.")
