"""Micro-batch update latency/throughput vs vocabulary size.

Measures the kind-partitioned sparse-delta pipeline (core.updates
apply_add_batch / apply_del_*_batch via the apply_update_batch shim)
against the seed's dense mixed path (apply_update_batch_dense: gather
[batch, n_items] rows, compute every update rule, select, scatter dense
deltas) for add-only, delete-only and mixed micro-batches at
n_items ∈ {1k, 10k, 100k}.

The headline claim (ISSUE 1 acceptance): add-only batches touch O(basket)
state per event, so their latency stays flat as n_items grows, while the
dense path scales linearly.  Results land in BENCH_updates.json so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_update_batch.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (StreamState, TifuParams, apply_update_batch,
                        apply_update_batch_dense)
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, KIND_NOOP, PAD_ID, UpdateBatch)

M_USERS = 1024
MAX_BASKETS = 24
MAX_BSIZE = 16
BATCH = 256
SEED_BASKETS = 6


def make_params(n_items: int) -> TifuParams:
    return TifuParams(n_items=n_items, group_size=7, r_b=0.9, r_g=0.7)


def seed_state(params: TifuParams, rng) -> StreamState:
    """Give every user SEED_BASKETS baskets via the batched add path."""
    state = StreamState.zeros(M_USERS, params.n_items, MAX_BASKETS,
                              MAX_BSIZE, MAX_BASKETS)
    for _ in range(SEED_BASKETS):
        for lo in range(0, M_USERS, BATCH):
            users = np.arange(lo, lo + BATCH, dtype=np.int32)
            state = apply_update_batch(
                state, make_batch(rng, users, "add", state), params)
    return state


def make_batch(rng, users, kind: str, state: StreamState) -> UpdateBatch:
    """One fixed-shape mixed batch over the given (distinct) users."""
    u = len(users)
    kinds = np.zeros(u, np.int32)
    items = np.full((u, MAX_BSIZE), PAD_ID, np.int32)
    pos = np.zeros(u, np.int32)
    item = np.full(u, PAD_ID, np.int32)
    nb = np.asarray(state.n_baskets)
    hist = None
    for r, uu in enumerate(users):
        # deterministic composition: stable sub-batch sizes => the pow2
        # buckets compile once in warmup and the loop times steady state
        # (add: all adds; del: 50/50 basket/item; mixed: 2/1/1).
        roll = {"add": 0.0, "del": 0.6 + 0.3 * (r % 2),
                "mixed": (0.0, 0.0, 0.6, 0.9)[r % 4]}[kind]
        if roll < 0.5 or nb[uu] == 0:
            kinds[r] = KIND_ADD_BASKET
            b = rng.choice(state.n_items,
                           size=int(rng.integers(2, MAX_BSIZE // 2)),
                           replace=False)
            items[r, :len(b)] = b
        elif roll < 0.75:
            kinds[r] = KIND_DEL_BASKET
            pos[r] = int(rng.integers(0, nb[uu]))
        else:
            kinds[r] = KIND_DEL_ITEM
            pos[r] = int(rng.integers(0, nb[uu]))
            if hist is None:
                hist = np.asarray(state.history)
            row = hist[uu, pos[r]]
            row = row[row >= 0]
            item[r] = int(row[0]) if row.size else 0
            if not row.size:
                kinds[r] = KIND_NOOP
    return UpdateBatch(kind=jnp.asarray(kinds), user=jnp.asarray(users),
                       basket_items=jnp.asarray(items),
                       basket_pos=jnp.asarray(pos), item=jnp.asarray(item))


def bench(apply_fn, params, rng, kind: str, iters: int) -> dict:
    state = seed_state(params, rng)
    user_sets = [np.arange(lo, lo + BATCH, dtype=np.int32)
                 for lo in range(0, M_USERS, BATCH)]
    # warmup/compile (several batches: mixed batches flip between pow2
    # sub-batch buckets, each bucket combination compiles once)
    for _ in range(3):
        state = apply_fn(state, make_batch(rng, user_sets[0], kind, state),
                         params)
    jax.block_until_ready(state.user_vecs)
    times = []
    for i in range(iters):
        batch = make_batch(rng, user_sets[(i + 1) % len(user_sets)], kind,
                           state)
        t0 = time.perf_counter()
        state = apply_fn(state, batch, params)
        jax.block_until_ready(state.user_vecs)
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    return {"kind": kind, "n_items": params.n_items, "batch": BATCH,
            "iters": iters, "mean_ms": float(times.mean() * 1e3),
            "p50_ms": float(np.median(times) * 1e3),
            "min_ms": float(times.min() * 1e3),
            "events_per_s": float(BATCH / times.mean())}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer iterations; skip the heaviest dense rows "
                         "(100k del/mixed)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_updates.json"))
    args = ap.parse_args()
    iters = 4 if args.quick else 8
    dense_iters = 2 if args.quick else 4

    results = []
    for n_items in (1_000, 10_000, 100_000):
        params = make_params(n_items)
        for kind in ("add", "del", "mixed"):
            rng = np.random.default_rng(0)
            r = bench(apply_update_batch, params, rng, kind, iters)
            r["path"] = "partitioned"
            results.append(r)
            print(f"partitioned {kind:5s} n_items={n_items:>6d} "
                  f"mean={r['mean_ms']:8.2f} ms  "
                  f"({r['events_per_s']:,.0f} ev/s)")
            if args.quick and n_items == 100_000 and kind != "add":
                continue   # the dense 100k del/mixed rows are the most
            rng = np.random.default_rng(0)     # expensive configurations
            r = bench(apply_update_batch_dense, params, rng, kind,
                      dense_iters)
            r["path"] = "dense_seed"
            results.append(r)
            print(f"dense_seed  {kind:5s} n_items={n_items:>6d} "
                  f"mean={r['mean_ms']:8.2f} ms  "
                  f"({r['events_per_s']:,.0f} ev/s)")

    def pick(path, kind, n):
        return next(r for r in results if r["path"] == path
                    and r["kind"] == kind and r["n_items"] == n)

    add_growth = (pick("partitioned", "add", 100_000)["mean_ms"]
                  / pick("partitioned", "add", 1_000)["mean_ms"])
    speedup_100k = (pick("dense_seed", "add", 100_000)["mean_ms"]
                    / pick("partitioned", "add", 100_000)["mean_ms"])
    summary = {"add_latency_growth_1k_to_100k": add_growth,
               "add_speedup_vs_dense_at_100k": speedup_100k}
    print(f"\nadd growth 1k->100k: {add_growth:.2f}x "
          f"(acceptance: < 1.5x)\n"
          f"add speedup vs dense @100k: {speedup_100k:.2f}x "
          f"(acceptance: >= 3x)")

    payload = {
        "benchmark": "bench_update_batch",
        "backend": jax.default_backend(),
        "config": {"m_users": M_USERS, "batch": BATCH,
                   "max_baskets": MAX_BASKETS, "max_basket_size": MAX_BSIZE,
                   "seed_baskets": SEED_BASKETS},
        "summary": summary,
        "results": results,
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
