"""Checkpointing (incl. elastic re-mesh restore) and optimizers."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, restore_pytree,
                              save_pytree)
from repro.optim import adafactor, adamw, clip_by_global_norm, sgd


def test_save_restore_roundtrip(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32),
            "b": [jnp.arange(3), {"c": jnp.ones((2,), jnp.bfloat16)}]}
    save_pytree(tree, str(tmp_path), 7)
    assert latest_step(str(tmp_path)) == 7
    out = restore_pytree(tree, str(tmp_path))
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_async_checkpointer(tmp_path, rng):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    for s in (1, 2, 3):
        ck.save({"w": tree["w"] * s}, s)
    ck.close()
    assert latest_step(str(tmp_path)) == 3
    out = restore_pytree(tree, str(tmp_path))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(tree["w"]) * 3)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "src")
from repro.checkpoint import save_pytree, restore_pytree
d = sys.argv[1]
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
# save from a 4-device mesh
mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
xs = jax.device_put(x, NamedSharding(mesh4, P("data", None)))
save_pytree({"x": xs}, d, 1)
# elastic restore onto an 8-device mesh (scale-up restart)
mesh8 = jax.make_mesh((8,), ("data",))
out = restore_pytree({"x": x}, d,
                     shardings={"x": NamedSharding(mesh8, P("data", None))})
assert out["x"].sharding.num_devices == 8
np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
print("ELASTIC_OK")
"""


def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Save on a 4-device mesh, restore sharded over 8 devices."""
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT,
                        str(tmp_path)], capture_output=True, text=True,
                       timeout=300, cwd="/root/repo")
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_stream_state_restore_across_representations(tmp_path, rng):
    """Pre-scale, scaled and cached-corpus representations of the same
    logical state all restore and serve identically (DESIGN.md §3.3/§3.6).

    * scaled: a live store (uv/lgv scales != 1) checkpointed as-is;
    * pre-scale: the same state with the scale leaves stripped from the
      npz (a checkpoint written before the scaled representation, which
      restore() migrates to scales of 1) after folding them in;
    * cached-corpus: the serving cache is warm at checkpoint time — it is
      never persisted, and a cold restore must rebuild it identically.
    """
    from repro.core import RefEngine, TifuParams, knn, renormalize_users
    from repro.streaming import Event, StateStore, StoreConfig, \
        StreamingEngine
    from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                                  KIND_DEL_ITEM)

    P = TifuParams(n_items=37, group_size=3, r_b=0.9, r_g=0.7,
                   k_neighbors=4, alpha=0.7)
    M, N, B = 12, 24, 5

    def make_store():
        return StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                      max_baskets=N, max_basket_size=B,
                                      max_groups=N))

    store = make_store()
    eng = StreamingEngine(store, P, batch_size=8)
    ref = RefEngine(P, dtype=np.float32)
    events = []
    for _ in range(150):
        u = int(rng.integers(0, M))
        st = ref.state(u)
        if st.n_baskets == 0 or rng.random() < 0.7:
            items = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                               replace=False).astype(np.int32)
            ref.add_basket(u, items)
            events.append(Event(KIND_ADD_BASKET, u, items=items))
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, st.n_baskets))
            ref.delete_basket(u, pos)
            events.append(Event(KIND_DEL_BASKET, u, pos=pos))
        else:
            pos = int(rng.integers(0, st.n_baskets))
            item = int(rng.choice(st.history[pos]))
            ref.delete_item(u, pos, item)
            events.append(Event(KIND_DEL_ITEM, u, pos=pos, item=item))
    eng.submit(events)
    eng.run_until_drained()
    assert float(store.state.uv_scale.min()) < 1.0   # genuinely scaled

    users = jnp.arange(M, dtype=jnp.int32)

    def serve(st):
        return np.asarray(knn.recommend_for_users(
            st.corpus(), users, k=P.k_neighbors, alpha=P.alpha, topn=5))

    baseline_recs = serve(store)          # warm cached-corpus serving

    # -- scaled representation checkpoint ----------------------------------
    d_scaled = os.path.join(str(tmp_path), "scaled")
    eng.checkpoint(d_scaled, 1)

    # -- pre-scale checkpoint: fold scales, strip the scale leaves ---------
    folded = make_store()
    folded.state = renormalize_users(
        jax.tree_util.tree_map(lambda x: x.copy(), store.state),
        jnp.arange(M, dtype=jnp.int32))
    d_pre = os.path.join(str(tmp_path), "prescale")
    folded.checkpoint(d_pre, 1)
    npz = os.path.join(d_pre, "state_0000000001.npz")
    leaves = dict(np.load(npz))
    for key in ("uv_scale", "lgv_scale"):
        leaves.pop(key)
    with open(npz, "wb") as f:
        np.savez_compressed(f, **leaves)
    # a genuinely pre-scale-era checkpoint also predates the commit
    # CRCs (DESIGN.md §9.1) — strip them so the simulation restores via
    # the legacy-accept path instead of (correctly) failing integrity
    latest = os.path.join(d_pre, "LATEST")
    with open(latest) as f:
        meta = json.load(f)
    for key in ("meta_crc32", "npz_crc32", "npz_bytes"):
        meta.pop(key, None)
    with open(latest, "w") as f:
        json.dump(meta, f)

    for directory in (d_scaled, d_pre):
        restored = make_store()
        restored.restore(directory)
        np.testing.assert_allclose(
            np.asarray(restored.state.materialized_user_vecs()),
            np.asarray(store.state.materialized_user_vecs()),
            rtol=1e-5, atol=1e-6, err_msg=directory)
        np.testing.assert_array_equal(serve(restored), baseline_recs)

    # -- cached corpus is not persisted: restoring over a warm cache -------
    warm = make_store()
    warm.corpus()                         # cold build on empty state
    warm.restore(d_scaled)                # must invalidate it
    np.testing.assert_array_equal(serve(warm), baseline_recs)
    assert warm.corpus_full_builds == 2


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(lr=0.1, warmup_steps=1, total_steps=100,
                  weight_decay=0.0),
    lambda: adafactor(lr=0.02, clip_norm=1e9),
    lambda: sgd(lr=0.05, clip_norm=1e9),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]),
              "m": jnp.full((200, 200), 0.3)}   # factored path for adafactor

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               np.asarray([0.6, 0.8]), rtol=1e-5)


def test_opt_state_pspecs_match_structure():
    from repro.optim import adamw_state_pspecs, adafactor_state_pspecs
    from jax.sharding import PartitionSpec as P
    params = {"w": jax.ShapeDtypeStruct((256, 512), jnp.float32),
              "b": jax.ShapeDtypeStruct((512,), jnp.float32)}
    pspecs = {"w": P("model", "data"), "b": P(None)}
    opt = adamw(total_steps=1)
    st = jax.eval_shape(opt.init, params)
    sp = adamw_state_pspecs(pspecs)
    jax.tree_util.tree_structure(st.inner)  # same nesting must flatten
    assert sp.inner["m"]["w"] == P("model", "data")
    sp2 = adafactor_state_pspecs(params, pspecs)
    assert sp2.inner["w"]["vr"] == P("model")
    assert sp2.inner["w"]["vc"] == P("data")
