"""Bench trend gate: diff a fresh bench JSON against the committed one.

Compares the summary *speedup* metrics of every run in ``--new`` against
the baseline run with the same (backend, mode) in ``--baseline`` and
fails (exit 1) when any enforced metric regressed by more than
``--tolerance`` (default 30%, the ISSUE 3 acceptance bound).  Speedups
are arm-vs-arm ratios measured in one process, so they are far less
load-sensitive than absolute latencies — that is what makes them
gateable on shared CI runners.

Rules:
  * only ``*speedup*`` summary keys are enforced as ratios
    (absolute-latency and growth metrics are printed for context only);
  * ``*compiled*`` summary keys (the serving shape-bucketing counts,
    ISSUE 5) are enforced as UPPER BOUNDS: the new count may never
    exceed the committed one — counts are load-insensitive, so there is
    no tolerance and no floor;
  * ``*slo*`` summary keys (the compliance arm's deletion-latency
    budgets, ISSUE 9) are normalized measured/objective fractions and
    must stay ``<= 1.0`` — the SLO itself is the contract, so the gate
    ignores the committed value and enforces the constant bound;
  * metrics whose BASELINE value is below ``--floor`` (default 1.5x) are
    reported but not enforced — smoke-scale ratios near 1x are noise;
  * ``interpret``-backend runs are never enforced (interpret-mode Pallas
    timings are equivalence/plumbing numbers, not perf);
  * runs present in only one file are skipped with a note (a TPU entry
    in the committed file does not fail a CPU-only CI run);
  * every summary key (in BOTH files) must classify under the
    gated/parity naming convention (repro.analysis.bench_schema, lint
    rule EN03) — an unknown key is a hard failure, because a silently
    unclassifiable key is how a renamed speedup metric escapes this
    gate.

    PYTHONPATH=src python benchmarks/bench_trend.py \
        --new bench-smoke.json --baseline BENCH_updates.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    from repro.analysis.bench_schema import classify_summary_key
except ImportError:  # run as a plain script without PYTHONPATH=src
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.analysis.bench_schema import classify_summary_key


def _runs(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if "runs" in payload:
        runs = payload["runs"]
    else:                                   # legacy single-run layout
        runs = [payload]
    return {(r.get("backend", "cpu"), r.get("mode", "full"),
             r.get("arm")): r for r in runs}


def _key_name(key) -> str:
    backend, mode, arm = key
    return f"{backend}/{mode}" + (f"/{arm}" if arm else "")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", required=True, help="freshly produced JSON")
    ap.add_argument("--baseline", required=True, help="committed JSON")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="max allowed fractional regression (0.30 = 30%%)")
    ap.add_argument("--floor", type=float, default=1.5,
                    help="baseline speedups below this are not enforced")
    args = ap.parse_args(argv)

    new_runs = _runs(args.new)
    base_runs = _runs(args.baseline)

    unknown = []
    for src_name, runs in (("--new", new_runs),
                           ("--baseline", base_runs)):
        for key, run in runs.items():
            for metric in run.get("summary", {}):
                if classify_summary_key(metric) == "unknown":
                    unknown.append((src_name, _key_name(key), metric))
    if unknown:
        print("summary key(s) outside the gated/parity naming "
              "convention (rule EN03, repro.analysis.bench_schema):")
        for src_name, run_name, metric in unknown:
            print(f"  {src_name} [{run_name}] {metric}")
        return 1

    regressions = []
    compared = 0
    for key, new in sorted(new_runs.items(), key=lambda kv: _key_name(
            kv[0])):
        base = base_runs.get(key)
        if base is None:
            print(f"[skip] no baseline run for {_key_name(key)}")
            continue
        ns, bs = new.get("summary", {}), base.get("summary", {})
        for metric in sorted(set(ns) & set(bs)):
            nv, bv = ns[metric], bs[metric]
            if not isinstance(nv, (int, float)) \
                    or not isinstance(bv, (int, float)):
                continue
            cls = classify_summary_key(metric)
            # interpret-mode runs are equivalence/plumbing numbers (the
            # bench refuses them outside --smoke); never gate on them
            enforced = cls == "gated-ratio" and bv >= args.floor \
                and key[0] != "interpret"
            status = "ok"
            if cls == "gated-bound" and key[0] != "interpret":
                # shape-bucketing counts: hard upper bound, no tolerance
                if nv > bv:
                    status = f"INCREASED {bv:.0f} -> {nv:.0f}"
                    regressions.append((key, metric, bv, nv,
                                        f"+{nv - bv:.0f} compiled "
                                        f"shape(s)"))
                compared += 1
            elif cls == "gated-slo" and key[0] != "interpret":
                # normalized SLO fractions: the objective is the bound,
                # not the committed value — enforce the constant 1.0
                if nv > 1.0:
                    status = f"SLO BREACH {nv:.2f} > 1.00"
                    regressions.append((key, metric, bv, nv,
                                        f"{nv:.2f}x of its objective"))
                compared += 1
            elif enforced and bv > 0:
                drop = 1.0 - nv / bv
                if drop > args.tolerance:
                    status = f"REGRESSED {drop:.0%}"
                    regressions.append((key, metric, bv, nv,
                                        f"-{drop:.0%}"))
                compared += 1
            elif cls == "gated-ratio":
                status = "below floor, not enforced"
            else:
                status = "informational"
            print(f"[{_key_name(key)}] {metric}: {bv:.2f} -> {nv:.2f} "
                  f"({status})")
    if regressions:
        print(f"\n{len(regressions)} summary metric(s) regressed "
              f"(speedups by more than {args.tolerance:.0%}, compiled-"
              f"program counts that increased, or SLO fractions above "
              f"1.0):")
        for key, metric, bv, nv, what in regressions:
            print(f"  [{_key_name(key)}] {metric}: {bv:.2f} -> {nv:.2f} "
                  f"({what})")
        return 1
    print(f"\nbench-trend OK ({compared} enforced comparisons)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
