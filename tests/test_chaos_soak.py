"""Chaos soak (DESIGN.md §9, the PR's headline gate): the 520-event
mixed stream driven under ~150 seeded fault schedules — a process crash
at every commit site (killing a chosen shard's commit), torn and
bit-flipped checkpoint files of every class, transient I/O errors,
crashes of the async background checkpoint writer mid-flight (§12), and
seeded at-least-once redelivery — at 1, 2, and 4 shards.

Every schedule must end with the recovered engine BITWISE identical to
the fault-free run: per-user materialized state equal to the fault-free
single-shard engine, recommendations equal to its fused serving path,
and state allclose (1e-4) to the paper-faithful float32 RefEngine.  No
event may be lost, double-applied, or resurrected.

A handful of unmarked quick schedules run in tier-1; the full sweep is
``pytest -m chaos`` (deselected by default via pyproject addopts).
``CHAOS_SCHEDULES=<k>`` caps the per-shard-level schedule count for CI
smoke budgets (deterministic stride subsample)."""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.compliance import certify, retained_histories
from repro.core import RefEngine, TifuParams, knn
from repro.core.types import KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM
from repro.parallel.sharding import UserShardSpec
from repro.streaming import (AsyncCheckpointer, Event,
                             ShardedStreamingEngine, StateStore,
                             StoreConfig, StreamingEngine, faults)

P = TifuParams(n_items=41, group_size=3, r_b=0.9, r_g=0.7)
M, N, B = 8, 48, 6
TOPN, K_NN = 5, 4
SEG1, SEG2 = 200, 380          # checkpoint boundaries in the 520 stream


def build(n_shards, checkpointer=None):
    """A fresh engine: the flat single engine at 1, sharded above."""
    if n_shards == 1:
        store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                       max_baskets=N, max_basket_size=B))
        return StreamingEngine(store, P, batch_size=16,
                               checkpointer=checkpointer)
    return ShardedStreamingEngine.create(
        UserShardSpec(M, n_shards), P, max_baskets=N, max_basket_size=B,
        batch_size=16, checkpointer=checkpointer)


def state_rows(eng):
    """Global [M, n_items] materialized user vectors."""
    if isinstance(eng, StreamingEngine):
        return np.asarray(eng.store.state.materialized_user_vecs())
    out = np.empty((M, P.n_items), np.float32)
    for u in range(M):
        s, r = eng.spec.shard_of(u), eng.spec.local_row(u)
        out[u] = np.asarray(
            eng.shards[s].store.state.materialized_user_vecs()[r])
    return out


def random_mixed_events(rng, ref, n_events):
    """Valid mixed add/del-basket/del-item stream with explicit seqnos,
    applied to ``ref`` as drawn (same construction as the sharded
    acceptance stream)."""
    events = []
    for seqno in range(n_events):
        u = int(rng.integers(0, M))
        st = ref.state(u)
        nb = st.n_baskets
        if nb == 0 or (rng.random() < 0.6 and nb < N - 2):
            items = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                               replace=False).astype(np.int32)
            ref.add_basket(u, items)
            events.append(Event(KIND_ADD_BASKET, u, items=items,
                                seqno=seqno))
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            ref.delete_basket(u, pos)
            events.append(Event(KIND_DEL_BASKET, u, pos=pos, seqno=seqno))
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(st.history[pos]))
            ref.delete_item(u, pos, item)
            events.append(Event(KIND_DEL_ITEM, u, pos=pos, item=item,
                                seqno=seqno))
    return events


@pytest.fixture(scope="module")
def baseline():
    """Fault-free ground truth: one 520-event stream, drained through a
    single engine (existing acceptance tests pin that 2/4-shard runs
    match it bitwise), plus the RefEngine oracle."""
    rng = np.random.default_rng(7)
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 520)
    eng = build(1)
    eng.submit(events)
    assert eng.run_until_drained() == len(events)
    return {"events": events,
            "state": state_rows(eng),
            "recs": eng.recommend(np.arange(M), topn=TOPN, k=K_NN),
            "ref_vecs": np.stack([ref.state(u).user_vec.astype(np.float32)
                                  for u in range(M)])}


# ---------------------------------------------------------------------------
# The schedule driver
# ---------------------------------------------------------------------------

def run_schedule(n_shards, sched, baseline, tmp_path):
    """Drive the stream with one injected fault, 'restart the process',
    recover, replay at-least-once, and assert bitwise equality."""
    kind, a, b, redeliver_seed = sched
    events = baseline["events"]
    ck = str(tmp_path / "ck")

    eng = build(n_shards)
    eng.submit(events[:SEG1])
    eng.run_until_drained()
    eng.checkpoint(ck, 1)
    eng.submit(events[SEG1:SEG2])
    eng.run_until_drained()

    if kind == "crash":
        plan = faults.FaultPlan(crash_site=a, crash_on_hit=b)
        with faults.inject(plan):
            try:
                eng.checkpoint(ck, 2)
                crashed = False
            except faults.InjectedCrash:
                crashed = True
        assert crashed, f"schedule never reached fault site {a!r}"
    elif kind == "io" and not a.endswith(".read"):
        plan = faults.FaultPlan(io_errors={a: b})
        with faults.inject(plan):
            eng.checkpoint(ck, 2)        # transient errors absorbed
        assert plan.io_errors[a] == 0
    else:
        # .read-site io errors fire during the restore below
        eng.checkpoint(ck, 2)

    if kind == "corrupt":
        d = ck if n_shards == 1 else os.path.join(ck, f"shard_{b:03d}")
        if a == "latest_flip":
            faults.bitflip_file(os.path.join(d, "LATEST"),
                                seed=redeliver_seed, n_bits=8)
        elif a == "latest_tear":
            faults.tear_file(os.path.join(d, "LATEST"), keep_frac=0.5)
        elif a == "latest_tear0":
            faults.tear_file(os.path.join(d, "LATEST"), keep_frac=0.0)
        elif a == "npz_flip":
            faults.bitflip_file(os.path.join(d, "state_0000000002.npz"),
                                seed=redeliver_seed, n_bits=8)
        else:
            faults.tear_file(os.path.join(d, "state_0000000002.npz"),
                             keep_frac=0.5)

    # "process restart": fresh engine, restore, at-least-once replay.
    # FIRST deliveries replay in seqno order (the delivery contract,
    # DESIGN.md §7.2); the shuffled seeded duplicates — now all copies
    # of delivered events — arrive after, in any order, half-way
    # through processing and again at the end.
    eng2 = build(n_shards)
    if kind == "io" and a.endswith(".read"):
        plan = faults.FaultPlan(io_errors={a: b})
        with faults.inject(plan):
            eng2.restore(ck)
        assert plan.io_errors[a] == 0    # retries absorbed them all
    else:
        eng2.restore(ck)
    eng2.submit(events)
    dups = faults.redelivered(events, seed=redeliver_seed)
    eng2.submit(dups)
    eng2.step()
    eng2.submit(dups)
    eng2.run_until_drained()
    eng2.submit(dups)                    # late duplicates after drain
    assert eng2.run_until_drained() == 0

    got = state_rows(eng2)
    np.testing.assert_array_equal(got, baseline["state"],
                                  err_msg=f"state diverged: {sched}")
    np.testing.assert_allclose(got, baseline["ref_vecs"], atol=1e-4,
                               err_msg=f"ref oracle diverged: {sched}")
    recs = eng2.recommend(np.arange(M), topn=TOPN, k=K_NN)
    np.testing.assert_array_equal(recs, baseline["recs"],
                                  err_msg=f"recs diverged: {sched}")
    # a valid stream must never shed or quarantine anything
    if isinstance(eng2, StreamingEngine):
        assert eng2.metrics.dead_letters == 0
        assert eng2.metrics.backpressure_rejections == 0
    else:
        assert eng2.dead_letters == 0
        assert eng2.backpressure_rejections == 0


# ---------------------------------------------------------------------------
# Schedule enumeration (deterministic)
# ---------------------------------------------------------------------------

CORRUPT_CLASSES = ("latest_flip", "latest_tear", "latest_tear0",
                   "npz_flip", "npz_tear")
IO_SITES = ("npz.pre_write", "npz.pre_replace", "LATEST.pre_replace",
            "LATEST.read", "npz.read")


def all_schedules(n_shards):
    """(kind, a, b, redelivery_seed) tuples: crash site x victim shard,
    corruption class x shard, transient I/O site, redelivery seeds."""
    scheds = []
    sites = (faults.SHARD_CRASH_SITES if n_shards > 1
             else faults.CRASH_SITES)
    for site in sites:
        one_hit = site.startswith("SHARDS") or n_shards == 1
        for hit in ((1,) if one_hit else (1, n_shards)):
            for rs in (0, 1):
                scheds.append(("crash", site, hit, rs))
    for cls in CORRUPT_CLASSES:
        for shard in range(n_shards):
            for rs in (0, 1):
                scheds.append(("corrupt", cls, shard, rs))
    for site in IO_SITES:
        scheds.append(("io", site, 2, 0))
    for rs in range(4):
        scheds.append(("redeliver", None, None, rs))
    cap = int(os.environ.get("CHAOS_SCHEDULES", "0"))
    if cap and cap < len(scheds):
        idx = np.linspace(0, len(scheds) - 1, cap).astype(int)
        scheds = [scheds[i] for i in idx]
    return scheds


def _sched_id(s):
    return "-".join(str(x) for x in s if x is not None)


# ---------------------------------------------------------------------------
# Tier-1 quick subset (unmarked): one schedule of each fault family
# ---------------------------------------------------------------------------

QUICK = [
    (1, ("crash", "LATEST.pre_replace", 1, 0)),
    (2, ("crash", "npz.post_replace", 2, 1)),
    (2, ("corrupt", "npz_tear", 0, 0)),
    (4, ("redeliver", None, None, 3)),
]


@pytest.mark.parametrize("n_shards,sched", QUICK,
                         ids=[f"S{n}-{_sched_id(s)}" for n, s in QUICK])
def test_chaos_quick(n_shards, sched, baseline, tmp_path):
    run_schedule(n_shards, sched, baseline, tmp_path)


# ---------------------------------------------------------------------------
# Full soak (pytest -m chaos)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("n_shards,sched",
                         [(n, s) for n in (1, 2, 4)
                          for s in all_schedules(n)],
                         ids=[f"S{n}-{_sched_id(s)}" for n in (1, 2, 4)
                              for s in all_schedules(n)])
def test_chaos_soak(n_shards, sched, baseline, tmp_path):
    run_schedule(n_shards, sched, baseline, tmp_path)


# ---------------------------------------------------------------------------
# Async (snapshot-then-write) crash-in-flight schedules (DESIGN.md §12):
# the BACKGROUND writer dies mid-commit — at a §9 commit site or at one
# of its own ASYNC_CRASH_SITES — while the engine keeps streaming.  The
# crash must surface at flush, restore must land on the last *committed*
# LATEST (never a torn one), and replay must reconverge bitwise.
# ---------------------------------------------------------------------------


def run_async_schedule(n_shards, sched, baseline, tmp_path):
    """Crash the background checkpoint writer while a commit is in
    flight and the hot path keeps processing events; restore + replay
    must be indistinguishable from the synchronous crash schedules."""
    site, hit, redeliver_seed = sched
    events = baseline["events"]
    ck = str(tmp_path / "ck")

    eng = build(n_shards, checkpointer=AsyncCheckpointer())
    eng.submit(events[:SEG1])
    eng.run_until_drained()
    eng.checkpoint(ck, 1)
    eng.flush_checkpoints()              # commit 1 fully durable
    eng.submit(events[SEG1:SEG2])
    eng.run_until_drained()

    plan = faults.FaultPlan(crash_site=site, crash_on_hit=hit)
    crashed = False
    with faults.inject(plan):
        try:
            eng.checkpoint(ck, 2)        # snapshot + enqueue, returns
            eng.submit(events[SEG2:])    # hot path streams PAST the
            eng.run_until_drained()      # in-flight commit
            eng.flush_checkpoints()      # writer crash surfaces HERE
        except faults.InjectedCrash:
            crashed = True
    assert crashed, f"async schedule never crashed at {site!r}"

    # "process restart": fresh engine + fresh writer; restore must find
    # the last committed LATEST (step 2's jobs at/behind the crash were
    # discarded whole or committed atomically — never torn)
    eng2 = build(n_shards, checkpointer=AsyncCheckpointer())
    eng2.restore(ck)
    eng2.submit(events)
    dups = faults.redelivered(events, seed=redeliver_seed)
    eng2.submit(dups)
    eng2.step()
    eng2.submit(dups)
    eng2.run_until_drained()
    eng2.submit(dups)
    assert eng2.run_until_drained() == 0

    got = state_rows(eng2)
    np.testing.assert_array_equal(got, baseline["state"],
                                  err_msg=f"state diverged: {sched}")
    recs = eng2.recommend(np.arange(M), topn=TOPN, k=K_NN)
    np.testing.assert_array_equal(recs, baseline["recs"],
                                  err_msg=f"recs diverged: {sched}")


def async_schedules(n_shards):
    """(crash_site, crash_on_hit, redelivery_seed) for the async writer:
    every §9 commit site (now tripped ON the writer thread) plus the
    writer's own dequeue/post-commit sites."""
    scheds = []
    sites = (faults.SHARD_CRASH_SITES if n_shards > 1
             else faults.CRASH_SITES) + faults.ASYNC_CRASH_SITES
    for site in sites:
        one_hit = site.startswith("SHARDS") or n_shards == 1
        for hit in ((1,) if one_hit else (1, n_shards)):
            for rs in (0, 1):
                scheds.append((site, hit, rs))
    return scheds


ASYNC_QUICK = [
    (1, ("async.dequeue", 1, 0)),
    (2, ("npz.pre_replace", 2, 1)),
    (1, ("LATEST.post_replace", 1, 0)),
]


@pytest.mark.parametrize("n_shards,sched", ASYNC_QUICK,
                         ids=[f"S{n}-async-{_sched_id(s)}"
                              for n, s in ASYNC_QUICK])
def test_async_crash_quick(n_shards, sched, baseline, tmp_path):
    run_async_schedule(n_shards, sched, baseline, tmp_path)


@pytest.mark.chaos
@pytest.mark.parametrize("n_shards,sched",
                         [(n, s) for n in (1, 2, 4)
                          for s in async_schedules(n)],
                         ids=[f"S{n}-async-{_sched_id(s)}"
                              for n in (1, 2, 4)
                              for s in async_schedules(n)])
def test_async_crash_soak(n_shards, sched, baseline, tmp_path):
    run_async_schedule(n_shards, sched, baseline, tmp_path)


# ---------------------------------------------------------------------------
# Deletion-burst (forget) schedules: GDPR compliance under faults
# (ISSUE 9) — a crash mid-burst, then restore + at-least-once replay,
# must still end in a certifiably compliant, no-trace state.
# ---------------------------------------------------------------------------

FORGET_USERS = (2, 5)


def forget_burst(events):
    """Explicit-seqno burst erasing FORGET_USERS' history after `events`."""
    hist = retained_histories(events, M)
    burst, seqno = [], len(events)
    for u in FORGET_USERS:
        for p in range(len(hist[u]) - 1, -1, -1):
            burst.append(Event(KIND_DEL_BASKET, u, pos=p, seqno=seqno))
            seqno += 1
    return burst


def run_forget_schedule(n_shards, sched, baseline, tmp_path):
    """Checkpoint, crash mid-deletion-burst, restore, replay at-least-
    once, scrub via ``forget_user`` (idempotent on the erased users) and
    certify the recovered engine against the full event log."""
    kind, site, hit, redeliver_seed = sched
    events = baseline["events"][:SEG1]
    burst = forget_burst(events)
    ck = str(tmp_path / "ck")

    eng = build(n_shards)
    eng.submit(events)
    eng.run_until_drained()
    eng.checkpoint(ck, 1)
    eng.submit(burst)
    eng.step()
    eng.step()                           # burst partially applied
    if kind == "crash":
        plan = faults.FaultPlan(crash_site=site, crash_on_hit=hit)
        with faults.inject(plan):
            try:
                eng.checkpoint(ck, 2)
                crashed = False
            except faults.InjectedCrash:
                crashed = True
        assert crashed, f"schedule never reached fault site {site!r}"

    # "process restart": restore, replay everything at-least-once
    eng2 = build(n_shards)
    eng2.restore(ck)
    eng2.submit(events)
    eng2.submit(burst)
    eng2.submit(faults.redelivered(burst, seed=redeliver_seed))
    eng2.run_until_drained()

    # the front-door scrub must be idempotent: the burst already erased
    # the histories, so the receipts report zero deletions and no trace
    for u in FORGET_USERS:
        receipt = eng2.forget_user(u)
        assert receipt.n_baskets_deleted == 0
        assert receipt.clean, f"user {u} residue: {receipt.residue}"
    report = certify(eng2, events + burst,
                     forgotten_users=FORGET_USERS,
                     checkpoint_dir=str(tmp_path / "cert_ck"))
    assert report.compliant, report.summary()


def forget_schedules(n_shards):
    """(kind, site, hit, redelivery_seed): crash at every commit site
    mid-burst, plus crash-free redelivery-only schedules."""
    scheds = [("none", None, 1, rs) for rs in (0, 1)]
    sites = (faults.SHARD_CRASH_SITES if n_shards > 1
             else faults.CRASH_SITES)
    for site in sites:
        for rs in (0, 1):
            scheds.append(("crash", site, 1, rs))
    return scheds


FORGET_QUICK = [(2, ("crash", "npz.pre_replace", 1, 0))]


@pytest.mark.parametrize("n_shards,sched", FORGET_QUICK,
                         ids=[f"S{n}-forget-{_sched_id(s)}"
                              for n, s in FORGET_QUICK])
def test_forget_burst_quick(n_shards, sched, baseline, tmp_path):
    run_forget_schedule(n_shards, sched, baseline, tmp_path)


@pytest.mark.chaos
@pytest.mark.parametrize("n_shards,sched",
                         [(n, s) for n in (1, 2, 4)
                          for s in forget_schedules(n)],
                         ids=[f"S{n}-forget-{_sched_id(s)}"
                              for n in (1, 2, 4)
                              for s in forget_schedules(n)])
def test_forget_burst_soak(n_shards, sched, baseline, tmp_path):
    run_forget_schedule(n_shards, sched, baseline, tmp_path)
