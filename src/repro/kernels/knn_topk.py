"""Fused similarity × streaming top-k Pallas kernel (TPU target).

Serves TIFU-kNN neighbour search (paper §2.2) and the two-tower /
bert4rec ``retrieval_cand`` cells: Q queries against M corpus rows,
returning per-query top-k WITHOUT materializing the [Q, M] score matrix
in HBM — the win over the reference path at M = 10⁶.

Design (DESIGN.md §3.4):
  grid = (Q/bq, M/bm), M innermost (sequential).  Per step the MXU
  computes a [bq, bm] score tile in VMEM (2·q@cᵀ − |c|², the monotone
  euclidean surrogate); a running [bq, k] top-k buffer lives in VMEM
  scratch and is merged tile-by-tile; only [Q, k] leaves the chip.

  The merge uses lax.top_k on the concatenated [bq, k+bm] tile.  On
  current Mosaic this lowers through sort; if a target toolchain lacks
  it, set merge="iterative" (k-round max-mask) — same results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, c_ref, cn_ref, vals_ref, idx_ref, acc_vals, acc_idx,
            *, k: int, bm: int, metric: str):
    mi = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        acc_vals[...] = jnp.full_like(acc_vals, -jnp.inf)
        acc_idx[...] = jnp.zeros_like(acc_idx)

    q = q_ref[...]                                   # [bq, D]
    c = c_ref[...]                                   # [bm, D]
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [bq, bm]
    if metric == "euclidean":
        scores = 2.0 * scores - cn_ref[...][None, :]
    tile_idx = mi * bm + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    merged_vals = jnp.concatenate([acc_vals[...], scores], axis=1)
    merged_idx = jnp.concatenate([acc_idx[...], tile_idx], axis=1)
    top_vals, top_pos = jax.lax.top_k(merged_vals, k)
    acc_vals[...] = top_vals
    acc_idx[...] = jnp.take_along_axis(merged_idx, top_pos, axis=1)

    @pl.when(mi == nm - 1)
    def _done():
        vals_ref[...] = acc_vals[...]
        idx_ref[...] = acc_idx[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "bq", "bm", "metric", "interpret"))
def knn_topk(queries, corpus, k: int, bq: int = 128, bm: int = 512,
             metric: str = "euclidean", interpret: bool = False):
    """queries [Q, D] × corpus [M, D] → (vals [Q, k], idx [Q, k])."""
    qn, d = queries.shape
    m = corpus.shape[0]
    bq = min(bq, qn)
    bm = min(bm, m)
    assert qn % bq == 0 and m % bm == 0, (qn, bq, m, bm)
    cnorm = jnp.sum(corpus.astype(jnp.float32) ** 2, axis=-1)
    grid = (qn // bq, m // bm)
    kernel = functools.partial(_kernel, k=k, bm=bm, metric=metric)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda qi, mi: (qi, 0)),
            pl.BlockSpec((bm, d), lambda qi, mi: (mi, 0)),
            pl.BlockSpec((bm,), lambda qi, mi: (mi,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qi, mi: (qi, 0)),
            pl.BlockSpec((bq, k), lambda qi, mi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),   # running top-k vals
            pltpu.VMEM((bq, k), jnp.int32),     # running top-k idx
        ],
        interpret=interpret,
    )(queries, corpus, cnorm)
