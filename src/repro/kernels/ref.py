"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's tests sweep shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def knn_topk_ref(queries, corpus, k: int, metric: str = "euclidean"):
    """Top-k (scores, indices) of each query against the corpus.

    euclidean uses the monotone surrogate 2qc − |c|² (per-query |q|² is
    rank-irrelevant and omitted, matching the kernel).
    """
    if metric == "euclidean":
        scores = (2.0 * queries @ corpus.T
                  - jnp.sum(corpus * corpus, axis=-1)[None, :])
    elif metric == "dot":
        scores = queries @ corpus.T
    else:
        raise ValueError(metric)
    return jax.lax.top_k(scores.astype(jnp.float32), k)


def decayed_scatter_ref(ids, weights, n_items: int):
    """Weighted multi-hot scatter: out[i] = Σ_{n,b} w[n]·[ids[n,b] == i].

    ids: i32[N, B] (PAD=-1), weights: f32[N] → f32[n_items].
    This is the TIFU-kNN user-vector builder AND the EmbeddingBag-grad
    shape (one-hot-matmul on TPU).
    """
    flat = ids.reshape(-1)
    w = jnp.repeat(weights, ids.shape[1])
    valid = flat >= 0
    return jnp.zeros((n_items,), jnp.float32).at[
        jnp.where(valid, flat, 0)].add(jnp.where(valid, w, 0.0))


def sparse_row_scatter_ref(table, rows, ids, vals):
    """Sparse per-row scatter-add into a [M, I] table.

    table: f32[M, I]; rows: i32[U]; ids: i32[U, W] (PAD=-1 skipped);
    vals: f32[U, W].  Returns table with

        out[rows[r], ids[r, w]] += vals[r, w]      for ids[r, w] >= 0.

    Duplicate (row, id) pairs accumulate.  Only O(U·W) elements of the
    table are addressed — this is the batched add path's delta applier
    (DESIGN.md §3.3).
    """
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    v = jnp.where(valid, vals, 0.0)
    return table.at[rows[:, None], safe].add(v)


def sparse_row_gather_ref(table, rows, ids):
    """Sparse per-row gather from a [M, I] table.

    table: f32[M, I]; rows: i32[U]; ids: i32[U, W] (PAD=-1 → 0.0).
    Returns f32[U, W] with out[r, w] = table[rows[r], ids[r, w]].

    The read half of the sparse_row_scatter pair: the decremental paths
    gather the raw values on an event's support before computing the
    reset/delta terms (DESIGN.md §3.5).  O(U·W) elements addressed.
    """
    m = table.shape[0]
    valid = ids >= 0
    safe_rows = jnp.clip(rows, 0, m - 1)
    vals = table[safe_rows[:, None], jnp.where(valid, ids, 0)]
    return jnp.where(valid, vals, 0.0).astype(table.dtype)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """Plain attention oracle. q,k,v: [B,S,H,D] (H == KV heads here)."""
    b, s, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)
