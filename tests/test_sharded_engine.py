"""User-axis sharded deployment (DESIGN.md §7): routing, per-shard
exactly-once logs under cross-shard redelivery and torn commits,
resharding (N→M) restore, cross-shard KNN serving parity, and the
host-measured tile hints threaded through the appliers.

The headline acceptance pin: a 2-shard and a 4-shard engine replaying
the same 520-event mixed stream produce recommendations **bitwise
identical** to the single-shard engine (and state matching the
paper-faithful RefEngine), including after a mid-stream crash/restore
and a reshard restore."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import RefEngine, TifuParams, knn
from repro.core.types import KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM
from repro.kernels import ops
from repro.parallel.sharding import UserShardSpec
from repro.streaming import (Event, ShardedStreamingEngine, StateStore,
                             StoreConfig, StreamingEngine)

P = TifuParams(n_items=41, group_size=3, r_b=0.9, r_g=0.7)
M, N, B = 8, 48, 6
TOPN, K_NN = 5, 4


def make_single(batch_size=16):
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B))
    return StreamingEngine(store, P, batch_size=batch_size), store


def make_sharded(n_shards, batch_size=16):
    return ShardedStreamingEngine.create(
        UserShardSpec(M, n_shards), P, max_baskets=N, max_basket_size=B,
        batch_size=batch_size)


def random_mixed_events(rng, ref: RefEngine, n_events: int, n_users: int,
                        n_items=P.n_items, p_add=0.6):
    """Valid mixed add/del-basket/del-item stream with explicit seqnos,
    applying each event to ``ref`` as it is drawn."""
    events = []
    for seqno in range(n_events):
        u = int(rng.integers(0, n_users))
        st = ref.state(u)
        nb = st.n_baskets
        if nb == 0 or (rng.random() < p_add and nb < N - 2):
            items = rng.choice(n_items, size=int(rng.integers(1, B)),
                               replace=False).astype(np.int32)
            ref.add_basket(u, items)
            events.append(Event(KIND_ADD_BASKET, u, items=items,
                                seqno=seqno))
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            ref.delete_basket(u, pos)
            events.append(Event(KIND_DEL_BASKET, u, pos=pos, seqno=seqno))
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(st.history[pos]))
            ref.delete_item(u, pos, item)
            events.append(Event(KIND_DEL_ITEM, u, pos=pos, item=item,
                                seqno=seqno))
    return events


def sharded_state_rows(eng: ShardedStreamingEngine):
    """Global [M, I] materialized user vectors re-assembled from shards."""
    out = np.empty((M, P.n_items), np.float32)
    for u in range(M):
        s = eng.spec.shard_of(u)
        r = eng.spec.local_row(u)
        out[u] = np.asarray(
            eng.shards[s].store.state.materialized_user_vecs()[r])
    return out


def single_recs(store):
    return np.asarray(knn.recommend_for_users(
        store.corpus(), jnp.asarray(np.arange(M)), k=K_NN, alpha=P.alpha,
        topn=TOPN))


@pytest.fixture(scope="module")
def stream():
    """One 520-event mixed stream + the drained single-shard engine."""
    rng = np.random.default_rng(7)
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 520, M)
    eng, store = make_single()
    eng.submit(events)
    assert eng.run_until_drained() == len(events)
    return {"events": events, "ref": ref, "single": eng, "store": store,
            "recs": single_recs(store)}


# ---------------------------------------------------------------------------
# Partitioning contract
# ---------------------------------------------------------------------------

def test_user_shard_spec_bijection():
    for n_users, n_shards in [(8, 2), (10, 4), (7, 3), (5, 1), (3, 8)]:
        spec = UserShardSpec(n_users, n_shards)
        assert sum(spec.shard_users(s) for s in range(n_shards)) == n_users
        seen = set()
        for s in range(n_shards):
            owned = spec.owned_users(s)
            assert len(owned) == spec.shard_users(s)
            for r, u in enumerate(owned):
                assert spec.shard_of(u) == s
                assert spec.local_row(u) == r
                assert spec.global_user(s, r) == u
                seen.add(int(u))
        assert seen == set(range(n_users))


def test_make_user_shard_meshes_smoke():
    from repro.launch.mesh import make_user_shard_meshes
    meshes = make_user_shard_meshes(3)
    assert len(meshes) == 3
    for m in meshes:
        assert set(m.axis_names) == {"data", "model"}


# ---------------------------------------------------------------------------
# Cross-shard serving
# ---------------------------------------------------------------------------

def test_sharded_knn_matches_single_corpus(rng):
    """Per-shard candidates + merge == single-corpus top-k, bitwise, on a
    random corpus (independent of the engine)."""
    m, n_items, k, topn = 23, 37, 7, 6
    corpus = rng.normal(size=(m, n_items)).astype(np.float32)
    users = rng.choice(m, size=9, replace=False)
    want = np.asarray(knn.recommend_for_users(
        jnp.asarray(corpus), jnp.asarray(users.astype(np.int32)), k=k,
        alpha=0.7, topn=topn))
    for n_shards in (2, 3, 5):
        spec = UserShardSpec(m, n_shards)
        corpora = [jnp.asarray(corpus[spec.owned_users(s)])
                   for s in range(n_shards)]
        got = knn.sharded_recommend_for_users(
            corpora, users, k=k, alpha=0.7, topn=topn, n_shards=n_shards)
        np.testing.assert_array_equal(got, want, err_msg=f"S={n_shards}")


# ---------------------------------------------------------------------------
# Engine equivalence on the 520-event stream (acceptance pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_stream_bitwise_vs_single_and_ref(stream, n_shards):
    eng = make_sharded(n_shards)
    eng.submit(stream["events"])
    assert eng.run_until_drained() == len(stream["events"])
    # per-user state: bitwise vs the single-shard engine (same compiled
    # per-row math, disjoint users), allclose vs the paper oracle
    got = sharded_state_rows(eng)
    want = np.asarray(stream["store"].state.materialized_user_vecs())
    np.testing.assert_array_equal(got, want)
    for u in range(M):
        np.testing.assert_allclose(
            got[u], stream["ref"].state(u).user_vec.astype(np.float32),
            atol=1e-4)
    # recommendations: bitwise vs the single-shard fused serving path
    recs = eng.recommend(np.arange(M), topn=TOPN, k=K_NN)
    np.testing.assert_array_equal(recs, stream["recs"])


def test_sharded_crash_restore_and_reshard(stream, tmp_path):
    """Mid-stream crash → restore (2 shards), reshard restores 2→4 and
    4→2, full-stream replay after each: recommendations stay bitwise
    equal to the single-shard engine."""
    events = stream["events"]
    half = len(events) // 2

    eng = make_sharded(2)
    eng.submit(events[:half])
    eng.run_until_drained()
    ck2 = str(tmp_path / "ck2")
    eng.checkpoint(ck2, step=1)

    # crash/restore at the same shard count + at-least-once full replay
    eng2 = make_sharded(2)
    eng2.restore(ck2)
    eng2.submit(events)          # first half must dedup against the log
    assert eng2.n_pending == len(events) - half
    eng2.run_until_drained()
    np.testing.assert_array_equal(
        eng2.recommend(np.arange(M), topn=TOPN, k=K_NN), stream["recs"])

    # reshard the mid-stream checkpoint 2 → 4, replay the full stream
    eng4 = make_sharded(4)
    eng4.restore(ck2)
    assert eng4._legacy and eng4._legacy[0]["n_shards"] == 2
    eng4.submit(events)
    assert eng4.n_pending == len(events) - half   # legacy logs dedup
    eng4.run_until_drained()
    np.testing.assert_array_equal(
        eng4.recommend(np.arange(M), topn=TOPN, k=K_NN), stream["recs"])

    # ... and back: drained 4-shard checkpoint → 2 shards; a further
    # replay is now FULLY deduplicated through the legacy logs
    ck4 = str(tmp_path / "ck4")
    eng4.checkpoint(ck4, step=2)
    eng2b = make_sharded(2)
    eng2b.restore(ck4)
    eng2b.submit(events)
    assert eng2b.n_pending == 0
    np.testing.assert_array_equal(
        eng2b.recommend(np.arange(M), topn=TOPN, k=K_NN), stream["recs"])


def test_flat_single_engine_checkpoint_reshards(stream, tmp_path):
    """A plain StreamingEngine checkpoint (no manifest) restores into a
    sharded deployment as the N=1 special case."""
    ck = str(tmp_path / "flat")
    stream["single"].checkpoint(ck, step=3)
    eng = make_sharded(2)
    eng.restore(ck)
    eng.submit(stream["events"])       # all processed pre-reshard
    assert eng.n_pending == 0
    np.testing.assert_array_equal(
        eng.recommend(np.arange(M), topn=TOPN, k=K_NN), stream["recs"])


# ---------------------------------------------------------------------------
# Per-shard exactly-once
# ---------------------------------------------------------------------------

def test_exactly_once_under_cross_shard_redelivery(rng):
    """At-least-once redelivery of mixed cross-shard batches — before
    processing, straddling partial processing, and after a drain — must
    never double-apply on any shard."""
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 60, M)
    eng = make_sharded(2, batch_size=4)
    eng.submit(events)
    n0 = eng.n_pending
    eng.submit(events)                  # redelivery before any processing
    assert eng.n_pending == n0
    for _ in range(3):                  # partial progress on both shards
        eng.step()
    done = eng.events_processed
    eng.submit(events)                  # straddles processed AND pending
    assert eng.n_pending == n0 - done
    eng.run_until_drained()
    eng.submit(events)                  # after drain: all duplicates
    assert eng.n_pending == 0
    assert eng.events_processed == len(events)
    got = sharded_state_rows(eng)
    for u in range(M):
        np.testing.assert_allclose(
            got[u], ref.state(u).user_vec.astype(np.float32), atol=1e-4)


def test_exactly_once_across_torn_shard_commits(rng, tmp_path):
    """Crash BETWEEN shard commits: one shard checkpointed at a later
    step than the other.  Restore + full replay must re-apply exactly
    the lost events per shard (DESIGN.md §7 failure table)."""
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 60, M)
    half = len(events) // 2
    ck = str(tmp_path / "torn")

    eng = make_sharded(2)
    eng.submit(events[:half])
    eng.run_until_drained()
    eng.checkpoint(ck, step=1)
    eng.submit(events[half:])
    eng.run_until_drained()
    # simulate the crash: only shard 0 commits step 2
    eng.shards[0].checkpoint(eng._shard_dir(ck, 0), step=2)

    eng2 = make_sharded(2)
    eng2.restore(ck)
    # shard 0 restored beyond shard 1: replay fills only shard 1's gap
    assert eng2.shards[0].watermark > eng2.shards[1].watermark
    eng2.submit(events)
    eng2.run_until_drained()
    got = sharded_state_rows(eng2)
    for u in range(M):
        np.testing.assert_allclose(
            got[u], ref.state(u).user_vec.astype(np.float32), atol=1e-4,
            err_msg=f"u={u}")


def test_checkpoint_refuses_layout_mismatch(rng, tmp_path):
    """Re-using a checkpoint directory across layouts would tear the
    manifest's view of the shard files — must raise."""
    eng = make_sharded(2)
    eng.add_basket(0, [1, 2, 3])
    eng.run_until_drained()
    eng.checkpoint(str(tmp_path), step=1)
    other = make_sharded(4)
    with pytest.raises(ValueError, match="layout"):
        other.checkpoint(str(tmp_path), step=2)


# ---------------------------------------------------------------------------
# Host-measured tile hints (T_max threading, ROADMAP open item)
# ---------------------------------------------------------------------------

def test_tile_hint_stream_matches_ref_interpret():
    """Mixed stream through the tile-planned Pallas kernels (interpret
    mode) with host-measured T_max hints enabled: an unsound hint would
    truncate the plan and corrupt the state, so equivalence with the
    RefEngine pins the hints' soundness end-to-end."""
    p = TifuParams(n_items=256, group_size=3)   # 256 % 128 == 0: planned
    rng = np.random.default_rng(3)
    ref = RefEngine(p, dtype=np.float32)
    events = random_mixed_events(rng, ref, 60, M, n_items=p.n_items)
    with ops.default_impl("interpret"):
        store = StateStore(StoreConfig(n_users=M, n_items=p.n_items,
                                       max_baskets=N, max_basket_size=B))
        eng = StreamingEngine(store, p, batch_size=8, tile_hints=True)
        eng.submit(events)
        eng.run_until_drained()
        mat = np.asarray(store.state.materialized_user_vecs())
    for u in range(M):
        np.testing.assert_allclose(
            mat[u], ref.state(u).user_vec.astype(np.float32), atol=1e-4)


def test_tile_hints_bound_measured_tiles(rng):
    """The per-kind hints are sound upper bounds on the touched tiles of
    the ids the appliers actually construct."""
    from repro.kernels.tile_plan import max_touched_tiles
    p = TifuParams(n_items=256, group_size=3)
    store = StateStore(StoreConfig(n_users=M, n_items=p.n_items,
                                   max_baskets=N, max_basket_size=B))
    eng = StreamingEngine(store, p, batch_size=8, tile_hints=True)
    for t in range(30):
        eng.add_basket(int(rng.integers(0, M)),
                       rng.choice(p.n_items, size=3, replace=False))
    eng.run_until_drained()
    bi = ops.plan_bi(p.n_items)
    adds = [Event(KIND_ADD_BASKET, u,
                  items=rng.choice(p.n_items, size=4, replace=False)
                  .astype(np.int32)) for u in range(M)]
    delb = [Event(KIND_DEL_BASKET, u, pos=0) for u in range(M)]
    hints = eng._tile_hints(adds, delb, [])
    hist = np.asarray(store.state.history)
    nb = np.asarray(store.state.n_baskets)
    ng = np.asarray(store.state.n_groups)
    gs = np.asarray(store.state.group_sizes)
    for u in range(M):
        window = hist[u, :nb[u]].ravel()
        assert hints[KIND_DEL_BASKET] >= max_touched_tiles(
            window[None, :], bi)
        # the add support is the LAST group's rows plus the new basket
        tau = gs[u, ng[u] - 1] if ng[u] > 0 else 0
        support = np.concatenate([hist[u, nb[u] - tau:nb[u]].ravel(),
                                  adds[u].items])
        assert hints[KIND_ADD_BASKET] >= max_touched_tiles(
            support[None, :], bi)
