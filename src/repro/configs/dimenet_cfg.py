"""dimenet [arXiv:2003.03123] — n_blocks=6 d_hidden=128 n_bilinear=8
n_spherical=7 n_radial=6.

All four GNN shape cells lower train_step (the shapes are training
regimes).  Edge/triplet arrays are sharded over (data×model) with
partition-local triplets (DESIGN.md §5); nodes replicated.  Non-geometric
graphs receive precomputed dist/angle inputs (frontend adaptation note,
DESIGN.md §4)."""
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchDef, recsys_cell, sds
from repro.models import dimenet

# ---- cell geometry (static shapes; triplet caps documented) ---------------
# edge/triplet counts are padded up to multiples of 512 with ghost edges
# (dst → node 0, weight-0 basis) so they shard over the 512-chip mesh;
# the true benchmark sizes are in the comments.
def _pad(n, m=512):
    return (n + m - 1) // m * m


CELLS = {
    # cora-like: 2708 nodes, 10556 edges (padded 10752), 1433 feats
    "full_graph_sm": dict(n_nodes=2708, n_edges=_pad(10556),
                          n_tri=_pad(42240), d_feat=1433, n_targets=7,
                          geometric=False),
    # reddit-like sampled: 1024 seeds, fanout 15-10 → padded subgraph
    "minibatch_lg": dict(n_nodes=174080, n_edges=169984, n_tri=1699840,
                         d_feat=602, n_targets=41, geometric=False),
    # ogbn-products full batch: 61,859,140 edges (padded 61,859,328);
    # triplets capped at 1×E (sampled)
    "ogb_products": dict(n_nodes=2449029, n_edges=_pad(61859140),
                         n_tri=_pad(61859140), d_feat=100, n_targets=47,
                         geometric=False),
    # 128 molecules × 30 atoms, 64 edges each
    "molecule": dict(n_nodes=3840, n_edges=8192, n_tri=32768,
                     d_feat=0, n_targets=1, geometric=True, n_graphs=128),
}


def make_config(cell="molecule"):
    g = CELLS[cell]
    return dimenet.DimeNetConfig(d_node_feat=g["d_feat"],
                                 n_targets=g["n_targets"])


def smoke_config():
    return dimenet.DimeNetConfig(n_blocks=2, d_hidden=32, n_bilinear=4,
                                 n_spherical=3, n_radial=4)


def _batch_builder(cell):
    g = CELLS[cell]

    def build(c, mesh, rules):
        graph_ax = tuple(a for a in ("data", "model")
                         if a in mesh.axis_names)
        e = P(graph_ax)
        n = P(None)
        batch = {
            "edge_src": sds((g["n_edges"],), jnp.int32),
            "edge_dst": sds((g["n_edges"],), jnp.int32),
            "dist": sds((g["n_edges"],), jnp.float32),
            "angle": sds((g["n_tri"],), jnp.float32),
            "tri_kj": sds((g["n_tri"],), jnp.int32),
            "tri_ji": sds((g["n_tri"],), jnp.int32),
        }
        shard = {"edge_src": e, "edge_dst": e, "dist": e, "angle": e,
                 "tri_kj": e, "tri_ji": e}
        if g["geometric"]:
            batch["z"] = sds((g["n_nodes"],), jnp.int32)
            batch["graph_id"] = sds((g["n_nodes"],), jnp.int32)
            batch["labels"] = sds((g["n_graphs"],), jnp.float32)
            shard.update({"z": n, "graph_id": n, "labels": n})
        else:
            batch["node_feat"] = sds((g["n_nodes"], g["d_feat"]),
                                     jnp.float32)
            batch["labels"] = sds((g["n_nodes"],), jnp.int32)
            shard.update({"node_feat": n, "labels": n})
        return batch, {k: NamedSharding(mesh, v) for k, v in shard.items()}
    return build


def _flops(cell):
    g = CELLS[cell]

    def f(c):
        d, b = c.d_hidden, c.n_bilinear
        per_block = (2 * g["n_edges"] * d * d * 2       # msg MLPs
                     + g["n_tri"] * (d * b + b * b * d)  # bilinear path
                     + 2 * g["n_edges"] * d * d)         # output blocks
        return 6.0 * c.n_blocks * per_block              # fwd+bwd
    return f


def _cfg(cell):
    return lambda: make_config(cell)


ARCH = ArchDef(
    name="dimenet", family="gnn",
    cells={cell: recsys_cell(dimenet, _cfg(cell), _batch_builder(cell),
                             f"dimenet {cell} train", train=True,
                             pass_mesh=True, flops_fn=_flops(cell))
           for cell in CELLS},
    make_smoke=smoke_config,
    notes="triplet-gather regime; segment_sum message passing; "
          "tri_kj/tri_ji are LOCAL indices into the edge partition "
          "(partition-aware sampling, data.graph_sampler). dist/angle "
          "are inputs for non-geometric graphs (DESIGN.md §4). "
          "Paper technique N/A (documented).")
