"""Paper Fig. 2a/2b: update latency vs accumulated updates.

2a: sequentially add baskets — incremental O(1) vs baseline O(n) retrain.
2b: delete baskets from end / start / random — near-constant / linear /
    in-between; baseline is O(n) everywhere.

Setup follows §6.2: single user, single-item baskets [{1},{1},...].
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import RefEngine, TifuParams
from repro.core.tifu import default_group_sizes, user_vector_ragged

P = TifuParams(n_items=1, group_size=7, r_b=0.9, r_g=0.7)
BASKET = np.array([0])


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        fn()
        best = min(best, time.perf_counter_ns() - t0)
    return best / 1e3  # µs


def fig2a_additions(n_max=4000, sample_every=250):
    """Returns rows (n, t_incremental_us, t_baseline_us)."""
    eng = RefEngine(P)
    hist = []
    rows = []
    for n in range(1, n_max + 1):
        t_incr = _time(lambda: eng.add_basket(0, BASKET), reps=1)
        hist.append(BASKET)
        if n % sample_every == 0 or n == 1:
            t_base = _time(lambda: user_vector_ragged(
                hist, default_group_sizes(len(hist), P.group_size), P))
            rows.append((n, t_incr, t_base))
    return rows


def _build(n):
    eng = RefEngine(P)
    for _ in range(n):
        eng.add_basket(0, BASKET)
    return eng


def fig2b_deletions(n0=2000, n_del=1500, sample_every=100, seed=0):
    """Returns rows (k_deleted, t_end_us, t_start_us, t_random_us,
    t_baseline_us)."""
    rng = np.random.default_rng(seed)
    eng_end, eng_start, eng_rand = _build(n0), _build(n0), _build(n0)
    rows = []
    for k in range(1, n_del + 1):
        n_now = n0 - k + 1
        t_end = _time(lambda: eng_end.delete_basket(0, n_now - 1), reps=1)
        t_start = _time(lambda: eng_start.delete_basket(0, 0), reps=1)
        pos = int(rng.integers(0, n_now))
        t_rand = _time(lambda: eng_rand.delete_basket(0, pos), reps=1)
        if k % sample_every == 0 or k == 1:
            hist = eng_end.state(0).history
            t_base = _time(lambda: user_vector_ragged(
                hist, eng_end.state(0).group_sizes, P))
            rows.append((k, t_end, t_start, t_rand, t_base))
    return rows


def main():
    print("# fig2a: n,t_incr_us,t_baseline_us")
    rows = fig2a_additions(n_max=3000, sample_every=500)
    for r in rows:
        print(f"fig2a,{r[0]},{r[1]:.1f},{r[2]:.1f}")
    # the paper's claim: incremental time does not grow with n
    t_first, t_last = rows[0][1], rows[-1][1]
    print(f"# incr latency at n=1: {t_first:.1f}us; at n={rows[-1][0]}: "
          f"{t_last:.1f}us (constant)")
    print(f"# baseline grows: {rows[0][2]:.1f} → {rows[-1][2]:.1f}us")

    print("# fig2b: k,t_end_us,t_start_us,t_random_us,t_baseline_us")
    for r in fig2b_deletions(n0=1500, n_del=1000, sample_every=250):
        print(f"fig2b,{r[0]},{r[1]:.1f},{r[2]:.1f},{r[3]:.1f},{r[4]:.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
