"""Streaming engine (Algorithm 1): parity with the ref engine, per-user
ordering under conflicts, exactly-once recovery, stability refresh."""
import dataclasses

import numpy as np

from repro.core import RefEngine, TifuParams, KIND_ADD_BASKET
from repro.data import stream, synthetic
from repro.streaming import Event, StateStore, StoreConfig, StreamingEngine

P = TifuParams(n_items=29, group_size=3)


def make_engine(n_users=8, batch_size=16, **kw):
    store = StateStore(StoreConfig(n_users=n_users, n_items=P.n_items,
                                   max_baskets=24, max_basket_size=6))
    return StreamingEngine(store, P, batch_size=batch_size, **kw), store


def test_engine_matches_ref(rng):
    eng, store = make_engine()
    ref = RefEngine(P, dtype=np.float32)
    for _ in range(120):
        u = int(rng.integers(0, 8))
        nb = ref.state(u).n_baskets
        if nb == 0 or (rng.random() < 0.7 and nb < 22):
            items = rng.choice(P.n_items, size=int(rng.integers(1, 5)),
                               replace=False)
            eng.add_basket(u, items)
            ref.add_basket(u, items)
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            eng.delete_basket(u, pos)
            ref.delete_basket(u, pos)
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(ref.state(u).history[pos]))
            eng.delete_item(u, pos, item)
            ref.delete_item(u, pos, item)
    eng.run_until_drained()
    for u in range(8):
        np.testing.assert_allclose(
            np.asarray(store.state.materialized_user_vecs()[u]),
            ref.state(u).user_vec.astype(np.float32), atol=1e-4)


def test_per_user_order_preserved_under_conflicts(rng):
    """Many events for ONE user in a single submit: the engine must apply
    them sequentially (one per micro-batch) in order."""
    eng, store = make_engine(batch_size=4)
    ref = RefEngine(P, dtype=np.float32)
    baskets = [rng.choice(P.n_items, size=3, replace=False)
               for _ in range(10)]
    for b in baskets:
        eng.add_basket(3, b)
        ref.add_basket(3, b)
    eng.delete_basket(3, 0)
    ref.delete_basket(3, 0)
    eng.run_until_drained()
    np.testing.assert_allclose(np.asarray(store.state.materialized_user_vecs()[3]),
                               ref.state(3).user_vec.astype(np.float32),
                               atol=1e-4)
    assert int(store.state.n_baskets[3]) == 9


def test_exactly_once_recovery(rng, tmp_path):
    """Process half the stream, checkpoint, replay everything from the
    start against the restored engine: already-processed seqnos must be
    skipped and the final state must equal the single-pass run."""
    events = []
    for t in range(40):
        u = int(rng.integers(0, 8))
        items = rng.choice(P.n_items, size=3, replace=False)
        events.append(Event(KIND_ADD_BASKET, u, items=items))

    # single-pass reference run
    eng1, store1 = make_engine()
    eng1.submit(events)
    eng1.run_until_drained()

    # half-run + crash + restore + full replay
    eng2, store2 = make_engine()
    eng2.submit(events)
    for _ in range(2):
        eng2.step()
    eng2.checkpoint(str(tmp_path), 1)
    processed = eng2.metrics.events_processed

    eng3, store3 = make_engine()
    eng3.restore(str(tmp_path))
    # replay the FULL stream with original seqnos (at-least-once delivery)
    replay = [dataclasses.replace(ev, seqno=i)
              for i, ev in enumerate(events)]
    eng3.submit(replay)
    assert eng3.n_pending == len(events) - processed  # dups skipped
    eng3.run_until_drained()
    np.testing.assert_allclose(np.asarray(store3.state.materialized_user_vecs()),
                               np.asarray(store1.state.materialized_user_vecs()),
                               atol=1e-5)


def test_paper_deletion_scenario(rng):
    """§6.1 setup: 1/1000 users delete 10% of baskets; engine stays
    consistent with from-scratch on the surviving history."""
    ds = synthetic.generate("tafeng", scale=0.004, seed=1)
    p = ds.params
    n_users = len(ds.histories)
    store = StateStore(StoreConfig(
        n_users=n_users, n_items=p.n_items,
        max_baskets=max(len(h) for h in ds.histories.values()) + 4,
        max_basket_size=max((len(b) for h in ds.histories.values()
                             for b in h), default=8) + 2))
    eng = StreamingEngine(store, p, batch_size=64)
    events = stream.make_stream(ds.histories, deletion_user_rate=0.1,
                                deletion_basket_frac=0.3, seed=2)
    eng.submit(events)
    n = eng.run_until_drained()
    assert n == len(events)
    # spot-check a few users against from-scratch on the engine's history
    from repro.core.tifu import user_vector_padded
    for u in list(ds.histories)[:5]:
        vec = np.asarray(store.state.materialized_user_vecs()[u])
        fresh = np.asarray(user_vector_padded(
            store.state.history[u], store.state.group_sizes[u],
            store.state.n_groups[u], p))
        np.testing.assert_allclose(vec, fresh, atol=1e-3)
