"""Sparse per-row scatter-add into a [M, I] table (TPU Pallas).

The batched add path (core.updates.apply_add_batch, DESIGN.md §3.3)
produces per-event deltas whose support is only the touched items:
``(rows[U], ids[U, W], vals[U, W])`` with W ≪ I.  This kernel applies

    table[rows[r], ids[r, w]] += vals[r, w]        (PAD ids skipped)

in place (``input_output_aliases``), so the full [M, I] state never
leaves HBM.  TPUs dislike data-dependent scatter, so per tile the update
is a compare + reduce: the [W, bi] one-hot of a row's ids against the
item tile's iota, contracted with vals.

The grid is driven by a **touched-tile plan** (kernels.tile_plan): the
``(U, T_max)`` step sequence enumerates only the ``(target row, item
tile)`` blocks some row's ids actually touch, sorted by (row, tile) so
every output block's visits — including visits contributed by duplicate
target rows — are *consecutive* grid steps.  The scalar-prefetched plan
arrays drive the block index maps; a step DMAs only a genuinely dirty
``[1, bi]`` tile (padding steps clone the previous block, which the
pipeline does not re-fetch, and a PAD ``pl.when`` guard skips their
compute), so HBM traffic is O(U·W) — matching the XLA reference path's
asymptotics (kernels.ref.sparse_row_scatter_ref, the CPU/GPU path) and
the paper's flat latency-vs-vocabulary curve on TPU.

Within one output block's run the kernel accumulates in a VMEM scratch
(loaded on the run's first step, stored on its last), which is the same
consecutive-revisit contract the pre-plan kernel relied on — the plan's
(row, tile) sort is what makes it hold for duplicate rows with differing
supports.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem as _avmem
from repro.analysis.contracts import KernelContract, register
from repro.kernels.tile_plan import build_plan


def _kernel(pbatch_ref, prow_ref, ptile_ref, pvalid_ref, ids_ref, vals_ref,
            tab_ref, out_ref, acc, *, bi: int, t_max: int):
    del pbatch_ref  # consumed by the ids/vals index maps only
    r = pl.program_id(0)
    t = pl.program_id(1)
    s = r * t_max + t
    ns = pl.num_programs(0) * t_max

    row = prow_ref[s]
    tile = ptile_ref[s]
    sp = jnp.maximum(s - 1, 0)
    sn = jnp.minimum(s + 1, ns - 1)
    prev_same = (s > 0) & (prow_ref[sp] == row) & (ptile_ref[sp] == tile)
    next_same = (s < ns - 1) & (prow_ref[sn] == row) & (ptile_ref[sn] == tile)

    @pl.when(jnp.logical_not(prev_same))
    def _load():
        acc[...] = tab_ref[0, :]

    @pl.when(pvalid_ref[s] == 1)
    def _accumulate():
        ids = ids_ref[0, :]                          # [W] i32, PAD=-1
        vals = vals_ref[0, :]                        # [W] f32
        base = tile * bi
        grid = base + jax.lax.broadcasted_iota(jnp.int32,
                                               (ids.shape[0], bi), 1)
        onehot = (ids[:, None] == grid).astype(jnp.float32)  # PAD misses
        acc[...] += jnp.sum(onehot * vals[:, None], axis=0)

    @pl.when(jnp.logical_not(next_same))
    def _store():
        out_ref[0, :] = acc[...]


@functools.partial(jax.jit, static_argnames=("bi", "t_max", "interpret"))
def sparse_row_scatter(table, rows, ids, vals, bi: int = 512,
                       t_max: int | None = None, interpret: bool = False):
    """Scatter-add sparse per-row deltas into ``table`` in place.

    table f32[M, I] += scatter(rows i32[U], ids i32[U, W] PAD=-1,
    vals f32[U, W]); returns the updated table (aliased via
    ``input_output_aliases``).  Duplicate rows are handled (the tile plan sorts every (row, tile)
    block's visits onto consecutive grid steps, accumulating).  Requires
    I % bi == 0 and ``t_max`` >= the largest per-row touched-tile count
    (None picks the always-safe ``min(W, I/bi)``); the ops.py dispatcher
    selects both / falls back to the XLA reference.
    """
    m, n_items = table.shape
    u, w = ids.shape
    bi = min(bi, n_items)
    assert n_items % bi == 0, (n_items, bi)
    n_tiles = n_items // bi
    if t_max is None:
        t_max = min(w, n_tiles)
    t_max = max(1, min(t_max, w, n_tiles))
    order = jnp.argsort(rows, stable=True)
    rows_s = jnp.clip(rows[order], 0, m - 1).astype(jnp.int32)
    ids_s = ids[order]
    vals_s = jnp.where(ids_s >= 0, vals[order], 0.0)
    plan = build_plan(rows_s, ids_s, bi=bi, t_max=t_max, order="target")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(u, t_max),
        in_specs=[
            pl.BlockSpec((1, w),
                         lambda r, t, pb, pr, pt, pv: (pb[r * t_max + t], 0)),
            pl.BlockSpec((1, w),
                         lambda r, t, pb, pr, pt, pv: (pb[r * t_max + t], 0)),
            pl.BlockSpec((1, bi),
                         lambda r, t, pb, pr, pt, pv: (pr[r * t_max + t],
                                                       pt[r * t_max + t])),
        ],
        out_specs=pl.BlockSpec((1, bi),
                               lambda r, t, pb, pr, pt, pv:
                               (pr[r * t_max + t], pt[r * t_max + t])),
        scratch_shapes=[pltpu.VMEM((bi,), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bi=bi, t_max=t_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={6: 0},   # table (after prefetch + ids/vals)
        interpret=interpret,
    )(plan.batch, plan.row, plan.tile, plan.valid, ids_s, vals_s, table)


# Kernel contract (DESIGN.md §10.1).  The (U, T_max) grid axes are
# plan-driven (neither cdiv nor exact division of an array axis);
# divisible=True records the I % bi == 0 precondition asserted above.
register(KernelContract(
    module="repro.kernels.sparse_row_scatter",
    entry="sparse_row_scatter",
    body="_kernel",
    grid_rank=2,
    scalar_prefetch=4,
    divisible=True,
    accumulators=("float32",),
    vmem_model=_avmem.sparse_row_scatter_block_bytes,
    max_shapes={"w": 4096, "bi": 512},
))
