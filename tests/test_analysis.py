"""The static-analysis pass (repro.analysis): corpus + clean-repo gate.

Two-sided validation of the linter itself (DESIGN.md §10.5): every
seeded-violation corpus case must be flagged with its expected rule id
(the analyzer finds what it claims to find), and the repo must lint
clean (the rules describe the code as it actually is).
"""
from __future__ import annotations

import ast
import json
from pathlib import Path

import pytest

from repro.analysis import astutil, corpus, linter
from repro.analysis.bench_schema import classify_summary_key
from repro.analysis.report import RULES, Finding, Report

ROOT = Path(__file__).resolve().parents[1]
CORPUS_DIR = ROOT / "tests" / "analysis_corpus"
MANIFEST = json.loads((CORPUS_DIR / "manifest.json").read_text())


@pytest.mark.parametrize("case", sorted(MANIFEST))
def test_corpus_case_flagged(case):
    result = corpus.run_case(CORPUS_DIR / case, MANIFEST[case])
    assert result.ok, str(result)


def test_corpus_rules_are_known():
    for case, spec in MANIFEST.items():
        for rule in spec["rules"]:
            assert rule in RULES, (case, rule)


def test_corpus_covers_every_family():
    seeded = {r for spec in MANIFEST.values() for r in spec["rules"]}
    assert {"KC01", "KC02", "KC03", "KC04", "KC05", "KC06", "KC07",
            "KC08", "OR01", "OR03", "EN01", "EN02", "EN03"} <= seeded
    assert len(MANIFEST) >= 10


def test_repo_lints_clean():
    report = linter.lint_repo(ROOT)
    assert report.ok, "\n".join(str(f) for f in report.findings)
    # the contract registry is populated and every registered kernel
    # module contributed at least one contract
    modules = {m for m, _ in linter.REGISTRY}
    assert modules == set(linter.KERNEL_MODULES)


def test_report_json_shape():
    f = Finding(rule="KC01", path="a.py", line=3, message="m")
    report = Report(findings=[f])
    doc = json.loads(report.to_json())
    assert doc["ok"] is False
    assert doc["counts"] == {"KC01": 1}
    assert doc["findings"][0]["description"] == RULES["KC01"]
    assert str(f) == "a.py:3: KC01 m"
    assert Report().ok


def test_bench_key_classifier():
    assert classify_summary_key("speedup_vs_ref") == "gated-ratio"
    assert classify_summary_key("pallas_compiled") == "gated-bound"
    assert classify_summary_key("p50_ms") == "parity"
    assert classify_summary_key("qps_mean") == "parity"
    assert classify_summary_key("shards") == "parity"
    assert classify_summary_key("frobnication_index") == "unknown"


def test_all_bench_keys_classify():
    # the repo's own BENCH files obey the convention end to end
    for name in ("BENCH_updates.json",):
        path = ROOT / name
        data = json.loads(path.read_text())
        for run in data.get("runs", []):
            for key in run.get("summary", {}):
                assert classify_summary_key(key) != "unknown", (name, key)


def test_cdiv_normalization_equates_spellings():
    a = ast.parse("def f(d, bd):\n    nt = pl.cdiv(d, bd)\n    return nt\n")
    b = ast.parse("def f(d, bd):\n    nt = -(-d // bd)\n    return nt\n")
    c = ast.parse("def f(d, bd):\n    nt = d // bd\n    return nt\n")
    dump = astutil.normalized_body_dump
    fa, fb, fc = (t.body[0] for t in (a, b, c))
    assert dump(fa) == dump(fb)
    assert dump(fa) != dump(fc)


def test_pallas_site_extraction_on_real_kernel():
    sf = astutil.load(ROOT / "src" / "repro" / "kernels" / "knn_topk.py")
    sites = {s.entry: s for s in astutil.find_pallas_sites(sf.tree)}
    assert set(sites) == {"knn_topk", "knn_topk_dtiled"}
    mono = sites["knn_topk"]
    assert len(mono.grid) == 2 and mono.grid_parsed
    assert mono.kernel_body == "_kernel"
    assert [s.arity for s in mono.in_specs] == [2, 2, 2, 2]
    assert mono.scratch_dtypes == ["float32", "int32"]
    dt = sites["knn_topk_dtiled"]
    assert len(dt.grid) == 3
    assert dt.scratch_dtypes == ["float32", "float32", "int32"]
