"""Corpus case: undeclared scalar prefetch (expected KC02).

The site uses PrefetchScalarGridSpec(num_scalar_prefetch=1) but its
contract declares scalar_prefetch=0, so every index-map arity the
contract implies is off by one.
"""
import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(plan_ref, x_ref, o_ref, acc_ref, *, m):
    tile = pl.program_id(1)
    vals = x_ref[...]
    vals = jnp.where(tile >= m, 0.0, vals)
    acc_ref[...] = vals
    o_ref[...] = acc_ref[...]


def thing(plan, x, n, m, bq=128, bm=256):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pl.cdiv(n, bq), pl.cdiv(m, bm)),
        in_specs=[
            pl.BlockSpec((bq, bm), lambda qi, mi, plan_ref: (qi, mi)),
        ],
        out_specs=pl.BlockSpec((bq, bm),
                               lambda qi, mi, plan_ref: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )
    kernel = functools.partial(_kernel, m=m)
    return pl.pallas_call(kernel, grid_spec=grid_spec)(plan, x)
