"""Deterministic fault injection for the streaming engine (DESIGN.md §9).

The paper's premise is that GDPR deletions *take effect*: a delete event
silently dropped on a crash, double-applied on redelivery, or
resurrected from a torn checkpoint is a compliance violation.  PRs 3–4
built the exactly-once log and the atomic per-shard LATEST/manifest
commits; this module is the harness that actually *exercises* those
guarantees under failure, deterministically and under seed control.

Mechanism: the store's commit/fsync/read sites call :func:`trip` with a
stable site name (the canonical list is :data:`CRASH_SITES` /
:data:`SHARD_CRASH_SITES` / :data:`READ_SITES`).  With no plan
installed, ``trip`` is a no-op costing one attribute read — production
code paths carry no fault logic.  Inside ``with inject(plan):`` the
active :class:`FaultPlan` decides, per trip, whether to raise

  * :class:`InjectedCrash` — simulates the process dying at that exact
    point.  Derives from ``BaseException`` (like ``KeyboardInterrupt``)
    so no ``except Exception``/``except OSError`` retry or cleanup
    handler can accidentally "survive" a crash; and

  * :class:`InjectedIOError` — a transient I/O failure (``OSError``
    subclass), which the store's bounded retry-with-backoff loop is
    expected to absorb.

File corruption (torn writes from dying disks, bit rot) cannot be
modeled as an exception at a site — it is injected *between* runs by
:func:`tear_file` / :func:`bitflip_file` on a committed file class, and
the recovery path must detect it via the checksums recorded in the
commit metadata (``state_store``) and fall back to the last good commit.

Event-stream faults (at-least-once redelivery, reordering, duplication)
are produced by :func:`redelivered`, seeded.

Typical chaos-soak schedule (tests/test_chaos_soak.py)::

    plan = FaultPlan(crash_site="LATEST.pre_replace")
    with inject(plan):
        try:
            engine.checkpoint(ckpt_dir, step)
        except InjectedCrash:
            pass                       # the "process" died here
    engine = rebuild()                 # fresh process
    engine.restore(ckpt_dir)           # must find a consistent commit
    engine.submit(redelivered(events, seed=7))   # at-least-once replay
    engine.run_until_drained()         # state must match fault-free run
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "CRASH_SITES", "SHARD_CRASH_SITES", "READ_SITES", "ASYNC_CRASH_SITES",
    "InjectedCrash", "InjectedIOError", "FaultPlan",
    "inject", "active_plan", "trip",
    "tear_file", "bitflip_file", "redelivered",
]


# Commit-path sites of one engine checkpoint, in temporal order.  A crash
# at each must leave a restorable directory (DESIGN.md §9 crash matrix).
CRASH_SITES = (
    "npz.pre_write",        # before the state npz tmp file is written
    "npz.pre_replace",      # npz durable in tmp, not yet renamed
    "npz.post_replace",     # npz committed, LATEST still old
    "LATEST.pre_replace",   # new LATEST durable in tmp, not yet renamed
    "LATEST.post_replace",  # commit complete
)

# Additional sites of a sharded checkpoint (the SHARDS manifest commit).
SHARD_CRASH_SITES = CRASH_SITES + (
    "SHARDS.pre_replace",
    "SHARDS.post_replace",
)

# Restore-path read sites (targets for transient I/O errors).
READ_SITES = ("LATEST.read", "npz.read")

# Background-writer sites of an async (snapshot-then-write) checkpoint:
# the worker thread trips "async.dequeue" just before it starts a
# dequeued commit job and "async.post_commit" right after the job's
# atomic LATEST replace.  A crash at either point dies on the *writer*
# thread — the engine keeps streaming and must observe the failure at
# the next flush/commit boundary (DESIGN.md §12).
ASYNC_CRASH_SITES = (
    "async.dequeue",
    "async.post_commit",
)


class InjectedCrash(BaseException):
    """A simulated process death at a named fault site.

    BaseException on purpose: retry loops and cleanup handlers that
    catch ``Exception``/``OSError`` must not be able to swallow a crash
    — a real SIGKILL would not be catchable either.
    """

    def __init__(self, site: str):
        super().__init__(f"injected crash at fault site {site!r}")
        self.site = site


class InjectedIOError(OSError):
    """A transient I/O failure at a named fault site (retryable)."""

    def __init__(self, site: str):
        super().__init__(f"injected transient I/O error at {site!r}")
        self.site = site


@dataclasses.dataclass
class FaultPlan:
    """One deterministic fault schedule.

    ``crash_site``: site name to crash at (None = never crash);
    ``crash_on_hit``: crash on the Nth trip of that site (1-based) — a
    multi-shard checkpoint trips each site once per shard, so this
    selects *which* shard's commit dies;
    ``io_errors``: site -> number of transient ``InjectedIOError`` to
    raise at that site before letting it succeed (exercises the bounded
    retry budget; counts are consumed in place).

    ``fired`` records every site tripped, in order — assertions can pin
    that a schedule actually reached its target site.
    """

    crash_site: Optional[str] = None
    crash_on_hit: int = 1
    io_errors: Dict[str, int] = dataclasses.field(default_factory=dict)
    fired: List[str] = dataclasses.field(default_factory=list)
    _crash_hits: int = dataclasses.field(default=0, repr=False)

    def on_trip(self, site: str) -> None:
        self.fired.append(site)
        if self.io_errors.get(site, 0) > 0:
            self.io_errors[site] -= 1
            raise InjectedIOError(site)
        if site == self.crash_site:
            self._crash_hits += 1
            if self._crash_hits >= self.crash_on_hit:
                raise InjectedCrash(site)


_ACTIVE: Optional[FaultPlan] = None


def active_plan() -> Optional[FaultPlan]:
    """The installed plan, or None (no fault injection)."""
    return _ACTIVE


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Install ``plan`` for the duration of the block (not reentrant)."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("fault plans do not nest")
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None


def trip(site: str) -> None:
    """Fault site hook: no-op unless a plan is installed via inject()."""
    if _ACTIVE is not None:
        _ACTIVE.on_trip(site)


# ---------------------------------------------------------------------------
# File corruption (injected between runs, detected by commit checksums)
# ---------------------------------------------------------------------------

def tear_file(path: str, keep_frac: float = 0.5) -> int:
    """Truncate ``path`` to a prefix — a torn write.  Returns new size.

    ``keep_frac=0`` models a created-but-empty file.  The checksums in
    the commit metadata must catch the tear on restore.
    """
    size = os.path.getsize(path)
    keep = int(size * keep_frac)
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep


def bitflip_file(path: str, seed: int = 0, n_bits: int = 1) -> list:
    """Flip ``n_bits`` seeded-random bits in ``path``; returns offsets.

    Models silent media corruption: the file stays the same size and
    (for json) may even stay parseable — only a checksum catches it.
    """
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if not data:
        return []
    rng = np.random.default_rng(seed)
    offsets = []
    for _ in range(n_bits):
        off = int(rng.integers(0, len(data)))
        data[off] ^= 1 << int(rng.integers(0, 8))
        offsets.append(off)
    with open(path, "wb") as f:
        f.write(bytes(data))
    return offsets


# ---------------------------------------------------------------------------
# Event-stream faults (at-least-once source behaviors)
# ---------------------------------------------------------------------------

def redelivered(events, seed: int = 0, dup_frac: float = 0.5,
                shuffle: bool = True) -> list:
    """A seeded at-least-once redelivery of ``events``.

    Samples ``dup_frac`` of the events (each keeps its original seqno —
    redeliveries carry the seqno of their first delivery) and optionally
    shuffles them: duplicates may arrive in any order, only FIRST
    deliveries are contractually in-order (DESIGN.md §7.2).
    """
    rng = np.random.default_rng(seed)
    events = list(events)
    mask = rng.random(len(events)) < dup_frac
    dups = [ev for ev, m in zip(events, mask) if m]
    if shuffle:
        rng.shuffle(dups)
    return dups
