"""Quickstart: TIFU-kNN next-basket recommendation with O(1) learning
and low-latency forgetting (the paper's full loop in ~60 lines).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import RefEngine, knn
from repro.core.tifu import user_vector_ragged
from repro.data import synthetic

# 1. a TaFeng-statistics synthetic dataset (no internet in this box)
ds = synthetic.generate("tafeng", scale=0.03, seed=0)
params = ds.params
train, test = ds.train_test_split()
users = sorted(train)
print(f"dataset: {len(users)} users, {params.n_items} items")

# 2. "train" = build user vectors incrementally, basket by basket (Eq. 7-9)
eng = RefEngine(params)
t0 = time.perf_counter()
for u in users:
    for basket in train[u]:
        eng.add_basket(u, basket)
print(f"built {sum(len(train[u]) for u in users)} baskets in "
      f"{time.perf_counter()-t0:.2f}s (O(1) per basket)")

# 3. recommend: personal component + k nearest neighbours
corpus = jnp.asarray(eng.user_matrix(users), jnp.float32)
pred = knn.predict(corpus, corpus, k=params.k_neighbors,
                   alpha=params.alpha, exclude_self=True)
recs = np.asarray(knn.recommend_topn(pred, 10))
truth = [test[u] for u in users]
print(f"Recall@10 = {knn.recall_at_k(recs, truth, 10):.4f}   "
      f"NDCG@10 = {knn.ndcg_at_k(recs, truth, 10):.4f}")

# 4. a user exercises the right to be forgotten: delete their 1st basket
victim = users[0]
t0 = time.perf_counter_ns()
eng.delete_basket(victim, 0)
dt_us = (time.perf_counter_ns() - t0) / 1e3
print(f"deleted basket 0 of user {victim} in {dt_us:.0f} µs (Eq. 10-12)")

# 5. verify: identical to retraining from scratch on the surviving data
st = eng.state(victim)
scratch = user_vector_ragged(st.history, st.group_sizes, params)
err = np.max(np.abs(st.user_vec - scratch))
print(f"max |maintained − retrained| = {err:.2e}  (same model, "
      f"{dt_us:.0f} µs instead of a full retrain)")

# 6. and forget a single item from a basket (Eq. 13)
item = int(eng.state(victim).history[0][0])
eng.delete_item(victim, 0, item)
print(f"forgot item {item} from user {victim}'s basket 0 — done.")
