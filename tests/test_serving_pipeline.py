"""Fused Pallas serving pipeline (DESIGN.md §8).

Three layers of pins:

  * kernel edge cases — masked tail blocks (prime Q/M), empty corpus,
    k > M, and duplicate-score TIE-BREAK PARITY with `jax.lax.top_k`
    for both the Pallas kernel and the pure-JAX streaming schedule;
  * dispatch parity — the ops CPU path is bitwise the historical
    (pre-fusion) serving output, and the interpret-mode Pallas pipeline
    matches it exactly, for the single-corpus, per-shard-candidate and
    cross-shard blend stages;
  * the engine-side request batcher — pow2 bucketing returns the
    unpadded answers and bounds the compiled-shape count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knn
from repro.kernels import ops, ref
from repro.kernels.knn_topk import knn_topk
from repro.kernels.serving_topn import blend_topn_onehot, blend_topn_rows


# ---------------------------------------------------------------------------
# streaming_topk (pure-JAX schedule) edge cases
# ---------------------------------------------------------------------------

def test_streaming_topk_empty_corpus(rng):
    q = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    vals, idx = knn.streaming_topk(q, jnp.zeros((0, 8), jnp.float32), k=3)
    assert vals.shape == (5, 3) and idx.shape == (5, 3)
    assert np.all(np.asarray(vals) == -np.inf)


def test_streaming_topk_k_exceeds_m(rng):
    q = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(9, 8)), jnp.float32)
    vals, idx = knn.streaming_topk(q, c, k=16, chunk=4)
    rv, ri = knn.nearest_neighbors(q, c, k=9)
    np.testing.assert_allclose(np.asarray(vals)[:, :9], np.asarray(rv),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx)[:, :9], np.asarray(ri))
    assert np.all(np.asarray(vals)[:, 9:] == -np.inf)


def test_streaming_topk_duplicate_score_tiebreak(rng):
    """Duplicate corpus rows ⇒ exact-score ties; the streaming merge
    must pick the same (lowest) indices lax.top_k picks."""
    q = jnp.asarray(rng.normal(size=(7, 12)), jnp.float32)
    c0 = jnp.asarray(rng.normal(size=(20, 12)), jnp.float32)
    c = jnp.concatenate([c0, c0, c0], axis=0)            # every score x3
    vals, idx = knn.streaming_topk(q, c, k=11, chunk=16)
    rv, ri = knn.nearest_neighbors(q, c, k=11)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-5)


# ---------------------------------------------------------------------------
# knn_topk kernel edge cases (interpret mode)
# ---------------------------------------------------------------------------

def test_knn_topk_masked_tails_prime_dims(rng):
    """Q and M prime: neither divides its block — the removed
    divisibility assert is covered by in-kernel tail masks."""
    q = jnp.asarray(rng.normal(size=(37, 24)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(641, 24)), jnp.float32)
    v, i = knn_topk(q, c, k=7, bq=16, bm=128, interpret=True)
    rv, ri = ref.knn_topk_ref(q, c, 7)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), atol=1e-3,
                               rtol=1e-4)
    assert np.all(np.asarray(i) < 641)       # tail columns never selected
    for a, b in zip(np.asarray(i), np.asarray(ri)):
        assert set(map(int, a)) == set(map(int, b))


def test_knn_topk_empty_shapes():
    v, i = knn_topk(jnp.zeros((4, 8)), jnp.zeros((0, 8)), k=3,
                    interpret=True)
    assert v.shape == (4, 3) and np.all(np.asarray(v) == -np.inf)
    v, i = knn_topk(jnp.zeros((0, 8)), jnp.zeros((5, 8)), k=3,
                    interpret=True)
    assert v.shape == (0, 3) and i.shape == (0, 3)


def test_knn_topk_k_exceeds_m(rng):
    q = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(9, 8)), jnp.float32)
    v, i = knn_topk(q, c, k=16, bq=8, bm=8, interpret=True)
    rv, ri = ref.knn_topk_ref(q, c, 9)
    np.testing.assert_allclose(np.asarray(v)[:, :9], np.asarray(rv),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(i)[:, :9], np.asarray(ri))
    assert np.all(np.asarray(v)[:, 9:] == -np.inf)


def test_knn_topk_duplicate_score_tiebreak(rng):
    q = jnp.asarray(rng.normal(size=(8, 12)), jnp.float32)
    c0 = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)
    c = jnp.concatenate([c0, c0, c0], axis=0)
    v, i = knn_topk(q, c, k=10, bq=8, bm=32, interpret=True)
    rv, ri = ref.knn_topk_ref(q, c, 10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_knn_topk_fused_self_exclusion(rng):
    """query_gids masking == the reference .at[r, id].set(-inf) path."""
    c = jnp.asarray(rng.normal(size=(63, 16)), jnp.float32)
    qids = jnp.asarray(rng.choice(63, 21, replace=False).astype(np.int32))
    v, i = knn_topk(c[qids], c, k=5, bq=8, bm=16, interpret=True,
                    query_gids=qids)
    rv, ri = knn.nearest_neighbors(c[qids], c, k=5, exclude_self=True,
                                   query_ids=qids)
    assert not np.any(np.asarray(i) == np.asarray(qids)[:, None])
    for a, b in zip(np.asarray(i), np.asarray(ri)):
        assert set(map(int, a)) == set(map(int, b))


def test_knn_topk_shard_gid_exclusion(rng):
    """col_offset/col_stride global ids: a query is excluded only on the
    shard owning its global id (DESIGN.md §7.1 round-robin layout)."""
    n_shards, m = 3, 60
    corpus = jnp.asarray(rng.normal(size=(m, 16)), jnp.float32)
    qids = jnp.asarray(np.arange(12, dtype=np.int32))
    queries = corpus[qids]
    for shard in range(n_shards):
        local = corpus[shard::n_shards]
        v, i = knn_topk(queries, local, k=4, bq=8, bm=8, interpret=True,
                        query_gids=qids, col_offset=shard,
                        col_stride=n_shards)
        gids = np.asarray(i) * n_shards + shard
        assert not np.any(gids == np.asarray(qids)[:, None])


# ---------------------------------------------------------------------------
# blend/top-n kernels vs the ref oracles (interpret mode)
# ---------------------------------------------------------------------------

def test_blend_topn_onehot_matches_gather_path(rng):
    m, n_items, q_n, k = 101, 67, 13, 5       # all prime-ish tails
    corpus = jnp.asarray(rng.normal(size=(m, n_items)), jnp.float32)
    uids = jnp.asarray(rng.choice(m, q_n, replace=False).astype(np.int32))
    idx = jnp.asarray(rng.integers(0, m, (q_n, k)), jnp.int32)
    v, i = blend_topn_onehot(corpus, uids, idx, alpha=0.7, topn=6,
                             bq=8, bm=32, bi=32, kc=2, interpret=True)
    pred = (0.7 * corpus[uids]
            + 0.3 * jnp.mean(corpus[idx], axis=1))
    rv, ri = jax.lax.top_k(pred, 6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), atol=1e-4)


def test_blend_topn_onehot_duplicate_item_tiebreak(rng):
    """Identical item columns ⇒ exact prediction ties; the running
    merge must keep lax.top_k's lowest-item-id order."""
    m, q_n, k = 64, 9, 4
    base = jnp.asarray(rng.normal(size=(m, 8)), jnp.float32)
    corpus = jnp.tile(base, (1, 4))           # items repeat every 8
    uids = jnp.asarray(rng.choice(m, q_n, replace=False).astype(np.int32))
    idx = jnp.asarray(rng.integers(0, m, (q_n, k)), jnp.int32)
    v, i = blend_topn_onehot(corpus, uids, idx, alpha=0.7, topn=10,
                             bq=4, bm=16, bi=8, kc=2, interpret=True)
    pred = 0.7 * corpus[uids] + 0.3 * jnp.mean(corpus[idx], axis=1)
    np.testing.assert_array_equal(np.asarray(i),
                                  np.asarray(jax.lax.top_k(pred, 10)[1]))


def test_blend_topn_rows_matches_ref(rng):
    q_n, k, n_items = 13, 5, 67
    queries = jnp.asarray(rng.normal(size=(q_n, n_items)), jnp.float32)
    nbr = jnp.asarray(rng.normal(size=(q_n, k, n_items)), jnp.float32)
    v, i = blend_topn_rows(queries, nbr, alpha=0.3, topn=7, bq=4, bi=16,
                           interpret=True)
    ri = ref.blend_topn_rows_ref(queries, nbr, 0.3, 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


# ---------------------------------------------------------------------------
# ops dispatch parity: cpu == historical output == interpret Pallas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n_items,q_n,k,topn", [
    (101, 67, 23, 7, 9),       # prime tails everywhere
    (128, 64, 32, 8, 10),      # block-aligned
    (33, 41, 33, 5, 41),       # every user queries; topn == n_items
])
def test_fused_recommend_cpu_is_bitwise_historical(rng, m, n_items, q_n,
                                                   k, topn):
    corpus = jnp.asarray(rng.normal(size=(m, n_items)), jnp.float32)
    uids = jnp.asarray(rng.choice(m, q_n, replace=False).astype(np.int32))
    # the pre-fusion recommend_for_users body, verbatim
    queries = corpus[uids]
    pred = knn.predict(queries, corpus, k=k, alpha=0.7,
                       exclude_self=True, query_ids=uids)
    want = np.asarray(knn.recommend_topn(pred, topn))
    got = np.asarray(knn.recommend_for_users(corpus, uids, k=k, alpha=0.7,
                                             topn=topn))
    np.testing.assert_array_equal(got, want)
    with ops.default_impl("interpret"):
        got_i = np.asarray(knn.recommend_for_users(corpus, uids, k=k,
                                                   alpha=0.7, topn=topn))
    np.testing.assert_array_equal(got_i, want)


def test_fused_recommend_oracle_matches_predict_ulp(rng):
    """The ref.py oracle's prediction == core.knn.predict bitwise (the
    ISSUE's ≤1-ulp validation of the oracle against the predict path —
    both run the identical jnp program)."""
    corpus = jnp.asarray(rng.normal(size=(53, 29)), jnp.float32)
    uids = jnp.asarray(rng.choice(53, 11, replace=False).astype(np.int32))
    scores_core = knn.pairwise_scores(corpus[uids], corpus, "euclidean")
    scores_ref = ref._pairwise_scores(corpus[uids], corpus, "euclidean")
    np.testing.assert_array_equal(np.asarray(scores_core),
                                  np.asarray(scores_ref))
    got = np.asarray(ref.fused_recommend_ref(corpus, uids, 6, 0.7, 8))
    pred = knn.predict(corpus[uids], corpus, k=6, alpha=0.7,
                       exclude_self=True, query_ids=uids)
    np.testing.assert_array_equal(got,
                                  np.asarray(knn.recommend_topn(pred, 8)))


def test_fused_recommend_alpha_extremes(rng):
    corpus = jnp.asarray(rng.normal(size=(40, 24)), jnp.float32)
    uids = jnp.asarray(np.arange(10, dtype=np.int32))
    for alpha in (0.0, 1.0):
        want = np.asarray(knn.recommend_for_users(corpus, uids, k=4,
                                                  alpha=alpha, topn=5))
        with ops.default_impl("interpret"):
            got = np.asarray(knn.recommend_for_users(corpus, uids, k=4,
                                                     alpha=alpha, topn=5))
        np.testing.assert_array_equal(got, want, err_msg=f"alpha={alpha}")


def test_fused_recommend_empty_and_invalid():
    corpus = jnp.zeros((6, 12), jnp.float32)
    out = ops.fused_recommend(corpus, jnp.zeros((0,), jnp.int32), k=3,
                              alpha=0.7, topn=4)
    assert out.shape == (0, 4)
    out = ops.fused_recommend(jnp.zeros((0, 12), jnp.float32),
                              jnp.zeros((0,), jnp.int32), k=3, alpha=0.7,
                              topn=4)
    assert out.shape == (0, 4)
    with pytest.raises(ValueError, match="topn"):
        ops.fused_recommend(corpus, jnp.zeros((2,), jnp.int32), k=3,
                            alpha=0.7, topn=13)


def test_fused_recommend_k_clamped_below_m(rng):
    """k >= M must serve (clamped to M−1: self-exclusion leaves M−1
    finite candidates, and a −inf slot would resolve differently in the
    kernel vs the reference), not crash like the pre-fusion path —
    and the interpret path must still match the cpu path exactly."""
    corpus = jnp.asarray(rng.normal(size=(9, 16)), jnp.float32)
    uids = jnp.asarray(np.arange(4, dtype=np.int32))
    want = np.asarray(knn.recommend_for_users(corpus, uids, k=8,
                                              alpha=0.7, topn=5))
    for k in (9, 100):
        got = np.asarray(knn.recommend_for_users(corpus, uids, k=k,
                                                 alpha=0.7, topn=5))
        np.testing.assert_array_equal(got, want)
        with ops.default_impl("interpret"):
            got_i = np.asarray(knn.recommend_for_users(
                corpus, uids, k=k, alpha=0.7, topn=5))
        np.testing.assert_array_equal(got_i, want, err_msg=f"k={k}")


def test_shard_topk_k_exceeds_shard_interpret_matches_cpu(rng):
    """k >= m_s on the owner shard admits the excluded self column as a
    −inf candidate; its global id must resolve to the self gid in both
    impls (the cross-shard merge compares the (score, gid) lists)."""
    n_shards, m = 2, 8
    corpus = np.asarray(rng.normal(size=(m, 12)), np.float32)
    qids = jnp.asarray(np.arange(6, dtype=np.int32))
    queries = jnp.asarray(corpus[:6])
    for shard in range(n_shards):
        local = jnp.asarray(corpus[shard::n_shards])   # m_s = 4 <= k
        want_v, want_g = ops.shard_topk(queries, local, k=4, shard=shard,
                                        n_shards=n_shards,
                                        query_gids=qids, impl="ref")
        with ops.default_impl("interpret"):
            got_v, got_g = ops.shard_topk(queries, local, k=4,
                                          shard=shard, n_shards=n_shards,
                                          query_gids=qids)
        np.testing.assert_array_equal(np.asarray(got_g),
                                      np.asarray(want_g))
        np.testing.assert_allclose(np.asarray(got_v),
                                   np.asarray(want_v), atol=1e-3,
                                   rtol=1e-4)


def test_shard_topk_interpret_matches_cpu(rng):
    n_shards, m = 3, 61                        # ragged shard sizes
    corpus = np.asarray(rng.normal(size=(m, 24)), np.float32)
    qids = jnp.asarray(rng.choice(m, 14, replace=False).astype(np.int32))
    queries = jnp.asarray(corpus[np.asarray(qids)])
    for shard in range(n_shards):
        local = jnp.asarray(corpus[shard::n_shards])
        want_v, want_g = ops.shard_topk(queries, local, k=6, shard=shard,
                                        n_shards=n_shards,
                                        query_gids=qids, impl="ref")
        with ops.default_impl("interpret"):
            got_v, got_g = ops.shard_topk(queries, local, k=6,
                                          shard=shard, n_shards=n_shards,
                                          query_gids=qids)
        np.testing.assert_array_equal(np.asarray(got_g),
                                      np.asarray(want_g))
        np.testing.assert_allclose(np.asarray(got_v),
                                   np.asarray(want_v), atol=1e-3,
                                   rtol=1e-4)


def test_sharded_recommend_interpret_matches_cpu(rng):
    from repro.parallel.sharding import UserShardSpec
    m, n_items = 23, 37
    corpus = rng.normal(size=(m, n_items)).astype(np.float32)
    users = rng.choice(m, 9, replace=False)
    want = np.asarray(knn.recommend_for_users(
        jnp.asarray(corpus), jnp.asarray(users.astype(np.int32)), k=7,
        alpha=0.7, topn=6))
    for n_shards in (2, 3):
        spec = UserShardSpec(m, n_shards)
        corpora = [jnp.asarray(corpus[spec.owned_users(s)])
                   for s in range(n_shards)]
        with ops.default_impl("interpret"):
            got = knn.sharded_recommend_for_users(
                corpora, users, k=7, alpha=0.7, topn=6,
                n_shards=n_shards)
        np.testing.assert_array_equal(got, want, err_msg=f"S={n_shards}")


# ---------------------------------------------------------------------------
# Engine-side request batcher
# ---------------------------------------------------------------------------

def test_engine_recommend_pads_to_pow2_buckets(rng):
    from repro.core import TifuParams
    from repro.streaming import StateStore, StoreConfig, StreamingEngine
    p = TifuParams(n_items=41, group_size=3, k_neighbors=4, alpha=0.7)
    store = StateStore(StoreConfig(n_users=16, n_items=41, max_baskets=8,
                                   max_basket_size=6))
    eng = StreamingEngine(store, p, batch_size=16)
    for u in range(16):
        eng.add_basket(u, rng.choice(41, size=3, replace=False))
    eng.run_until_drained()
    corpus = store.corpus()
    sizes = [1, 3, 5, 6, 7, 9, 13, 16]
    for q_n in sizes:
        users = rng.choice(16, size=q_n, replace=False)
        got = eng.recommend(users, topn=5)
        assert got.shape == (q_n, 5)
        want = np.asarray(knn.recommend_for_users(
            corpus, jnp.asarray(users.astype(np.int32)), k=4, alpha=0.7,
            topn=5))
        np.testing.assert_array_equal(got, want)
    # 8 distinct request sizes, but only pow2 buckets {1,4,8,16} compile
    assert eng.metrics.serve_requests == len(sizes)
    assert eng.metrics.serve_compiled_shapes == 4
    assert eng.recommend(np.zeros((0,), np.int64)).shape == (0, 10)
