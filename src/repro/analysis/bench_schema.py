"""The BENCH summary-key naming convention (rule EN03, DESIGN.md §10.4).

Every ``summary`` key a benchmark records in ``BENCH_updates.json``
must classify as one of:

* ``gated-ratio`` — contains ``speedup``: a relative-performance claim
  the trend gate (benchmarks/bench_trend.py) enforces with a tolerance
  ratio above its floor (interpret-backend runs never enforced).
* ``gated-bound`` — contains ``compiled``: a compiled-program count the
  trend gate enforces as a hard upper bound (bucketing regressions).
* ``gated-slo`` — contains ``slo``: a normalized service-level
  fraction (measured / objective) the trend gate enforces as a hard
  ``<= 1.0`` bound — the SLO itself is the contract, not the committed
  baseline value (interpret-backend runs never enforced).
* ``parity`` — an informational fact the trend report prints but does
  not gate: latency/recovery percentiles and means (``_ms``), growth
  ratios, throughput (``qps``/``per_s``), capacity/extent markers
  (``max_``, ``vmem``, ``hbm``), agreement metrics (``parity``,
  ``overlap``), sweep descriptors (``swept``, ``grid``, ``shards``),
  robustness counters (``dead_letters``, ``rejections``) and the
  compliance arm's drift/certification facts (``drift``,
  ``certified``).

Anything else is ``unknown`` — EN03 in the linter, and a hard failure
in ``bench_trend.py`` (a silently-ignored key is how a renamed speedup
metric escapes the regression gate).
"""
from __future__ import annotations

# Substrings that mark a key as an ungated informational (parity) fact.
PARITY_MARKERS = (
    "parity", "growth", "qps", "per_s", "overlap", "hbm", "vmem",
    "swept", "grid", "dead_letters", "rejections", "max_", "_ms",
    "drift", "certified",
)

# Keys that are parity facts by exact name (no marker substring).
PARITY_EXACT = frozenset({"shards"})


def classify_summary_key(key: str) -> str:
    """Classify ``key`` under the EN03 naming convention.

    Returns one of 'gated-ratio' | 'gated-bound' | 'gated-slo' |
    'parity' | 'unknown'.
    """
    if "speedup" in key:
        return "gated-ratio"
    if "compiled" in key:
        return "gated-bound"
    if "slo" in key:
        return "gated-slo"
    if key in PARITY_EXACT or any(m in key for m in PARITY_MARKERS):
        return "parity"
    return "unknown"
