"""Background snapshot-then-write checkpoint executor (DESIGN.md §12).

The synchronous commit path (`StateStore.checkpoint`) serializes and
fsyncs a full state snapshot *inside* the streaming hot path, so every
checkpointed step pays disk latency in its p99.  This module moves the
write off the hot path while keeping the §9 crash matrix intact, using
the snapshot-then-write split pioneered by levanter-style trainers:

1. **Snapshot (caller thread, cheap)** — the commit point copies the
   state leaves to host memory *now* (`StateStore._snapshot_leaves`).
   The copy is mandatory, not an optimization: the engine's appliers
   donate their input buffers, so a zero-copy view handed to a
   background thread would be read-after-free one micro-batch later.
2. **Write (worker thread, slow)** — the snapshot plus the existing
   atomic protocol (`write_npz` → retain-previous → `atomic_write_json`
   LATEST) runs as an opaque job on a single FIFO worker.  The atomic
   LATEST replace *is* the commit callback, so a restore can never
   observe a half-written commit — it lands on the last LATEST whose
   replace completed, exactly as in the synchronous path.

Failure semantics are deliberately process-like.  A job that raises —
including :class:`repro.streaming.faults.InjectedCrash`, which is a
``BaseException`` precisely so cleanup handlers cannot swallow it — is
recorded as the checkpointer's terminal error; every job queued behind
it is **discarded, never half-run** (a crashed writer commits nothing
further), and the error surfaces on the caller thread at the next
:meth:`AsyncCheckpointer.flush` / :meth:`AsyncCheckpointer.submit`.
Because jobs run in submission order on one worker, a sharded commit
(N shard jobs, then the SHARDS manifest job) preserves the §7.4
invariant that the manifest commits last.

Fault sites: the worker trips ``"async.dequeue"`` before starting a
job and ``"async.post_commit"`` after it returns — the
:data:`repro.streaming.faults.ASYNC_CRASH_SITES` pair that the chaos
soak uses to kill the writer mid-flight.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, List, Optional, Tuple

from repro.streaming import faults

__all__ = ["AsyncCheckpointer"]

# A commit job: fully self-contained closure ending in an atomic
# LATEST replace.  Paired with a label for error reporting.
_Job = Tuple[Callable[[], None], str]


class AsyncCheckpointer:
    """Single-threaded FIFO executor for snapshot-then-write commits.

    One daemon worker thread drains a FIFO queue of commit jobs;
    submission order is completion order.  The first raising job
    becomes the terminal ``error``: later queued jobs are discarded
    deterministically and both :meth:`submit` and :meth:`flush`
    re-raise it, so a caller cannot keep streaming past a dead writer
    without noticing.  Instances are cheap; a "restarted process"
    (chaos-soak rebuild) simply constructs a fresh one.
    """

    def __init__(self, name: str = "ckpt-writer") -> None:
        self._name = name
        self._queue: "queue.Queue[Optional[_Job]]" = queue.Queue()
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._error_label: Optional[str] = None
        self._completed: List[str] = []
        self._pending = 0
        self._closed = False

    # -- caller-thread API -------------------------------------------------

    def submit(self, job: Callable[[], None], label: str = "commit") -> None:
        """Enqueue ``job`` for the background writer (FIFO).

        Raises the recorded terminal error instead of enqueueing if a
        previous job already died — the failure is surfaced at the
        next commit attempt, never silently dropped.
        """
        self.raise_if_failed()
        with self._lock:
            if self._closed:
                raise RuntimeError(f"{self._name}: submit after close()")
            self._pending += 1
            self._ensure_worker()
        self._queue.put((job, label))

    def flush(self) -> None:
        """Block until every submitted job committed or was discarded.

        Re-raises the first job error (including injected crashes) on
        the caller thread.  This is the synchronization point restore
        and shutdown paths must cross before trusting LATEST.
        """
        if self._worker is not None:
            self._queue.join()
        self.raise_if_failed()

    def close(self) -> None:
        """Flush-less shutdown: stop the worker after the queued jobs.

        Does not raise on a recorded error (mirrors process exit); use
        :meth:`flush` first when the caller needs the error surfaced.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            worker = self._worker
        if worker is not None:
            self._queue.put(None)
            worker.join()

    def raise_if_failed(self) -> None:
        """Re-raise the terminal error recorded by the worker, if any."""
        with self._lock:
            err = self._error
        if err is not None:
            raise err

    @property
    def pending(self) -> int:
        """Jobs submitted but not yet committed or discarded."""
        with self._lock:
            return self._pending

    @property
    def error(self) -> Optional[BaseException]:
        """The terminal error recorded by the worker, or None."""
        with self._lock:
            return self._error

    @property
    def completed_labels(self) -> Tuple[str, ...]:
        """Labels of jobs that committed successfully, in order."""
        with self._lock:
            return tuple(self._completed)

    # -- worker thread -----------------------------------------------------

    def _ensure_worker(self) -> None:
        # Lazily started under self._lock so exactly one worker exists.
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._run, name=self._name, daemon=True
            )
            self._worker.start()

    def _run(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                self._queue.task_done()
                return
            job, label = item
            try:
                if self._error is None:
                    # A crashed writer commits nothing further: once an
                    # error is recorded, queued jobs are discarded whole
                    # (never half-run) so the on-disk state stays at the
                    # last completed atomic replace.
                    faults.trip("async.dequeue")
                    job()
                    faults.trip("async.post_commit")
                    with self._lock:
                        self._completed.append(label)
            except BaseException as err:  # noqa: BLE001 - InjectedCrash
                with self._lock:
                    if self._error is None:
                        self._error = err
                        self._error_label = label
            finally:
                with self._lock:
                    self._pending -= 1
                self._queue.task_done()
