"""Micro-batch streaming engine — the Spark Structured Streaming analog.

Implements Algorithm 1 of the paper (joint incremental/decremental state
updates) as a batched SPMD program:

  * incoming events (basket additions, basket/item deletion requests)
    are buffered in per-user pending queues and cut into micro-batches
    of at most one event per user (conflicting events for the same user
    wait for the next batch — this preserves per-user sequential
    semantics while letting independent users update in parallel,
    exactly the paper's user-level parallelism);

  * each micro-batch is **partitioned by event kind** into homogeneous
    ``AddBatch`` / ``DelBasketBatch`` / ``DelItemBatch`` sub-batches
    (DESIGN.md §4), so each compiled program runs exactly one update
    rule — the add path applies sparse deltas (O(basket) state traffic),
    the decremental paths pay their paper-given linear cost;

  * an idempotent update log (sequence numbers + processed watermark)
    makes recovery exactly-once: after restoring a checkpoint, events
    with seqno <= watermark are skipped on replay;

  * users whose numerical-error bound crossed the stability threshold
    are refreshed from scratch after the batch (core.stability), and
    users whose representation scale approaches SCALE_FLOOR are
    renormalized in place (core.updates.renormalize_users).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import json
import os
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn, stability
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, PAD_ID, AddBatch,
                              DelBasketBatch, DelItemBatch, StreamState,
                              TifuParams, _pow2_pad)
from repro.core.updates import (SCALE_CEIL, SCALE_FLOOR,
                                apply_add_batch_counted,
                                apply_del_basket_batch, apply_del_item_batch,
                                refresh_users, renormalize_users)
from repro.kernels import tile_plan
from repro.parallel.sharding import UserShardSpec
from repro.streaming.async_checkpoint import AsyncCheckpointer
from repro.streaming.state_store import (CorruptCheckpointError, StateStore,
                                         StoreConfig, atomic_write_json,
                                         load_checkpoint_arrays,
                                         load_json_checked)


# -- device-side step-summary programs (DESIGN.md §12) ----------------------
#
# Everything the host decides per micro-batch — maintenance triggers,
# poison checks, tile-plan bounds — is computed on device by these small
# programs and fetched together in ONE transfer per step (`_fetch`), so
# the hot path never round-trips whole state leaves.

@jax.jit
def _maintenance_probe(err_mult, uv_scale, lgv_scale):
    """Fused maintenance reduction: (err_max, scale_min, scale_max).

    One pass over the three O(n_users) maintenance leaves; the scalars
    ride the step's single transfer, replacing the full ``err_mult``
    fetch every batch and the separate min/max scale probe.
    """
    return (err_mult.max(),
            jnp.minimum(uv_scale.min(), lgv_scale.min()),
            jnp.maximum(uv_scale.max(), lgv_scale.max()))


@functools.partial(jax.jit, static_argnames="bi")
def _add_tile_bound(history, group_sizes, n_baskets, n_groups, idx,
                    new_ids, valid, *, bi: int):
    """Device touched-tile bound for an add sub-batch's support rows."""
    return tile_plan.add_support_tile_bound(
        history[idx], group_sizes[idx], n_baskets[idx], n_groups[idx],
        new_ids, valid, bi=bi)


@functools.partial(jax.jit, static_argnames="bi")
def _hist_tile_bound(history, n_baskets, idx, extra, valid, *, bi: int):
    """Device touched-tile bound for a delete sub-batch's history rows."""
    return tile_plan.history_support_tile_bound(
        history[idx], n_baskets[idx], extra, valid, bi=bi)


class InvalidEventError(ValueError):
    """A malformed event was rejected eagerly at submit time.

    Carries the offending event and a human-readable reason — raised
    instead of failing deep inside ``_apply_events`` with a shape or
    index error far from the cause (DESIGN.md §9).  ``submit(...,
    on_invalid="quarantine")`` routes these to the dead-letter queue
    instead of raising.
    """

    def __init__(self, event, reason: str):
        super().__init__(f"invalid event {event!r}: {reason}")
        self.event = event
        self.reason = reason


class Backpressure(RuntimeError):
    """Submit crossed the pending-queue high-water mark.

    The engine admitted a PREFIX of the call's events (``admitted``) and
    rejected the rest (``rejected``); rejected events were never
    assigned seqnos and count as **not delivered** — a contract-abiding
    at-least-once source resends from ``first_rejected_seqno`` (or the
    first rejected payload) once the queues drain.  Admitted events stay
    admitted.
    """

    def __init__(self, admitted: int, rejected: int,
                 first_rejected_seqno: Optional[int] = None,
                 pending: int = 0):
        super().__init__(
            f"pending queues at high-water mark ({pending} buffered): "
            f"admitted {admitted}, rejected {rejected} event(s)"
            + (f" from seqno {first_rejected_seqno}"
               if first_rejected_seqno is not None else ""))
        self.admitted = admitted
        self.rejected = rejected
        self.first_rejected_seqno = first_rejected_seqno
        self.pending = pending


@dataclasses.dataclass
class AdmissionResult:
    """What one ``submit`` call did with its events (DESIGN.md §9).

    ``admitted`` entered the pending queues; ``deduped`` were
    at-least-once redeliveries skipped by the exactly-once log;
    ``quarantined`` were malformed and moved to the dead-letter queue;
    ``rejected`` were shed by backpressure (never delivered — resend
    them).  ``first_rejected_seqno`` is the resume point for an
    explicit-seqno source.
    """

    admitted: int = 0
    deduped: int = 0
    quarantined: int = 0
    rejected: int = 0
    first_rejected_seqno: Optional[int] = None

    def merge(self, other: "AdmissionResult") -> "AdmissionResult":
        """Fold another result in (sharded router aggregation)."""
        self.admitted += other.admitted
        self.deduped += other.deduped
        self.quarantined += other.quarantined
        self.rejected += other.rejected
        if other.first_rejected_seqno is not None and (
                self.first_rejected_seqno is None
                or other.first_rejected_seqno < self.first_rejected_seqno):
            self.first_rejected_seqno = other.first_rejected_seqno
        return self


def _pad_request(user_ids) -> tuple:
    """Pad a serving request to its pow2 bucket (DESIGN.md §8.3).

    Returns ``(padded_ids i64[bucket], q_n, bucket)``; padding repeats
    the first user id (computed and sliced off by the caller).  Shared
    by the single-engine and sharded request batchers so the bucketing
    contract cannot drift between them.
    """
    ids = np.asarray(user_ids, np.int64).ravel()
    q_n = ids.size
    if q_n == 0:
        return ids, 0, 0
    bucket = _pow2_pad(q_n)
    if bucket > q_n:
        ids = np.concatenate([ids, np.full(bucket - q_n, ids[0],
                                           ids.dtype)])
    return ids, q_n, bucket


@dataclasses.dataclass(frozen=True)
class Event:
    """One streaming event. ``seqno`` is assigned by the engine."""
    kind: int
    user: int
    items: Optional[np.ndarray] = None   # for adds
    pos: int = 0                         # for deletes
    item: int = PAD_ID                   # for item deletes
    seqno: int = -1


@dataclasses.dataclass(frozen=True)
class ForgetReceipt:
    """Receipt of one ``forget_user`` call (the GDPR front door).

    ``seqnos`` are the deletion events emitted on the user's behalf (the
    audit trail tying the forget to the exactly-once log),
    ``purged_dead_letters`` the quarantined events of theirs that were
    dropped, and ``residue`` the post-scrub :meth:`StateStore.row_residue`
    measurement — ``clean`` is True iff every artifact reads zero.  The
    receipt is the per-call half of the compliance story; the full
    certificate is ``repro.compliance.certify`` over the event log.
    """

    user: int
    n_baskets_deleted: int
    seqnos: tuple
    purged_dead_letters: int
    latency_s: float
    residue: dict

    @property
    def clean(self) -> bool:
        """True iff no live artifact still holds the user's data."""
        return all(v == 0.0 for v in self.residue.values())


@dataclasses.dataclass
class EngineMetrics:
    """Counters one engine accumulates over its lifetime.

    Observability only — never read back by the update logic.
    """

    events_processed: int = 0
    batches: int = 0
    refreshes: int = 0
    renormalizations: int = 0
    # adds masked to no-ops by apply_add_batch's capacity guard
    dropped_adds: int = 0
    # explicit-seqno submissions skipped by the exactly-once dedup.
    # Under the documented contract these are redeliveries; a number
    # far above the source's redelivery rate means the contract is
    # being violated (out-of-order FIRST deliveries are
    # indistinguishable from duplicates and are dropped — watch this).
    dedup_skips: int = 0
    # pow2 sub-batch bucket transitions (each is a fresh compile unless
    # that bucket was seen before); shrinks are hysteresis-gated
    bucket_grows: int = 0
    bucket_shrinks: int = 0
    last_batch_seconds: float = 0.0
    # serving request batches answered via `recommend`, and the number
    # of distinct (pow2 query bucket, topn, k, metric) shapes they
    # compiled — bounded at O(log max_batch) per parameter set by the
    # request bucketing (DESIGN.md §8); a count tracking the raw
    # request-size spread means the bucketing regressed
    serve_requests: int = 0
    serve_compiled_shapes: int = 0
    # host transfers performed by the step path (`_fetch` calls): the
    # fused step summary counts one per micro-batch; maintenance slow
    # paths (triggered refresh/renorm row lookups) count one more each.
    # The device-residency contract — <= 1 per healthy add-path step —
    # is pinned by the transfer-budget regression test and reported as
    # ``transfers_per_step`` by the device_resident bench arm.
    host_fetches: int = 0
    # malformed/poison events moved to the dead-letter queue (submit-time
    # validation + apply-time impossible-delete checks, DESIGN.md §9)
    dead_letters: int = 0
    # events shed by the pending-queue high-water mark (never delivered;
    # the source resends them once the queues drain)
    backpressure_rejections: int = 0


class StreamingEngine:
    """Joint incremental/decremental state maintenance (Algorithm 1)."""

    def __init__(self, store: StateStore, params: TifuParams,
                 batch_size: int = 256,
                 stability_target_rel_err: Optional[float] = 1e-2,
                 renorm_check_interval: int = 64,
                 bucket_hysteresis: int = 8,
                 tile_hints: Optional[bool] = None,
                 max_pending: Optional[int] = None,
                 dead_letter_cap: int = 1024,
                 checkpointer: Optional[AsyncCheckpointer] = None):
        self.store = store
        self.params = params
        self.batch_size = batch_size
        # Optional background checkpoint writer (DESIGN.md §12): with a
        # checkpointer installed, `checkpoint` snapshots synchronously
        # and hands serialization to the writer thread; `restore` and
        # `flush_checkpoints` are the synchronization points where
        # writer failures surface.  None keeps the fully synchronous
        # §9 commit path.
        self.checkpointer = checkpointer
        # Bounded ingestion (DESIGN.md §9): with ``max_pending`` set,
        # `submit` admits events only while the buffered count is below
        # the high-water mark and sheds (or raises Backpressure on) the
        # rest — memory stays bounded under a slow-consumer scenario.
        self.max_pending = max_pending
        # Dead-letter queue: (event, reason) pairs for malformed/poison
        # events, ring-buffered so a poison flood cannot grow unbounded.
        self.dead_letter: deque = deque(maxlen=max(1, dead_letter_cap))
        # First-rejected explicit seqno not yet readmitted: while set,
        # first deliveries ABOVE it keep being shed — admitting them
        # would open a permanent gap below the watermark and turn the
        # rejected event's redelivery into a dropped "duplicate".
        self._shed_from: Optional[int] = None
        # Device-measured touched-tile bounds (T_max) threaded into the
        # jitted appliers as static args (DESIGN.md §3.3): shrinks the
        # tile-planned TPU kernel grids below the static min(W, I/bi)
        # worst case.  The bounds are computed on device from the
        # touched rows' history metadata and ride the step's single
        # fused transfer (§12) — no extra fetch — but each distinct
        # bound still selects a compiled applier shape, so it defaults
        # on only where it pays (the Pallas path); tests force it on
        # under interpret mode.
        if tile_hints is None:
            tile_hints = jax.default_backend() == "tpu"
        self.tile_hints = tile_hints
        # pow2 sub-batch bucket hysteresis (DESIGN.md §4.1): a kind's
        # bucket grows immediately (the rows exist, there is no choice)
        # but only shrinks after this many CONSECUTIVE micro-batches
        # whose sub-batch would fit the smaller bucket — kind counts that
        # straddle a pow2 boundary no longer flip-flop compiled shapes.
        self.bucket_hysteresis = max(1, bucket_hysteresis)
        self._kind_bucket: Dict[int, int] = {}
        self._below_bucket: Dict[int, int] = {}
        # The renormalization probe must fire before a scale that passed
        # the last probe can underflow f32 (raw rows scale as 1/scale).
        # A user gets at most one event per batch; the worst per-add
        # shrink factor is min(r_b, r_g)/2 (k=1 group opening / tau=1
        # append) and the worst per-delete growth factor is its inverse
        # 2/min(r_b, r_g) (Eq. 12 fold, k=2), so cap the interval I at
        # f^I >= 1e-14: a scale inside the probe bounds then stays
        # within a further 1e14 factor — raw magnitudes <= ~1e30/1e-30,
        # safely inside f32 range in both directions.
        f = min(params.r_b, params.r_g) / 2.0
        sound = int(np.floor(np.log(1e-14) / np.log(f))) if f < 1.0 else 64
        self.renorm_check_interval = max(1, min(renorm_check_interval,
                                                sound))
        # Deferred step summary (DESIGN.md §12): maintenance for batch N
        # runs at the START of step N+1, where its probe scalars ride
        # the same single transfer as batch N+1's poison/tile metadata —
        # identical state trajectory (apply_N -> maintain -> apply_N+1),
        # zero extra syncs.  The probe now rides EVERY step's fetch
        # (strictly more often than any interval, so the soundness cap
        # above is trivially met; the attribute is kept as the
        # documented knob/observable).  `_flush_deferred` settles the
        # pending probe at drain/checkpoint boundaries.
        self._maintenance_due = False
        # dropped-add counts accumulate ON DEVICE and ride the next
        # step's fetch — `int(dropped)` per batch was a hidden transfer
        self._dropped_dev: Optional[jax.Array] = None
        # Per-user pending queues + a min-heap of (head seqno, user):
        # cutting a batch pops at most one event per user in seqno order
        # and costs O(taken·log users) — a hot user with a deep queue no
        # longer forces a rescan of the whole buffer every step.
        self._queues: Dict[int, deque] = {}
        self._heap: List[tuple] = []   # a user is in the heap iff its
        self._n_pending = 0            # queue exists in _queues
        # Exactly-once bookkeeping (DESIGN.md §5/§7).  Conflict deferral
        # (one event per user per micro-batch) processes events OUT of
        # seqno order, so a plain high-watermark would drop
        # deferred-but-unprocessed events on replay.  We track a frontier
        # + the sparse set of processed seqnos above it, PLUS the seqnos
        # currently sitting in the pending queues: an at-least-once
        # source may redeliver an event before its first copy was ever
        # processed, and without the pending set that duplicate would be
        # enqueued (and applied) twice.
        #
        # SUBSEQUENCE SEMANTICS: this engine may be one shard of a
        # user-partitioned deployment, in which case it sees only the
        # subsequence of global seqnos routed to it.  The watermark
        # therefore means "every seqno <= watermark that was DELIVERED to
        # this engine has been processed", and it advances past gaps
        # (seqnos owned by other shards) up to `_max_delivered` — but
        # never past a pending (delivered, unprocessed) seqno and never
        # past `_max_delivered` itself.  The contract this relies on:
        # FIRST deliveries arrive in increasing seqno order (standard log
        # semantics; duplicates may arrive in any order).  The dense
        # single-engine stream is the gap-free special case.
        self.watermark = -1                 # all delivered <= this: done
        self._processed_above: set = set()
        self._pending_seqnos: set = set()
        self._max_delivered = -1
        self._next_seqno = 0
        # distinct (bucket, topn, k, metric) serving shapes compiled —
        # the host-side view of `kernels.ops.serving_cache_size`
        self._serve_shapes: set = set()
        self.metrics = EngineMetrics()
        if stability_target_rel_err is not None:
            self.err_threshold = stability.refresh_threshold(
                stability_target_rel_err, np.finfo(np.float32).eps)
        else:
            self.err_threshold = None

    # -- ingestion ------------------------------------------------------------

    @property
    def n_pending(self) -> int:
        """Number of buffered (not yet applied) events."""
        return self._n_pending

    def _enqueue(self, ev: Event) -> None:
        q = self._queues.get(ev.user)
        if q is None:
            q = self._queues[ev.user] = deque()
            heapq.heappush(self._heap, (ev.seqno, ev.user))
        q.append(ev)
        self._pending_seqnos.add(ev.seqno)
        self._n_pending += 1

    def _invalid_reason(self, ev: Event) -> Optional[str]:
        """Why ``ev`` is statically malformed, or None if well-formed.

        Static checks only (shape-config bounds); the position-vs-actual
        -history check is dynamic and happens at apply time
        (`_apply_events`), because the history length may legitimately
        change between submit and apply.
        """
        cfg = self.store.cfg
        if ev.kind not in (KIND_ADD_BASKET, KIND_DEL_BASKET,
                           KIND_DEL_ITEM):
            return f"unknown event kind {ev.kind}"
        if not 0 <= ev.user < cfg.n_users:
            return f"user {ev.user} outside [0, {cfg.n_users})"
        if ev.kind == KIND_ADD_BASKET:
            items = np.asarray(
                [] if ev.items is None else ev.items, np.int64).ravel()
            if items.size == 0:
                return "add-basket event with no items"
            if items.size > cfg.max_basket_size:
                return (f"basket of {items.size} items exceeds "
                        f"max_basket_size {cfg.max_basket_size}")
            bad = items[(items < 0) | (items >= cfg.n_items)]
            if bad.size:
                return f"item id {int(bad[0])} outside [0, {cfg.n_items})"
            return None
        if not 0 <= ev.pos < cfg.max_baskets:
            return (f"delete position {ev.pos} outside "
                    f"[0, {cfg.max_baskets})")
        if ev.kind == KIND_DEL_ITEM and not 0 <= ev.item < cfg.n_items:
            return f"item id {ev.item} outside [0, {cfg.n_items})"
        return None

    def _quarantine(self, ev: Event, reason: str) -> None:
        """Move a malformed/poison event to the dead-letter queue."""
        self.dead_letter.append((ev, reason))
        self.metrics.dead_letters += 1

    def _would_shed(self, seqno: Optional[int] = None) -> bool:
        """Would an event (with optional explicit seqno) be shed now?

        A rejected explicit seqno is an OPEN GAP: everything above it —
        including any seqno that would be freshly assigned — must keep
        shedding until that seqno's own redelivery is readmitted.
        Otherwise the watermark rolls past the gap (it looks like an
        other-shard seqno) and the redelivery is dropped as a
        "duplicate": a lost event.
        """
        if self._shed_from is not None and (seqno is None
                                            or seqno > self._shed_from):
            return True
        return (self.max_pending is not None
                and self._n_pending >= self.max_pending)

    def submit(self, events: Iterable[Event], *,
               on_invalid: str = "raise",
               on_overflow: str = "raise") -> AdmissionResult:
        """Enqueue events: dedup, validate, admit under backpressure.

        Per event, in order: (1) explicit-seqno redeliveries already
        processed (``<= watermark`` under the subsequence semantics, or
        in the sparse processed set above it) or still buffered are
        skipped — at-least-once becomes exactly-once.  CONTRACT: first
        deliveries arrive in increasing seqno order; a late out-of-order
        first delivery is indistinguishable from a redelivery and is
        dropped (counted in ``metrics.dedup_skips``).  (2) Statically
        malformed events raise :class:`InvalidEventError`
        (``on_invalid="raise"``) or move to the dead-letter queue
        (``"quarantine"``); a quarantined event CONSUMES its seqno and
        is marked processed, so replays skip it instead of
        re-quarantining forever.  (3) With ``max_pending`` set, events
        past the high-water mark are shed: never assigned a seqno, no
        log state touched — the source resends them.  Once an explicit
        seqno is shed, everything above it keeps shedding until its
        redelivery is admitted (`_would_shed`).  ``on_overflow="raise"``
        raises :class:`Backpressure` AFTER the admitted prefix is safely
        enqueued; ``"shed"`` only counts.  Cost: O(1) per event
        (amortized heap push).
        """
        if on_invalid not in ("raise", "quarantine"):
            raise ValueError(f"on_invalid={on_invalid!r}")
        if on_overflow not in ("raise", "shed"):
            raise ValueError(f"on_overflow={on_overflow!r}")
        res = AdmissionResult()
        for ev in events:
            explicit = ev.seqno >= 0
            if explicit and (ev.seqno <= self.watermark
                             or ev.seqno in self._processed_above
                             or ev.seqno in self._pending_seqnos):
                # replay of an event that was already processed OR is
                # still buffered: skip (at-least-once -> exactly-once)
                self.metrics.dedup_skips += 1
                res.deduped += 1
                continue
            reason = self._invalid_reason(ev)
            if reason is not None:
                if on_invalid == "raise":
                    raise InvalidEventError(ev, reason)
                if not explicit:
                    ev = dataclasses.replace(ev, seqno=self._next_seqno)
                    self._next_seqno += 1
                else:
                    self._next_seqno = max(self._next_seqno, ev.seqno + 1)
                self._max_delivered = max(self._max_delivered, ev.seqno)
                self._processed_above.add(ev.seqno)
                self._advance_watermark()
                self._quarantine(ev, reason)
                res.quarantined += 1
                continue
            if self._would_shed(ev.seqno if explicit else None):
                self.metrics.backpressure_rejections += 1
                res.rejected += 1
                if explicit:
                    if (res.first_rejected_seqno is None
                            or ev.seqno < res.first_rejected_seqno):
                        res.first_rejected_seqno = ev.seqno
                    if (self._shed_from is None
                            or ev.seqno < self._shed_from):
                        self._shed_from = ev.seqno
                continue
            if not explicit:
                ev = dataclasses.replace(ev, seqno=self._next_seqno)
                self._next_seqno += 1
            else:
                self._next_seqno = max(self._next_seqno, ev.seqno + 1)
                if ev.seqno == self._shed_from:
                    self._shed_from = None    # gap closed: admissions resume
            self._max_delivered = max(self._max_delivered, ev.seqno)
            self._enqueue(ev)
            res.admitted += 1
        if res.rejected and on_overflow == "raise":
            raise Backpressure(res.admitted, res.rejected,
                               res.first_rejected_seqno, self._n_pending)
        return res

    def add_basket(self, user: int, items: Sequence[int]) -> None:
        """Enqueue one basket addition (Eq. 7–9) for ``user``."""
        self.submit([Event(KIND_ADD_BASKET, user,
                           items=np.asarray(items, np.int32))])

    def delete_basket(self, user: int, pos: int) -> None:
        """Enqueue deletion of basket ``pos`` (Eq. 10–12) for ``user``."""
        self.submit([Event(KIND_DEL_BASKET, user, pos=pos)])

    def delete_item(self, user: int, pos: int, item: int) -> None:
        """Enqueue deletion of ``item`` from basket ``pos`` (Eq. 13)."""
        self.submit([Event(KIND_DEL_ITEM, user, pos=pos, item=item)])

    # -- unlearning front door (DESIGN.md §11) ----------------------------------

    def forget_user(self, user: int) -> ForgetReceipt:
        """Erase ``user``'s entire history and every live trace of it.

        The GDPR right-to-be-forgotten front door: drains the pending
        queues (so the user's in-flight events land first), emits one
        ``KIND_DEL_BASKET`` per remaining basket — last position first,
        so every position stays valid — through the normal exactly-once
        path, then scrubs the float dust the deletion arithmetic may
        leave outside the final support (`_scrub_user`) and purges the
        user's dead-letter entries (quarantined events carry payloads —
        residue too).  Synchronous: returns only after the state is
        clean, with a :class:`ForgetReceipt` tying the emitted seqnos to
        the measured residue.  Cost: the user's O(n_baskets) deletion
        events plus one O(n_items) row scrub.  Idempotent.
        """
        t0 = time.perf_counter()
        self.run_until_drained()
        # index on device, fetch one scalar — np.asarray(n_baskets)[user]
        # would pull the whole O(n_users) leaf to read one element
        nb = int(jax.device_get(self.store.state.n_baskets[user]))
        first = self._next_seqno
        if nb:
            self.submit([Event(KIND_DEL_BASKET, user, pos=p)
                         for p in range(nb - 1, -1, -1)])
            self.run_until_drained()
        self._scrub_user(user)
        purged = self._purge_dead_letters(user)
        return ForgetReceipt(
            user=user, n_baskets_deleted=nb,
            seqnos=tuple(range(first, first + nb)),
            purged_dead_letters=purged,
            latency_s=time.perf_counter() - t0,
            residue=self.store.row_residue([user]))

    def _scrub_user(self, user: int) -> None:
        """Zero a forgotten user's row exactly, caches included.

        The deletion arithmetic zeroes the support cells of the final
        history exactly (scenario 3 scatters the exact negation), but
        earlier item deletes can leave f32 dust at cells OUTSIDE that
        support — `refresh_users` on the now-empty history recomputes
        the row from the integer leaves alone: exact zeros, scales
        reset to 1.  `scrub_rows` then pushes the zeros into whichever
        serving caches exist.
        """
        rows = jnp.asarray([user], jnp.int32)
        self.store.state = refresh_users(self.store.state, rows,
                                         self.params)
        self.store.scrub_rows([user])

    def _purge_dead_letters(self, user: int) -> int:
        """Drop the user's quarantined events (they carry payloads)."""
        kept = [(ev, why) for ev, why in self.dead_letter
                if ev.user != user]
        purged = len(self.dead_letter) - len(kept)
        if purged:
            self.dead_letter.clear()
            self.dead_letter.extend(kept)
        return purged

    # -- micro-batch processing -------------------------------------------------

    def _cut_batch(self) -> List[Event]:
        """Take up to batch_size events in seqno order, one per user.

        A user's later events stay queued for the next batch; cost is
        O(taken · log users) heap work.
        """
        taken: List[Event] = []
        requeue = []
        while self._heap and len(taken) < self.batch_size:
            _, user = heapq.heappop(self._heap)
            q = self._queues[user]
            taken.append(q.popleft())
            if q:
                requeue.append((q[0].seqno, user))
            else:
                del self._queues[user]
        for entry in requeue:
            heapq.heappush(self._heap, entry)
        for ev in taken:
            self._pending_seqnos.discard(ev.seqno)
        self._n_pending -= len(taken)
        return taken

    def _bucket(self, kind: int, n: int) -> int:
        """Pick the padded sub-batch size for ``n`` rows of ``kind``.

        Shrink hysteresis (DESIGN.md §4.1): growth is immediate, shrink
        waits for ``bucket_hysteresis`` consecutive under-boundary
        micro-batches.
        """
        want = _pow2_pad(n, self.batch_size)
        cur = self._kind_bucket.get(kind, 0)
        if want >= cur:
            if want > cur and cur:
                self.metrics.bucket_grows += 1
            self._kind_bucket[kind] = want
            self._below_bucket[kind] = 0
            return want
        self._below_bucket[kind] = self._below_bucket.get(kind, 0) + 1
        if self._below_bucket[kind] >= self.bucket_hysteresis:
            self._kind_bucket[kind] = want
            self._below_bucket[kind] = 0
            self.metrics.bucket_shrinks += 1
            return want
        return cur

    def _decay_absent_buckets(self, present) -> None:
        """Advance the shrink hysteresis of kinds ABSENT from a batch.

        Without this, a one-off burst (e.g. a GDPR delete wave) pins its
        large pow2 bucket forever: the kind never appears again,
        `_bucket` is never consulted, and the next singleton of that
        kind pads to the stale burst-sized bucket.  An absent batch
        counts as a zero-row batch, so after ``bucket_hysteresis``
        consecutive batches without the kind its bucket decays to the
        minimum (re-growth stays immediate, and previously compiled
        buckets are still cached).
        """
        for kind in list(self._kind_bucket):
            if kind not in present and self._kind_bucket[kind] > 1:
                self._bucket(kind, 0)

    def _fetch(self, tree):
        """The step path's host transfer: one counted ``device_get``.

        Every device→host read in the step loop goes through here, so
        ``metrics.host_fetches`` is exactly the number of transfers the
        transfer-budget regression test and the ``device_resident``
        bench arm observe.  The §12 contract: the fused step summary is
        ONE call per micro-batch; only triggered maintenance slow paths
        add more.
        """
        self.metrics.host_fetches += 1
        return jax.device_get(tree)

    def _dispatch_tile_bounds(self, adds, delb, deli) -> Dict[int, jax.Array]:
        """Dispatch per-kind device touched-tile-bound programs (§3.3).

        For each kind sub-batch, a small jitted program over the
        touched rows' history metadata computes the maximum number of
        item tiles any row's support ids touch — the add support is the
        new basket plus the last group's history window, the delete
        supports the whole live history (plus the deleted item id).
        Returns ``{kind: i32[] device scalar}`` so the bounds ride the
        step's single fused transfer instead of forcing their own
        O(batch·N·B) host fetch.  Rows are padded to the pow2 event
        bucket (validity-masked, so padding contributes count 1) to
        bound compiled shapes.  Sound because distinct tiles <= distinct
        ids and the supports are supersets of what the appliers
        construct; empty when the kernels run the XLA reference.
        """
        from repro.kernels import ops
        bi = ops.plan_bi(self.store.cfg.n_items)
        if bi is None:       # kernels fall back to the XLA reference
            return {}
        st = self.store.state
        w = self.store.cfg.max_basket_size

        def pad_users(evs):
            n = len(evs)
            m = _pow2_pad(n, self.batch_size)
            users = np.zeros(m, np.int32)
            users[:n] = [ev.user for ev in evs]
            valid = np.zeros(m, bool)
            valid[:n] = True
            return jnp.asarray(users), jnp.asarray(valid)

        bounds: Dict[int, jax.Array] = {}
        if adds:
            idx, valid = pad_users(adds)
            new_ids = np.full((idx.shape[0], w), -1, np.int32)
            for r, ev in enumerate(adds):
                ids = np.asarray(ev.items, np.int32).ravel()[:w]
                new_ids[r, :ids.size] = ids
            bounds[KIND_ADD_BASKET] = _add_tile_bound(
                st.history, st.group_sizes, st.n_baskets, st.n_groups,
                idx, jnp.asarray(new_ids), valid, bi=bi)
        for kind, evs in ((KIND_DEL_BASKET, delb), (KIND_DEL_ITEM, deli)):
            if not evs:
                continue
            idx, valid = pad_users(evs)
            extra = np.full(idx.shape[0], -1, np.int32)
            if kind == KIND_DEL_ITEM:
                extra[:len(evs)] = [ev.item for ev in evs]
            bounds[kind] = _hist_tile_bound(
                st.history, st.n_baskets, idx, jnp.asarray(extra),
                valid, bi=bi)
        return bounds

    def _tile_hints(self, adds, delb, deli) -> Dict[int, int]:
        """Per-kind pow2 touched-tile bounds, fetched eagerly.

        Compatibility wrapper over `_dispatch_tile_bounds` + one
        transfer; the step loop instead folds the device scalars into
        its fused summary fetch (`_prepare_step`/`_complete_step`).
        """
        bounds = self._dispatch_tile_bounds(adds, delb, deli)
        if not bounds:
            return {}
        return {kind: _pow2_pad(max(int(v), 1))
                for kind, v in self._fetch(bounds).items()}

    def _poison_filter(self, delb, deli, nb):
        """Quarantine deletes whose position exceeds the CURRENT history.

        Dynamic poison check (DESIGN.md §9): a delete position at or
        beyond the user's current history length would be clipped by
        the applier's safe_pos guard and silently delete the WRONG
        basket — quarantine it instead.  The event still counts as
        processed (its seqno advances the log via `_finish_step`), so a
        replay skips it rather than re-poisoning.  ``nb`` is the
        per-delete-row basket-count gather that rode the fused step
        summary — no extra transfer.
        """
        keep_b: List[Event] = []
        keep_i: List[Event] = []
        for ev, n in zip(delb + deli, np.asarray(nb)):
            if ev.pos >= int(n):
                self._quarantine(
                    ev, f"delete position {ev.pos} beyond user "
                        f"{ev.user}'s history of {int(n)} basket(s)")
            elif ev.kind == KIND_DEL_BASKET:
                keep_b.append(ev)
            else:
                keep_i.append(ev)
        return keep_b, keep_i

    def _apply_sub_batches(self, adds, delb, deli,
                           hints: Dict[int, int]) -> None:
        """Apply one micro-batch's kind-partitioned sub-batches.

        One homogeneous compiled program per kind present (users are
        disjoint across the sub-batches, so application order is
        irrelevant): adds pay O(batch·W), deletions O(batch·N·B)
        (DESIGN.md §3.3/§3.5).  ``hints`` are the pow2 touched-tile
        bounds from the step summary (empty → static worst case).
        """
        self._decay_absent_buckets({kind for kind, evs in
                                    ((KIND_ADD_BASKET, adds),
                                     (KIND_DEL_BASKET, delb),
                                     (KIND_DEL_ITEM, deli)) if evs})
        b = self.store.cfg.max_basket_size
        if adds:
            batch = AddBatch.build(
                [ev.user for ev in adds], [ev.items for ev in adds], b,
                pad_to=self._bucket(KIND_ADD_BASKET, len(adds)))
            # the counted variant surfaces capacity drops (masked to
            # no-ops by the guard) from the same fused program; the
            # count ACCUMULATES on device and rides the next step's
            # summary fetch — int(dropped) here would be a second
            # per-batch transfer
            self.store.state, dropped = apply_add_batch_counted(
                self.store.state, batch, self.params,
                t_max_cap=hints.get(KIND_ADD_BASKET, 0))
            self._dropped_dev = (dropped if self._dropped_dev is None
                                 else self._dropped_dev + dropped)
        if delb:
            batch = DelBasketBatch.build(
                [ev.user for ev in delb], [ev.pos for ev in delb],
                pad_to=self._bucket(KIND_DEL_BASKET, len(delb)))
            self.store.state = apply_del_basket_batch(
                self.store.state, batch, self.params,
                t_max_cap=hints.get(KIND_DEL_BASKET, 0))
        if deli:
            batch = DelItemBatch.build(
                [ev.user for ev in deli], [ev.pos for ev in deli],
                [ev.item for ev in deli],
                pad_to=self._bucket(KIND_DEL_ITEM, len(deli)))
            self.store.state = apply_del_item_batch(
                self.store.state, batch, self.params,
                t_max_cap=hints.get(KIND_DEL_ITEM, 0))
        # serving-corpus cache: only the APPLIED rows changed (§3.6) —
        # quarantined events touched nothing
        self.store.invalidate_users(
            [ev.user for ev in adds + delb + deli])

    def _apply_maintenance(self, err_max, lo, hi) -> None:
        """Stability refreshes + scale renorm from the probe scalars.

        The fast path — healthy error bounds, in-range scales — costs
        nothing beyond the three scalars that already rode the step
        summary.  Each TRIGGERED path pays one extra explicit fetch to
        locate the offending rows; both are rare by construction
        (stability §3.3; the scale drift analysis in ``__init__``).
        """
        if self.err_threshold is not None and err_max > self.err_threshold:
            err = np.asarray(self._fetch(self.store.state.err_mult))
            bad = np.nonzero(err > self.err_threshold)[0]
            if bad.size:
                self.store.state = refresh_users(
                    self.store.state, jnp.asarray(bad, jnp.int32),
                    self.params)
                self.metrics.refreshes += int(bad.size)
                # a refresh changes the served values (it resets the
                # accumulated fp error), so those rows are stale too
                self.store.invalidate_users(bad)
        floor = SCALE_FLOOR * 1e2   # renormalize well before the bounds
        ceil = SCALE_CEIL * 1e-2
        if lo < floor or hi > ceil:
            uv_h, lgv_h = self._fetch((self.store.state.uv_scale,
                                       self.store.state.lgv_scale))
            uv_h, lgv_h = np.asarray(uv_h), np.asarray(lgv_h)
            out = np.nonzero((uv_h < floor) | (lgv_h < floor)
                             | (uv_h > ceil) | (lgv_h > ceil))[0]
            self.store.state = renormalize_users(
                self.store.state, jnp.asarray(out, jnp.int32))
            self.metrics.renormalizations += int(out.size)

    def _consume_summary(self, host: dict) -> None:
        """Apply the deferred halves of a fetched step summary."""
        if "dropped" in host:
            self.metrics.dropped_adds += int(host["dropped"])
            self._dropped_dev = None
        if "probe" in host:
            self._apply_maintenance(*host["probe"])
            self._maintenance_due = False

    def _flush_deferred(self) -> None:
        """Settle deferred maintenance/counters now (drain boundary).

        Deferral moves batch N's maintenance probe into step N+1's
        fused fetch; the LAST batch before a drain, checkpoint or
        forget has no next batch, so these boundaries flush explicitly
        — `run_until_drained` always ends on the empty step that pays
        this one fetch.
        """
        fetch: dict = {}
        st = self.store.state
        if self._maintenance_due:
            fetch["probe"] = _maintenance_probe(st.err_mult, st.uv_scale,
                                                st.lgv_scale)
        if self._dropped_dev is not None:
            fetch["dropped"] = self._dropped_dev
        if fetch:
            self._consume_summary(self._fetch(fetch))

    def _prepare_step(self):
        """Cut a micro-batch and dispatch its device-side step summary.

        Everything the host must learn from the device this step — the
        previous batch's deferred maintenance probe and dropped-add
        count, the delete rows' basket counts (poison check), the
        per-kind touched-tile bounds — is dispatched here as one dict
        of device values, so `_complete_step` fetches them in a SINGLE
        transfer.  Split from `_complete_step` so a sharded deployment
        dispatches every shard's programs before any shard blocks on
        its fetch (`ShardedStreamingEngine.step`).
        """
        events = self._cut_batch()
        adds = [ev for ev in events if ev.kind == KIND_ADD_BASKET]
        delb = [ev for ev in events if ev.kind == KIND_DEL_BASKET]
        deli = [ev for ev in events if ev.kind == KIND_DEL_ITEM]
        st = self.store.state
        fetch: dict = {}
        if self._maintenance_due:
            fetch["probe"] = _maintenance_probe(st.err_mult, st.uv_scale,
                                                st.lgv_scale)
        if self._dropped_dev is not None:
            fetch["dropped"] = self._dropped_dev
        if delb or deli:
            idx = jnp.asarray(np.asarray(
                [ev.user for ev in delb + deli], np.int32))
            fetch["del_nb"] = st.n_baskets[idx]
        if events and self.tile_hints:
            bounds = self._dispatch_tile_bounds(adds, delb, deli)
            if bounds:
                fetch["tiles"] = bounds
        return events, adds, delb, deli, fetch

    def _complete_step(self, prep) -> List[Event]:
        """Fetch the step summary (ONE transfer) and apply the batch.

        Order matters: the summary was computed from the pre-
        maintenance state, which is sound — refresh/renorm touch only
        the float leaves, never ``history``/``n_baskets``/group
        metadata — and running maintenance before the appliers
        reproduces the legacy trajectory (apply_N → maintain →
        apply_N+1) exactly.
        """
        events, adds, delb, deli, fetch = prep
        host = self._fetch(fetch) if fetch else {}
        self._consume_summary(host)
        if not events:
            return events
        if "del_nb" in host:
            delb, deli = self._poison_filter(delb, deli, host["del_nb"])
        hints = {kind: _pow2_pad(max(int(v), 1))
                 for kind, v in host.get("tiles", {}).items()}
        self._apply_sub_batches(adds, delb, deli, hints)
        self._maintenance_due = True
        return events

    def _begin_step(self) -> List[Event]:
        """Cut one micro-batch and apply it (one fused summary fetch)."""
        return self._complete_step(self._prepare_step())

    def _finish_step(self, events: List[Event], t0: float) -> int:
        """Exactly-once log advance + counters for one micro-batch."""
        for ev in events:
            self._processed_above.add(ev.seqno)
        self._advance_watermark()
        self.metrics.events_processed += len(events)
        self.metrics.batches += 1
        self.metrics.last_batch_seconds = time.perf_counter() - t0
        return len(events)

    def _advance_watermark(self) -> None:
        """Advance the frontier under the subsequence semantics.

        A seqno can be passed when it was processed here, OR when it was
        never delivered here (another shard owns it — in-order first
        delivery guarantees it never will be).  Pending seqnos
        (delivered, unprocessed) and anything beyond ``_max_delivered``
        block.
        """
        nxt = self.watermark + 1
        while nxt <= self._max_delivered and nxt not in self._pending_seqnos:
            self._processed_above.discard(nxt)
            self.watermark = nxt
            nxt += 1

    def step(self) -> int:
        """Process one micro-batch. Returns number of events applied."""
        t0 = time.perf_counter()
        events = self._begin_step()
        if not events:
            return 0
        return self._finish_step(events, t0)

    def run_until_drained(self, max_batches: int = 10_000) -> int:
        """Step until the pending queues empty; returns events applied."""
        total = 0
        for _ in range(max_batches):
            n = self.step()
            if n == 0:
                break
            total += n
        return total

    # -- serving (DESIGN.md §8) -------------------------------------------------

    def recommend(self, user_ids, topn: int = 10, k: Optional[int] = None,
                  alpha: Optional[float] = None,
                  metric: str = "euclidean",
                  quantized: bool = False) -> np.ndarray:
        """Top-n recommendations for ``user_ids`` — the request batcher.

        Reads the cached serving corpus (``StateStore.corpus()`` —
        micro-batches between requests invalidated only the touched
        rows) and serves through the fused pipeline
        (`core.knn.recommend_for_users` → `kernels.ops`).  The query
        batch is padded to a pow2 BUCKET (repeating the first user; the
        padding rows are computed and discarded), so serving compiles
        O(log max_batch) programs per (topn, k, metric) instead of one
        per distinct request-batch size — the compiled-shape count is
        tracked in ``metrics.serve_compiled_shapes``.  Cost: one fused
        device program per request batch, O(topn) host output per user.

        ``quantized=True`` serves the D-tiled int8 path instead
        (DESIGN.md §8.4): the ``StateStore.quantized_corpus()`` cache
        (row-invalidated alongside the fp32 one) through
        `core.knn.recommend_for_users_quant` — VMEM flat in n_items,
        ¼ the HBM bytes, euclidean only.
        """
        ids, q_n, bucket = _pad_request(user_ids)
        if q_n == 0:
            return np.zeros((0, topn), np.int32)
        k = self.params.k_neighbors if k is None else k
        alpha = self.params.alpha if alpha is None else alpha
        if quantized:
            if metric != "euclidean":
                raise ValueError("quantized serving is euclidean-only")
            corpus_q, c_scale = self.store.quantized_corpus()
            recs = knn.recommend_for_users_quant(
                corpus_q, c_scale, jnp.asarray(ids.astype(np.int32)),
                k=k, alpha=alpha, topn=topn)
        else:
            recs = knn.recommend_for_users(
                self.store.corpus(), jnp.asarray(ids.astype(np.int32)),
                k=k, alpha=alpha, topn=topn, metric=metric)
        self.metrics.serve_requests += 1
        # alpha included: it is a static (compile-triggering) arg of
        # the Pallas serving path, so per-request alphas must show up
        # in the gated compiled-shape count
        self._serve_shapes.add((bucket, topn, k, float(alpha), metric,
                                quantized))
        self.metrics.serve_compiled_shapes = len(self._serve_shapes)
        return np.asarray(recs)[:q_n]

    def freeze_serving(self) -> None:
        """Enter degraded serving: pin the current corpus snapshot.

        ``recommend`` keeps answering from the pinned snapshot while the
        live state is being rebuilt/restored (DESIGN.md §9); answers are
        stale but well-formed.  Idempotent.
        """
        self.store.freeze_serving()

    def thaw_serving(self) -> None:
        """Leave degraded serving; `recommend` reads live state again."""
        self.store.thaw_serving()

    @property
    def serving_degraded(self) -> bool:
        """True while `recommend` answers from a pinned stale snapshot."""
        return self.store.serving_degraded

    # -- recovery ---------------------------------------------------------------

    def checkpoint(self, directory: str, step: int) -> None:
        """Commit state + exactly-once log atomically (DESIGN.md §5).

        The log rides inside the store's LATEST metadata, which is the
        checkpoint's single atomic commit point (fsync'd tmp +
        os.replace): a crash anywhere — even between files — can never
        pair a new state npz with an old/truncated log (a torn pair
        would replay below the old watermark onto the new state:
        double-apply).  Deferred maintenance is flushed first so the
        committed state matches the drained trajectory.

        With an async ``checkpointer`` installed (§12) the caller-thread
        cost is one host snapshot copy; serialization and the atomic
        commit run on the writer thread in submission order, and writer
        failures surface at the next `checkpoint`/`flush_checkpoints`/
        `restore`.  Without one: one O(state) snapshot + inline write.
        """
        self._flush_deferred()
        extra = {"engine": {
            "watermark": self.watermark,
            "processed_above": sorted(self._processed_above),
            "delivered": self._max_delivered,
            "next_seqno": self._next_seqno}}
        if self.checkpointer is not None:
            self.store.checkpoint_async(self.checkpointer, directory,
                                        step, extra_meta=extra)
        else:
            self.store.checkpoint(directory, step, extra_meta=extra)

    def flush_checkpoints(self) -> None:
        """Block until every async commit landed (no-op when sync).

        Re-raises the writer thread's first error — the synchronization
        point a caller must cross before trusting that a `checkpoint`
        call's commit exists on disk.
        """
        if self.checkpointer is not None:
            self.checkpointer.flush()

    def restore(self, directory: str) -> None:
        """Install a checkpoint: state, serving cache, exactly-once log.

        Pending queues are dropped (they were never part of the commit);
        an at-least-once source replays the stream WITH THE ORIGINAL
        seqnos and `submit` skips everything at or below the restored
        log (a replay without seqnos is indistinguishable from new
        traffic and will re-apply).  Pending async commits are flushed
        FIRST (deterministic LATEST: restore must never race its own
        writer; a recorded writer crash re-raises here instead of being
        silently absorbed).  Cost: one O(state) read + device upload.
        """
        self.flush_checkpoints()
        self.store.restore(directory)
        meta = self.store.last_restored_meta.get("engine")
        if meta is None:
            # legacy checkpoint layout: separate ENGINE file
            with open(os.path.join(directory, "ENGINE")) as f:
                meta = json.load(f)
        self._load_log(meta)
        self._queues.clear()
        self._heap.clear()
        self._pending_seqnos.clear()
        self._n_pending = 0
        # dropped queues also drop any open backpressure gap: the source
        # replays from the restored log, so there is no seqno to readmit
        self._shed_from = None
        # the restored state has no batch behind it: nothing deferred
        self._maintenance_due = False
        self._dropped_dev = None

    def _load_log(self, meta: dict) -> None:
        """Install a persisted exactly-once log (see `checkpoint`)."""
        self.watermark = meta["watermark"]
        self._processed_above = set(meta.get("processed_above", []))
        self._next_seqno = meta["next_seqno"]
        # legacy (pre-sharding) checkpoints lack `delivered`; they were
        # written by dense single engines, where every seqno below
        # next_seqno was delivered
        self._max_delivered = meta.get("delivered", meta["next_seqno"] - 1)

    def _reset_log(self) -> None:
        """Fresh empty log (resharding restore starts a new shard log)."""
        self.watermark = -1
        self._processed_above = set()
        self._pending_seqnos = set()
        self._max_delivered = -1
        self._next_seqno = 0
        self._queues.clear()
        self._heap.clear()
        self._n_pending = 0
        self._shed_from = None
        self._maintenance_due = False
        self._dropped_dev = None


# ---------------------------------------------------------------------------
# User-axis sharded deployment (DESIGN.md §7)
# ---------------------------------------------------------------------------

_SHARD_MANIFEST = "SHARDS"

# every StreamState leaf, derived so a new field cannot silently be
# dropped by the resharding assembler
_STATE_LEAVES = tuple(f.name for f in dataclasses.fields(StreamState))


class ShardedStreamingEngine:
    """User-axis sharded streaming maintenance (DESIGN.md §7).

    The paper's Spark deployment partitions the keyed state store by
    user; this is the jax analog: ``n_shards`` fully independent
    :class:`StreamingEngine` instances, each owning its own
    :class:`StateStore` (optionally on its own device mesh, see
    ``launch.mesh.make_user_shard_meshes``), its own exactly-once log,
    its own pow2 sub-batch buckets and its own atomic ``LATEST`` commit.
    This router only (a) assigns global seqnos, (b) routes events to
    shard ``user % n_shards`` translated to local row ``user //
    n_shards`` (:class:`repro.parallel.sharding.UserShardSpec`), and
    (c) orchestrates cross-shard checkpoint/restore and serving — no
    per-event cross-shard communication exists, matching the paper's
    "each user vector is calculated independently".

    Exactly-once across shards: each shard's log stores its watermark
    under SUBSEQUENCE semantics (see :class:`StreamingEngine`), so a
    crash that lands between two shard commits restores shards at
    different steps and a full-stream replay re-applies exactly the
    events each shard lost — never a double-apply (the failure table in
    DESIGN.md §7).  Resharding (restore an N-shard checkpoint into M
    shards) reassembles global rows by the spec bijection and carries
    the N old logs as **legacy logs**: redelivered events are checked
    against the log of their OLD owner shard (`user % N` is computable
    at submit time), which is exact, bounded, and survives further
    checkpoints.
    """

    def __init__(self, stores: Sequence[StateStore], params: TifuParams,
                 spec: UserShardSpec, **engine_kw):
        if len(stores) != spec.n_shards:
            raise ValueError(f"{len(stores)} stores for {spec.n_shards} "
                             "shards")
        for s, st in enumerate(stores):
            want = spec.shard_users(s)
            if st.cfg.n_users != want:
                raise ValueError(
                    f"shard {s}: store has {st.cfg.n_users} user rows, "
                    f"spec owns {want} (n_users={spec.n_users})")
        self.spec = spec
        self.params = params
        self.shards = [StreamingEngine(st, params, **engine_kw)
                       for st in stores]
        # One shared background writer for the whole deployment (§12):
        # FIFO submission order means the SHARDS manifest job queued
        # after the shard commits can never land before them.
        self.checkpointer: Optional[AsyncCheckpointer] = \
            engine_kw.get("checkpointer")
        self._next_seqno = 0
        # Legacy exactly-once logs from resharding restores:
        # [{"n_shards": N_old, "logs": [{"watermark", "processed_above"}]}]
        self._legacy: List[dict] = []
        # Router-level dead letters: events with no owner shard (global
        # user id out of range) — per-shard queues hold the rest.
        self.dead_letter: deque = deque(maxlen=1024)
        self.router_dead_letters = 0

    @classmethod
    def create(cls, spec: UserShardSpec, params: TifuParams,
               max_baskets: int, max_basket_size: int,
               max_groups: Optional[int] = None, meshes=None,
               **engine_kw) -> "ShardedStreamingEngine":
        """Build the per-shard stores from the spec and store shapes.

        ``meshes`` (optional) is one device mesh per shard
        (``launch.mesh.make_user_shard_meshes``); None keeps every
        shard's arrays on the default device.
        """
        stores = []
        for s in range(spec.n_shards):
            cfg = StoreConfig(n_users=spec.shard_users(s),
                              n_items=params.n_items,
                              max_baskets=max_baskets,
                              max_basket_size=max_basket_size,
                              max_groups=max_groups)
            stores.append(StateStore(
                cfg, mesh=None if meshes is None else meshes[s]))
        return cls(stores, params, spec, **engine_kw)

    # -- ingestion ------------------------------------------------------------

    @property
    def n_pending(self) -> int:
        """Buffered (not yet applied) events across all shards."""
        return sum(sh.n_pending for sh in self.shards)

    @property
    def events_processed(self) -> int:
        """Total events applied across all shards."""
        return sum(sh.metrics.events_processed for sh in self.shards)

    @property
    def dead_letters(self) -> int:
        """Total quarantined events (router-level plus every shard)."""
        return (self.router_dead_letters
                + sum(sh.metrics.dead_letters for sh in self.shards))

    @property
    def backpressure_rejections(self) -> int:
        """Total backpressure-shed events across all shards."""
        return sum(sh.metrics.backpressure_rejections
                   for sh in self.shards)

    def _legacy_processed(self, user: int, seqno: int) -> bool:
        """True when a pre-reshard deployment already processed seqno.

        The old owner shard of ``user`` is computable from the legacy
        partition count, so the check is an exact per-event lookup into
        that shard's persisted log — O(#reshards) per event.
        """
        for entry in self._legacy:
            log = entry["logs"][user % entry["n_shards"]]
            if seqno <= log["watermark"] \
                    or seqno in log["processed_above"]:
                return True
        return False

    def submit(self, events: Iterable[Event], *,
               on_invalid: str = "raise",
               on_overflow: str = "raise") -> AdmissionResult:
        """Assign global seqnos and route events to their owner shards.

        Explicit-seqno events (at-least-once redelivery) are first
        checked against the legacy logs of any previous shard layout,
        then against the owner shard's live log (inside the shard's own
        ``submit``).  Events whose GLOBAL user id has no owner shard
        raise/quarantine at the router (``self.dead_letter``); all other
        validation/backpressure happens in the owner shard and is
        aggregated into one :class:`AdmissionResult` (or one
        :class:`Backpressure`, raised after the call's admissible events
        are enqueued).  A seqno-less event probes the owner shard
        BEFORE a global seqno is assigned: a shed event must stay
        seqno-less (it was never delivered), or its burned seqno becomes
        a permanent gap in the shard's log.  Cost: O(1) per event plus
        O(#reshards) dedup.
        """
        if on_invalid not in ("raise", "quarantine"):
            raise ValueError(f"on_invalid={on_invalid!r}")
        if on_overflow not in ("raise", "shed"):
            raise ValueError(f"on_overflow={on_overflow!r}")
        res = AdmissionResult()
        for ev in events:
            explicit = ev.seqno >= 0
            if explicit:
                self._next_seqno = max(self._next_seqno, ev.seqno + 1)
                if self._legacy and self._legacy_processed(ev.user,
                                                           ev.seqno):
                    res.deduped += 1
                    continue
            if not 0 <= ev.user < self.spec.n_users:
                reason = (f"user {ev.user} outside the deployment's "
                          f"[0, {self.spec.n_users}) global range")
                if on_invalid == "raise":
                    raise InvalidEventError(ev, reason)
                self.dead_letter.append((ev, reason))
                self.router_dead_letters += 1
                res.quarantined += 1
                continue
            sh = self.shards[self.spec.shard_of(ev.user)]
            if not explicit:
                if sh._would_shed(None):
                    sh.metrics.backpressure_rejections += 1
                    res.rejected += 1
                    continue
                ev = dataclasses.replace(ev, seqno=self._next_seqno)
                self._next_seqno += 1
            res.merge(sh.submit(
                [dataclasses.replace(
                    ev, user=int(self.spec.local_row(ev.user)))],
                on_invalid=on_invalid, on_overflow="shed"))
        if res.rejected and on_overflow == "raise":
            raise Backpressure(res.admitted, res.rejected,
                               res.first_rejected_seqno, self.n_pending)
        return res

    def add_basket(self, user: int, items: Sequence[int]) -> None:
        """Enqueue one basket addition (Eq. 7–9) for global ``user``."""
        self.submit([Event(KIND_ADD_BASKET, user,
                           items=np.asarray(items, np.int32))])

    def delete_basket(self, user: int, pos: int) -> None:
        """Enqueue deletion of basket ``pos`` (Eq. 10–12) for ``user``."""
        self.submit([Event(KIND_DEL_BASKET, user, pos=pos)])

    def delete_item(self, user: int, pos: int, item: int) -> None:
        """Enqueue deletion of ``item`` from basket ``pos`` (Eq. 13)."""
        self.submit([Event(KIND_DEL_ITEM, user, pos=pos, item=item)])

    # -- unlearning front door (DESIGN.md §11) ----------------------------------

    def forget_user(self, user: int) -> ForgetReceipt:
        """Erase global ``user``'s history and every live trace of it.

        Same contract as :meth:`StreamingEngine.forget_user`, with the
        deletion events submitted THROUGH THE ROUTER: the router owns
        the global seqno counter, and a shard-local submit would
        self-assign seqnos that collide with future router-assigned
        ones — silently deduping later legitimate traffic.  Scrubs at
        the owner shard and purges both the router's dead letters
        (global ids) and the shard's (local rows).
        """
        if not 0 <= user < self.spec.n_users:
            raise InvalidEventError(
                Event(KIND_DEL_BASKET, user),
                f"user {user} outside the deployment's "
                f"[0, {self.spec.n_users}) global range")
        t0 = time.perf_counter()
        self.run_until_drained()
        sh = self.shards[self.spec.shard_of(user)]
        local = int(self.spec.local_row(user))
        # device-side scalar read (see StreamingEngine.forget_user)
        nb = int(jax.device_get(sh.store.state.n_baskets[local]))
        first = self._next_seqno
        if nb:
            self.submit([Event(KIND_DEL_BASKET, user, pos=p)
                         for p in range(nb - 1, -1, -1)])
            self.run_until_drained()
        sh._scrub_user(local)
        purged = sh._purge_dead_letters(local)
        kept = [(ev, why) for ev, why in self.dead_letter
                if ev.user != user]
        purged += len(self.dead_letter) - len(kept)
        self.dead_letter.clear()
        self.dead_letter.extend(kept)
        return ForgetReceipt(
            user=user, n_baskets_deleted=nb,
            seqnos=tuple(range(first, first + nb)),
            purged_dead_letters=purged,
            latency_s=time.perf_counter() - t0,
            residue=sh.store.row_residue([local]))

    # -- micro-batch processing -----------------------------------------------

    def step(self) -> int:
        """Process one micro-batch per shard; returns events applied.

        Kind partitioning happens locally, so pow2 sub-batch bucket
        sizes stay shard-local.  Three phases: every shard first cuts
        its batch and dispatches its device step-summary programs
        (`_prepare_step`, async), then every shard blocks on its ONE
        summary fetch and applies (`_complete_step`), then the log
        advances — so no shard's transfer delays another shard's
        dispatch.  Each shard's ``last_batch_seconds`` covers only its
        own phase durations, not the other shards' syncs.
        """
        prepped = []
        for sh in self.shards:
            t0 = time.perf_counter()
            prep = sh._prepare_step()
            prepped.append((sh, prep, time.perf_counter() - t0))
        begun = []
        for sh, prep, dt in prepped:
            t0 = time.perf_counter()
            evs = sh._complete_step(prep)
            begun.append((sh, evs, dt + time.perf_counter() - t0))
        total = 0
        for sh, evs, own_dt in begun:
            if evs:
                # shift the start so elapsed = own phases + own finish
                total += sh._finish_step(evs, time.perf_counter() - own_dt)
        return total

    def run_until_drained(self, max_batches: int = 10_000) -> int:
        """Step all shards until no shard has pending events."""
        total = 0
        for _ in range(max_batches):
            n = self.step()
            if n == 0:
                break
            total += n
        return total

    # -- serving ---------------------------------------------------------------

    def corpora(self) -> List[jax.Array]:
        """Per-shard cached serving corpora (each shard-local, §3.6)."""
        return [sh.store.corpus() for sh in self.shards]

    def quantized_corpora(self) -> List[tuple]:
        """Per-shard int8 corpora ``[(q, scale), ...]`` (§8.4 cache)."""
        return [sh.store.quantized_corpus() for sh in self.shards]

    def recommend(self, user_ids, topn: int = 10, k: Optional[int] = None,
                  alpha: Optional[float] = None,
                  metric: str = "euclidean",
                  quantized: bool = False) -> np.ndarray:
        """Cross-shard top-n recommendations for global ``user_ids``.

        Delegates to ``core.knn.sharded_recommend_for_users`` (per-shard
        candidate top-k, streaming merge of [Q, k] score lists — never a
        corpus gather; DESIGN.md §7).  Query batches are padded to pow2
        buckets exactly like the single-engine batcher
        (`StreamingEngine.recommend`): every shard's candidate program
        sees the bucketed Q, so the per-shard compiled-shape count stays
        O(log max_batch) too.  ``quantized=True`` runs the int8 D-tiled
        pipeline over the per-shard quantized caches instead
        (`core.knn.sharded_recommend_for_users_quant`, DESIGN.md §8.4;
        euclidean only) — row-wise quantization makes the cross-shard
        merge bitwise the single-engine quantized path.
        """
        ids, q_n, _ = _pad_request(user_ids)
        if q_n == 0:
            return np.zeros((0, topn), np.int32)
        k = self.params.k_neighbors if k is None else k
        alpha = self.params.alpha if alpha is None else alpha
        if quantized:
            if metric != "euclidean":
                raise ValueError("quantized serving is euclidean-only")
            recs = knn.sharded_recommend_for_users_quant(
                self.quantized_corpora(), ids, k=k, alpha=alpha,
                topn=topn, n_shards=self.spec.n_shards)
        else:
            recs = knn.sharded_recommend_for_users(
                self.corpora(), ids, k=k, alpha=alpha,
                topn=topn, n_shards=self.spec.n_shards, metric=metric)
        return np.asarray(recs)[:q_n]

    # -- recovery ---------------------------------------------------------------

    def _shard_dir(self, directory: str, shard: int) -> str:
        return os.path.join(directory, f"shard_{shard:03d}")

    def _serialized_legacy(self) -> list:
        return [{"n_shards": e["n_shards"],
                 "logs": [{"watermark": lg["watermark"],
                           "processed_above": sorted(lg["processed_above"])}
                          for lg in e["logs"]]} for e in self._legacy]

    @staticmethod
    def _parse_legacy(raw: list) -> list:
        return [{"n_shards": e["n_shards"],
                 "logs": [{"watermark": lg["watermark"],
                           "processed_above":
                               set(lg.get("processed_above", []))}
                          for lg in e["logs"]]} for e in raw]

    def checkpoint(self, directory: str, step: int) -> None:
        """Commit every shard, then the cross-shard manifest.

        Each shard commits independently and atomically (its own
        fsync'd ``LATEST``, carrying its own exactly-once log); the
        ``SHARDS`` manifest (atomic too) only records the layout, the
        router seqno counter and the legacy logs.  A crash anywhere
        leaves shards at possibly different steps — recoverable by
        replay (DESIGN.md §7 failure table).  A directory written under
        a DIFFERENT layout is refused: re-partitioned shard files would
        tear the old manifest's view.
        """
        os.makedirs(directory, exist_ok=True)
        man_path = os.path.join(directory, _SHARD_MANIFEST)
        if os.path.exists(man_path):
            try:
                man = load_json_checked(man_path)
            except CorruptCheckpointError as e:
                raise CorruptCheckpointError(
                    f"existing manifest {man_path} is torn/corrupt "
                    f"({e}); refusing to commit over a directory whose "
                    "layout cannot be verified — use a fresh directory "
                    "or restore first") from e
            if man["n_shards"] != self.spec.n_shards \
                    or man["n_users"] != self.spec.n_users:
                raise ValueError(
                    f"checkpoint directory holds a "
                    f"{man['n_shards']}-shard/{man['n_users']}-user "
                    f"layout; refusing to overwrite with "
                    f"{self.spec.n_shards}/{self.spec.n_users} — use a "
                    "fresh directory after resharding")
        for s, sh in enumerate(self.shards):
            sh.checkpoint(self._shard_dir(directory, s), step)
        payload = {
            "version": 1,
            "n_shards": self.spec.n_shards,
            "n_users": self.spec.n_users,
            "step": step,
            "next_seqno": self._next_seqno,
            "legacy_logs": self._serialized_legacy(),
        }
        if self.checkpointer is not None:
            # FIFO: queued AFTER every shard's commit job, so the
            # manifest can never describe shards that have not landed
            self.checkpointer.submit(
                functools.partial(atomic_write_json, man_path, payload),
                label=f"{man_path}@{step}")
        else:
            atomic_write_json(man_path, payload)

    def flush_checkpoints(self) -> None:
        """Block until every shard commit + manifest landed (see §12)."""
        if self.checkpointer is not None:
            self.checkpointer.flush()

    def restore(self, directory: str) -> None:
        """Install a sharded checkpoint, resharding when layouts differ.

        Same shard count: each shard restores its own commit (states may
        sit at different steps after a torn crash; replay converges
        them).  Different shard count (N→M): global user rows are
        reassembled through the spec bijection and the N old logs become
        legacy logs (`_legacy_processed`).  A flat single-engine
        checkpoint (no manifest, root ``LATEST``) restores as N=1.
        Pending async commits are flushed first (deterministic LATEST
        + manifest; a recorded writer crash re-raises here).
        """
        self.flush_checkpoints()
        man_path = os.path.join(directory, _SHARD_MANIFEST)
        man = None
        if os.path.exists(man_path):
            try:
                man = load_json_checked(man_path)
            except CorruptCheckpointError as e:
                raise CorruptCheckpointError(
                    f"sharded checkpoint manifest {man_path} is "
                    f"torn/corrupt ({e}); the per-shard commits may "
                    "still be intact — restore shard directories "
                    "individually or rebuild the manifest") from e
            n_old = man["n_shards"]
            if man["n_users"] != self.spec.n_users:
                raise ValueError(
                    f"checkpoint n_users={man['n_users']} != spec "
                    f"n_users={self.spec.n_users}")
            dirs = [self._shard_dir(directory, s) for s in range(n_old)]
        elif os.path.exists(os.path.join(directory, "LATEST")):
            n_old, dirs = 1, [directory]      # flat single-engine layout
        else:
            raise FileNotFoundError(
                f"no {_SHARD_MANIFEST} manifest or LATEST in {directory}")
        # every shard directory must hold a restorable commit before ANY
        # shard is touched: failing fast with the offending path beats a
        # bare traceback after half the fleet was already overwritten
        missing = [d for d in dirs
                   if not (os.path.exists(os.path.join(d, "LATEST"))
                           or os.path.exists(os.path.join(d,
                                                          "LATEST.prev")))]
        if missing:
            raise FileNotFoundError(
                f"sharded checkpoint {directory} declares {n_old} "
                f"shard(s) but is missing commit(s) in: "
                f"{', '.join(missing)} — expected shard_000 … "
                f"shard_{n_old - 1:03d}, each holding a LATEST (or "
                "LATEST.prev) commit")
        self._legacy = self._parse_legacy(man.get("legacy_logs", [])
                                          if man else [])
        if n_old == self.spec.n_shards:
            for s, sh in enumerate(self.shards):
                sh.restore(dirs[s])
            self._next_seqno = max(
                [sh._next_seqno for sh in self.shards]
                + ([man["next_seqno"]] if man else []))
        else:
            self._restore_resharded(dirs, n_old)

    def recover_shard(self, shard: int, directory: str) -> dict:
        """Re-restore ONE shard's commit with its serving kept degraded.

        Freezes the shard's serving corpus first, so cross-shard
        ``recommend`` keeps answering from the pinned snapshot (stale
        but well-formed) while the shard's state store restores from its
        last good commit — the other shards are untouched.  On success
        serving thaws to the recovered state and the shard's recovery
        info (``{"source", "skipped"}``, see
        ``state_store.load_checkpoint_arrays``) is returned; on failure
        the shard STAYS frozen, still answering from the snapshot, and
        the error propagates.
        """
        sh = self.shards[shard]
        sh.freeze_serving()
        sh.restore(self._shard_dir(directory, shard))
        info = dict(sh.store.last_restored_meta.get(
            "_recovery", {"source": "LATEST", "skipped": []}))
        sh.thaw_serving()
        return info

    def _restore_resharded(self, dirs: List[str], n_old: int) -> None:
        """N→M restore: re-partition states, demote old logs to legacy."""
        spec = self.spec
        metas, leaves, old_logs = [], [], []
        for d in dirs:
            meta, lv = load_checkpoint_arrays(d)
            # shape validation minus the per-shard user count (which
            # legitimately differs across layouts)
            probe = dict(meta)
            probe.pop("n_users", None)
            self.shards[0].store._validate_meta(probe)
            log = meta.get("engine")
            if log is None:
                path = os.path.join(d, "ENGINE")
                if os.path.exists(path):       # legacy flat layout
                    with open(path) as f:
                        log = json.load(f)
            if log is None:
                raise ValueError(
                    f"shard checkpoint {d} carries no exactly-once log; "
                    "refusing to reshard (replay could double-apply)")
            metas.append(meta)
            leaves.append(lv)
            old_logs.append(log)
        n_total = sum(lv["n_baskets"].shape[0] for lv in leaves)
        if n_total != spec.n_users:
            raise ValueError(f"checkpoint holds {n_total} user rows, spec "
                             f"n_users={spec.n_users}")
        # assemble per-new-shard host buffers; the spec bijection covers
        # every row, so no initialization value survives
        out = []
        for s in range(spec.n_shards):
            cfg = self.shards[s].store.cfg
            zero = StreamState.zeros(cfg.n_users, cfg.n_items,
                                     cfg.max_baskets, cfg.max_basket_size,
                                     cfg.max_groups)
            out.append({name: np.asarray(getattr(zero, name)).copy()
                        for name in _STATE_LEAVES})
        for so, lv in enumerate(leaves):
            rows = lv["n_baskets"].shape[0]
            u_glob = np.arange(rows, dtype=np.int64) * n_old + so
            keep = u_glob < spec.n_users
            u_glob = u_glob[keep]
            ns, nr = u_glob % spec.n_shards, u_glob // spec.n_shards
            for name in _STATE_LEAVES:
                src = lv[name][keep]
                for s in range(spec.n_shards):
                    m = ns == s
                    out[s][name][nr[m]] = src[m]
        for s, sh in enumerate(self.shards):
            sh.store.install_state(StreamState(
                **{k: jnp.asarray(v) for k, v in out[s].items()}))
            sh._reset_log()
        self._legacy.append({"n_shards": n_old, "logs": [
            {"watermark": lg["watermark"],
             "processed_above": set(lg.get("processed_above", []))}
            for lg in old_logs]})
        self._next_seqno = max(max(lg["next_seqno"] for lg in old_logs),
                               self._next_seqno)
