"""Decayed multi-hot scatter as a one-hot matmul Pallas kernel (TPU).

Builds TIFU-kNN user vectors (closed-form weighted multi-hot sum,
DESIGN.md §3.1) and doubles as the TPU-native EmbeddingBag-transpose:

    out[i] = Σ_{n,b} w[n] · [ ids[n,b] == i ]        (ids PAD=-1)

TPUs dislike data-dependent scatter; the MXU/VPU love regular compare +
reduce.  Grid = (I / bi) item tiles × (N / bn) row tiles (rows inner,
sequential): each step builds the [bn·B, bi] one-hot tile by comparing
the id block against the tile's iota and accumulates ``wᵀ @ onehot``
into a VMEM accumulator; only [I] leaves the chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem as _avmem
from repro.analysis.contracts import KernelContract, register


def _kernel(ids_ref, w_ref, out_ref, acc, *, bi: int):
    ii = pl.program_id(0)
    ni = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(ni == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    ids = ids_ref[...]                               # [bn, B] i32
    w = w_ref[...]                                   # [bn]
    flat = ids.reshape(-1)                           # [bn*B]
    wf = jnp.repeat(w, ids.shape[1])                 # [bn*B]
    base = ii * bi
    # one-hot against this item tile: [bn*B, bi]
    tile_ids = base + jax.lax.broadcasted_iota(jnp.int32, (flat.shape[0],
                                                           bi), 1)
    onehot = (flat[:, None] == tile_ids).astype(jnp.float32)
    acc[...] += jnp.sum(onehot * wf[:, None], axis=0)

    @pl.when(ni == nn - 1)
    def _done():
        out_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("n_items", "bi", "bn",
                                             "interpret"))
def decayed_scatter(ids, weights, n_items: int, bi: int = 512, bn: int = 256,
                    interpret: bool = False):
    """ids i32[N, B] (PAD=-1), weights f32[N] → f32[n_items]."""
    n, b = ids.shape
    bi = min(bi, n_items)
    bn = min(bn, n)
    assert n_items % bi == 0 and n % bn == 0, (n_items, bi, n, bn)
    grid = (n_items // bi, n // bn)
    # PAD ids (-1) never match a non-negative tile id → contribute 0.
    return pl.pallas_call(
        functools.partial(_kernel, bi=bi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, b), lambda ii, ni: (ni, 0)),
            pl.BlockSpec((bn,), lambda ii, ni: (ni,)),
        ],
        out_specs=pl.BlockSpec((bi,), lambda ii, ni: (ii,)),
        out_shape=jax.ShapeDtypeStruct((n_items,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi,), jnp.float32)],
        interpret=interpret,
    )(ids, weights)


@functools.partial(jax.jit, static_argnames=("n_items", "interpret"))
def batched_decayed_scatter(ids, weights, n_items: int,
                            interpret: bool = False):
    """vmap over users: ids [U, N, B], weights [U, N] → [U, n_items]."""
    return jax.vmap(lambda i, w: decayed_scatter(i, w, n_items,
                                                 interpret=interpret))(
        ids, weights)


# Kernel contract (DESIGN.md §10.1): both grid axes are exact divisions
# guarded by the assert in the entry (divisible=True).
register(KernelContract(
    module="repro.kernels.decayed_scatter",
    entry="decayed_scatter",
    body="_kernel",
    grid_rank=2,
    divisible=True,
    accumulators=("float32",),
    vmem_model=_avmem.decayed_scatter_block_bytes,
    max_shapes={"b": 512, "bn": 256, "bi": 512},
))
