"""Sparse per-row gather from a [M, I] table (TPU Pallas).

The sparse decremental paths (core.updates.apply_del_basket_batch /
apply_del_item_batch, DESIGN.md §3.5) and the sparse add path both need
the *current raw values* of a [M, I] state table at a per-event support
``(rows[U], ids[U, W])`` with W ≪ I:

    vals[r, w] = table[rows[r], ids[r, w]]          (PAD ids give 0)

This is the read half of the ``sparse_row_scatter`` pair.  TPUs dislike
data-dependent gather, so per tile the read is a compare + reduce: the
[W, bi] one-hot of the row's ids against the item tile's iota,
contracted with the tile values.

Like the scatter, the grid is driven by a **touched-tile plan**
(kernels.tile_plan): grid ``(U, T_max)`` with the scalar-prefetched plan
arrays driving the table block index map, so a step DMAs only a ``[1,
bi]`` tile the row's ids actually touch — O(U·W) HBM traffic, matching
the XLA reference path (kernels.ref.sparse_row_gather_ref, the CPU/GPU
path).  The plan keeps ``order="batch"``: reads commute, so duplicate
target rows need no sorting, and each ``[1, W]`` output block is
resident for exactly its row's tile run (zeroed on the first step,
accumulated across the run).  Padding steps repeat the row's last real
tile (no block change → no DMA) and are ``pl.when``-guarded out of the
compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem as _avmem
from repro.analysis.contracts import KernelContract, register
from repro.kernels.tile_plan import build_plan


def _kernel(pbatch_ref, prow_ref, ptile_ref, pvalid_ref, ids_ref, tab_ref,
            out_ref, *, bi: int, t_max: int):
    del pbatch_ref, prow_ref  # consumed by the index maps only
    r = pl.program_id(0)
    t = pl.program_id(1)
    s = r * t_max + t

    @pl.when(t == 0)
    def _zero():
        out_ref[0, :] = jnp.zeros_like(out_ref[0, :])

    @pl.when(pvalid_ref[s] == 1)
    def _accumulate():
        ids = ids_ref[0, :]                          # [W] i32, PAD=-1
        tile_vals = tab_ref[0, :]                    # [bi]
        base = ptile_ref[s] * bi
        grid = base + jax.lax.broadcasted_iota(jnp.int32,
                                               (ids.shape[0], bi), 1)
        onehot = (ids[:, None] == grid).astype(tile_vals.dtype)  # PAD misses
        out_ref[0, :] += jnp.sum(onehot * tile_vals[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("bi", "t_max", "interpret"))
def sparse_row_gather(table, rows, ids, bi: int = 512,
                      t_max: int | None = None, interpret: bool = False):
    """vals f32[U, W] = table[rows i32[U], ids i32[U, W]] (PAD ids → 0).

    Requires I % bi == 0 and ``t_max`` >= the largest per-row
    touched-tile count (None picks the always-safe ``min(W, I/bi)``) —
    the ops.py dispatcher selects both / falls back to the XLA reference.
    """
    m, n_items = table.shape
    u, w = ids.shape
    bi = min(bi, n_items)
    assert n_items % bi == 0, (n_items, bi)
    n_tiles = n_items // bi
    if t_max is None:
        t_max = min(w, n_tiles)
    t_max = max(1, min(t_max, w, n_tiles))
    rows = jnp.clip(rows, 0, m - 1).astype(jnp.int32)
    plan = build_plan(rows, ids, bi=bi, t_max=t_max, order="batch")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(u, t_max),
        in_specs=[
            pl.BlockSpec((1, w), lambda r, t, pb, pr, pt, pv: (r, 0)),
            pl.BlockSpec((1, bi),
                         lambda r, t, pb, pr, pt, pv: (pr[r * t_max + t],
                                                       pt[r * t_max + t])),
        ],
        out_specs=pl.BlockSpec((1, w),
                               lambda r, t, pb, pr, pt, pv: (r, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, bi=bi, t_max=t_max),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((u, w), table.dtype),
        interpret=interpret,
    )(plan.batch, plan.row, plan.tile, plan.valid, ids, table)


# Kernel contract (DESIGN.md §10.1): plan-driven grid, no scratch (the
# [1, W] output block is the run-resident accumulator); divisible=True
# records the I % bi == 0 precondition asserted above.
register(KernelContract(
    module="repro.kernels.sparse_row_gather",
    entry="sparse_row_gather",
    body="_kernel",
    grid_rank=2,
    scalar_prefetch=4,
    divisible=True,
    accumulators=(),
    vmem_model=_avmem.sparse_row_gather_block_bytes,
    max_shapes={"w": 4096, "bi": 512},
))
