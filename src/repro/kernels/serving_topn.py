"""Fused neighbour-blend + top-n Pallas kernels (serving stage B).

The TIFU-kNN prediction a request needs per (query q, item i) is

    p[q, i] = alpha · corpus[uid_q, i]
            + (1 − alpha)/k · Σ_{j ∈ topk(q)} corpus[j, i]

followed by a top-n over i.  The reference path materializes the
neighbour gather ``corpus[idx]`` — [Q, k, I] in HBM (80 GB at Q=4096,
k=300, I=16k) — plus a [Q, I] prediction round-trip.  These kernels
keep both on chip (DESIGN.md §8):

``blend_topn_onehot`` — the single-corpus fused path.  grid =
(⌈Q/bq⌉, ⌈I/bi⌉, ⌈M/bm⌉), M innermost: per item tile the neighbour sum
accumulates as a **one-hot matmul** ``member[bq, bm] @ corpus[bm, bi]``
on the MXU (membership counts built from the [bq, k] index lists, in
k-chunks to bound VMEM), the query row is recovered the same way
(``uid`` one-hot — no [Q, I] query gather at all), and after the last
corpus tile the blended prediction tile merges into a running [bq, n]
top-n buffer.  Only [Q, n] leaves the chip; HBM traffic is
O(Q/bq · M · I) corpus reads + O(Q·k) index reads.

``blend_topn_rows`` — the cross-shard path (DESIGN.md §7.3), where the
k selected neighbour rows were already fetched from their owner shards
([Q, k, I] is the unavoidable cross-shard traffic).  grid =
(⌈Q/bq⌉, ⌈I/bi⌉): mean-over-k + blend + running top-n per item tile —
the [Q, I] prediction intermediate never exists.

Both merges preserve lax.top_k's lowest-index tie-break: the running
buffer (earlier = lower item ids) sits first in the concatenated
top_k input.  Tail blocks in Q, I and M are masked in-kernel, so no
dimension needs to divide its block size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem as _avmem
from repro.analysis.contracts import OOB_WRITE, KernelContract, register


def _merge_topn(top_vals, top_idx, pred, item_ids, n: int):
    """Merge a [bq, bi] prediction tile into the running [bq, n] buffer."""
    mv = jnp.concatenate([top_vals[...], pred], axis=1)
    mi = jnp.concatenate([top_idx[...], item_ids], axis=1)
    tv, tp = jax.lax.top_k(mv, n)
    top_vals[...] = tv
    top_idx[...] = jnp.take_along_axis(mi, tp, axis=1)


def _onehot_kernel(uid_ref, idx_ref, c_ref, vals_ref, ids_ref, acc_self,
                   acc_nbr, top_vals, top_idx, *, k: int, alpha: float,
                   topn: int, bm: int, bi: int, m: int, n_items: int,
                   kc: int):
    ii = pl.program_id(1)
    mi = pl.program_id(2)
    ni = pl.num_programs(1)
    nm = pl.num_programs(2)

    @pl.when((ii == 0) & (mi == 0))
    def _init_topn():
        top_vals[...] = jnp.full_like(top_vals, -jnp.inf)
        top_idx[...] = jnp.zeros_like(top_idx)

    @pl.when(mi == 0)
    def _init_acc():
        acc_self[...] = jnp.zeros_like(acc_self)
        acc_nbr[...] = jnp.zeros_like(acc_nbr)

    rows = mi * bm + jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
    c = c_ref[...]                                    # [bm, bi]
    # tail corpus rows carry garbage (OOB block read) — zero them so the
    # contraction below cannot leak NaN into valid accumulator lanes
    row_col = mi * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bi), 0)
    c = jnp.where(row_col < m, c, 0.0)
    uid = uid_ref[...]                                # [bq]
    # self row via one-hot matmul: exactly corpus[uid] (one 1.0 per row)
    self_sel = (uid[:, None] == rows).astype(jnp.float32)
    acc_self[...] += jax.lax.dot_general(
        self_sel, c, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # neighbour membership counts, built in k-chunks to bound VMEM
    # ([bq, kc, bm] compare tensors instead of [bq, k, bm])
    member = jnp.zeros(self_sel.shape, jnp.float32)
    for lo in range(0, k, kc):
        chunk = idx_ref[:, lo:min(lo + kc, k)]        # [bq, <=kc]
        member += jnp.sum(
            (chunk[:, :, None] == rows[None, :, :]).astype(jnp.float32),
            axis=1)                                   # PAD (-1) never hits
    acc_nbr[...] += jax.lax.dot_general(
        member, c, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(mi == nm - 1)
    def _merge():
        pred = (alpha * acc_self[...]
                + (1.0 - alpha) * acc_nbr[...] / k)   # [bq, bi]
        item_ids = ii * bi + jax.lax.broadcasted_iota(jnp.int32,
                                                      pred.shape, 1)
        pred = jnp.where(item_ids >= n_items, -jnp.inf, pred)
        _merge_topn(top_vals, top_idx, pred, item_ids, topn)

    @pl.when((ii == ni - 1) & (mi == nm - 1))
    def _done():
        vals_ref[...] = top_vals[...]
        ids_ref[...] = top_idx[...]


@functools.partial(jax.jit, static_argnames=("alpha", "topn", "bq", "bm",
                                             "bi", "kc", "interpret"))
def blend_topn_onehot(corpus, user_ids, nbr_idx, alpha: float, topn: int,
                      bq: int = 128, bm: int = 512, bi: int = 512,
                      kc: int = 32, interpret: bool = False):
    """Fused one-hot blend + top-n over the corpus (stage B, §8.1).

    corpus [M, I] × user_ids i32[Q] × nbr_idx i32[Q, k] →
    (vals f32[Q, topn], item ids i32[Q, topn]).  ``nbr_idx`` are local corpus rows (entries of −1 contribute zero but
    still count toward the mean divisor k, matching the reference mean
    over a fixed k).  ``user_ids`` select the query rows — the alpha
    term reads them through the same one-hot contraction, so the [Q, I]
    query gather never materializes.
    """
    q_n = user_ids.shape[0]
    m, n_items = corpus.shape
    k = nbr_idx.shape[1]
    if q_n == 0 or m == 0:
        return (jnp.full((q_n, topn), -jnp.inf, jnp.float32),
                jnp.zeros((q_n, topn), jnp.int32))
    bq = min(bq, q_n)
    bm = min(bm, m)
    bi = min(bi, n_items)
    grid = (pl.cdiv(q_n, bq), pl.cdiv(n_items, bi), pl.cdiv(m, bm))
    kernel = functools.partial(_onehot_kernel, k=k, alpha=float(alpha),
                               topn=topn, bm=bm, bi=bi, m=m,
                               n_items=n_items, kc=kc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda qi, ii, mi: (qi,)),
            pl.BlockSpec((bq, k), lambda qi, ii, mi: (qi, 0)),
            pl.BlockSpec((bm, bi), lambda qi, ii, mi: (mi, ii)),
        ],
        out_specs=[
            pl.BlockSpec((bq, topn), lambda qi, ii, mi: (qi, 0)),
            pl.BlockSpec((bq, topn), lambda qi, ii, mi: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, topn), jnp.float32),
            jax.ShapeDtypeStruct((q_n, topn), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, bi), jnp.float32),    # alpha (self) partial
            pltpu.VMEM((bq, bi), jnp.float32),    # neighbour-sum partial
            pltpu.VMEM((bq, topn), jnp.float32),  # running top-n vals
            pltpu.VMEM((bq, topn), jnp.int32),    # running top-n ids
        ],
        interpret=interpret,
    )(user_ids.astype(jnp.int32), nbr_idx, corpus)


def _rows_kernel(q_ref, nbr_ref, vals_ref, ids_ref, top_vals, top_idx, *,
                 alpha: float, topn: int, bi: int, n_items: int):
    ii = pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when(ii == 0)
    def _init():
        top_vals[...] = jnp.full_like(top_vals, -jnp.inf)
        top_idx[...] = jnp.zeros_like(top_idx)

    neighbors = jnp.mean(nbr_ref[...], axis=1)        # [bq, bi]
    pred = (alpha * q_ref[...] + (1.0 - alpha) * neighbors
            ).astype(jnp.float32)
    item_ids = ii * bi + jax.lax.broadcasted_iota(jnp.int32, pred.shape, 1)
    pred = jnp.where(item_ids >= n_items, -jnp.inf, pred)
    _merge_topn(top_vals, top_idx, pred, item_ids, topn)

    @pl.when(ii == ni - 1)
    def _done():
        vals_ref[...] = top_vals[...]
        ids_ref[...] = top_idx[...]


@functools.partial(jax.jit, static_argnames=("alpha", "topn", "bq", "bi",
                                             "interpret"))
def blend_topn_rows(queries, neighbor_rows, alpha: float, topn: int,
                    bq: int = 8, bi: int = 512, interpret: bool = False):
    """Blend pre-fetched neighbour rows and emit top-n (stage B, §7.3).

    queries [Q, I] × neighbor_rows [Q, k, I] →
    (vals f32[Q, topn], item ids i32[Q, topn]).
    The cross-shard final stage: the k rows were already fetched, so the
    fusion win is skipping the [Q, I] prediction intermediate — mean,
    blend and the top-n merge run per item tile.  ``bq`` defaults low:
    a [bq, k, bi] neighbour block must fit VMEM.
    """
    q_n, n_items = queries.shape
    k = neighbor_rows.shape[1]
    if q_n == 0:
        return (jnp.full((0, topn), -jnp.inf, jnp.float32),
                jnp.zeros((0, topn), jnp.int32))
    bq = min(bq, q_n)
    bi = min(bi, n_items)
    grid = (pl.cdiv(q_n, bq), pl.cdiv(n_items, bi))
    kernel = functools.partial(_rows_kernel, alpha=float(alpha), topn=topn,
                               bi=bi, n_items=n_items)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bi), lambda qi, ii: (qi, ii)),
            pl.BlockSpec((bq, k, bi), lambda qi, ii: (qi, 0, ii)),
        ],
        out_specs=[
            pl.BlockSpec((bq, topn), lambda qi, ii: (qi, 0)),
            pl.BlockSpec((bq, topn), lambda qi, ii: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, topn), jnp.float32),
            jax.ShapeDtypeStruct((q_n, topn), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, topn), jnp.float32),
            pltpu.VMEM((bq, topn), jnp.int32),
        ],
        interpret=interpret,
    )(queries, neighbor_rows)


def _rows_quant_kernel(q_ref, qs_ref, nbr_ref, ns_ref, vals_ref, ids_ref,
                       top_vals, top_idx, *, alpha: float, topn: int,
                       bi: int, n_items: int):
    ii = pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when(ii == 0)
    def _init():
        top_vals[...] = jnp.full_like(top_vals, -jnp.inf)
        top_idx[...] = jnp.zeros_like(top_idx)

    # dequantize in VMEM: only int8 rows crossed HBM (4× less traffic)
    nbr = nbr_ref[...].astype(jnp.float32) * ns_ref[...][:, :, None]
    neighbors = jnp.mean(nbr, axis=1)                 # [bq, bi]
    q = q_ref[...].astype(jnp.float32) * qs_ref[...][:, None]
    pred = (alpha * q + (1.0 - alpha) * neighbors).astype(jnp.float32)
    item_ids = ii * bi + jax.lax.broadcasted_iota(jnp.int32, pred.shape, 1)
    pred = jnp.where(item_ids >= n_items, -jnp.inf, pred)
    _merge_topn(top_vals, top_idx, pred, item_ids, topn)

    @pl.when(ii == ni - 1)
    def _done():
        vals_ref[...] = top_vals[...]
        ids_ref[...] = top_idx[...]


@functools.partial(jax.jit, static_argnames=("alpha", "topn", "bq", "bi",
                                             "interpret"))
def blend_topn_rows_quant(queries_q, q_scale, neighbor_rows_q, n_scale,
                          alpha: float, topn: int, bq: int = 8,
                          bi: int = 512, interpret: bool = False):
    """Quantized stage-B blend (DESIGN.md §8.4), int8 rows in VMEM.

    queries_q int8[Q, I] × neighbor_rows_q int8[Q, k, I] →
    (vals f32[Q, topn], ids i32[Q, topn]).  The int8 twin of :func:`blend_topn_rows`: the k selected rows cross
    HBM quantized (¼ the bytes of the fp32 fetch) with their per-row
    scales (``q_scale`` f32[Q], ``n_scale`` f32[Q, k]), and are
    dequantized in VMEM — exact elementwise multiplies, so the blended
    prediction matches ``ref.blend_topn_rows_quant_ref`` on the same
    operands.  Mean divisor, tail-mask and the lowest-index tie-break
    follow :func:`blend_topn_rows`.  VMEM per step is O(bq·k·bi) int8 +
    f32 dequant scratch; ``bq`` defaults low accordingly.
    """
    q_n, n_items = queries_q.shape
    k = neighbor_rows_q.shape[1]
    if q_n == 0:
        return (jnp.full((0, topn), -jnp.inf, jnp.float32),
                jnp.zeros((0, topn), jnp.int32))
    bq = min(bq, q_n)
    bi = min(bi, n_items)
    grid = (pl.cdiv(q_n, bq), pl.cdiv(n_items, bi))
    kernel = functools.partial(_rows_quant_kernel, alpha=float(alpha),
                               topn=topn, bi=bi, n_items=n_items)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bi), lambda qi, ii: (qi, ii)),
            pl.BlockSpec((bq,), lambda qi, ii: (qi,)),
            pl.BlockSpec((bq, k, bi), lambda qi, ii: (qi, 0, ii)),
            pl.BlockSpec((bq, k), lambda qi, ii: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, topn), lambda qi, ii: (qi, 0)),
            pl.BlockSpec((bq, topn), lambda qi, ii: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n, topn), jnp.float32),
            jax.ShapeDtypeStruct((q_n, topn), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, topn), jnp.float32),
            pltpu.VMEM((bq, topn), jnp.int32),
        ],
        interpret=interpret,
    )(queries_q, q_scale, neighbor_rows_q, n_scale)


# Kernel contracts (DESIGN.md §10.1).  Query-axis tails are handled by
# Pallas OOB write masking; item/corpus axes by the quoted in-kernel
# masks.
register(KernelContract(
    module="repro.kernels.serving_topn",
    entry="blend_topn_onehot",
    body="_onehot_kernel",
    grid_rank=3,
    tail={0: OOB_WRITE, 1: "item_ids >= n_items", 2: "row_col < m"},
    accumulators=("float32", "float32", "float32", "int32"),
    vmem_model=_avmem.blend_topn_onehot_block_bytes,
    max_shapes={"k": 1024, "topn": 512, "bq": 128, "bm": 512,
                "bi": 512},
))
register(KernelContract(
    module="repro.kernels.serving_topn",
    entry="blend_topn_rows",
    body="_rows_kernel",
    grid_rank=2,
    tail={0: OOB_WRITE, 1: "item_ids >= n_items"},
    accumulators=("float32", "int32"),
    vmem_model=_avmem.blend_topn_rows_block_bytes,
    max_shapes={"k": 900, "topn": 512, "bq": 8, "bi": 512},
))
register(KernelContract(
    module="repro.kernels.serving_topn",
    entry="blend_topn_rows_quant",
    body="_rows_quant_kernel",
    grid_rank=2,
    tail={0: OOB_WRITE, 1: "item_ids >= n_items"},
    accumulators=("float32", "int32"),
    vmem_model=_avmem.blend_topn_rows_quant_block_bytes,
    max_shapes={"k": 900, "topn": 512, "bq": 8, "bi": 512},
))
