"""Personalised collaborative-filtering prediction and ranking metrics.

Covers the paper's prediction step (§2.2, last part) and the evaluation
metrics (Recall@K, NDCG@K — §6.1).

Prediction:  p = alpha * u_target + (1 - alpha) * mean(top-k neighbours).

``nearest_neighbors``/``predict`` are the reference (jnp) formulation —
the semantics oracle and the building block for ad-hoc analysis.  The
SERVING entry points (`recommend_for_users`, `shard_topk_candidates`,
`sharded_recommend_for_users`) dispatch through ``kernels.ops``
(DESIGN.md §8): on TPU they run the fused Pallas pipeline
(`kernels.knn_topk` streaming top-k + `kernels.serving_topn` one-hot
blend/top-n — O(Q·k) HBM intermediates, never a [Q, M] score matrix or
[Q, k, I] gather); on CPU they run `kernels.ref` oracles that are
bitwise the historical unfused outputs, and interpret mode drives the
Pallas path on any host (tests pin all three against each other).
Distances follow TIFU-kNN: Euclidean by default, cosine optional
(cosine serves through the reference path — the kernels fuse the
euclidean surrogate and dot only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ops


def pairwise_scores(queries, corpus, metric: str = "euclidean"):
    """Similarity scores (higher = closer). [Q,I] x [M,I] → [Q,M]."""
    if metric == "euclidean":
        # -||q - c||^2 = 2 q·c - ||q||^2 - ||c||^2 (monotone in distance)
        qc = queries @ corpus.T
        qn = jnp.sum(queries * queries, axis=-1, keepdims=True)
        cn = jnp.sum(corpus * corpus, axis=-1)[None, :]
        return 2.0 * qc - qn - cn
    if metric == "cosine":
        qn = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-12)
        cn = corpus / jnp.maximum(
            jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-12)
        return qn @ cn.T
    if metric == "dot":
        return queries @ corpus.T
    raise ValueError(f"unknown metric {metric}")


@functools.partial(jax.jit, static_argnames=("k", "metric", "exclude_self"))
def nearest_neighbors(queries, corpus, k: int, metric: str = "euclidean",
                      exclude_self: bool = False, query_ids=None):
    """Top-k neighbour indices per query. Returns (scores, indices)."""
    scores = pairwise_scores(queries, corpus, metric)
    if exclude_self:
        ids = (jnp.arange(queries.shape[0]) if query_ids is None
               else query_ids)
        scores = scores.at[jnp.arange(queries.shape[0]), ids].set(-jnp.inf)
    return jax.lax.top_k(scores, k)


@functools.partial(jax.jit, static_argnames=("k", "metric", "exclude_self",
                                              "mesh", "rules"))
def predict(queries, corpus, k: int, alpha: float,
            metric: str = "euclidean", exclude_self: bool = True,
            query_ids=None, mesh=None, rules=None):
    """Final TIFU-kNN prediction vector p per query user. [Q,I].

    With a mesh: corpus users sharded over (pod,data), items over model —
    scores are constrained corpus-sharded (never [Q,M]-replicated), the
    per-shard top-k merge is XLA's partitioned top-k, and the neighbour
    gather stays item-sharded.  Sharding-agnostic semantics otherwise.
    """
    if mesh is None:
        _, idx = nearest_neighbors(queries, corpus, k, metric, exclude_self,
                                   query_ids)
        neighbors = jnp.mean(corpus[idx], axis=1)        # [Q, I]
        return alpha * queries + (1.0 - alpha) * neighbors

    _, idx = streaming_topk(queries, corpus, k, metric,
                            exclude_self=exclude_self, query_ids=query_ids)
    neighbors = chunked_neighbor_mean(corpus, idx)
    return alpha * queries + (1.0 - alpha) * neighbors


def streaming_topk(queries, corpus, k: int, metric: str = "euclidean",
                   chunk: int = 65536, exclude_self: bool = False,
                   query_ids=None):
    """Top-k without materializing the [Q, M] score matrix.

    Scans corpus chunks with a running top-k merge — the pure-JAX
    rendition of kernels.knn_topk (the Pallas kernel is the on-chip TPU
    version of this schedule).
    """
    q_n, d = queries.shape
    m = corpus.shape[0]
    # Remainder rows are handled as one extra masked tail block (padding
    # only O(chunk) rows, never a full-corpus copy).  Shrinking the chunk
    # instead degenerates to chunk=1 — a scan of length M — for
    # prime-sized corpora.
    chunk = max(1, min(chunk, m))    # m == 0 → zero blocks, -inf result
    nc = m // chunk
    blocks = corpus[:nc * chunk].reshape(nc, chunk, d)
    qids = (jnp.arange(q_n) if query_ids is None else query_ids)

    def body(carry, inp):
        vals, idx = carry
        block, ci = inp
        s = pairwise_scores(queries, block, metric)       # [Q, chunk]
        tile = ci * chunk + jnp.arange(chunk)[None, :]
        s = jnp.where(tile >= m, -jnp.inf, s)             # padding rows
        if exclude_self:
            s = jnp.where(tile == qids[:, None], -jnp.inf, s)
        mv = jnp.concatenate([vals, s.astype(jnp.float32)], axis=1)
        mi = jnp.concatenate([idx, jnp.broadcast_to(tile, s.shape)], axis=1)
        tv, tp_ = jax.lax.top_k(mv, k)
        return (tv, jnp.take_along_axis(mi, tp_, axis=1)), None

    init = (jnp.full((q_n, k), -jnp.inf, jnp.float32),
            jnp.zeros((q_n, k), jnp.int32))
    carry, _ = jax.lax.scan(body, init, (blocks, jnp.arange(nc)))
    rem = m - nc * chunk
    if rem:
        tail = jnp.zeros((chunk, d), corpus.dtype).at[:rem].set(
            corpus[nc * chunk:])
        carry, _ = body(carry, (tail, jnp.asarray(nc)))
    return carry


def distributed_predict(queries, corpus, k: int, alpha: float, mesh, rules,
                        metric: str = "euclidean"):
    """Optimized distributed TIFU-kNN prediction (EXPERIMENTS.md §Perf H1).

    Sharding: corpus USERS over every mesh axis, items unsharded; queries
    replicated.  Per device: local scores + local top-k; two-stage
    hierarchical candidate merge (model axis then data axis — each an
    all-gather of only [Q, k] candidates); neighbour mean as a local
    one-hot matmul (MXU-friendly, no [Q,k,I] gather) psum'd once.

    vs the natural item-TP formulation (psum of [Q, M] partial scores +
    row gathers): measured 26 GiB → <1 GiB collectives per device.
    """
    from jax.sharding import PartitionSpec as P
    axes = tuple(a for a in ("pod", "data", "model")
                 if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_shards = int(np.prod([sizes[a] for a in axes]))
    m_loc = corpus.shape[0] // n_shards
    q_n = queries.shape[0]

    def body(q, c_loc):
        shard = jnp.zeros((), jnp.int32)
        for a in axes:
            shard = shard * sizes[a] + jax.lax.axis_index(a)
        lo = shard * m_loc
        s = pairwise_scores(q, c_loc, metric).astype(jnp.float32)
        vals, idx = jax.lax.top_k(s, k)                  # local candidates
        idx = idx + lo
        # hierarchical merge: innermost axis first
        for a in reversed(axes):
            vals_g = jax.lax.all_gather(vals, a, axis=1, tiled=True)
            idx_g = jax.lax.all_gather(idx, a, axis=1, tiled=True)
            vals, pos = jax.lax.top_k(vals_g, k)
            idx = jnp.take_along_axis(idx_g, pos, axis=1)
        # neighbour mean via one-hot matmul over the local rows
        local_id = idx - lo
        valid = (local_id >= 0) & (local_id < m_loc)
        rows = jnp.where(valid, local_id, 0)
        sel = jnp.zeros((q_n, m_loc), c_loc.dtype)
        sel = sel.at[jnp.arange(q_n)[:, None], rows].add(
            valid.astype(c_loc.dtype))
        partial = sel @ c_loc                            # [Q, I] partial sum
        nbr_sum = jax.lax.psum(partial, axes)
        return alpha * q + (1.0 - alpha) * nbr_sum / k

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(axes, None)),
        out_specs=P(None, None), check_vma=False,
    )(queries, corpus)


def chunked_neighbor_mean(corpus, idx, chunk_k: int = 8):
    """mean(corpus[idx], axis=1) accumulated over neighbour chunks.

    Avoids the [Q, k, I] gather (Q=4096, k=300, I=16k ⇒ 80 GB).
    """
    q_n, k = idx.shape
    # Pad the neighbour list to a chunk multiple (index -1, masked in the
    # body) rather than shrinking chunk_k to 1 for prime k.
    chunk_k = max(1, min(chunk_k, k))
    pad = (-k) % chunk_k
    if pad:
        idx = jnp.concatenate(
            [idx, jnp.full((q_n, pad), -1, idx.dtype)], axis=1)
    blocks = idx.reshape(q_n, (k + pad) // chunk_k,
                         chunk_k).transpose(1, 0, 2)

    def body(acc, ib):
        valid = (ib >= 0)[..., None].astype(corpus.dtype)
        rows = jnp.where(ib >= 0, ib, 0)
        return acc + jnp.sum(corpus[rows] * valid, axis=1), None

    acc, _ = jax.lax.scan(
        body, jnp.zeros((q_n, corpus.shape[1]), corpus.dtype), blocks)
    return acc / k


def recommend_topn(pred, n: int):
    """Indices of the top-n scored items per user. [Q, n]."""
    return jax.lax.top_k(pred, n)[1]


def recommend_for_users(corpus, user_ids, k: int, alpha: float, topn: int,
                        metric: str = "euclidean"):
    """Fused serving path: corpus rows → top-n item ids (DESIGN.md §8).

    ``corpus`` is the (cached) materialized corpus f32[M, I]
    (``StateStore.corpus()``, DESIGN.md §3.6); ``user_ids`` i32[Q] are
    the requesting users, which are corpus rows (self-excluded from the
    neighbourhood).  Dispatches through ``kernels.ops.fused_recommend``:
    one compiled program per request batch shape — the engine-side pow2
    request bucketing (`StreamingEngine.recommend`) bounds how many
    such shapes serving ever compiles.  Returns i32[Q, topn] item ids.
    """
    return ops.fused_recommend(corpus, user_ids, k=k, alpha=alpha,
                               topn=topn, metric=metric)


# ---------------------------------------------------------------------------
# Cross-shard serving (user-axis sharded deployment, DESIGN.md §7)
# ---------------------------------------------------------------------------

def shard_topk_candidates(queries, corpus, k: int, shard: int,
                          n_shards: int, query_ids=None,
                          metric: str = "euclidean"):
    """Per-shard neighbour candidates: ``([Q, k] scores, global ids)``.

    ``corpus`` is one shard's local corpus (rows = users owned by
    ``shard`` under the round-robin `UserShardSpec` contract, so local
    row r is global user ``r·n_shards + shard``).  Scores are the same
    per-pair values the single-corpus path computes; self-exclusion
    compares global ids, so a query user is masked only on its owner
    shard.  Dispatches through ``kernels.ops.shard_topk`` (on TPU the
    streaming top-k kernel — the [Q, M_s] score matrix stays on chip).
    O(Q·M_s) compute, O(Q·k) output — the merge step moves candidate
    lists, never corpora.
    """
    return ops.shard_topk(queries, corpus, k=k, shard=shard,
                          n_shards=n_shards, query_gids=query_ids,
                          metric=metric)


def sharded_recommend_for_users(corpora, user_ids, k: int, alpha: float,
                                topn: int, n_shards: int,
                                metric: str = "euclidean") -> np.ndarray:
    """Distributed TIFU-kNN serving over per-shard corpora (§7).

    Pipeline: (1) gather query rows from their owner shards; (2) each
    shard scores queries against only its local corpus and returns its
    top-k candidate ``(score, global id)`` lists; (3) a streaming merge
    takes the global top-k — candidates are ordered by (score desc,
    global id asc), exactly `jax.lax.top_k`'s tie-break on a single
    corpus, so the selected neighbour set and order match the unsharded
    path bitwise; (4) only the k selected neighbour ROWS are fetched
    (O(Q·k·I), never a corpus) and blended exactly as
    `recommend_for_users` does (``kernels.ops.blend_topn_rows`` — on
    TPU the fused mean/blend/top-n kernel, no [Q, I] prediction
    intermediate).  Cross-shard traffic is the [Q, k] candidate lists
    plus the selected rows — corpora and row invalidation stay
    shard-local (`StateStore.corpus`).

    Returns i32[Q, topn] item ids, bitwise-identical to
    ``recommend_for_users`` on the equivalent single corpus
    (tests/test_sharded_engine.py pins this).
    """
    user_ids = np.asarray(user_ids, np.int64)
    corpora_np = [np.asarray(c) for c in corpora]
    q_n = user_ids.shape[0]
    n_items = corpora_np[0].shape[1]
    queries = np.empty((q_n, n_items), corpora_np[0].dtype)
    for s in range(n_shards):
        m = user_ids % n_shards == s
        if m.any():
            queries[m] = corpora_np[s][user_ids[m] // n_shards]
    qs = jnp.asarray(queries)
    qids = jnp.asarray(user_ids.astype(np.int32))
    vals, gids = [], []
    for s in range(n_shards):
        v, g = shard_topk_candidates(qs, corpora[s], k, s, n_shards,
                                     query_ids=qids, metric=metric)
        vals.append(np.asarray(v))
        gids.append(np.asarray(g))
    all_vals = np.concatenate(vals, axis=1)
    all_gids = np.concatenate(gids, axis=1)
    # merge: score desc, global id asc — lax.top_k's tie-break order
    order = np.lexsort((all_gids, -all_vals), axis=-1)
    sel = np.take_along_axis(all_gids, order, axis=1)[:, :k]
    neighbor_rows = np.empty((q_n, sel.shape[1], n_items),
                             corpora_np[0].dtype)
    for s in range(n_shards):
        m = sel % n_shards == s
        if m.any():
            neighbor_rows[m] = corpora_np[s][sel[m] // n_shards]
    return np.asarray(ops.blend_topn_rows(qs, jnp.asarray(neighbor_rows),
                                          alpha, topn))


def recommend_for_users_quant(corpus_q, c_scale, user_ids, k: int,
                              alpha: float, topn: int, bd: int = 512):
    """Int8 fused serving (DESIGN.md §8.4): quantized corpus → top-n ids.

    The million-item twin of :func:`recommend_for_users`: ``corpus_q``
    int8[M, I] with per-row power-of-two ``c_scale`` f32[M]
    (``StateStore.quantized_corpus()``).  Stage A streams the corpus in
    D-tiles of width ``bd`` (VMEM flat in I), stage B fetches only the
    selected k rows — int8 on the wire.  Bitwise-deterministic across
    cpu/interpret/tpu dispatch (exact int32 partials + exact
    power-of-two scale application).  Euclidean only.  Returns
    i32[Q, topn] item ids.
    """
    return ops.fused_recommend_quant(corpus_q, c_scale, user_ids, k=k,
                                     alpha=alpha, topn=topn, bd=bd)


def sharded_recommend_for_users_quant(quant_corpora, user_ids, k: int,
                                      alpha: float, topn: int,
                                      n_shards: int,
                                      bd: int = 512) -> np.ndarray:
    """Distributed int8 serving over per-shard quantized corpora (§8.4).

    Same four-stage pipeline as :func:`sharded_recommend_for_users`,
    int8 end to end: ``quant_corpora`` is a list of per-shard
    ``(corpus_q int8[M_s, I], scale f32[M_s])`` pairs
    (``StateStore.quantized_corpus()``).  Because row quantization is
    corpus-partition invariant (per-row scales — a row's (q, scale)
    does not depend on which shard holds it), every per-pair candidate
    score equals the single-corpus int8 score bitwise, so the merge
    (score desc, global id asc) selects the same neighbour set and the
    result is bitwise ``recommend_for_users_quant`` on the equivalent
    single corpus (tests/test_quantized_serving.py pins this).
    Cross-shard traffic: [Q, k] candidates + the selected rows — int8,
    ¼ the fp32 path's row-fetch bytes.
    """
    user_ids = np.asarray(user_ids, np.int64)
    corpora_np = [np.asarray(q) for q, _ in quant_corpora]
    scales_np = [np.asarray(s) for _, s in quant_corpora]
    q_n = user_ids.shape[0]
    n_items = corpora_np[0].shape[1]
    queries_q = np.empty((q_n, n_items), np.int8)
    q_scale = np.empty((q_n,), np.float32)
    for s in range(n_shards):
        m = user_ids % n_shards == s
        if m.any():
            queries_q[m] = corpora_np[s][user_ids[m] // n_shards]
            q_scale[m] = scales_np[s][user_ids[m] // n_shards]
    qs_j = jnp.asarray(queries_q)
    qscale_j = jnp.asarray(q_scale)
    qids = jnp.asarray(user_ids.astype(np.int32))
    vals, gids = [], []
    for s, (cq, cs) in enumerate(quant_corpora):
        v, g = ops.shard_topk_quant(qs_j, qscale_j, cq, cs, k, shard=s,
                                    n_shards=n_shards, query_gids=qids,
                                    bd=bd)
        vals.append(np.asarray(v))
        gids.append(np.asarray(g))
    all_vals = np.concatenate(vals, axis=1)
    all_gids = np.concatenate(gids, axis=1)
    order = np.lexsort((all_gids, -all_vals), axis=-1)
    sel = np.take_along_axis(all_gids, order, axis=1)[:, :k]
    neighbor_q = np.empty((q_n, sel.shape[1], n_items), np.int8)
    n_scale = np.empty((q_n, sel.shape[1]), np.float32)
    for s in range(n_shards):
        m = sel % n_shards == s
        if m.any():
            neighbor_q[m] = corpora_np[s][sel[m] // n_shards]
            n_scale[m] = scales_np[s][sel[m] // n_shards]
    return np.asarray(ops.blend_topn_rows_quant(
        qs_j, qscale_j, jnp.asarray(neighbor_q), jnp.asarray(n_scale),
        alpha, topn))


# ---------------------------------------------------------------------------
# Ranking metrics (numpy; evaluation only)
# ---------------------------------------------------------------------------

def recall_at_k(recommended: np.ndarray, truth: list, k: int) -> float:
    """Mean Recall@k over users. ``truth``: list of item-id arrays."""
    vals = []
    for recs, t in zip(np.asarray(recommended)[:, :k], truth):
        t = set(int(x) for x in np.asarray(t).ravel() if x >= 0)
        if not t:
            continue
        hit = len(t.intersection(int(r) for r in recs))
        vals.append(hit / len(t))
    return float(np.mean(vals)) if vals else 0.0


def ndcg_at_k(recommended: np.ndarray, truth: list, k: int) -> float:
    """Mean NDCG@k over users (binary relevance)."""
    vals = []
    discounts = 1.0 / np.log2(np.arange(2, k + 2))
    for recs, t in zip(np.asarray(recommended)[:, :k], truth):
        t = set(int(x) for x in np.asarray(t).ravel() if x >= 0)
        if not t:
            continue
        rel = np.array([1.0 if int(r) in t else 0.0 for r in recs])
        dcg = float(np.sum(rel * discounts[:len(rel)]))
        idcg = float(np.sum(discounts[:min(len(t), k)]))
        vals.append(dcg / idcg if idcg > 0 else 0.0)
    return float(np.mean(vals)) if vals else 0.0
