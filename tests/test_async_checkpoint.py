"""AsyncCheckpointer unit contract + snapshot-then-write semantics
(DESIGN.md §12): FIFO commit order, process-like failure fencing
(first error freezes the writer; queued jobs are discarded whole, never
half-run), bitwise sync/async commit equivalence, and snapshot
isolation — a commit serialized long after the engine mutated on must
still land the snapshot-time state.
"""
import threading

import numpy as np
import pytest

from repro.core import TifuParams
from repro.streaming import (AsyncCheckpointer, StateStore, StoreConfig,
                             StreamingEngine, load_checkpoint_arrays)

P = TifuParams(n_items=23, group_size=3, r_b=0.9, r_g=0.7)


def small_store():
    return StateStore(StoreConfig(n_users=4, n_items=P.n_items,
                                  max_baskets=12, max_basket_size=4))


def warmed_engine(n_events=24, checkpointer=None, store=None):
    """An engine with some nontrivial state to checkpoint."""
    rng = np.random.default_rng(3)
    eng = StreamingEngine(store or small_store(), P, batch_size=4,
                          checkpointer=checkpointer)
    for _ in range(n_events):
        items = rng.choice(P.n_items, size=3, replace=False)
        eng.add_basket(int(rng.integers(0, 4)), items)
    assert eng.run_until_drained() == n_events
    return eng


# ---------------------------------------------------------------------------
# AsyncCheckpointer unit contract
# ---------------------------------------------------------------------------

def test_fifo_order_and_completed_labels():
    ck = AsyncCheckpointer()
    ran = []
    for i in range(5):
        ck.submit(lambda i=i: ran.append(i), label=f"job{i}")
    ck.flush()
    assert ran == [0, 1, 2, 3, 4]
    assert list(ck.completed_labels) == [f"job{i}" for i in range(5)]
    assert ck.pending == 0
    assert ck.error is None
    ck.close()


def test_error_fences_queue_and_surfaces_everywhere():
    ck = AsyncCheckpointer()
    gate = threading.Event()
    ran_after = []
    ck.submit(lambda: gate.wait(timeout=30), label="blocker")
    ck.submit(lambda: (_ for _ in ()).throw(ValueError("disk gone")),
              label="boom")
    # queued BEHIND the failing job: must be discarded whole, never run
    ck.submit(lambda: ran_after.append(1), label="after")
    gate.set()
    with pytest.raises(ValueError, match="disk gone"):
        ck.flush()
    assert ran_after == []
    assert list(ck.completed_labels) == ["blocker"]
    assert ck.error is not None
    # every later sync point keeps surfacing the recorded failure
    with pytest.raises(ValueError):
        ck.submit(lambda: None)
    with pytest.raises(ValueError):
        ck.flush()
    ck.close()


def test_closed_checkpointer_rejects_submit():
    ck = AsyncCheckpointer()
    ck.submit(lambda: None)
    ck.close()
    with pytest.raises(RuntimeError):
        ck.submit(lambda: None)


# ---------------------------------------------------------------------------
# snapshot-then-write semantics on the store
# ---------------------------------------------------------------------------

def test_async_commit_bitwise_equals_sync(tmp_path):
    store = warmed_engine().store
    ck = AsyncCheckpointer()
    store.checkpoint(str(tmp_path / "sync"), 5)
    path = store.checkpoint_async(ck, str(tmp_path / "async"), 5)
    ck.flush()
    assert path.endswith("state_0000000005.npz")

    meta_s, leaves_s = load_checkpoint_arrays(str(tmp_path / "sync"))
    meta_a, leaves_a = load_checkpoint_arrays(str(tmp_path / "async"))
    assert set(leaves_s) == set(leaves_a)
    for name in leaves_s:
        np.testing.assert_array_equal(leaves_s[name], leaves_a[name])
    # identical leaves serialize to identical committed bytes
    for key in ("step", "npz_crc32", "npz_bytes"):
        assert meta_s[key] == meta_a[key]
    ck.close()


def test_snapshot_isolation_under_later_mutation(tmp_path):
    """The commit lands the SNAPSHOT-time state, not the write-time one.

    The worker is gated shut while the engine keeps mutating (its donated
    appliers invalidate the old device buffers — the exact hazard the
    deep-copy snapshot exists for); the commit that then lands must
    restore bitwise to the state at ``checkpoint_async`` time.
    """
    ck = AsyncCheckpointer()
    eng = warmed_engine(checkpointer=ck)
    frozen = {k: v.copy()
              for k, v in eng.store._snapshot_leaves().items()}

    gate = threading.Event()
    ck.submit(lambda: gate.wait(timeout=30), label="gate")
    eng.store.checkpoint_async(ck, str(tmp_path / "ck"), 1)

    # mutate well past the snapshot while the writer is still gated
    rng = np.random.default_rng(9)
    for _ in range(16):
        eng.add_basket(int(rng.integers(0, 4)),
                       rng.choice(P.n_items, size=3, replace=False))
    eng.run_until_drained()
    gate.set()
    ck.flush()

    _, leaves = load_checkpoint_arrays(str(tmp_path / "ck"))
    for name, want in frozen.items():
        np.testing.assert_array_equal(leaves[name], want)
    # and the post-mutation live state genuinely moved on
    assert not np.array_equal(
        np.asarray(eng.store.state.n_baskets), frozen["n_baskets"])
    ck.close()


# ---------------------------------------------------------------------------
# engine-level async roundtrip
# ---------------------------------------------------------------------------

def test_engine_async_checkpoint_roundtrip(tmp_path):
    ck = AsyncCheckpointer()
    eng = warmed_engine(checkpointer=ck)
    eng.checkpoint(str(tmp_path / "ck"), 1)
    eng.flush_checkpoints()
    want = np.asarray(eng.store.state.materialized_user_vecs())

    eng2 = StreamingEngine(small_store(), P, batch_size=4)
    eng2.restore(str(tmp_path / "ck"))
    np.testing.assert_array_equal(
        np.asarray(eng2.store.state.materialized_user_vecs()), want)
    assert eng2.watermark == eng.watermark
    ck.close()
