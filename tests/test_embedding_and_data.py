"""EmbeddingBag substrate + paper-rule bag maintenance + data pipeline."""
import jax.numpy as jnp
import numpy as np

from repro.core import decay
from repro.data import synthetic
from repro.data.graph_sampler import (CSRGraph, LayeredSampler,
                                      build_triplets)
from repro.models.embedding import (TableSpec, bag_incremental_add,
                                    embedding_bag, embedding_lookup,
                                    init_table)


def test_embedding_bag_matches_manual(rng):
    spec = TableSpec((50, 30), dim=8)
    table = init_table(jnp.asarray(np.zeros(2), jnp.int32) * 0
                       if False else __import__("jax").random.PRNGKey(0),
                       spec)
    ids = jnp.asarray(rng.integers(-1, 30, (4, 2, 5)), jnp.int32)
    out = embedding_bag(table, ids, spec, mode="sum")
    tab = np.asarray(table)
    offs = spec.offsets
    for b in range(4):
        for f in range(2):
            exp = np.zeros(8)
            for h in np.asarray(ids[b, f]):
                if h >= 0:
                    exp += tab[offs[f] + h]
            np.testing.assert_allclose(np.asarray(out[b, f]), exp,
                                       atol=1e-5)


def test_lookup_chunked_equals_direct(rng):
    import jax
    spec = TableSpec((100,), dim=4)
    table = init_table(jax.random.PRNGKey(1), spec)
    ids = jnp.asarray(rng.integers(0, 100, (96, 3)), jnp.int32)
    a = embedding_lookup(table, ids, spec, chunk=16)
    b = embedding_lookup(table, ids, spec, chunk=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_bag_maintenance_uses_paper_rules(rng):
    """Adding/removing an interaction embedding from a user's decayed bag
    follows Eq. 3/4 — the DLRM/two-tower unlearning hook (DESIGN.md §4)."""
    vecs = rng.normal(size=(10, 6))
    r = 0.9
    avg = decay.decayed_average(vecs[:9], r, xp=np)
    # Eq. 3 add
    incr = bag_incremental_add(avg, 9, vecs[9], r)
    np.testing.assert_allclose(incr, decay.decayed_average(vecs, r, xp=np),
                               rtol=1e-9)
    # Eq. 4 delete (element 3, 1-based i=3)
    avg10 = decay.decayed_average(vecs, r, xp=np)
    out = decay.decremental_delete(avg10, 10, vecs[2:], 3, r, xp=np)
    np.testing.assert_allclose(
        out, decay.decayed_average(np.delete(vecs, 2, axis=0), r, xp=np),
        rtol=1e-7)


def test_synthetic_dataset_statistics():
    ds = synthetic.generate("tafeng", scale=0.02, seed=0)
    stats = synthetic.DATASET_STATS["tafeng"]
    sizes = [len(b) for h in ds.histories.values() for b in h]
    counts = [len(h) for h in ds.histories.values()]
    assert abs(np.mean(sizes) - stats["avg_basket_size"]) < 2.0
    assert abs(np.mean(counts) - stats["avg_baskets"]) < 2.0
    train, test = ds.train_test_split()
    u = next(iter(train))
    assert len(train[u]) == len(ds.histories[u]) - 1


def test_neighbor_sampler_fanout():
    g = CSRGraph.random(500, avg_degree=10, seed=0)
    sampler = LayeredSampler(g, fanouts=[5, 3], seed=1)
    seeds = np.arange(16)
    src, dst, nodes = sampler.sample(seeds)
    assert len(src) == len(dst) > 0
    assert len(src) <= 16 * 5 + 16 * 5 * 3
    # every sampled edge's endpoint is a known node
    assert set(dst).issubset(set(nodes))


def test_partition_local_triplets():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 50, 200)
    dst = rng.integers(0, 50, 200)
    tkj, tji = build_triplets(src, dst, n_partitions=4, max_per_edge=4)
    part = 200 // 4
    assert len(tkj) == len(tji)
    # local indices stay within one partition's range
    assert tkj.max(initial=0) < part and tji.max(initial=0) < part
    # triplet validity in partition 0: src[e] == dst[f] for local e, f
    for f, e in zip(tkj[:50], tji[:50]):
        assert src[e] == dst[f]
