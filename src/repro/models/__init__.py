from repro.models import (bert4rec, deepfm, dimenet, dlrm, embedding,
                          transformer, two_tower)

__all__ = ["bert4rec", "deepfm", "dimenet", "dlrm", "embedding",
           "transformer", "two_tower"]
