"""Corpus case: durable write bypassing the atomic commit (EN01).

save_snapshot writes bytes directly at the destination path — a crash
mid-write leaves a torn file with no LATEST manifest to fall back to.
Every public durable-write path must reach atomic_write_json.
"""
import os


def atomic_write_json(path, payload):
    tmp = str(path) + ".tmp"
    with open(tmp, "w") as f:
        f.write(payload)
    os.replace(tmp, path)


def save_snapshot(path, blob):
    with open(path, "wb") as f:
        f.write(blob)
