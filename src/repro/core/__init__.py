"""Core: the paper's contribution — TIFU-kNN maintenance under additions
and deletions of baskets and items (Wang & Schelter, ORSUM@RecSys'21)."""
from repro.core.types import (PAD_ID, KIND_NOOP, KIND_ADD_BASKET,
                              KIND_DEL_BASKET, KIND_DEL_ITEM,
                              PAPER_HYPERPARAMS, AddBatch, DelBasketBatch,
                              DelItemBatch, RaggedUserState, StreamState,
                              TifuParams, UpdateBatch)
from repro.core import decay, knn, stability, tifu
from repro.core.ref_engine import RefEngine
from repro.core.updates import (SCALE_CEIL, SCALE_FLOOR, apply_add_batch,
                                apply_del_basket_batch,
                                apply_del_basket_batch_dense,
                                apply_del_item_batch,
                                apply_del_item_batch_dense,
                                apply_update_batch, apply_update_batch_dense,
                                refresh_users, renormalize_users)

__all__ = [
    "PAD_ID", "KIND_NOOP", "KIND_ADD_BASKET", "KIND_DEL_BASKET",
    "KIND_DEL_ITEM", "PAPER_HYPERPARAMS", "AddBatch", "DelBasketBatch",
    "DelItemBatch", "RaggedUserState", "StreamState", "TifuParams",
    "UpdateBatch", "decay", "knn", "stability", "tifu", "RefEngine",
    "SCALE_CEIL", "SCALE_FLOOR", "apply_add_batch",
    "apply_del_basket_batch", "apply_del_basket_batch_dense",
    "apply_del_item_batch", "apply_del_item_batch_dense",
    "apply_update_batch", "apply_update_batch_dense",
    "refresh_users", "renormalize_users",
]
