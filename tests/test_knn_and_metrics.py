"""kNN prediction stage + ranking metrics + streaming top-k schedule."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knn


def test_streaming_topk_matches_direct(rng):
    q = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(640, 24)), jnp.float32)
    sv, si = knn.streaming_topk(q, c, k=10, chunk=128)
    dv, di = knn.nearest_neighbors(q, c, k=10)
    np.testing.assert_allclose(np.asarray(sv), np.asarray(dv), atol=1e-4)
    for a, b in zip(np.asarray(si), np.asarray(di)):
        assert set(map(int, a)) == set(map(int, b))


def test_streaming_topk_exclude_self(rng):
    c = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    sv, si = knn.streaming_topk(c, c, k=5, chunk=16, exclude_self=True)
    for row, ids in enumerate(np.asarray(si)):
        assert row not in ids


def test_streaming_topk_prime_corpus_keeps_chunk(rng):
    """Prime-sized corpora must be padded, not degenerate to chunk=1
    (a scan of length M)."""
    q = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(641, 12)), jnp.float32)   # prime
    sv, si = knn.streaming_topk(q, c, k=7, chunk=128)
    dv, di = knn.nearest_neighbors(q, c, k=7)
    np.testing.assert_allclose(np.asarray(sv), np.asarray(dv), atol=1e-4)
    for a, b in zip(np.asarray(si), np.asarray(di)):
        assert set(map(int, a)) == set(map(int, b))
    assert np.all(np.asarray(si) < 641)   # padding rows never selected


def test_chunked_neighbor_mean(rng):
    c = jnp.asarray(rng.normal(size=(100, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 100, (7, 12)), jnp.int32)
    out = knn.chunked_neighbor_mean(c, idx, chunk_k=4)
    exp = jnp.mean(c[idx], axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_chunked_neighbor_mean_prime_k(rng):
    c = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, (5, 13)), jnp.int32)  # prime k
    out = knn.chunked_neighbor_mean(c, idx, chunk_k=4)
    exp = jnp.mean(c[idx], axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


def test_predict_combines_components(rng):
    q = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    p1 = knn.predict(q, c, k=4, alpha=1.0, exclude_self=False)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(q), atol=1e-6)
    p0 = knn.predict(q, c, k=4, alpha=0.0, exclude_self=False)
    _, idx = knn.nearest_neighbors(q, c, k=4)
    exp = jnp.mean(c[idx], axis=1)
    np.testing.assert_allclose(np.asarray(p0), np.asarray(exp), atol=1e-5)


def test_recall_and_ndcg_hand_cases():
    recs = np.array([[1, 2, 3, 4], [9, 8, 7, 6]])
    truth = [np.array([2, 3]), np.array([5])]
    assert knn.recall_at_k(recs, truth, 4) == pytest.approx(0.5)
    assert knn.recall_at_k(recs, truth, 2) == pytest.approx(0.25)
    # NDCG: user0 hits at ranks 2,3 → dcg = 1/log2(3)+1/log2(4);
    # idcg = 1/log2(2)+1/log2(3); user1: 0
    dcg = 1 / np.log2(3) + 1 / np.log2(4)
    idcg = 1.0 + 1 / np.log2(3)
    assert knn.ndcg_at_k(recs, truth, 4) == pytest.approx(
        (dcg / idcg) / 2)


def test_euclidean_surrogate_is_rank_equivalent(rng):
    q = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    s = np.asarray(knn.pairwise_scores(q, c, "euclidean"))
    true_d = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(c)[None],
                            axis=-1)
    for i in range(4):
        np.testing.assert_array_equal(np.argsort(-s[i]),
                                      np.argsort(true_d[i], kind="stable"))
