from repro.optim.optimizers import (adamw, adafactor, sgd, clip_by_global_norm,
                                    OptState, Optimizer, adamw_state_pspecs,
                                    adafactor_state_pspecs, sgd_state_pspecs)

__all__ = ["adamw", "adafactor", "sgd", "clip_by_global_norm", "OptState",
           "Optimizer", "adamw_state_pspecs", "adafactor_state_pspecs",
           "sgd_state_pspecs"]
