"""BERT4Rec (Sun et al., arXiv:1904.06690) — bidirectional transformer
over item sequences, trained with the cloze (masked item) objective.

Encoder-only: there is no autoregressive decode step; all serving shapes
lower full forward passes (DESIGN.md §4).  The paper's unlearning
technique does NOT apply here (learned sequential model — documented in
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import layer_norm


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000        # +2 special tokens (pad=0, mask=1)
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: int = 256
    dtype: Optional[object] = jnp.float32

    @property
    def vocab(self):
        # pad to a multiple of 512 so the item table row-shards over any
        # mesh (pad=0, mask=1 special tokens included)
        return (self.n_items + 2 + 511) // 512 * 512

    def n_params(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 2 * d * self.d_ff + 4 * d + self.d_ff + d
        return self.vocab * d + self.seq_len * d \
            + self.n_blocks * per_block + 2 * d


def _block_shapes(c: Bert4RecConfig):
    d, f, L = c.embed_dim, c.d_ff, c.n_blocks
    return {
        "wq": (L, d, d), "wk": (L, d, d), "wv": (L, d, d), "wo": (L, d, d),
        "ln1_w": (L, d), "ln1_b": (L, d), "ln2_w": (L, d), "ln2_b": (L, d),
        "w1": (L, d, f), "b1": (L, f), "w2": (L, f, d), "b2": (L, d),
    }


def param_shapes(c: Bert4RecConfig):
    return {
        "item_emb": (c.vocab, c.embed_dim),
        "pos_emb": (c.seq_len, c.embed_dim),
        "blocks": _block_shapes(c),
        "out_ln_w": (c.embed_dim,), "out_ln_b": (c.embed_dim,),
        "out_bias": (c.vocab,),
    }


def init_params(c: Bert4RecConfig, key):
    shapes = param_shapes(c)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shape), k in zip(flat, keys):
        name = path[-1].key
        if name.endswith(("_b", "bias")):
            leaves.append(jnp.zeros(shape, c.dtype))
        elif name.endswith("_w"):
            leaves.append(jnp.ones(shape, c.dtype))
        else:
            leaves.append((jax.random.normal(k, shape, jnp.float32)
                           * 0.02).astype(c.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(c: Bert4RecConfig):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, c.dtype),
                        param_shapes(c), is_leaf=lambda x: isinstance(x, tuple))


def param_pspecs(c: Bert4RecConfig, mesh, rules):
    # item table rows sharded over every mesh axis (model-parallel
    # embeddings): vocab is padded to a multiple of 512 at init.
    all_axes = tuple(mesh.axis_names)
    rows = all_axes if c.vocab % int(np.prod(mesh.devices.shape)) == 0 \
        else (rules.tensor if rules.tensor in mesh.axis_names else None)
    blocks = {k: P(*([None] * len(s)))
              for k, s in _block_shapes(c).items()}
    return {
        "item_emb": P(rows, None), "pos_emb": P(None, None),
        "blocks": blocks,
        "out_ln_w": P(None), "out_ln_b": P(None), "out_bias": P(rows),
    }


def encoder(params, ids, c: Bert4RecConfig, mesh=None, rules=None):
    """ids [B,S] → hidden [B,S,D] (bidirectional, pad-masked)."""
    b, s = ids.shape
    x = params["item_emb"][ids].astype(c.dtype) \
        + params["pos_emb"][None, :s, :].astype(c.dtype)
    from repro.models.dlrm import _constrain_batchwise
    x = _constrain_batchwise(x, mesh, rules, b)
    pad = (ids == 0)
    bias = jnp.where(pad[:, None, None, :], -1e30, 0.0)     # [B,1,1,S]
    h, d = c.n_heads, c.embed_dim // c.n_heads
    scale = 1.0 / math.sqrt(d)

    def body(x, blk):
        q = (x @ blk["wq"]).reshape(b, s, h, d)
        k = (x @ blk["wk"]).reshape(b, s, h, d)
        v = (x @ blk["wv"]).reshape(b, s, h, d)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
        probs = jax.nn.softmax(scores * scale + bias, axis=-1).astype(x.dtype)
        att = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = layer_norm(x + att @ blk["wo"], blk["ln1_w"], blk["ln1_b"])
        f = jax.nn.gelu(x @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
        x = layer_norm(x + f, blk["ln2_w"], blk["ln2_b"])
        x = _constrain_batchwise(x, mesh, rules, b)
        return x, None

    # remat: [B,h,S,S] attention scores are recomputed in backward rather
    # than saved (B=65536 training cell: −21 GiB peak)
    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    return layer_norm(x, params["out_ln_w"], params["out_ln_b"])


def forward_logits(params, ids, c: Bert4RecConfig):
    """Full-vocab logits at every position (tied item embeddings)."""
    x = encoder(params, ids, c)
    return (x @ params["item_emb"].T.astype(c.dtype)) + params["out_bias"]


def cloze_loss(params, batch, c: Bert4RecConfig):
    """batch: {"ids": [B,S] (with [MASK]=1 tokens), "targets": [B,S]
    (true item at masked positions, -1 elsewhere)}."""
    x = encoder(params, batch["ids"], c)
    logits = (x @ params["item_emb"].T.astype(c.dtype)
              + params["out_bias"]).astype(jnp.float32)
    t = batch["targets"]
    mask = (t >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(t, 0)[..., None],
                               axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def sampled_cloze_loss(params, batch, c: Bert4RecConfig, mesh=None,
                       rules=None):
    """Cloze loss with sampled negatives — the big-vocab (10⁶ items)
    training path: full [B,S,V] logits are never materialized.

    batch: {"ids": [B,S], "mask_pos": [B,M], "targets": [B,M] (−1 pad),
            "negatives": [K]}  — targets scored against K shared sampled
    negatives + the gold item (standard sampled softmax).
    """
    x = encoder(params, batch["ids"], c, mesh, rules)       # [B,S,D]
    mp = jnp.maximum(batch["mask_pos"], 0)
    h = jnp.take_along_axis(x, mp[..., None], axis=1)       # [B,M,D]
    t = batch["targets"]
    emb = params["item_emb"]
    gold_e = emb[jnp.maximum(t, 0)].astype(c.dtype)         # [B,M,D]
    neg_e = emb[batch["negatives"]].astype(c.dtype)         # [K,D]
    gold_logit = jnp.sum(h * gold_e, -1).astype(jnp.float32)
    neg_logits = jnp.einsum("bmd,kd->bmk", h, neg_e).astype(jnp.float32)
    lse = jax.nn.logsumexp(
        jnp.concatenate([gold_logit[..., None], neg_logits], -1), axis=-1)
    mask = (t >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold_logit) * mask) / jnp.maximum(jnp.sum(mask),
                                                            1.0)


def make_train_step(c: Bert4RecConfig, optimizer, sampled: bool = False,
                    mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        if sampled:
            fn = lambda p: sampled_cloze_loss(p, batch, c, mesh, rules)
        else:
            fn = lambda p: cloze_loss(p, batch, c)
        l, grads = jax.value_and_grad(fn)(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": l}
    return train_step


def serve_step(params, batch, c: Bert4RecConfig, top_n: int = 20,
               mesh=None, rules=None, vocab_chunk: int = 65536,
               batch_chunk: int = 16384):
    """Next-item recommendation: top-n over the full 10⁶-item catalogue.

    The [B, V] logit matrix is never materialized (262144 × 10⁶ × 4B =
    1 TB): we scan the item table in vocab chunks keeping a running
    top-n — the same streaming-top-k schedule as kernels.knn_topk.
    Huge serve batches additionally run in batch chunks (bulk scoring).
    """
    if batch["ids"].shape[0] > batch_chunk:
        from repro.models.common import map_batch_chunks
        return map_batch_chunks(
            lambda sub: serve_step(params, sub, c, top_n, mesh, rules,
                                   vocab_chunk, batch_chunk),
            batch, batch_chunk, keys=["ids"])
    x = encoder(params, batch["ids"], c, mesh, rules)
    q = x[:, -1, :]                                       # [B, D]
    v = params["item_emb"].shape[0]

    # §Perf H2 (see EXPERIMENTS.md): GSPMD turns a top-k over the sharded
    # catalogue into full-score all-gathers (~1 TiB/device measured), and
    # constraints alone only move the gather.  The fix is a MANUAL
    # shard_map: catalogue rows over 'model' (one small reshard), each
    # device scores its V/TP rows and keeps a LOCAL top-n; only
    # [B, TP·top_n] candidates cross the wire.
    if mesh is not None and rules is not None \
            and rules.tensor in mesh.axis_names \
            and v % int(dict(zip(mesh.axis_names,
                                 mesh.devices.shape))[rules.tensor]) == 0:
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import batch_axes
        import numpy as np
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        tp_ax = rules.tensor
        n_tp = sizes[tp_ax]
        b_ax = batch_axes(mesh, rules) or None
        nb = int(np.prod([sizes[a] for a in (b_ax or ())])) or 1
        if q.shape[0] % nb:
            b_ax = None
        v_loc = v // n_tp

        def body(ql, e_loc, b_loc):
            mi = jax.lax.axis_index(tp_ax)
            scores = (ql @ e_loc.T.astype(c.dtype)
                      + b_loc).astype(jnp.float32)       # [B_loc, V_loc]
            lv, li = jax.lax.top_k(scores, top_n)        # local top-n
            li = li + mi * v_loc
            cv = jax.lax.all_gather(lv, tp_ax, axis=1, tiled=True)
            ci = jax.lax.all_gather(li, tp_ax, axis=1, tiled=True)
            tv, tp_ = jax.lax.top_k(cv, top_n)
            return tv, jnp.take_along_axis(ci, tp_, axis=1)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(b_ax, None), P(tp_ax, None), P(tp_ax)),
            out_specs=(P(b_ax, None), P(b_ax, None)),
            check_vma=False,
        )(q, params["item_emb"], params["out_bias"])

    # single-device / unshardable fallback: vocab-chunked streaming top-k
    chunk = min(vocab_chunk, v)
    nc = v // chunk
    emb = params["item_emb"][:nc * chunk].reshape(nc, chunk, c.embed_dim)
    bias = params["out_bias"][:nc * chunk].reshape(nc, chunk)

    def chunk_body(carry, inp):
        vals, idx = carry
        e, b_, ci = inp
        scores = (q @ e.T.astype(c.dtype) + b_).astype(jnp.float32)
        tile_idx = ci * chunk + jnp.arange(chunk)[None, :]
        m_vals = jnp.concatenate([vals, scores], axis=1)
        m_idx = jnp.concatenate(
            [idx, jnp.broadcast_to(tile_idx, scores.shape)], axis=1)
        tv, tp = jax.lax.top_k(m_vals, top_n)
        return (tv, jnp.take_along_axis(m_idx, tp, axis=1)), None

    init = (jnp.full((q.shape[0], top_n), -jnp.inf, jnp.float32),
            jnp.zeros((q.shape[0], top_n), jnp.int32))
    (vals, idx), _ = jax.lax.scan(chunk_body, init,
                                  (emb, bias, jnp.arange(nc)))
    return vals, idx


def retrieval_step(params, batch, c: Bert4RecConfig, top_n: int = 100,
                   mesh=None, rules=None):
    """retrieval_cand cell: one query's last hidden state scored against
    ``candidates`` item-embedding rows (uses the kNN kernel shape)."""
    x = encoder(params, batch["ids"], c, mesh, rules)   # [1,S,D]
    q = x[:, -1, :]                                 # [1,D]
    scores = q @ batch["candidates"].T.astype(c.dtype)
    return jax.lax.top_k(scores.astype(jnp.float32), top_n)
