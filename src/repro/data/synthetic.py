"""Synthetic next-basket datasets matching the paper's dataset statistics
(Table 1) — this container has no internet, so TaFeng/Instacart/
ValuedShopper are modelled by their published statistics:

  dataset        #users  #items  #baskets  avg |b|  avg #b/user
  TaFeng          13949   11997    79423     6.2       5.7
  Instacart       19935    7999   158933     8.9       8.0
  ValuedShopper   10000    7874   568573     9.1      56.9

Generation: Zipf item popularity + per-user preference mixtures with
repeat-purchase bias (the signal TIFU-kNN exploits), Poisson basket
counts/sizes around the dataset means.  ``scale`` shrinks users/items
proportionally for CI-speed runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.types import PAPER_HYPERPARAMS, TifuParams

DATASET_STATS = {
    "tafeng": dict(n_users=13949, n_items=11997, avg_baskets=5.7,
                   avg_basket_size=6.2),
    "instacart": dict(n_users=19935, n_items=7999, avg_baskets=8.0,
                      avg_basket_size=8.9),
    "valuedshopper": dict(n_users=10000, n_items=7874, avg_baskets=56.9,
                          avg_basket_size=9.1),
}


@dataclasses.dataclass
class BasketDataset:
    name: str
    n_items: int
    histories: Dict[int, List[np.ndarray]]   # user → chronological baskets
    params: TifuParams

    def train_test_split(self):
        """Paper §6.1: hold out each user's LAST basket for evaluation."""
        train, test = {}, {}
        for u, h in self.histories.items():
            if len(h) >= 2:
                train[u], test[u] = h[:-1], h[-1]
        return train, test


def generate(name: str, seed: int = 0, scale: float = 1.0,
             repeat_bias: float = 0.6) -> BasketDataset:
    stats = DATASET_STATS[name]
    rng = np.random.default_rng(seed)
    n_users = max(int(stats["n_users"] * scale), 16)
    n_items = max(int(stats["n_items"] * scale), 64)
    pop = 1.0 / np.arange(1, n_items + 1) ** 1.1      # Zipf popularity
    pop /= pop.sum()

    histories: Dict[int, List[np.ndarray]] = {}
    for u in range(n_users):
        n_b = max(2, rng.poisson(stats["avg_baskets"]))
        # a per-user preferred-item pool (drives repeat purchases + kNN
        # structure: users sharing pools are true neighbours)
        pool_size = max(8, int(stats["avg_basket_size"] * 3))
        pool = rng.choice(n_items, size=pool_size, replace=False, p=pop)
        baskets = []
        for _ in range(n_b):
            size = max(1, rng.poisson(stats["avg_basket_size"]))
            size = min(size, n_items)
            n_rep = int(size * repeat_bias)
            rep = rng.choice(pool, size=min(n_rep, pool_size), replace=False)
            n_new = size - len(rep)
            fresh = rng.choice(n_items, size=max(n_new, 0), replace=False,
                               p=pop)
            basket = np.unique(np.concatenate([rep, fresh]))[:size]
            baskets.append(basket.astype(np.int64))
        histories[u] = baskets

    base = PAPER_HYPERPARAMS.get(name)
    params = TifuParams(
        n_items=n_items, group_size=base.group_size, r_b=base.r_b,
        r_g=base.r_g,
        k_neighbors=min(base.k_neighbors, max(n_users // 4, 1)),
        alpha=base.alpha)
    return BasketDataset(name=name, n_items=n_items, histories=histories,
                         params=params)
