"""jax version-compatibility shims.

The codebase targets the current jax API (``jax.set_mesh``,
``jax.shard_map(check_vma=...)``, differentiable
``lax.optimization_barrier``); the pinned runtime may predate parts of
it.  Every shim resolves to the native API when present, so this module
is a no-op on new-enough jax.

* ``set_mesh(mesh)``  — context manager; falls back to entering the
  ``Mesh`` itself (the pre-0.5 way to install the ambient mesh).
* ``shard_map(...)``  — accepts ``check_vma``; falls back to
  ``jax.experimental.shard_map.shard_map`` mapping it to ``check_rep``
  (the old name for the same replication check).
* ``optimization_barrier(x)`` — identity-gradient wrapper; old jax has
  no AD rule for the primitive (the barrier is AD-transparent by
  definition: it only pins XLA scheduling).
"""
from __future__ import annotations

import contextlib

import jax

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        """Static size of a mapped axis (pre-0.5: psum of 1 constant-folds
        to the axis size without touching the wire)."""
        return jax.lax.psum(1, axis_name)


@jax.custom_jvp
def optimization_barrier(x):
    """``lax.optimization_barrier`` with an identity gradient."""
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    return optimization_barrier(primals[0]), tangents[0]
