"""Optimizers built from scratch in JAX (no optax dependency).

All optimizers are (init, update) pairs over arbitrary pytrees.  State
leaves inherit the parameter sharding (FSDP dims on params ⇒ optimizer
state is ZeRO-sharded for free; see parallel.sharding).

Adafactor keeps factored second moments for matrices (rows+cols instead
of full), the standard memory saver for 100B+ training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def _warmup_cosine(step, lr, warmup, total):
    warm = lr * (step + 1) / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          warmup_steps: int = 100, total_steps: int = 10_000,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner={"m": jax.tree.map(zeros, params),
                               "v": jax.tree.map(zeros, params)})

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state.step
        lr_t = _warmup_cosine(step, lr, warmup_steps, total_steps)
        bc1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
        bc2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh, vh = m / bc1, v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.inner["m"],
                           state.inner["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step + 1,
                                    inner={"m": new_m, "v": new_v})

    return Optimizer(init, update)


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: float = 1.0, min_dim_factored: int = 128)\
        -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018)."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def zero(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree.map(zero, params,
                                           is_leaf=lambda x: not isinstance(x, dict)))

    def update(grads, state, params):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "v" in s:
                v = beta * s["v"] + (1 - beta) * g2
                precond = g32 * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            else:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    (vr / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True), eps))[..., None]
                    + eps)
                cfac = jax.lax.rsqrt(vc + eps)[..., None, :]
                precond = g32 * rfac * cfac
                new_s = {"vr": vr, "vc": vc}
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-12)
            precond = precond / jnp.maximum(1.0, rms)
            return (p.astype(jnp.float32) - lr * precond).astype(p.dtype), new_s

        # params is a tree-prefix of state.inner (inner adds one dict level),
        # so tree.map passes the per-param state dict as the third arg.
        out = jax.tree.map(upd, params, grads, state.inner)
        # out is a pytree of (param, state) tuples aligned with params
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_inner = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step + 1, inner=new_inner)

    return Optimizer(init, update)


def adamw_state_pspecs(params_pspecs):
    """PartitionSpecs for adamw's OptState given param specs (ZeRO: state
    inherits the FSDP/TP sharding of its parameter)."""
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), inner={"m": params_pspecs,
                                     "v": params_pspecs})


def adafactor_state_pspecs(params_abstract, params_pspecs,
                           min_dim_factored: int = 128):
    """Specs for adafactor state: factored leaves drop the corresponding
    param dim's axis assignment."""
    from jax.sharding import PartitionSpec as P

    def one(p, spec):
        axes = tuple(spec) + (None,) * (len(p.shape) - len(tuple(spec)))
        if p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
                and p.shape[-2] >= min_dim_factored:
            return {"vr": P(*axes[:-1]), "vc": P(*axes[:-2], axes[-1])}
        return {"v": P(*axes)}

    return OptState(step=P(),
                    inner=jax.tree.map(one, params_abstract, params_pspecs,
                                       is_leaf=lambda x: isinstance(
                                           x, jax.ShapeDtypeStruct)))


def sgd_state_pspecs(params_pspecs):
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), inner=params_pspecs)


def sgd(lr: float = 1e-2, momentum: float = 0.9,
        clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree.map(
                            lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(grads, state, params):
        grads, _ = clip_by_global_norm(grads, clip_norm)

        def upd(p, g, m):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, params, grads, state.inner)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=state.step + 1, inner=new_m)

    return Optimizer(init, update)
