"""The paper-faithful reference engine vs the from-scratch oracle:
incremental exact (Table 2 claim), decremental allclose, item deletes,
varying-group-size bookkeeping, stability refresh."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import RefEngine, TifuParams
from repro.core.tifu import default_group_sizes, user_vector_ragged


def mirror_delete(sizes, pos):
    start = 0
    for j, tau in enumerate(sizes):
        if pos < start + tau:
            if tau > 1:
                sizes[j] -= 1
            else:
                sizes.pop(j)
            return
        start += tau
    raise AssertionError


@given(seed=st.integers(0, 10_000),
       m=st.integers(1, 6),
       r_b=st.floats(0.3, 1.0), r_g=st.floats(0.3, 1.0),
       n_ops=st.integers(5, 60))
@settings(max_examples=25, deadline=None)
def test_mixed_ops_match_oracle(seed, m, r_b, r_g, n_ops):
    """Random interleavings of adds / basket-deletes / item-deletes stay
    equal to TIFU-kNN retrained from scratch on the surviving history."""
    rng = np.random.default_rng(seed)
    p = TifuParams(n_items=23, group_size=m, r_b=r_b, r_g=r_g)
    eng = RefEngine(p)
    hist, sizes = [], []
    for _ in range(n_ops):
        op = rng.choice(["add", "del", "item"]) if hist else "add"
        if op == "add":
            b = rng.choice(p.n_items, size=int(rng.integers(1, 5)),
                           replace=False)
            eng.add_basket(0, b)
            hist.append(np.asarray(b, np.int64))
            if sizes and sizes[-1] < m:
                sizes[-1] += 1
            else:
                sizes.append(1)
        elif op == "del":
            pos = int(rng.integers(0, len(hist)))
            eng.delete_basket(0, pos)
            mirror_delete(sizes, pos)
            del hist[pos]
        else:
            pos = int(rng.integers(0, len(hist)))
            item = int(rng.choice(hist[pos]))
            eng.delete_item(0, pos, item)
            nb = hist[pos][hist[pos] != item]
            if len(nb) == 0:
                mirror_delete(sizes, pos)
                del hist[pos]
            else:
                hist[pos] = nb
        oracle = user_vector_ragged(hist, sizes, p)
        np.testing.assert_allclose(eng.state(0).user_vec, oracle,
                                   rtol=1e-7, atol=1e-8)
        assert eng.state(0).group_sizes == sizes


def test_incremental_is_exact_not_just_close(rng):
    """Paper Table 2: incremental results are IDENTICAL to baseline.
    (The incremental path performs the same fp ops as the recurrence —
    we assert to fp64 round-off.)"""
    p = TifuParams(n_items=50, group_size=3)
    eng = RefEngine(p)
    hist = []
    for _ in range(30):
        b = rng.choice(p.n_items, size=4, replace=False)
        hist.append(b)
        eng.add_basket(7, b)
    oracle = user_vector_ragged(hist, default_group_sizes(len(hist), 3), p)
    assert np.max(np.abs(eng.state(7).user_vec - oracle)) < 1e-13


def test_last_group_vec_maintained(rng):
    p = TifuParams(n_items=29, group_size=4)
    eng = RefEngine(p)
    for _ in range(11):
        eng.add_basket(0, rng.choice(p.n_items, size=3, replace=False))
    st_ = eng.state(0)
    from repro.core.tifu import group_vector_ragged
    start = sum(st_.group_sizes[:-1])
    expect = group_vector_ragged(st_.history[start:], p.n_items, p.r_b)
    np.testing.assert_allclose(st_.last_group_vec, expect, rtol=1e-9)


def test_delete_everything(rng):
    p = TifuParams(n_items=11, group_size=2)
    eng = RefEngine(p)
    for _ in range(5):
        eng.add_basket(0, rng.choice(p.n_items, size=2, replace=False))
    for _ in range(5):
        eng.delete_basket(0, 0)
    assert eng.state(0).n_baskets == 0
    assert np.all(eng.state(0).user_vec == 0)
    # and the user can come back
    eng.add_basket(0, np.array([1, 2]))
    assert eng.state(0).n_baskets == 1


def test_item_delete_noop_for_absent_item(rng):
    p = TifuParams(n_items=11, group_size=2)
    eng = RefEngine(p)
    eng.add_basket(0, np.array([1, 2]))
    before = eng.state(0).user_vec.copy()
    eng.delete_item(0, 0, 9)   # not in the basket
    np.testing.assert_array_equal(eng.state(0).user_vec, before)


def test_stability_refresh_triggers(rng):
    """With a threshold, heavy deletion loads reset err_mult via exact
    recomputation (beyond-paper stability tracker)."""
    p = TifuParams(n_items=17, group_size=1, r_g=0.7)  # every delete = Eq.12
    eng = RefEngine(p, stability_threshold=1e3)
    for _ in range(400):
        eng.add_basket(0, rng.choice(p.n_items, size=2, replace=False))
    worst = 1.0
    for _ in range(300):
        eng.delete_basket(0, 0)
        worst = max(worst, eng.state(0).err_mult)
        assert eng.state(0).err_mult <= 1e3 * (400 / (399 * 0.7)), \
            "refresh did not bound the error multiplier"
    assert worst > 1.0  # growth did happen before refreshes
    oracle = user_vector_ragged(eng.state(0).history,
                                eng.state(0).group_sizes, p)
    np.testing.assert_allclose(eng.state(0).user_vec, oracle, rtol=1e-6,
                               atol=1e-9)
