"""dlrm-mlperf [arXiv:1906.00091] — MLPerf/Criteo-1TB DLRM.
n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1 interaction=dot.  ~24B embedding params,
row-sharded over every mesh axis."""
from repro.configs import recsys_shapes as rs
from repro.configs.base import ArchDef, recsys_cell
from repro.models import dlrm


def make_config():
    return dlrm.DLRMConfig()


def smoke_config():
    return dlrm.DLRMConfig(vocab_sizes=tuple([64] * 26), embed_dim=16,
                           bot_mlp=(32, 16), top_mlp=(64, 32, 1))


def _flops_train(c):
    # fwd+bwd MLP flops dominate compute; 6 × (MLP params) × batch
    mlp = c.n_params() - c.table.padded_rows() * c.embed_dim
    return 6.0 * mlp * rs.TRAIN_BATCH


ARCH = ArchDef(
    name="dlrm-mlperf", family="recsys",
    cells={
        "train_batch": recsys_cell(dlrm, make_config,
                                   rs.dlrm_batch(rs.TRAIN_BATCH),
                                   "train B=65536", train=True, pass_mesh=True,
                                   flops_fn=_flops_train),
        "serve_p99": recsys_cell(dlrm, make_config,
                                 rs.dlrm_batch(rs.SERVE_P99, train=False),
                                 "serve B=512", pass_mesh=True),
        "serve_bulk": recsys_cell(dlrm, make_config,
                                  rs.dlrm_batch(rs.SERVE_BULK, train=False),
                                  "serve B=262144", pass_mesh=True),
        # ranking model: candidate scoring = 1M-row forward where the
        # candidate-item feature column varies (documented in DESIGN.md)
        "retrieval_cand": recsys_cell(
            dlrm, make_config, rs.dlrm_batch(rs.N_CANDIDATES, train=False),
            "score 1M candidates", pass_mesh=True),
    },
    make_smoke=smoke_config,
    notes="embedding lookup is the hot path; paper technique attaches to "
          "bag maintenance (DESIGN.md §4).")
