"""Maintenance rules for time-decayed averages (paper §4.1).

The decaying average of a series ``S = [x_1 .. x_n]`` with decay ``r`` is

    avg_n = (1/n) * sum_i r^(n-i) * x_i .

This module implements the three maintenance rules of the paper — each in
a shape-polymorphic form that works for scalars and for stacked vectors
(``x_i`` of any trailing shape):

  * ``incremental_add``  (Eq. 3)  O(1)
  * ``decremental_delete`` (Eq. 4)  O(n - i)   (suffix only)
  * ``inplace_update``   (Eq. 5)  O(1)

plus ``decayed_average`` (the from-scratch oracle) and the closed-form
suffix-coefficient helpers used by the batched JAX engine.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def decayed_average(xs, r, xp=np):
    """From-scratch decaying average. ``xs``: [n, ...]; returns [...]."""
    n = xs.shape[0]
    if n == 0:
        raise ValueError("decayed_average of an empty series")
    weights = r ** xp.arange(n - 1, -1, -1, dtype=xs.dtype if hasattr(xs, "dtype") else None)
    weights = xp.asarray(weights, dtype=xs.dtype)
    return xp.tensordot(weights, xs, axes=(0, 0)) / n


def incremental_add(avg_n, n, x_new, r):
    """Eq. 3:  avg_{n+1} = (r * n * avg_n + x_{n+1}) / (n + 1).

    O(1): only the current average, the count and the new element are
    touched.  Exact (no approximation).
    """
    return (r * n * avg_n + x_new) / (n + 1)


def suffix_coefficients(n: int, i: int, r: float, xp=np, dtype=None):
    """Coefficients c_t with  D([x_i..x_n])^T R(r, n-i) = sum_t c_t x_t.

    1-based positions; c_t = 0 for t < i,
    c_i = -r^(n-i),  c_t = r^(n-t+1) - r^(n-t)  for i < t <= n.

    Returns an array of length ``n`` (coefficient per series position).
    This is the vectorised expansion of the first-order-difference dot
    product from Eq. 4 — it lets the batched engine compute the suffix
    term as a single masked contraction.
    """
    t = xp.arange(1, n + 1)
    pow_nt = xp.asarray(r, dtype=dtype) ** (n - t)
    coeff = xp.where(t == i, -pow_nt, pow_nt * (r - 1.0))
    coeff = xp.where(t < i, xp.zeros_like(coeff), coeff)
    return coeff.astype(dtype) if dtype is not None else coeff


def decremental_delete(avg_n, n, xs_suffix, i, r, xp=np):
    """Eq. 4: delete the i-th (1-based) element of an n-series.

    ``xs_suffix`` must be the slice ``[x_i .. x_n]`` (length n - i + 1).
    Only this suffix is accessed — O(n - i), matching the paper's claimed
    complexity.  Numerically *unstable*: the result multiplies the
    incoming error by n / ((n-1) r) > 1 (paper §6.3).

    Returns avg'_{n-1}.
    """
    if n <= 1:
        # deleting the only element: average ceases to exist; by convention
        # return zeros (callers special-case this).
        return xp.zeros_like(avg_n)
    m = xs_suffix.shape[0]          # == n - i + 1
    # D = [x_{i+1}-x_i, ..., x_n - x_{n-1}, -x_n]   (length m)
    diffs = xp.concatenate(
        [xs_suffix[1:] - xs_suffix[:-1], -xs_suffix[-1:]], axis=0)
    # R = [r^(n-i), ..., r, 1]                      (length m)
    decays = xp.asarray(r, dtype=diffs.dtype) ** xp.arange(m - 1, -1, -1)
    decays = decays.astype(diffs.dtype)
    suffix_term = xp.tensordot(decays, diffs, axes=(0, 0))
    return (n * avg_n + suffix_term) / ((n - 1) * r)


def inplace_update(avg_n, n, x_old, x_new, i, r):
    """Eq. 5:  avg'_n = avg_n + r^(n-i) (x'_i - x_i) / n.   O(1)."""
    return avg_n + (r ** (n - i)) * (x_new - x_old) / n


# ---------------------------------------------------------------------------
# Batched JAX variants (fixed shapes, mask-driven) used by streaming.engine.
# ---------------------------------------------------------------------------

def batched_suffix_coefficients(n, i, r, length):
    """suffix_coefficients for traced scalars n, i over a fixed length grid.

    Positions t = 1..length; entries for t > n are zeroed.  ``n``/``i`` may
    be traced int scalars; ``length`` is static.
    """
    t = jnp.arange(1, length + 1)
    pow_nt = jnp.asarray(r, jnp.float32) ** (n - t)
    coeff = jnp.where(t == i, -pow_nt, pow_nt * (r - 1.0))
    coeff = jnp.where((t < i) | (t > n), 0.0, coeff)
    return coeff


def error_growth_factor(n, r):
    """Multiplicative worst-case error factor of one decremental update.

    From §6.3 of the paper: u' = alpha u + C with alpha = n / ((n-1) r) > 1.
    """
    return n / ((n - 1.0) * r)


def error_shrink_factor(n, r):
    """Error factor of one incremental update: r n / (n+1) < 1 (stable)."""
    return r * n / (n + 1.0)
