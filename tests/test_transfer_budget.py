"""Transfer-budget regression test (DESIGN.md §12, the PR's headline
perf contract): over the representative 520-event mixed stream, every
``step()`` performs at most ONE device→host transfer.

``jax.transfer_guard`` is the natural tool but is inert for these
transfer shapes on the CPU backend (``device_get``/``np.asarray``/
``int()`` of a committed CPU array never enter the guarded path), so
the pin uses a ``jax.device_get`` spy instead: the engine routes every
step-path transfer through ``StreamingEngine._fetch`` → ``
jax.device_get``, and ``EngineMetrics.host_fetches`` counts those
calls.  The spy asserts the budget from outside while the metric
cross-check pins that the engine's own accounting is the whole story —
a new ad-hoc ``device_get``/``np.asarray`` sneaking into the step path
shows up as spy > metric (or a budget breach) here.

The budget being pinned (all under the fused step summary):

* a micro-batch step costs <= 1 fetch (probe + dropped-adds + poison
  basket counts + tile bounds ride ONE ``device_get``);
* the drain-boundary flush of the last batch's deferred maintenance
  costs <= 1 fetch;
* idle steps after the flush cost 0;
* the stream must stay on the maintenance fast path (no triggered
  refresh/renorm — those legitimately pay one extra fetch and are
  covered by ``test_streaming``'s stability cases).
"""
import numpy as np
import pytest

import jax

from repro.core import RefEngine, TifuParams
from repro.core.types import KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM
from repro.streaming import Event, StateStore, StoreConfig, StreamingEngine

P = TifuParams(n_items=41, group_size=3, r_b=0.9, r_g=0.7)
M, N, B = 8, 48, 6


def mixed_stream(n_events=520, seed=7):
    """The chaos-suite stream construction, plus its RefEngine oracle."""
    rng = np.random.default_rng(seed)
    ref = RefEngine(P, dtype=np.float32)
    events = []
    for seqno in range(n_events):
        u = int(rng.integers(0, M))
        st = ref.state(u)
        nb = st.n_baskets
        if nb == 0 or (rng.random() < 0.6 and nb < N - 2):
            items = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                               replace=False).astype(np.int32)
            ref.add_basket(u, items)
            events.append(Event(KIND_ADD_BASKET, u, items=items,
                                seqno=seqno))
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            ref.delete_basket(u, pos)
            events.append(Event(KIND_DEL_BASKET, u, pos=pos, seqno=seqno))
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(st.history[pos]))
            ref.delete_item(u, pos, item)
            events.append(Event(KIND_DEL_ITEM, u, pos=pos, item=item,
                                seqno=seqno))
    return events, ref


@pytest.fixture()
def device_get_spy(monkeypatch):
    """Counting pass-through around ``jax.device_get``."""
    real = jax.device_get

    def spy(tree):
        spy.calls += 1
        return real(tree)

    spy.calls = 0
    monkeypatch.setattr(jax, "device_get", spy)
    return spy


@pytest.mark.parametrize("tile_hints", [False, True])
def test_transfers_per_step_budget(device_get_spy, tile_hints):
    events, ref = mixed_stream()
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B))
    eng = StreamingEngine(store, P, batch_size=16, tile_hints=tile_hints)
    eng.submit(events)

    per_step = []
    while True:
        before = device_get_spy.calls
        fetched_before = eng.metrics.host_fetches
        n = eng.step()
        cost = device_get_spy.calls - before
        per_step.append(cost)
        # the headline pin: one fused summary transfer, nothing else
        assert cost <= 1, f"step {len(per_step)} paid {cost} transfers"
        # the engine's own accounting sees every transfer the spy sees:
        # an ad-hoc device_get outside _fetch would break this equality
        assert cost == eng.metrics.host_fetches - fetched_before
        if n == 0:
            break
    assert eng.metrics.events_processed == len(events)

    # the stream stayed on the maintenance fast path, so the budget
    # above really is the healthy-path budget (triggered refresh/renorm
    # legitimately add one fetch each and are exercised elsewhere)
    assert eng.metrics.refreshes == 0
    assert eng.metrics.renormalizations == 0
    assert eng.metrics.host_fetches == sum(per_step)

    # idle steps after the drain-boundary flush are free
    for _ in range(3):
        before = device_get_spy.calls
        assert eng.step() == 0
        assert device_get_spy.calls == before

    # and the deferred pipeline converged to the right state
    got = np.asarray(eng.store.state.materialized_user_vecs())
    want = np.stack([ref.state(u).user_vec.astype(np.float32)
                     for u in range(M)])
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_empty_flush_costs_one_then_free(device_get_spy):
    """The deferred-maintenance flush is exactly one transfer, once."""
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B))
    eng = StreamingEngine(store, P, batch_size=16)

    # a fresh engine has nothing deferred: idle steps are free
    before = device_get_spy.calls
    assert eng.step() == 0
    assert device_get_spy.calls == before

    eng.add_basket(0, [1, 2, 3])
    eng.step()                       # applies; defers the probe
    before = device_get_spy.calls
    assert eng.step() == 0           # empty step settles the probe...
    assert device_get_spy.calls == before + 1
    before = device_get_spy.calls
    assert eng.step() == 0           # ...after which idling is free
    assert device_get_spy.calls == before
