"""Micro-batch update latency/throughput vs vocabulary size, per backend.

Measures the kind-partitioned sparse-delta pipeline (core.updates
apply_add_batch / apply_del_*_batch via the apply_update_batch shim)
against two dense baselines for add-only, del-basket-only, del-item-only
and mixed micro-batches at n_items ∈ {1k, 10k, 100k}:

  * ``dense_seed`` — the seed's mixed path (gather [batch, n_items]
    rows, compute every update rule, select, scatter dense deltas);
  * ``dense_kind`` — the homogeneous dense decremental paths
    (apply_del_*_batch_dense): one rule per program, still O(n_items)
    row traffic.  This is the honest baseline for the sparse deletes.

Headline claims (ISSUE 1 + ISSUE 2 acceptance): add latency is flat in
n_items (O(basket) state traffic), and the sparse decremental paths beat
the dense baseline by >= 5x at 100k items because their support is the
history window (N·B ids), not the vocabulary.

``--backend`` selects which kernel path the sparse pipeline exercises
(ROADMAP: track both backends):

  * ``cpu``       — natural dispatch on a CPU host (XLA reference
                    kernels; the numbers the sparse-speedup acceptance
                    gates on);
  * ``tpu``       — natural dispatch on a TPU host (tile-planned Pallas
                    kernels; requires jax.default_backend() == "tpu");
  * ``interpret`` — the tile-planned Pallas kernels in interpret mode on
                    any host.  Orders of magnitude slower per step
                    (plumbing/equivalence numbers, not perf), so it is
                    only allowed together with ``--smoke``.

``--shards N [N ...]`` switches to the **sharded-engine arm**: instead
of the kernel-path grid it measures end-to-end add latency through a
``ShardedStreamingEngine`` at each user-shard count (DESIGN.md §7) and
records one ``arm="sharded"`` entry — the acceptance claim is that add
latency stays flat in the shard count.

``--device-resident`` switches to the **engine hot-path arm**
(DESIGN.md §12): ``step()`` add/del p50/p99, the measured
``transfers_per_step`` (contract: exactly one fused summary fetch), and
a sync-vs-async checkpointed-step comparison whose
``async_ckpt_p99_speedup_vs_sync`` ratio is the gated claim that the
background writer keeps serialize/fsync out of the hot path's p99.

Each result row records its backend, and BENCH_updates.json accumulates
one entry per (backend, mode, arm) in ``runs`` — re-running a backend
replaces only that entry, so CPU and TPU numbers are tracked
side-by-side (schema: benchmarks/README.md).  ``benchmarks/
bench_trend.py`` diffs the summary speedups of a fresh run against the
committed file (the CI bench-trend step).

    PYTHONPATH=src python benchmarks/bench_update_batch.py [--quick]
    PYTHONPATH=src python benchmarks/bench_update_batch.py --smoke  # CI
    PYTHONPATH=src python benchmarks/bench_update_batch.py --shards 1 2 4

``--smoke`` shrinks every dimension (users/batch/vocab/iters) so the CI
bench job exercises the full harness in seconds on CPU; its numbers are
for plumbing validation, not for perf tracking.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (StreamState, TifuParams, apply_add_batch,
                        apply_del_basket_batch_dense,
                        apply_del_item_batch_dense, apply_update_batch,
                        apply_update_batch_dense)
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, KIND_NOOP, PAD_ID, AddBatch,
                              DelBasketBatch, DelItemBatch, UpdateBatch)
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class BenchConfig:
    m_users: int = 1024
    max_baskets: int = 24
    max_bsize: int = 16
    batch: int = 256
    seed_baskets: int = 6
    n_items_grid: tuple = (1_000, 10_000, 100_000)
    iters: int = 8
    dense_iters: int = 4


SMOKE = BenchConfig(m_users=128, max_baskets=12, max_bsize=8, batch=64,
                    seed_baskets=4, n_items_grid=(1_000, 4_000), iters=2,
                    dense_iters=1)
QUICK = BenchConfig(iters=4, dense_iters=2)

KINDS = ("add", "del_basket", "del_item", "mixed")

# impl override per --backend.  "cpu" pins the XLA reference path
# explicitly (NOT "auto": on a TPU host auto would silently measure the
# Pallas kernels under a 'cpu' label and poison the trend baseline);
# "tpu" uses natural dispatch on a TPU host (guarded in main()).
BACKEND_IMPL = {"cpu": "ref", "tpu": "auto", "interpret": "interpret"}


def make_params(n_items: int) -> TifuParams:
    return TifuParams(n_items=n_items, group_size=7, r_b=0.9, r_g=0.7)


def seed_state(params: TifuParams, rng, cfg: BenchConfig) -> StreamState:
    """Give every user seed_baskets baskets via the batched add path."""
    state = StreamState.zeros(cfg.m_users, params.n_items, cfg.max_baskets,
                              cfg.max_bsize, cfg.max_baskets)
    for _ in range(cfg.seed_baskets):
        for lo in range(0, cfg.m_users, cfg.batch):
            users = np.arange(lo, lo + cfg.batch, dtype=np.int32)
            state = apply_update_batch(
                state, make_batch(rng, users, "add", state, cfg), params)
    return state


def make_batch(rng, users, kind: str, state: StreamState,
               cfg: BenchConfig) -> UpdateBatch:
    """One fixed-shape batch over the given (distinct) users.

    Deterministic composition per kind: stable sub-batch sizes => the
    pow2 buckets compile once in warmup and the loop times steady state
    (add: all adds; del_basket/del_item: homogeneous; mixed: 2/1/1)."""
    u = len(users)
    kinds = np.zeros(u, np.int32)
    items = np.full((u, cfg.max_bsize), PAD_ID, np.int32)
    pos = np.zeros(u, np.int32)
    item = np.full(u, PAD_ID, np.int32)
    nb = np.asarray(state.n_baskets)
    hist = None
    for r, uu in enumerate(users):
        roll = {"add": 0.0, "del_basket": 0.6, "del_item": 0.9,
                "mixed": (0.0, 0.0, 0.6, 0.9)[r % 4]}[kind]
        if roll < 0.5 or nb[uu] == 0:
            kinds[r] = KIND_ADD_BASKET
            b = rng.choice(state.n_items,
                           size=int(rng.integers(2, cfg.max_bsize // 2)),
                           replace=False)
            items[r, :len(b)] = b
        elif roll < 0.75:
            kinds[r] = KIND_DEL_BASKET
            pos[r] = int(rng.integers(0, nb[uu]))
        else:
            kinds[r] = KIND_DEL_ITEM
            pos[r] = int(rng.integers(0, nb[uu]))
            if hist is None:
                hist = np.asarray(state.history)
            row = hist[uu, pos[r]]
            row = row[row >= 0]
            item[r] = int(row[0]) if row.size else 0
            if not row.size:
                kinds[r] = KIND_NOOP
    return UpdateBatch(kind=jnp.asarray(kinds), user=jnp.asarray(users),
                       basket_items=jnp.asarray(items),
                       basket_pos=jnp.asarray(pos), item=jnp.asarray(item))


def _dense_kind_apply(state, batch: UpdateBatch, params):
    """Route a homogeneous UpdateBatch to the dense per-kind baseline.

    Add rows (make_batch's nb==0 fallback) go through the same add path
    as the partitioned arm, so both arms evolve identical states and the
    reported delete speedup compares like against like."""
    kind = np.asarray(jax.device_get(batch.kind))
    user = np.asarray(jax.device_get(batch.user))
    cap = int(kind.shape[0])
    adds = np.nonzero(kind == KIND_ADD_BASKET)[0]
    delb = np.nonzero(kind == KIND_DEL_BASKET)[0]
    deli = np.nonzero(kind == KIND_DEL_ITEM)[0]
    if adds.size:
        items = np.asarray(jax.device_get(batch.basket_items))
        state = apply_add_batch(
            state, AddBatch.build(user[adds], items[adds], items.shape[1],
                                  pad_cap=cap), params)
    if delb.size:
        pos = np.asarray(jax.device_get(batch.basket_pos))
        state = apply_del_basket_batch_dense(
            state, DelBasketBatch.build(user[delb], pos[delb], pad_cap=cap),
            params)
    if deli.size:
        pos = np.asarray(jax.device_get(batch.basket_pos))
        it = np.asarray(jax.device_get(batch.item))
        state = apply_del_item_batch_dense(
            state, DelItemBatch.build(user[deli], pos[deli], it[deli],
                                      pad_cap=cap), params)
    return state


PATHS = {
    "partitioned": apply_update_batch,
    "dense_seed": apply_update_batch_dense,
    "dense_kind": _dense_kind_apply,
}


def bench_sharded(cfg: BenchConfig, shard_counts, backend: str) -> tuple:
    """Engine-level add latency vs user-shard count (DESIGN.md §7).

    Feeds identical per-iteration add batches (distinct users, routed by
    ``user % n_shards``) through a `ShardedStreamingEngine` and times
    `run_until_drained` — ingestion, routing, per-shard kind-partitioned
    sub-batch cuts and the batched add path, end to end.  The acceptance
    claim is that add latency stays FLAT in the shard count: sharding
    splits the same work across smaller per-shard sub-batches, so the
    per-batch wall time must not grow with n_shards (on a single test
    host the shards share one device; on a real deployment they run on
    disjoint device groups and this same number shrinks).
    """
    from repro.parallel.sharding import UserShardSpec
    from repro.streaming import ShardedStreamingEngine
    n_items = cfg.n_items_grid[min(1, len(cfg.n_items_grid) - 1)]
    params = make_params(n_items)
    # normalize: the growth metric is defined as max-vs-min shard count
    shard_counts = sorted(set(shard_counts))
    results = []
    for n_shards in shard_counts:
        spec = UserShardSpec(cfg.m_users, n_shards)
        eng = ShardedStreamingEngine.create(
            spec, params, max_baskets=cfg.max_baskets,
            max_basket_size=cfg.max_bsize, batch_size=cfg.batch)
        rng = np.random.default_rng(0)
        per_shard = cfg.batch // n_shards
        n_fed = sum(min(per_shard, spec.shard_users(s))
                    for s in range(n_shards))

        def feed():
            # shard-balanced batches (a hash-partitioned source): each
            # shard receives batch/n_shards events, so the per-shard
            # pow2 buckets sit at batch/n_shards instead of flapping
            # across the boundary on sampling noise
            for s in range(n_shards):
                owned = spec.owned_users(s)
                for u in rng.choice(owned, size=min(per_shard, len(owned)),
                                    replace=False):
                    eng.add_basket(int(u), rng.choice(
                        n_items,
                        size=int(rng.integers(2, cfg.max_bsize // 2)),
                        replace=False))

        for _ in range(3):                       # warmup/compile
            feed()
            eng.run_until_drained()
        times = []
        for _ in range(cfg.iters):
            feed()
            t0 = time.perf_counter()
            eng.run_until_drained()
            times.append(time.perf_counter() - t0)
        times = np.asarray(times)
        r = {"kind": "add", "path": "sharded_engine", "backend": backend,
             "shards": n_shards, "n_items": n_items, "batch": n_fed,
             "iters": cfg.iters, "mean_ms": float(times.mean() * 1e3),
             "p50_ms": float(np.median(times) * 1e3),
             "min_ms": float(times.min() * 1e3),
             "events_per_s": float(n_fed / times.mean())}
        results.append(r)
        print(f"sharded_engine add shards={n_shards:2d} "
              f"n_items={n_items:>6d} mean={r['mean_ms']:8.2f} ms  "
              f"({r['events_per_s']:,.0f} ev/s)")
    base = results[0]
    summary = {"shards": list(shard_counts),
               "add_mean_ms_by_shards": {str(r["shards"]): r["mean_ms"]
                                         for r in results},
               "add_latency_growth_max_vs_min_shards":
                   results[-1]["mean_ms"] / base["mean_ms"]}
    return results, summary


def bench_recovery(cfg: BenchConfig, backend: str) -> tuple:
    """Time-to-recover after an injected crash, vs history size.

    For each seeded history depth: drain a checkpointed engine, apply a
    delta window (including poison deletes that must quarantine, not
    wedge), kill the NEXT checkpoint at its commit point with an
    injected crash (``faults.FaultPlan``), then measure a fresh
    process's restore + at-least-once full-delta replay back to a
    drained engine (DESIGN.md §9).  Also exercises the bounded-ingestion
    path (a 2x-high-water burst against ``max_pending``) and reports the
    dead-letter and backpressure counters alongside the timings —
    recovery numbers are informational (bench_trend gates only speedup/
    compile-count keys).
    """
    import shutil
    import tempfile

    from repro.streaming import StateStore, StoreConfig, StreamingEngine
    from repro.streaming import Event, faults

    n_items = cfg.n_items_grid[min(1, len(cfg.n_items_grid) - 1)]
    params = make_params(n_items)
    hist_grid = [h for h in (4, 8, 16) if h + 4 <= cfg.max_baskets]
    results = []

    def make_engine():
        store = StateStore(StoreConfig(
            n_users=cfg.m_users, n_items=n_items,
            max_baskets=cfg.max_baskets, max_basket_size=cfg.max_bsize))
        return StreamingEngine(store, params, batch_size=cfg.batch)

    for h in hist_grid:
        rng = np.random.default_rng(0)
        eng = make_engine()
        seqno = 0
        for _ in range(h):
            seed = []
            for u in range(cfg.m_users):
                seed.append(Event(
                    KIND_ADD_BASKET, u, seqno=seqno,
                    items=rng.choice(n_items, size=cfg.max_bsize // 2,
                                     replace=False).astype(np.int32)))
                seqno += 1
            eng.submit(seed)
        eng.run_until_drained()
        ckpt = tempfile.mkdtemp(prefix="bench_recovery_")
        try:
            eng.checkpoint(ckpt, 1)
            # the delta a recovering engine must replay: 2 batches of
            # adds plus poison deletes (positions beyond every history)
            # that must land in the dead-letter queue at apply time
            delta = []
            for u in range(min(2 * cfg.batch, cfg.m_users)):
                delta.append(Event(
                    KIND_ADD_BASKET, u, seqno=seqno,
                    items=rng.choice(n_items, size=cfg.max_bsize // 2,
                                     replace=False).astype(np.int32)))
                seqno += 1
            for u in range(8):
                delta.append(Event(KIND_DEL_BASKET, u, seqno=seqno,
                                   pos=cfg.max_baskets - 1))
                seqno += 1
            eng.submit(delta, on_invalid="quarantine")
            eng.run_until_drained()
            with faults.inject(
                    faults.FaultPlan(crash_site="LATEST.pre_replace")):
                try:
                    eng.checkpoint(ckpt, 2)
                except faults.InjectedCrash:
                    pass            # the process died mid-commit
            restore_t, replay_t, n_replay = [], [], 0
            for _ in range(max(2, cfg.iters)):
                eng2 = make_engine()
                t0 = time.perf_counter()
                eng2.restore(ckpt)
                t1 = time.perf_counter()
                eng2.submit(delta, on_invalid="quarantine")
                n_replay = eng2.n_pending
                eng2.run_until_drained()
                t2 = time.perf_counter()
                restore_t.append(t1 - t0)
                replay_t.append(t2 - t1)
            # bounded ingestion: a 2x-high-water burst must shed
            # deterministically while the engine drains the rest
            eng2.max_pending = cfg.batch
            burst = [Event(KIND_ADD_BASKET, u % cfg.m_users,
                           items=np.arange(2, dtype=np.int32))
                     for u in range(2 * cfg.batch)]
            shed = eng2.submit(burst, on_overflow="shed")
            eng2.run_until_drained()
        finally:
            shutil.rmtree(ckpt, ignore_errors=True)
        restore_t, replay_t = np.asarray(restore_t), np.asarray(replay_t)
        total = restore_t + replay_t
        r = {"kind": "recovery", "path": "engine_recovery",
             "backend": backend, "n_items": n_items, "history": h,
             "events_replayed": n_replay,
             "iters": len(total),
             "restore_ms": float(restore_t.mean() * 1e3),
             "replay_ms": float(replay_t.mean() * 1e3),
             "recover_ms": float(total.mean() * 1e3),
             "p50_recover_ms": float(np.median(total) * 1e3),
             "replay_events_per_s": float(n_replay / replay_t.mean()),
             "dead_letters": eng2.metrics.dead_letters,
             "backpressure_rejections": shed.rejected,
             "crash_site": "LATEST.pre_replace"}
        results.append(r)
        print(f"recovery    history={h:3d} n_items={n_items:>6d} "
              f"recover={r['recover_ms']:8.2f} ms "
              f"(restore {r['restore_ms']:.2f} + replay "
              f"{r['replay_ms']:.2f}; {n_replay} events, "
              f"{r['dead_letters']} dead-lettered, "
              f"{r['backpressure_rejections']} shed)")
    last = results[-1]
    summary = {"history_grid": hist_grid,
               "recover_ms_by_history": {str(r["history"]): r["recover_ms"]
                                         for r in results},
               "recover_ms_at_max_history": last["recover_ms"],
               "restore_ms_at_max_history": last["restore_ms"],
               "recovery_replay_events_per_s":
                   last["replay_events_per_s"],
               "recovery_dead_letters": last["dead_letters"],
               "recovery_backpressure_rejections":
                   last["backpressure_rejections"]}
    return results, summary


def bench_device_resident(cfg: BenchConfig, backend: str) -> tuple:
    """Engine hot-path latency under the §12 device-residency contract.

    Times ``StreamingEngine.step()`` end to end for add-only and
    delete-only micro-batches (p50/p99) and reports the measured
    ``transfers_per_step`` — the fused-step-summary contract says a
    healthy step performs exactly ONE device→host transfer, pinned by
    tests/test_transfer_budget.py and tracked here as a parity fact.
    Then times a *checkpointed* step (step + commit initiation) with
    the synchronous §9 writer vs the §12 async snapshot-then-write
    path on the SAME engine: the gated claim is that moving
    serialize/fsync off the hot path beats the inline write at p99
    (``async_ckpt_p99_speedup_vs_sync``).  The async arm's flush —
    where writer errors surface and durability is guaranteed — happens
    once, outside the timed region, exactly as a deployment would
    sync at a barrier rather than per micro-batch.
    """
    import shutil
    import tempfile

    from repro.streaming import (AsyncCheckpointer, StateStore,
                                 StoreConfig, StreamingEngine)

    n_items = cfg.n_items_grid[min(1, len(cfg.n_items_grid) - 1)]
    params = make_params(n_items)
    store = StateStore(StoreConfig(
        n_users=cfg.m_users, n_items=n_items,
        max_baskets=cfg.max_baskets, max_basket_size=cfg.max_bsize))
    eng = StreamingEngine(store, params, batch_size=cfg.batch)
    rng = np.random.default_rng(0)
    nb = np.zeros(cfg.m_users, np.int64)   # host mirror of basket counts
    user_sets = [np.arange(lo, lo + cfg.batch, dtype=np.int32)
                 for lo in range(0, cfg.m_users, cfg.batch)]

    def feed(kind: str, i: int):
        for u in user_sets[i % len(user_sets)]:
            u = int(u)
            if kind == "add" or nb[u] == 0:
                eng.add_basket(u, rng.choice(
                    n_items, size=int(rng.integers(2, cfg.max_bsize // 2)),
                    replace=False))
                nb[u] += 1
            else:
                eng.delete_basket(u, int(nb[u] - 1))
                nb[u] -= 1

    for i in range(4):                       # seed history + compile
        feed("add", i)
        eng.run_until_drained()

    results = []
    steps = max(12, cfg.iters)
    transfers, steps_timed = 0, 0
    for kind in ("add", "del"):
        for i in range(3):                   # warmup this phase's buckets
            feed(kind, i)
            eng.run_until_drained()
        times = []
        fetches0 = eng.metrics.host_fetches  # timed steps only: warmup
        for i in range(steps):               # drains pay flush fetches
            feed(kind, i)
            t0 = time.perf_counter()
            eng.step()
            times.append(time.perf_counter() - t0)
            assert eng.n_pending == 0
        transfers += eng.metrics.host_fetches - fetches0
        steps_timed += steps
        times = np.asarray(times)
        r = {"kind": kind, "path": "engine_step", "backend": backend,
             "n_items": n_items, "batch": cfg.batch, "iters": steps,
             "mean_ms": float(times.mean() * 1e3),
             "p50_ms": float(np.median(times) * 1e3),
             "p99_ms": float(np.quantile(times, 0.99) * 1e3),
             "events_per_s": float(cfg.batch / times.mean())}
        results.append(r)
        print(f"engine_step {kind:10s} n_items={n_items:>6d} "
              f"p50={r['p50_ms']:8.2f} ms p99={r['p99_ms']:8.2f} ms  "
              f"({r['events_per_s']:,.0f} ev/s)")
    transfers_per_step = transfers / steps_timed

    # checkpointed step: commit initiation on the hot path, sync vs async
    ck_iters = max(6, cfg.dense_iters + 2)
    ckpt_dir = tempfile.mkdtemp(prefix="bench_device_resident_")
    ck = AsyncCheckpointer()
    ckpt_p99 = {}
    try:
        for mode in ("sync", "async"):
            eng.checkpointer = ck if mode == "async" else None
            eng.checkpoint(os.path.join(ckpt_dir, mode), 0)  # warm path
            times = []
            for i in range(ck_iters):
                feed("add", i)
                t0 = time.perf_counter()
                eng.step()
                eng.checkpoint(os.path.join(ckpt_dir, mode), i + 1)
                times.append(time.perf_counter() - t0)
            eng.flush_checkpoints()          # durability barrier,
            times = np.asarray(times)        # outside the timed region
            ckpt_p99[mode] = float(np.quantile(times, 0.99) * 1e3)
            results.append({
                "kind": "add", "path": f"ckpt_{mode}_step",
                "backend": backend, "n_items": n_items,
                "batch": cfg.batch, "iters": ck_iters,
                "mean_ms": float(times.mean() * 1e3),
                "p50_ms": float(np.median(times) * 1e3),
                "p99_ms": ckpt_p99[mode]})
            print(f"ckpt_{mode:5s} step      n_items={n_items:>6d} "
                  f"p50={np.median(times) * 1e3:8.2f} ms "
                  f"p99={ckpt_p99[mode]:8.2f} ms")
        ck.close()
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    add, dele = results[0], results[1]
    summary = {
        "transfers_per_step": transfers_per_step,
        "add_p50_ms": add["p50_ms"], "add_p99_ms": add["p99_ms"],
        "del_p50_ms": dele["p50_ms"], "del_p99_ms": dele["p99_ms"],
        "sync_ckpt_step_p99_ms": ckpt_p99["sync"],
        "async_ckpt_step_p99_ms": ckpt_p99["async"],
        "async_ckpt_p99_speedup_vs_sync":
            ckpt_p99["sync"] / ckpt_p99["async"],
    }
    return results, summary


def bench(path: str, params, rng, kind: str, iters: int,
          cfg: BenchConfig, backend: str) -> dict:
    apply_fn = PATHS[path]
    state = seed_state(params, rng, cfg)
    user_sets = [np.arange(lo, lo + cfg.batch, dtype=np.int32)
                 for lo in range(0, cfg.m_users, cfg.batch)]
    # warmup/compile (several batches: mixed batches flip between pow2
    # sub-batch buckets, each bucket combination compiles once)
    for _ in range(3):
        state = apply_fn(state, make_batch(rng, user_sets[0], kind, state,
                                           cfg), params)
    jax.block_until_ready(state.user_vecs)
    times = []
    for i in range(iters):
        batch = make_batch(rng, user_sets[(i + 1) % len(user_sets)], kind,
                           state, cfg)
        t0 = time.perf_counter()
        state = apply_fn(state, batch, params)
        jax.block_until_ready(state.user_vecs)
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    return {"kind": kind, "path": path, "backend": backend,
            "n_items": params.n_items, "batch": cfg.batch, "iters": iters,
            "mean_ms": float(times.mean() * 1e3),
            "p50_ms": float(np.median(times) * 1e3),
            "min_ms": float(times.min() * 1e3),
            "events_per_s": float(cfg.batch / times.mean())}


def run_grid(cfg: BenchConfig, backend: str, quick: bool) -> list:
    results = []
    for n_items in cfg.n_items_grid:
        params = make_params(n_items)
        for kind in KINDS:
            paths = ["partitioned", "dense_seed"]
            if kind in ("del_basket", "del_item"):
                paths.insert(1, "dense_kind")
            for path in paths:
                dense = path != "partitioned"
                if (quick and dense and kind != "add"
                        and n_items == 100_000 and path == "dense_seed"):
                    continue   # the heaviest redundant configurations
                rng = np.random.default_rng(0)
                iters = cfg.dense_iters if dense else cfg.iters
                r = bench(path, params, rng, kind, iters, cfg, backend)
                results.append(r)
                print(f"{path:11s} {kind:10s} n_items={n_items:>6d} "
                      f"mean={r['mean_ms']:8.2f} ms  "
                      f"({r['events_per_s']:,.0f} ev/s)")
    return results


def summarize(results: list, cfg: BenchConfig) -> dict:
    def pick(path, kind, n):
        return next((r for r in results if r["path"] == path
                     and r["kind"] == kind and r["n_items"] == n), None)

    n_lo, n_hi = cfg.n_items_grid[0], cfg.n_items_grid[-1]
    summary = {"max_n_items": n_hi}
    add_lo, add_hi = pick("partitioned", "add", n_lo), \
        pick("partitioned", "add", n_hi)
    summary["add_latency_growth_to_max_items"] = (
        add_hi["mean_ms"] / add_lo["mean_ms"])
    dense_add = pick("dense_seed", "add", n_hi)
    if dense_add:
        summary["add_speedup_vs_dense_at_max_items"] = (
            dense_add["mean_ms"] / add_hi["mean_ms"])
    for kind in ("del_basket", "del_item"):
        sp = pick("partitioned", kind, n_hi)
        dk = pick("dense_kind", kind, n_hi)
        if sp and dk:
            summary[f"{kind}_sparse_speedup_vs_dense_at_max_items"] = (
                dk["mean_ms"] / sp["mean_ms"])
    return summary


def merge_runs(out_path: str, entry: dict) -> dict:
    """Accumulate per-(backend, mode, arm) run entries in the bench JSON.

    Re-running one backend replaces only its entry (``arm`` is None for
    the default kernel-path grid, "sharded" for the ``--shards`` engine
    sweep); a legacy single-run file (pre-ISSUE-3 format) is migrated
    into ``runs`` first.  See benchmarks/README.md for the schema."""
    payload = {"benchmark": "bench_update_batch", "runs": []}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = {}
        if "runs" in old:
            payload["runs"] = old["runs"]
        elif "results" in old:                 # legacy single-run layout
            payload["runs"] = [{k: old.get(k) for k in
                                ("backend", "mode", "config", "summary",
                                 "results")}]
    key = (entry["backend"], entry["mode"], entry.get("arm"))
    payload["runs"] = [r for r in payload["runs"]
                       if (r.get("backend"), r.get("mode"),
                           r.get("arm")) != key]
    payload["runs"].append(entry)
    payload["runs"].sort(key=lambda r: (str(r.get("backend")),
                                        str(r.get("mode")),
                                        str(r.get("arm"))))
    return payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer iterations at full sizes")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + minimal iterations (CI smoke: "
                         "seconds on CPU, validates the harness only)")
    ap.add_argument("--backend", choices=sorted(BACKEND_IMPL),
                    default=None,
                    help="kernel path to exercise (default: tpu on a TPU "
                         "host, else cpu)")
    ap.add_argument("--shards", type=int, nargs="+", default=None,
                    metavar="N",
                    help="run the sharded-engine add-latency sweep over "
                         "these user-shard counts (e.g. --shards 1 2 4) "
                         "instead of the kernel-path grid; records one "
                         "arm='sharded' entry (DESIGN.md §7)")
    ap.add_argument("--recovery", action="store_true",
                    help="run the crash-recovery sweep (time-to-recover "
                         "after an injected commit-point crash vs "
                         "history size, plus dead-letter/backpressure "
                         "counters) instead of the kernel-path grid; "
                         "records one arm='recovery' entry (DESIGN.md "
                         "§9)")
    ap.add_argument("--device-resident", action="store_true",
                    help="run the engine hot-path arm: step() add/del "
                         "p50/p99, measured transfers/step, and the "
                         "sync-vs-async checkpointed-step comparison; "
                         "records one arm='device_resident' entry "
                         "(DESIGN.md §12)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_updates.json"))
    args = ap.parse_args()
    cfg = SMOKE if args.smoke else (QUICK if args.quick else BenchConfig())
    backend = args.backend or ("tpu" if jax.default_backend() == "tpu"
                               else "cpu")
    if backend == "tpu" and jax.default_backend() != "tpu":
        ap.error("--backend tpu requires a TPU host "
                 f"(jax.default_backend() == {jax.default_backend()!r})")
    if backend == "interpret" and not args.smoke:
        ap.error("--backend interpret is interpret-mode Pallas (orders of "
                 "magnitude slower): only allowed with --smoke")

    if sum(map(bool, (args.shards, args.recovery,
                      args.device_resident))) > 1:
        ap.error("--shards/--recovery/--device-resident are separate "
                 "arms; run them as distinct invocations (each records "
                 "its own entry)")
    with ops.default_impl(BACKEND_IMPL[backend]):
        if args.shards:
            results, summary = bench_sharded(cfg, args.shards, backend)
        elif args.recovery:
            results, summary = bench_recovery(cfg, backend)
        elif args.device_resident:
            results, summary = bench_device_resident(cfg, backend)
        else:
            results = run_grid(cfg, backend, args.quick)
            summary = summarize(results, cfg)
    print(f"\nsummary [{backend}]:")
    for k, v in summary.items():
        note = ""
        if k == "add_latency_growth_to_max_items":
            note = "  (acceptance: < 1.5x)"
        elif k == "add_latency_growth_max_vs_min_shards":
            note = "  (acceptance: flat, ~1x)"
        elif k == "async_ckpt_p99_speedup_vs_sync":
            note = "  (acceptance: > 1x)"
        elif k.startswith(("del_basket", "del_item")):
            note = "  (acceptance: >= 5x)"
        print(f"  {k}: {v:.2f}{note}" if isinstance(v, float)
              else f"  {k}: {v}")

    entry = {
        "backend": backend,
        "jax_backend": jax.default_backend(),
        "mode": "smoke" if args.smoke else ("quick" if args.quick
                                            else "full"),
        "config": dataclasses.asdict(cfg),
        "summary": summary,
        "results": results,
    }
    if args.shards:
        entry["arm"] = "sharded"
        entry["shards"] = summary["shards"]
    elif args.recovery:
        entry["arm"] = "recovery"
    elif args.device_resident:
        entry["arm"] = "device_resident"
    out = os.path.abspath(args.out)
    payload = merge_runs(out, entry)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out} ({len(payload['runs'])} run entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
