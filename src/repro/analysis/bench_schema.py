"""The BENCH summary-key naming convention (rule EN03, DESIGN.md §10.4).

Every ``summary`` key a benchmark records in ``BENCH_updates.json``
must classify as one of:

* ``gated-ratio`` — contains ``speedup``: a relative-performance claim
  the trend gate (benchmarks/bench_trend.py) enforces with a tolerance
  ratio above its floor (interpret-backend runs never enforced).
* ``gated-bound`` — contains ``compiled``: a compiled-program count the
  trend gate enforces as a hard upper bound (bucketing regressions).
* ``parity`` — an informational fact the trend report prints but does
  not gate: latency/recovery percentiles and means (``_ms``), growth
  ratios, throughput (``qps``/``per_s``), capacity/extent markers
  (``max_``, ``vmem``, ``hbm``), agreement metrics (``parity``,
  ``overlap``), sweep descriptors (``swept``, ``grid``, ``shards``)
  and robustness counters (``dead_letters``, ``rejections``).

Anything else is ``unknown`` — EN03 in the linter, and a hard failure
in ``bench_trend.py`` (a silently-ignored key is how a renamed speedup
metric escapes the regression gate).
"""
from __future__ import annotations

# Substrings that mark a key as an ungated informational (parity) fact.
PARITY_MARKERS = (
    "parity", "growth", "qps", "per_s", "overlap", "hbm", "vmem",
    "swept", "grid", "dead_letters", "rejections", "max_", "_ms",
)

# Keys that are parity facts by exact name (no marker substring).
PARITY_EXACT = frozenset({"shards"})


def classify_summary_key(key: str) -> str:
    """'gated-ratio' | 'gated-bound' | 'parity' | 'unknown' for ``key``."""
    if "speedup" in key:
        return "gated-ratio"
    if "compiled" in key:
        return "gated-bound"
    if key in PARITY_EXACT or any(m in key for m in PARITY_MARKERS):
        return "parity"
    return "unknown"
