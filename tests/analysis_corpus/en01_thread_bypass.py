"""Corpus case: background thread writes durable bytes off the commit
path (EN01, thread-target sub-check).

Every function here is private, so the public-path half of EN01 sees
nothing — but ``_start`` hands ``_spill_loop`` to a thread, and the
thread keeps writing raw bytes long after any caller's commit
discipline could apply.  The spawned target must reach
``atomic_write_json`` itself.
"""
import threading


class _Spooler:
    def _start(self):
        self._t = threading.Thread(target=self._spill_loop, daemon=True)
        self._t.start()

    def _spill_loop(self):
        while self._live:
            with open(self._path, "wb") as f:
                f.write(self._drain())
