"""tifu-knn — the paper's own architecture as a first-class config.

Production-scale cells (beyond the 40 assigned ones):
  stream_update : one jit'd micro-batch of mixed incremental/decremental
                  updates over M=1,048,576 users (Algorithm 1 at scale)
  serve_topk    : TIFU-kNN prediction — 4096 queries against the 1M-user
                  corpus (item axis TP-sharded, psum'd scores, top-k)
"""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchDef, CellProgram, sds
from repro.core import (TifuParams, apply_add_batch, apply_del_basket_batch,
                        apply_del_item_batch)
from repro.core.types import (AddBatch, DelBasketBatch, DelItemBatch,
                              StreamState)
from repro.parallel.sharding import batch_axes

M_USERS = 1_048_576
N_ITEMS = 16_384
MAX_BASKETS = 64
MAX_BSIZE = 32
UPDATE_BATCH = 16_384
DEL_BATCH = 1_024     # deletion traffic is ~1/16 of add traffic (§6.1)
N_QUERIES = 4_096
TOPK = 300


def make_params():
    return TifuParams(n_items=N_ITEMS, group_size=7, r_b=0.9, r_g=0.7,
                      k_neighbors=TOPK, alpha=0.7)


def _state_sds():
    return StreamState(
        user_vecs=sds((M_USERS, N_ITEMS)),
        last_group_vecs=sds((M_USERS, N_ITEMS)),
        history=sds((M_USERS, MAX_BASKETS, MAX_BSIZE), jnp.int32),
        group_sizes=sds((M_USERS, MAX_BASKETS), jnp.int32),
        n_baskets=sds((M_USERS,), jnp.int32),
        n_groups=sds((M_USERS,), jnp.int32),
        err_mult=sds((M_USERS,)),
        uv_scale=sds((M_USERS,)),
        lgv_scale=sds((M_USERS,)),
    )


def _state_shardings(mesh, rules):
    u = batch_axes(mesh, rules)
    tp = rules.tensor if rules.tensor in mesh.axis_names else None
    return StreamState(
        user_vecs=NamedSharding(mesh, P(u, tp)),
        last_group_vecs=NamedSharding(mesh, P(u, tp)),
        history=NamedSharding(mesh, P(u, None, None)),
        group_sizes=NamedSharding(mesh, P(u, None)),
        n_baskets=NamedSharding(mesh, P(u)),
        n_groups=NamedSharding(mesh, P(u)),
        err_mult=NamedSharding(mesh, P(u)),
        uv_scale=NamedSharding(mesh, P(u)),
        lgv_scale=NamedSharding(mesh, P(u)),
    )


def stream_update_cell(mesh, rules) -> CellProgram:
    """Kind-partitioned micro-batch: one homogeneous sub-batch per update
    kind (DESIGN.md §4) — the add path is sparse (O(batch·basket) state
    traffic), the decremental paths are dense masked rows."""
    params = make_params()
    u_ax = batch_axes(mesh, rules)
    adds = AddBatch(user=sds((UPDATE_BATCH,), jnp.int32),
                    items=sds((UPDATE_BATCH, MAX_BSIZE), jnp.int32),
                    valid=sds((UPDATE_BATCH,), jnp.bool_))
    delb = DelBasketBatch(user=sds((DEL_BATCH,), jnp.int32),
                          pos=sds((DEL_BATCH,), jnp.int32),
                          valid=sds((DEL_BATCH,), jnp.bool_))
    deli = DelItemBatch(user=sds((DEL_BATCH,), jnp.int32),
                        pos=sds((DEL_BATCH,), jnp.int32),
                        item=sds((DEL_BATCH,), jnp.int32),
                        valid=sds((DEL_BATCH,), jnp.bool_))
    ashard = AddBatch(user=NamedSharding(mesh, P(u_ax)),
                      items=NamedSharding(mesh, P(u_ax, None)),
                      valid=NamedSharding(mesh, P(u_ax)))
    bshard = DelBasketBatch(user=NamedSharding(mesh, P(u_ax)),
                            pos=NamedSharding(mesh, P(u_ax)),
                            valid=NamedSharding(mesh, P(u_ax)))
    ishard = DelItemBatch(user=NamedSharding(mesh, P(u_ax)),
                          pos=NamedSharding(mesh, P(u_ax)),
                          item=NamedSharding(mesh, P(u_ax)),
                          valid=NamedSharding(mesh, P(u_ax)))

    def fn(state, adds, delb, deli):
        state = apply_add_batch(state, adds, params)
        state = apply_del_basket_batch(state, delb, params)
        return apply_del_item_batch(state, deli, params)

    # adds: sparse support W = (m+1)·B per row — a W·log2(W) dedup sort
    # plus O(W) gathers/scatters; deletes are sparse too (DESIGN.md
    # §3.5): support W_d = N·B history-window slots per row — a
    # W_d·log2(W_d) dedup sort, per-slot coefficient math and O(W_d)
    # gathers/scatters, with no O(n_items) term.
    w = (params.group_size + 1) * MAX_BSIZE
    w_d = MAX_BASKETS * MAX_BSIZE
    flops = UPDATE_BATCH * (w * (w - 1).bit_length() + 4 * w) \
        + 2 * DEL_BATCH * (w_d * (w_d - 1).bit_length() + 8 * w_d)
    return CellProgram(
        fn=fn, args=(_state_sds(), adds, delb, deli),
        in_shardings=(_state_shardings(mesh, rules), ashard, bshard, ishard),
        donate_argnums=(0,),
        description=(f"kind-partitioned micro-batch adds={UPDATE_BATCH} "
                     f"dels=2x{DEL_BATCH}"),
        model_flops_per_step=float(flops))


def serve_topk_cell(mesh, rules) -> CellProgram:
    params = make_params()
    from repro.core import knn
    u_ax = batch_axes(mesh, rules)
    tp = rules.tensor if rules.tensor in mesh.axis_names else None
    queries = sds((N_QUERIES, N_ITEMS))
    corpus = sds((M_USERS, N_ITEMS))

    def fn(queries, corpus):
        return knn.predict(queries, corpus, k=TOPK, alpha=params.alpha,
                           exclude_self=False, mesh=mesh, rules=rules)

    flops = 2.0 * N_QUERIES * M_USERS * N_ITEMS \
        + 2.0 * N_QUERIES * TOPK * N_ITEMS
    return CellProgram(
        fn=fn, args=(queries, corpus),
        in_shardings=(NamedSharding(mesh, P(u_ax, tp)),
                      NamedSharding(mesh, P(u_ax, tp))),
        description=f"kNN predict Q={N_QUERIES} M={M_USERS}",
        model_flops_per_step=flops)


def serve_topk_opt_cell(mesh, rules) -> CellProgram:
    """§Perf H1: user-sharded corpus + local top-k + hierarchical merge +
    one-hot-matmul neighbour mean (see knn.distributed_predict)."""
    params = make_params()
    from repro.core import knn
    axes = tuple(a for a in ("pod", "data", "model")
                 if a in mesh.axis_names)
    queries = sds((N_QUERIES, N_ITEMS))
    corpus = sds((M_USERS, N_ITEMS))

    def fn(queries, corpus):
        return knn.distributed_predict(queries, corpus, k=TOPK,
                                       alpha=params.alpha, mesh=mesh,
                                       rules=rules)

    flops = 2.0 * N_QUERIES * M_USERS * N_ITEMS \
        + 2.0 * N_QUERIES * M_USERS * N_ITEMS  # + one-hot matmul mean
    return CellProgram(
        fn=fn, args=(queries, corpus),
        in_shardings=(NamedSharding(mesh, P(None, None)),
                      NamedSharding(mesh, P(axes, None))),
        description=f"kNN predict (opt) Q={N_QUERIES} M={M_USERS}",
        model_flops_per_step=flops)


def smoke_config():
    return TifuParams(n_items=64, group_size=3)


ARCH = ArchDef(
    name="tifu-knn", family="tifu",
    cells={"stream_update": stream_update_cell,
           "serve_topk": serve_topk_cell,
           "serve_topk_opt": serve_topk_opt_cell},
    make_smoke=smoke_config,
    notes="the paper's system at pod scale: users over (pod,data), "
          "items over model; serve_topk_opt is the §Perf-optimized "
          "user-sharded variant.")
