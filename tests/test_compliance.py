"""Compliance subsystem tests (ISSUE 9, DESIGN.md §11).

Certification on randomized deletion-burst streams (single + sharded),
seeded-violation detection (a skipped deletion MUST fail the
certificate), ``forget_user`` receipts and no-trace guarantees
(including the quantized cache, dead letters and checkpoint
round-trips), the envelope derivation, and the post-forget seqno
discipline of the sharded router.
"""
import numpy as np
import pytest

from repro.compliance import (basket_weights, certify,
                              divergence_envelope, retained_histories)
from repro.core.tifu import default_group_sizes, user_vector_ragged
from repro.core.types import (KIND_ADD_BASKET, KIND_DEL_BASKET,
                              KIND_DEL_ITEM, TifuParams)
from repro.parallel.sharding import UserShardSpec
from repro.streaming import (Event, ForgetReceipt,
                             ShardedStreamingEngine, StateStore,
                             StoreConfig, StreamingEngine)

P = TifuParams(n_items=29, group_size=3, k_neighbors=4)
M, N, B = 8, 24, 6


def build(n_shards):
    """Single or sharded engine at the module-level geometry."""
    if n_shards == 1:
        store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                       max_baskets=N, max_basket_size=B))
        return StreamingEngine(store, P, batch_size=16)
    return ShardedStreamingEngine.create(
        UserShardSpec(M, n_shards), P, max_baskets=N, max_basket_size=B,
        batch_size=16)


def gen_stream(rng, n_events=100, skip=()):
    """Randomized interleaved add/del_basket/del_item stream."""
    events, nb = [], [0] * M
    for _ in range(n_events):
        u = int(rng.integers(0, M))
        if u in skip:
            continue
        r = rng.random()
        if nb[u] > 0 and r < 0.25:
            pos = int(rng.integers(0, nb[u]))
            if r < 0.15:
                events.append(Event(KIND_DEL_BASKET, u, pos=pos))
                nb[u] -= 1
            else:
                events.append(Event(KIND_DEL_ITEM, u, pos=pos,
                                    item=int(rng.integers(0, P.n_items))))
        else:
            items = rng.choice(P.n_items, size=int(rng.integers(1, 5)),
                               replace=False)
            events.append(Event(KIND_ADD_BASKET, u, items=items.tolist()))
            nb[u] = min(nb[u] + 1, N - 2)
    return events


def forget_log(receipt):
    """The deletion events a forget receipt corresponds to."""
    return [Event(KIND_DEL_BASKET, receipt.user, pos=p)
            for p in range(receipt.n_baskets_deleted - 1, -1, -1)]


# ---------------------------------------------------------------------------
# retained_histories: the semantic replay oracle
# ---------------------------------------------------------------------------

def test_retained_histories_semantics():
    """Out-of-range/absent deletions noop; baskets dedup, sort, vanish."""
    ev = [Event(KIND_ADD_BASKET, 0, items=[1, 2, 3]),
          Event(KIND_ADD_BASKET, 0, items=[4, 5]),
          Event(KIND_DEL_BASKET, 0, pos=0),          # drops {1,2,3}
          Event(KIND_DEL_BASKET, 0, pos=5),          # out of range: noop
          Event(KIND_DEL_ITEM, 0, pos=0, item=4),    # {4,5} -> {5}
          Event(KIND_DEL_ITEM, 0, pos=0, item=9),    # absent: noop
          Event(KIND_DEL_ITEM, 0, pos=0, item=5)]    # basket vanishes
    hist = retained_histories(ev, 2)
    assert hist[0] == [] and hist[1] == []

    ev2 = [Event(KIND_ADD_BASKET, 1, items=[7, 7, 2])]
    hist = retained_histories(ev2, 2)
    assert hist[1][0].tolist() == [2, 7]              # deduped + sorted


# ---------------------------------------------------------------------------
# The §4.3 path-dependence envelope
# ---------------------------------------------------------------------------

def test_basket_weights_match_closed_form():
    """Per-basket weights reproduce the Eq. 1+2 ragged oracle."""
    sizes = [3, 3, 2]
    w = basket_weights(sizes, P.r_b, P.r_g)
    assert w.shape == (8,)
    # weights ARE the linear coefficients of Eq. 1+2: a one-item basket
    # stream reproduces the ragged oracle exactly
    hist = [np.array([i % P.n_items]) for i in range(8)]
    v = user_vector_ragged(hist, sizes, P)
    manual = np.zeros(P.n_items)
    for t, b in enumerate(hist):
        manual[b[0]] += w[t]
    np.testing.assert_allclose(v, manual, rtol=1e-12)


def test_divergence_envelope_is_a_bound():
    """E_u bounds the fit gap over random alternative partitions."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(1, 12))
        hist = [rng.choice(P.n_items, size=int(rng.integers(1, 4)),
                           replace=False) for _ in range(n)]
        canon = default_group_sizes(n, P.group_size)
        # a random alternative partition of the same n baskets
        alt, left = [], n
        while left:
            tau = int(rng.integers(1, left + 1))
            alt.append(tau)
            left -= tau
        env = divergence_envelope(alt, canon, P.r_b, P.r_g)
        d = np.abs(user_vector_ragged(hist, alt, P)
                   - user_vector_ragged(hist, canon, P)).max()
        assert d <= env + 1e-12


def test_divergence_envelope_rejects_mismatched_partitions():
    """Partitions of different basket counts raise ValueError."""
    with pytest.raises(ValueError):
        divergence_envelope([2, 2], [3], P.r_b, P.r_g)


# ---------------------------------------------------------------------------
# Certification: randomized burst streams + violation detection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2])
@pytest.mark.parametrize("seed", range(3))
def test_certify_randomized_burst_stream(seed, n_shards, tmp_path):
    """Clean burst streams + a forget certify at 1 and 2 shards."""
    rng = np.random.default_rng(seed)
    eng = build(n_shards)
    events = gen_stream(rng)
    eng.submit(events)
    eng.run_until_drained()
    victim = int(rng.integers(0, M))
    receipt = eng.forget_user(victim)
    assert receipt.clean
    report = certify(eng, events + forget_log(receipt),
                     forgotten_users=[victim],
                     checkpoint_dir=str(tmp_path / "ck"))
    assert report.compliant, report.summary()
    assert report.envelope_slack <= 0.0
    assert victim in report.forgotten_users


def test_certify_detects_skipped_deletion():
    """A deletion the engine never applied fails the certificate."""
    rng = np.random.default_rng(7)
    events = gen_stream(rng)
    skipped = next(e for e in events if e.kind == KIND_DEL_BASKET)
    eng = build(1)
    eng.submit([e for e in events if e is not skipped])
    eng.run_until_drained()
    report = certify(eng, events)
    assert not report.compliant
    assert any(c.name == "structural-retained-equivalence"
               for c in report.violations)


def test_certify_detects_phantom_deletion():
    # the engine processed a deletion the log does not contain
    """A deletion absent from the log fails the certificate."""
    rng = np.random.default_rng(8)
    events = gen_stream(rng)
    eng = build(1)
    eng.submit(events)
    eng.run_until_drained()
    u = next(u for u in range(M)
             if int(np.asarray(eng.store.state.n_baskets)[u]) > 0)
    eng.delete_basket(u, 0)
    eng.run_until_drained()
    report = certify(eng, events)
    assert not report.compliant


def test_certify_detects_unforgotten_user():
    # claiming a user was forgotten when their data is still live
    """Claiming a live user was forgotten fails the no-trace check."""
    rng = np.random.default_rng(9)
    events = gen_stream(rng)
    eng = build(1)
    eng.submit(events)
    eng.run_until_drained()
    u = next(u for u in range(M)
             if int(np.asarray(eng.store.state.n_baskets)[u]) > 0)
    report = certify(eng, events, forgotten_users=[u])
    assert not report.compliant
    assert any(c.name == "no-trace-live" for c in report.violations)


def test_certify_pure_add_stream_is_bitwise():
    """A deletion-free stream certifies via the bitwise replay path."""
    rng = np.random.default_rng(3)
    events = [e for e in gen_stream(rng)
              if e.kind == KIND_ADD_BASKET]
    eng = build(1)
    eng.submit(events)
    eng.run_until_drained()
    report = certify(eng, events)
    assert report.compliant, report.summary()
    assert report.pure_add_users and not report.deletion_users
    bitwise = next(c for c in report.checks
                   if c.name == "pure-add-bitwise")
    assert "bitwise-equal" in bitwise.detail


# ---------------------------------------------------------------------------
# forget_user: receipts, caches, dead letters, seqno discipline
# ---------------------------------------------------------------------------

def test_forget_receipt_and_cache_scrub():
    """forget_user scrubs both serving caches and is idempotent."""
    rng = np.random.default_rng(11)
    eng = build(1)
    eng.submit(gen_stream(rng))
    eng.run_until_drained()
    # warm BOTH serving caches so stale rows would be visible residue
    eng.store.corpus()
    eng.store.quantized_corpus()
    nb3 = int(np.asarray(eng.store.state.n_baskets)[3])
    assert nb3 > 0
    receipt = eng.forget_user(3)
    assert isinstance(receipt, ForgetReceipt)
    assert receipt.n_baskets_deleted == nb3
    assert len(receipt.seqnos) == nb3
    assert receipt.clean, receipt.residue
    assert {"corpus_absmax", "quant_nonzero"} <= set(receipt.residue)
    assert float(np.abs(np.asarray(eng.store.corpus())[3]).max()) == 0.0
    q, _ = eng.store.quantized_corpus()
    assert int((np.asarray(q)[3] != 0).sum()) == 0
    # idempotent: a second forget is a clean no-op
    again = eng.forget_user(3)
    assert again.n_baskets_deleted == 0 and again.clean


def test_forget_purges_dead_letters():
    """forget_user drops the user's quarantined dead-letter payloads."""
    eng = build(1)
    eng.add_basket(2, [1, 2])
    eng.run_until_drained()
    # quarantined deletion for user 2 (position out of range at apply)
    eng.submit([Event(KIND_DEL_BASKET, 2, pos=17)])
    eng.run_until_drained()
    assert any(ev.user == 2 for ev, _ in eng.dead_letter)
    receipt = eng.forget_user(2)
    assert receipt.purged_dead_letters >= 1
    assert not any(ev.user == 2 for ev, _ in eng.dead_letter)


def test_forget_during_frozen_serving_reports_residue():
    """A pinned frozen snapshot makes the receipt honestly unclean."""
    eng = build(1)
    eng.add_basket(1, [4, 5])
    eng.run_until_drained()
    eng.freeze_serving()
    receipt = eng.forget_user(1)
    # the pinned snapshot still serves the old values: NOT clean, and
    # the receipt says so instead of lying
    assert not receipt.clean
    assert receipt.residue["frozen_absmax"] > 0.0
    eng.thaw_serving()
    assert eng.store.row_residue([1])["user_vec_absmax"] == 0.0


def test_sharded_forget_routes_seqnos_through_router():
    """Sharded forget consumes router seqnos; later traffic admits."""
    rng = np.random.default_rng(13)
    eng = build(2)
    events = gen_stream(rng)
    eng.submit(events)
    eng.run_until_drained()
    receipt = eng.forget_user(5)
    assert receipt.clean
    # post-forget traffic must be fully admitted: a shard-local seqno
    # assignment in forget_user would collide with these router seqnos
    # and silently dedup legitimate events
    more = gen_stream(np.random.default_rng(14), n_events=30, skip=(5,))
    res = eng.submit(more)
    assert res.admitted == len(more) and res.deduped == 0
    eng.run_until_drained()
    report = certify(eng, events + forget_log(receipt) + more,
                     forgotten_users=[5])
    assert report.compliant, report.summary()


def test_sharded_forget_rejects_out_of_range_user():
    """Unknown user ids raise InvalidEventError, not a silent noop."""
    eng = build(2)
    from repro.streaming import InvalidEventError
    with pytest.raises(InvalidEventError):
        eng.forget_user(M + 3)


def test_checkpoint_round_trip_has_no_residue(tmp_path):
    """A forgotten row stays zero through checkpoint + restore."""
    rng = np.random.default_rng(17)
    eng = build(1)
    events = gen_stream(rng)
    eng.submit(events)
    eng.run_until_drained()
    receipt = eng.forget_user(0)
    ck = str(tmp_path / "ck")
    eng.checkpoint(ck, 1)
    eng2 = build(1)
    eng2.restore(ck)
    assert eng2.store.row_residue([0])["user_vec_absmax"] == 0.0
    assert int(np.asarray(eng2.store.state.n_baskets)[0]) == 0
    report = certify(eng2, events + forget_log(receipt),
                     forgotten_users=[0],
                     checkpoint_dir=str(tmp_path / "ck2"))
    assert report.compliant, report.summary()
