from repro.data import graph_sampler, stream, synthetic

__all__ = ["graph_sampler", "stream", "synthetic"]
