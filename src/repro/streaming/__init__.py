"""Streaming: micro-batch state maintenance (Spark Structured Streaming
analog — paper §5), exactly-once recovery, stability-triggered refresh."""
from repro.streaming.engine import Event, StreamingEngine
from repro.streaming.state_store import StateStore, StoreConfig, state_shardings

__all__ = ["Event", "StreamingEngine", "StateStore", "StoreConfig",
           "state_shardings"]
