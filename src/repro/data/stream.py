"""Event-stream generation for the streaming engine (paper §6.1 setup).

The paper's deletion scenario: ~1/1000 users issue GDPR requests, each
deleting 10% of their baskets; deletions interleave with new-basket
arrivals.  ``make_stream`` emits a chronological Event list.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.types import KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM
from repro.streaming.engine import Event


def make_stream(histories: Dict[int, List[np.ndarray]],
                deletion_user_rate: float = 1e-3,
                deletion_basket_frac: float = 0.10,
                item_deletion_rate: float = 0.0,
                seed: int = 0) -> List[Event]:
    """Interleave basket additions (round-robin over users, preserving
    each user's chronological order) with deletion requests."""
    rng = np.random.default_rng(seed)
    events: List[Event] = []
    # additions: round-robin so growth interleaves across users
    cursors = {u: 0 for u in histories}
    added = {u: 0 for u in histories}
    active = [u for u in histories if histories[u]]
    while active:
        nxt = []
        for u in active:
            events.append(Event(KIND_ADD_BASKET, u,
                                items=histories[u][cursors[u]]))
            cursors[u] += 1
            added[u] += 1
            if cursors[u] < len(histories[u]):
                nxt.append(u)
        active = nxt

    # deletion requests (appended post-load; engine interleaves by batch)
    users = list(histories)
    n_del_users = max(1, int(len(users) * deletion_user_rate))
    del_users = rng.choice(users, size=n_del_users, replace=False)
    for u in del_users:
        n = added[u]
        n_del = max(1, int(n * deletion_basket_frac))
        # positions re-evaluated against the shrinking history
        remaining = n
        for _ in range(n_del):
            if remaining == 0:
                break
            pos = int(rng.integers(0, remaining))
            events.append(Event(KIND_DEL_BASKET, int(u), pos=pos))
            remaining -= 1
    if item_deletion_rate > 0:
        for u in rng.choice(users, size=max(1, int(len(users)
                                                   * item_deletion_rate)),
                            replace=False):
            if added[u] == 0:
                continue
            pos = int(rng.integers(0, max(added[u] - 1, 1)))
            item = int(histories[u][pos][0])
            events.append(Event(KIND_DEL_ITEM, int(u), pos=pos, item=item))
    return events
