"""Batched JAX engine (core.updates) vs the paper-faithful ref engine."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM,
                        PAD_ID, RefEngine, StreamState, TifuParams,
                        UpdateBatch, apply_update_batch, refresh_users)

P = TifuParams(n_items=37, group_size=3, r_b=0.9, r_g=0.7)
M, N, B, K = 4, 32, 8, 32


def pad(b):
    out = np.full(B, PAD_ID, np.int32)
    out[:len(b)] = b
    return out


def one_op_batch(kind, u, items=None, pos=0, item=PAD_ID):
    return UpdateBatch(
        kind=jnp.array([kind], jnp.int32),
        user=jnp.array([u], jnp.int32),
        basket_items=jnp.array([pad(items if items is not None else [])],
                               jnp.int32),
        basket_pos=jnp.array([pos], jnp.int32),
        item=jnp.array([item], jnp.int32))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_ops_match_ref(seed):
    rng = np.random.default_rng(seed)
    state = StreamState.zeros(M, P.n_items, N, B, K)
    ref = RefEngine(P, dtype=np.float32)
    for t in range(60):
        u = int(rng.integers(0, M))
        st = ref.state(u)
        choices = [KIND_ADD_BASKET]
        if st.n_baskets > 0:
            choices += [KIND_DEL_BASKET, KIND_DEL_ITEM]
        kind = int(rng.choice(choices))
        if kind == KIND_ADD_BASKET and st.n_baskets >= N - 1:
            kind = KIND_DEL_BASKET
        if kind == KIND_ADD_BASKET:
            b = rng.choice(P.n_items, size=int(rng.integers(1, 6)),
                           replace=False)
            ref.add_basket(u, b)
            batch = one_op_batch(kind, u, items=b)
        elif kind == KIND_DEL_BASKET:
            pos = int(rng.integers(0, st.n_baskets))
            ref.delete_basket(u, pos)
            batch = one_op_batch(kind, u, pos=pos)
        else:
            pos = int(rng.integers(0, st.n_baskets))
            item = int(rng.choice(st.history[pos]))
            ref.delete_item(u, pos, item)
            batch = one_op_batch(kind, u, pos=pos, item=item)
        state = apply_update_batch(state, batch, P)
        np.testing.assert_allclose(
            np.asarray(state.materialized_user_vecs()[u]),
            ref.state(u).user_vec.astype(np.float32), atol=1e-4)
        assert int(state.n_baskets[u]) == ref.state(u).n_baskets
        assert int(state.n_groups[u]) == ref.state(u).n_groups
        gs = list(np.asarray(state.group_sizes[u])[:ref.state(u).n_groups])
        assert gs == ref.state(u).group_sizes


def test_batched_multiuser_batch(rng):
    """One batch updating several DISTINCT users at once."""
    state = StreamState.zeros(M, P.n_items, N, B, K)
    ref = RefEngine(P, dtype=np.float32)
    baskets = [rng.choice(P.n_items, size=3, replace=False)
               for _ in range(M)]
    for u, b in enumerate(baskets):
        ref.add_basket(u, b)
    batch = UpdateBatch(
        kind=jnp.full((M,), KIND_ADD_BASKET, jnp.int32),
        user=jnp.arange(M, dtype=jnp.int32),
        basket_items=jnp.stack([jnp.asarray(pad(b)) for b in baskets]),
        basket_pos=jnp.zeros((M,), jnp.int32),
        item=jnp.full((M,), PAD_ID, jnp.int32))
    state = apply_update_batch(state, batch, P)
    for u in range(M):
        np.testing.assert_allclose(
            np.asarray(state.materialized_user_vecs()[u]),
            ref.state(u).user_vec.astype(np.float32), atol=1e-5)


def test_noop_rows_do_not_disturb_state(rng):
    state = StreamState.zeros(M, P.n_items, N, B, K)
    b = rng.choice(P.n_items, size=3, replace=False)
    state = apply_update_batch(state, one_op_batch(KIND_ADD_BASKET, 1,
                                                   items=b), P)
    before = np.asarray(state.materialized_user_vecs())
    noop = UpdateBatch.noop(8, B)
    state = apply_update_batch(state, noop, P)
    np.testing.assert_array_equal(np.asarray(state.materialized_user_vecs()), before)


def test_refresh_users_resets_error(rng):
    state = StreamState.zeros(M, P.n_items, N, B, K)
    for t in range(6):
        b = rng.choice(P.n_items, size=3, replace=False)
        state = apply_update_batch(state, one_op_batch(KIND_ADD_BASKET, 0,
                                                       items=b), P)
    for t in range(3):
        state = apply_update_batch(state, one_op_batch(KIND_DEL_BASKET, 0,
                                                       pos=0), P)
    before = np.asarray(state.materialized_user_vecs()[0]).copy()
    state = refresh_users(state, jnp.array([0], jnp.int32), P)
    assert float(state.err_mult[0]) == 1.0
    np.testing.assert_allclose(np.asarray(state.materialized_user_vecs()[0]), before,
                               atol=1e-4)  # refresh ≈ maintained value
