"""Corpus case: index-map arity != grid rank (expected KC02).

The grid has rank 2 but every BlockSpec index map takes three
arguments — a copy-paste from a 3-axis kernel that Pallas only rejects
at trace time.
"""
import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref, *, m):
    tile = pl.program_id(1)
    vals = x_ref[...]
    vals = jnp.where(tile >= m, 0.0, vals)
    acc_ref[...] = vals
    o_ref[...] = acc_ref[...]


def thing(x, n, m, bq=128, bm=256):
    grid = (pl.cdiv(n, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bq, bm), lambda qi, mi, di: (qi, mi))],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi, di: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )(x)
