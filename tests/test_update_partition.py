"""Kind-partitioned sub-batch pipeline (DESIGN.md §3.3/§4) vs the
paper-faithful RefEngine: sparse-delta adds, homogeneous deletes, scale
renormalization, and full randomized mixed streams through the engine
(including replay-after-restore)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (RefEngine, StreamState, TifuParams, AddBatch,
                        DelBasketBatch, DelItemBatch, SCALE_CEIL,
                        SCALE_FLOOR, apply_add_batch,
                        apply_del_basket_batch, apply_del_basket_batch_dense,
                        apply_del_item_batch, apply_del_item_batch_dense,
                        renormalize_users)
from repro.core.types import KIND_ADD_BASKET, KIND_DEL_BASKET, KIND_DEL_ITEM
from repro.streaming import Event, StateStore, StoreConfig, StreamingEngine

P = TifuParams(n_items=41, group_size=3, r_b=0.9, r_g=0.7)
M, N, B, K = 8, 48, 6, 48


def random_mixed_events(rng, ref: RefEngine, n_events: int,
                        n_users: int, p_add=0.6):
    """Generate a valid mixed stream, applying each event to ``ref`` as
    it is drawn (deletes need the current history)."""
    events = []
    for _ in range(n_events):
        u = int(rng.integers(0, n_users))
        st = ref.state(u)
        nb = st.n_baskets
        if nb == 0 or (rng.random() < p_add and nb < N - 2):
            items = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                               replace=False).astype(np.int32)
            ref.add_basket(u, items)
            events.append(Event(KIND_ADD_BASKET, u, items=items))
        elif rng.random() < 0.5:
            pos = int(rng.integers(0, nb))
            ref.delete_basket(u, pos)
            events.append(Event(KIND_DEL_BASKET, u, pos=pos))
        else:
            pos = int(rng.integers(0, nb))
            item = int(rng.choice(st.history[pos]))
            ref.delete_item(u, pos, item)
            events.append(Event(KIND_DEL_ITEM, u, pos=pos, item=item))
    return events


def assert_matches_ref(state: StreamState, ref: RefEngine, n_users: int,
                       rtol=1e-4, atol=1e-5):
    mat = np.asarray(state.materialized_user_vecs())
    lg = np.asarray(state.materialized_last_group_vecs())
    for u in range(n_users):
        st = ref.state(u)
        np.testing.assert_allclose(mat[u], st.user_vec.astype(np.float32),
                                   rtol=rtol, atol=atol, err_msg=f"u={u}")
        np.testing.assert_allclose(lg[u],
                                   st.last_group_vec.astype(np.float32),
                                   rtol=rtol, atol=atol, err_msg=f"lgv u={u}")
        assert int(state.n_baskets[u]) == st.n_baskets
        assert int(state.n_groups[u]) == st.n_groups
        gs = list(np.asarray(state.group_sizes[u])[:st.n_groups])
        assert gs == st.group_sizes


# ---------------------------------------------------------------------------
# Direct sub-batch API
# ---------------------------------------------------------------------------

def test_add_batch_multiuser_matches_ref(rng):
    """One sparse AddBatch updating several distinct users, spanning both
    Eq. 7 (new group) and Eq. 8+9 (append) scenarios."""
    state = StreamState.zeros(M, P.n_items, N, B, K)
    ref = RefEngine(P, dtype=np.float32)
    # seed: u baskets for user u (users hit different group boundaries)
    for u in range(M):
        for _ in range(u):
            b = rng.choice(P.n_items, size=3, replace=False)
            ref.add_basket(u, b)
            state = apply_add_batch(state, AddBatch.build([u], [b], B), P)
    baskets = [rng.choice(P.n_items, size=4, replace=False)
               for _ in range(M)]
    for u, b in enumerate(baskets):
        ref.add_basket(u, b)
    state = apply_add_batch(
        state, AddBatch.build(list(range(M)), baskets, B), P)
    assert_matches_ref(state, ref, M)


def test_add_batch_padding_rows_are_noops(rng):
    state = StreamState.zeros(M, P.n_items, N, B, K)
    b = rng.choice(P.n_items, size=3, replace=False)
    state = apply_add_batch(state, AddBatch.build([1], [b], B), P)
    before = np.asarray(state.materialized_user_vecs())
    # build pads 3 -> 4 rows; the padding row aliases user 0
    batch = AddBatch.build([2, 4, 5],
                           [rng.choice(P.n_items, size=2, replace=False)
                            for _ in range(3)], B)
    assert batch.size == 4 and not bool(batch.valid[3])
    state = apply_add_batch(state, batch, P)
    after = np.asarray(state.materialized_user_vecs())
    np.testing.assert_array_equal(after[0], before[0])
    np.testing.assert_array_equal(after[1], before[1])


def test_del_batches_multiuser_match_ref(rng):
    state = StreamState.zeros(M, P.n_items, N, B, K)
    ref = RefEngine(P, dtype=np.float32)
    for u in range(M):
        for _ in range(6):
            b = rng.choice(P.n_items, size=3, replace=False)
            ref.add_basket(u, b)
            state = apply_add_batch(state, AddBatch.build([u], [b], B), P)
    # basket deletions for half the users, item deletions for the rest
    del_users = list(range(0, M, 2))
    positions = [int(rng.integers(0, ref.state(u).n_baskets))
                 for u in del_users]
    for u, pos in zip(del_users, positions):
        ref.delete_basket(u, pos)
    state = apply_del_basket_batch(
        state, DelBasketBatch.build(del_users, positions), P)
    item_users = list(range(1, M, 2))
    positions, items = [], []
    for u in item_users:
        pos = int(rng.integers(0, ref.state(u).n_baskets))
        it = int(rng.choice(ref.state(u).history[pos]))
        ref.delete_item(u, pos, it)
        positions.append(pos)
        items.append(it)
    state = apply_del_item_batch(
        state, DelItemBatch.build(item_users, positions, items), P)
    assert_matches_ref(state, ref, M)


def test_delete_on_empty_history_is_noop(rng):
    state = StreamState.zeros(M, P.n_items, N, B, K)
    b = rng.choice(P.n_items, size=3, replace=False)
    state = apply_add_batch(state, AddBatch.build([1], [b], B), P)
    before = np.asarray(state.materialized_user_vecs())
    state = apply_del_basket_batch(
        state, DelBasketBatch.build([2], [0]), P)   # user 2 is empty
    state = apply_del_item_batch(
        state, DelItemBatch.build([3], [0], [5]), P)
    np.testing.assert_array_equal(
        np.asarray(state.materialized_user_vecs()), before)


def test_add_at_capacity_is_noop(rng):
    """A full history row is not all-PAD, so the sparse history write
    must not touch it: adds to a full user are no-ops (regression:
    unguarded adds wrote item ids >= n_items into occupied rows)."""
    n, b = 4, 4
    state = StreamState.zeros(2, 20, n, b, n)
    ref = RefEngine(TifuParams(n_items=20, group_size=3), dtype=np.float32)
    p20 = TifuParams(n_items=20, group_size=3)
    baskets = [rng.choice(20, size=3, replace=False) for _ in range(6)]
    for bk in baskets[:n]:
        ref.add_basket(0, bk)
    for bk in baskets:      # two adds beyond capacity
        state = apply_add_batch(state, AddBatch.build([0], [bk], b), p20)
    hist = np.asarray(state.history[0])
    assert hist.max() < 20 and int(state.n_baskets[0]) == n
    np.testing.assert_allclose(
        np.asarray(state.materialized_user_vecs()[0]),
        ref.state(0).user_vec.astype(np.float32), rtol=1e-4, atol=1e-5)
    # deleting frees a row; the next add must land normally again
    ref.delete_basket(0, 1)
    state = apply_del_basket_batch(state, DelBasketBatch.build([0], [1]),
                                   p20)
    ref.add_basket(0, baskets[4])
    state = apply_add_batch(state, AddBatch.build([0], [baskets[4]], b),
                            p20)
    np.testing.assert_allclose(
        np.asarray(state.materialized_user_vecs()[0]),
        ref.state(0).user_vec.astype(np.float32), rtol=1e-4, atol=1e-5)


def test_renormalize_preserves_values(rng):
    """Drive the scales down with many group-opening adds, renormalize,
    and check the true vectors are unchanged and scales are reset."""
    p1 = TifuParams(n_items=29, group_size=1, r_b=0.9, r_g=0.7)  # every add
    state = StreamState.zeros(2, p1.n_items, 64, 4, 64)          # opens a group
    ref = RefEngine(p1, dtype=np.float32)
    for _ in range(40):
        b = rng.choice(p1.n_items, size=3, replace=False)
        ref.add_basket(0, b)
        state = apply_add_batch(state, AddBatch.build([0], [b], 4), p1)
    assert float(state.uv_scale[0]) < 1e-3          # scales really shrank
    before = np.asarray(state.materialized_user_vecs())
    state = renormalize_users(state, jnp.asarray([0], jnp.int32))
    assert float(state.uv_scale[0]) == 1.0
    assert float(state.lgv_scale[0]) == 1.0
    np.testing.assert_allclose(np.asarray(state.materialized_user_vecs()),
                               before, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(state.materialized_user_vecs()[0]),
        ref.state(0).user_vec.astype(np.float32), rtol=1e-4, atol=1e-5)
    assert float(SCALE_FLOOR) > 0.0


def test_restore_migrates_prescale_checkpoints(rng, tmp_path):
    """Checkpoints written before the scaled representation (no
    uv_scale/lgv_scale leaves) restore with scales of 1."""
    import os
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K))
    store.checkpoint(str(tmp_path), 0)
    path = os.path.join(str(tmp_path), "state_0000000000.npz")
    old = dict(np.load(path))
    for key in ("uv_scale", "lgv_scale"):
        old.pop(key)
    with open(path, "wb") as f:
        np.savez_compressed(f, **old)
    # pre-scale-era checkpoints also predate the commit CRCs (DESIGN.md
    # §9.1): strip them so the simulation takes the legacy-accept path
    import json
    latest = os.path.join(str(tmp_path), "LATEST")
    with open(latest) as f:
        meta = json.load(f)
    for key in ("meta_crc32", "npz_crc32", "npz_bytes"):
        meta.pop(key, None)
    with open(latest, "w") as f:
        json.dump(meta, f)
    store2 = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                    max_baskets=N, max_basket_size=B,
                                    max_groups=K))
    store2.restore(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(store2.state.uv_scale),
                                  np.ones(M, np.float32))
    np.testing.assert_array_equal(np.asarray(store2.state.lgv_scale),
                                  np.ones(M, np.float32))


def test_fast_decay_hot_user_stays_finite(rng):
    """r_g=0.2, group_size=1: uv_scale shrinks ~5x per add; the probe
    interval must be derived from the decay rates or the raw rows
    overflow f32 between probes and renormalization produces NaN
    (regression)."""
    p = TifuParams(n_items=30, group_size=1, r_b=0.9, r_g=0.2)
    store = StateStore(StoreConfig(n_users=2, n_items=30, max_baskets=128,
                                   max_basket_size=4, max_groups=128))
    eng = StreamingEngine(store, p, batch_size=1)
    assert eng.renorm_check_interval < 64   # derived from min(r_b, r_g)
    ref = RefEngine(p, dtype=np.float64)
    for _ in range(70):
        b = rng.choice(30, size=3, replace=False)
        eng.add_basket(0, b)
        ref.add_basket(0, b)
    eng.run_until_drained()
    assert eng.metrics.renormalizations > 0
    mat = np.asarray(store.state.materialized_user_vecs())
    assert np.all(np.isfinite(np.asarray(store.state.user_vecs)))
    np.testing.assert_allclose(mat[0], ref.state(0).user_vec, atol=1e-6)


def test_engine_counts_dropped_adds(rng):
    store = StateStore(StoreConfig(n_users=2, n_items=P.n_items,
                                   max_baskets=3, max_basket_size=B))
    eng = StreamingEngine(store, P, batch_size=4)
    for _ in range(5):
        eng.add_basket(0, rng.choice(P.n_items, size=3, replace=False))
    eng.run_until_drained()
    assert int(store.state.n_baskets[0]) == 3
    assert eng.metrics.dropped_adds == 2


# ---------------------------------------------------------------------------
# Sparse decremental paths vs the dense baselines (DESIGN.md §3.5)
# ---------------------------------------------------------------------------

def _seeded_pair(rng, ref, n_baskets_per_user=6):
    """Two identical StreamStates (sparse/dense arms) + a seeded ref."""
    state = StreamState.zeros(M, P.n_items, N, B, K)
    for u in range(M):
        for _ in range(n_baskets_per_user):
            b = rng.choice(P.n_items, size=int(rng.integers(1, B)),
                           replace=False)
            ref.add_basket(u, b)
            state = apply_add_batch(state, AddBatch.build([u], [b], B), P)
    clone = jax.tree_util.tree_map(lambda x: x.copy(), state)
    return state, clone


def test_sparse_del_basket_matches_dense_and_ref(rng):
    """One DelBasketBatch through both arms: sparse == dense == ref,
    covering Eq. 10/11 (tau_j > 1) and Eq. 12 (tau_j == 1) positions."""
    ref = RefEngine(P, dtype=np.float32)
    sparse, dense = _seeded_pair(rng, ref, 7)   # 7 = 3+3+1: a single-
    users = list(range(M))                      # basket last group
    positions = [u % 7 for u in users]          # spans all groups
    for u, pos in zip(users, positions):
        ref.delete_basket(u, pos)
    batch = DelBasketBatch.build(users, positions)
    sparse = apply_del_basket_batch(sparse, batch, P)
    dense = apply_del_basket_batch_dense(dense, batch, P)
    assert_matches_ref(sparse, ref, M)
    np.testing.assert_allclose(
        np.asarray(sparse.materialized_user_vecs()),
        np.asarray(dense.materialized_user_vecs()), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(sparse.materialized_last_group_vecs()),
        np.asarray(dense.materialized_last_group_vecs()),
        rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sparse.history),
                                  np.asarray(dense.history))
    np.testing.assert_array_equal(np.asarray(sparse.group_sizes),
                                  np.asarray(dense.group_sizes))


def test_sparse_del_item_matches_dense_and_ref(rng):
    """DelItemBatch through both arms, including the basket-vanish
    fallback (a singleton basket) and absent-item no-ops."""
    ref = RefEngine(P, dtype=np.float32)
    sparse, dense = _seeded_pair(rng, ref, 6)
    # user 0: make basket 2 a singleton so deleting its item vanishes it
    single_item = int(np.asarray(sparse.history[0, 2].max()))
    for _ in range(int(np.sum(np.asarray(sparse.history[0, 2]) >= 0)) - 1):
        row = np.asarray(sparse.history[0, 2])
        victim = int(row[row >= 0][0])
        if victim == single_item:
            victim = int(row[row >= 0][1])
        ref.delete_item(0, 2, victim)
        b = DelItemBatch.build([0], [2], [victim])
        sparse = apply_del_item_batch(sparse, b, P)
        dense = apply_del_item_batch_dense(dense, b, P)
    users, positions, items = [], [], []
    for u in range(M):
        if u == 0:
            pos, it = 2, single_item          # vanish fallback
        else:
            pos = int(rng.integers(0, ref.state(u).n_baskets))
            it = int(rng.choice(ref.state(u).history[pos]))
        ref.delete_item(u, pos, it)
        users.append(u)
        positions.append(pos)
        items.append(it)
    batch = DelItemBatch.build(users, positions, items)
    sparse = apply_del_item_batch(sparse, batch, P)
    dense = apply_del_item_batch_dense(dense, batch, P)
    assert_matches_ref(sparse, ref, M)
    np.testing.assert_allclose(
        np.asarray(sparse.materialized_user_vecs()),
        np.asarray(dense.materialized_user_vecs()), rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sparse.history),
                                  np.asarray(dense.history))


def test_sparse_delete_to_empty_and_rebuild(rng):
    """Deleting every basket empties the state (scenario 3) and
    subsequent adds rebuild it correctly on the residue-free support."""
    ref = RefEngine(P, dtype=np.float32)
    state = StreamState.zeros(M, P.n_items, N, B, K)
    baskets = [rng.choice(P.n_items, size=3, replace=False)
               for _ in range(4)]
    for b in baskets:
        ref.add_basket(0, b)
        state = apply_add_batch(state, AddBatch.build([0], [b], B), P)
    for _ in range(4):
        ref.delete_basket(0, 0)
        state = apply_del_basket_batch(state, DelBasketBatch.build([0], [0]),
                                       P)
    assert int(state.n_baskets[0]) == 0 and int(state.n_groups[0]) == 0
    np.testing.assert_allclose(
        np.asarray(state.materialized_user_vecs()[0]),
        np.zeros(P.n_items), atol=1e-5)
    b = rng.choice(P.n_items, size=4, replace=False)
    ref.add_basket(0, b)
    state = apply_add_batch(state, AddBatch.build([0], [b], B), P)
    assert_matches_ref(state, ref, 1)


def test_eq12_delete_grows_scale_and_renormalizes(rng):
    """Eq. 12 deletions fold the k/((k-1)·r_g) rescale into uv_scale
    (growth!); renormalize_users folds it back value-preservingly and
    the engine's ceiling probe keeps raw rows finite."""
    p1 = TifuParams(n_items=29, group_size=1, r_b=0.9, r_g=0.7)
    state = StreamState.zeros(2, p1.n_items, 64, 4, 64)
    ref = RefEngine(p1, dtype=np.float64)
    for _ in range(30):
        b = rng.choice(p1.n_items, size=3, replace=False)
        ref.add_basket(0, b)
        state = apply_add_batch(state, AddBatch.build([0], [b], 4), p1)
    s_after_adds = float(state.uv_scale[0])
    assert s_after_adds < 1e-3
    for _ in range(25):                   # every delete is an Eq. 12 case
        ref.delete_basket(0, 0)
        state = apply_del_basket_batch(state,
                                       DelBasketBatch.build([0], [0]), p1)
    assert float(state.uv_scale[0]) > s_after_adds * 100.0   # scale grew
    before = np.asarray(state.materialized_user_vecs())
    state = renormalize_users(state, jnp.asarray([0], jnp.int32))
    assert float(state.uv_scale[0]) == 1.0
    np.testing.assert_allclose(np.asarray(state.materialized_user_vecs()),
                               before, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(state.materialized_user_vecs()[0]),
        ref.state(0).user_vec.astype(np.float32), rtol=1e-3, atol=1e-4)
    assert SCALE_CEIL > 1.0 / SCALE_FLOOR * 1e-37   # bounds sane


def test_engine_bucket_hysteresis():
    """A kind's pow2 bucket grows immediately but shrinks only after
    bucket_hysteresis consecutive below-boundary micro-batches
    (ROADMAP: recompile churn when counts straddle a boundary)."""
    store = StateStore(StoreConfig(n_users=64, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K))
    eng = StreamingEngine(store, P, batch_size=32, bucket_hysteresis=3)
    rng = np.random.default_rng(0)

    def run_adds(n_users_in_batch, lo):
        for u in range(lo, lo + n_users_in_batch):
            eng.add_basket(u, rng.choice(P.n_items, size=3, replace=False))
        eng.step()

    run_adds(9, 0)                        # bucket -> 16
    assert eng._kind_bucket[KIND_ADD_BASKET] == 16
    for i in range(2):                    # below boundary, held at 16
        run_adds(5, 10 * (i + 1))
        assert eng._kind_bucket[KIND_ADD_BASKET] == 16
    run_adds(5, 40)                       # 3rd consecutive: shrink to 8
    assert eng._kind_bucket[KIND_ADD_BASKET] == 8
    assert eng.metrics.bucket_shrinks == 1
    run_adds(9, 50)                       # growth is immediate
    assert eng._kind_bucket[KIND_ADD_BASKET] == 16
    assert eng.metrics.bucket_grows == 1


def test_store_corpus_cache_tracks_state(rng):
    """store.corpus() == materialized_user_vecs() after every batch while
    refreshing only the rows the engine touched (threshold rebuilds
    disabled: batches here dirty half the 8-user store every step)."""
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K, corpus_rebuild_frac=1.0))
    eng = StreamingEngine(store, P, batch_size=4)
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 80, M)
    np.testing.assert_allclose(np.asarray(store.corpus()),
                               np.zeros((M, P.n_items)))   # cold build
    eng.submit(events)
    while eng.step():
        np.testing.assert_allclose(
            np.asarray(store.corpus()),
            np.asarray(store.state.materialized_user_vecs()),
            rtol=1e-6, atol=1e-7)
    assert store.corpus_full_builds == 1
    # each batch dirties <= batch_size rows; far fewer refreshes than a
    # full rebuild per step would cost
    assert 0 < store.corpus_rows_refreshed <= eng.metrics.batches * 4
    # restore invalidates: the next corpus() is a fresh full build
    store.invalidate_all()
    np.testing.assert_allclose(
        np.asarray(store.corpus()),
        np.asarray(store.state.materialized_user_vecs()), rtol=1e-6,
        atol=1e-7)
    assert store.corpus_full_builds == 2


def test_store_corpus_rebuild_threshold_crossover(rng):
    """Below ``corpus_rebuild_frac`` the cache refreshes rows; above it,
    one full materialize (ROADMAP: high delete rates).  Both paths are
    counted and both produce the exact corpus."""
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K, corpus_rebuild_frac=0.5))
    state = StreamState.zeros(M, P.n_items, N, B, K)
    for u in range(M):
        b = rng.choice(P.n_items, size=3, replace=False)
        state = apply_add_batch(state, AddBatch.build([u], [b], B), P)
    store.state = state
    store.corpus()                               # cold full build
    assert store.corpus_full_builds == 1

    def touch(users):
        b = [rng.choice(P.n_items, size=3, replace=False) for _ in users]
        store.state = apply_add_batch(
            store.state, AddBatch.build(list(users), b, B), P)
        store.invalidate_users(list(users))

    touch(range(3))                              # 3/8 <= 0.5: row refresh
    np.testing.assert_allclose(
        np.asarray(store.corpus()),
        np.asarray(store.state.materialized_user_vecs()), rtol=1e-6,
        atol=1e-7)
    assert store.corpus_threshold_rebuilds == 0
    assert store.corpus_rows_refreshed >= 3

    rows_before = store.corpus_rows_refreshed
    touch(range(5))                              # 5/8 > 0.5: full rebuild
    np.testing.assert_allclose(
        np.asarray(store.corpus()),
        np.asarray(store.state.materialized_user_vecs()), rtol=1e-6,
        atol=1e-7)
    assert store.corpus_threshold_rebuilds == 1
    assert store.corpus_full_builds == 2
    assert store.corpus_rows_refreshed == rows_before   # no scattered path


def test_engine_bucket_decay_for_absent_kinds():
    """A one-off burst of one kind must not pin its pow2 bucket forever:
    batches WITHOUT the kind advance its shrink hysteresis too, so the
    bucket decays and a later singleton pads small again (regression:
    a GDPR delete wave pinned del-basket at its burst bucket)."""
    store = StateStore(StoreConfig(n_users=64, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K))
    eng = StreamingEngine(store, P, batch_size=32, bucket_hysteresis=3)
    rng = np.random.default_rng(0)
    for u in range(64):
        eng.add_basket(u, rng.choice(P.n_items, size=3, replace=False))
    eng.run_until_drained()
    # burst: 9 basket deletions in one micro-batch -> bucket 16
    for u in range(9):
        eng.delete_basket(u, 0)
    eng.step()
    assert eng._kind_bucket[KIND_DEL_BASKET] == 16
    # add-only batches: the del-basket bucket decays after hysteresis
    for i in range(3):
        for u in range(4):
            eng.add_basket(10 + 4 * i + u,
                           rng.choice(P.n_items, size=3, replace=False))
        eng.step()
    assert eng._kind_bucket[KIND_DEL_BASKET] == 1
    assert eng.metrics.bucket_shrinks >= 1
    # a later singleton delete pads to the decayed bucket, not the burst
    eng.delete_basket(30, 0)
    eng.step()
    assert eng._kind_bucket[KIND_DEL_BASKET] == 1
    # and re-growth stays immediate
    for u in range(40, 49):
        eng.delete_basket(u, 0)
    eng.step()
    assert eng._kind_bucket[KIND_DEL_BASKET] == 16


# ---------------------------------------------------------------------------
# Randomized mixed streams through the engine (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_engine_mixed_stream_500_events_matches_ref(seed):
    """>= 500 interleaved add/delete events: the engine's (sparse-path)
    state matches BOTH the RefEngine user vectors and a dense-baseline
    shadow arm (apply_del_*_batch_dense) to <= 1e-4 relative error."""
    rng = np.random.default_rng(seed)
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K))
    eng = StreamingEngine(store, P, batch_size=16)
    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 520, M)
    # shadow arm: the same stream through the retained dense baselines
    dense = StreamState.zeros(M, P.n_items, N, B, K)
    for ev in events:
        if ev.kind == KIND_ADD_BASKET:
            dense = apply_add_batch(
                dense, AddBatch.build([ev.user], [ev.items], B), P)
        elif ev.kind == KIND_DEL_BASKET:
            dense = apply_del_basket_batch_dense(
                dense, DelBasketBatch.build([ev.user], [ev.pos]), P)
        else:
            dense = apply_del_item_batch_dense(
                dense, DelItemBatch.build([ev.user], [ev.pos], [ev.item]),
                P)
    eng.submit(events)
    n = eng.run_until_drained()
    assert n == len(events)
    assert_matches_ref(store.state, ref, M, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(store.state.materialized_user_vecs()),
        np.asarray(dense.materialized_user_vecs()), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(store.state.materialized_last_group_vecs()),
        np.asarray(dense.materialized_last_group_vecs()),
        rtol=1e-4, atol=1e-5)


def test_engine_mixed_replay_after_restore(rng, tmp_path):
    """Mixed stream, crash mid-way, restore, at-least-once full replay:
    duplicates are skipped and the result matches the single-pass run."""
    def make():
        store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                       max_baskets=N, max_basket_size=B,
                                       max_groups=K))
        return StreamingEngine(store, P, batch_size=16), store

    ref = RefEngine(P, dtype=np.float32)
    events = random_mixed_events(rng, ref, 200, M)

    eng1, store1 = make()
    eng1.submit(events)
    eng1.run_until_drained()
    assert_matches_ref(store1.state, ref, M)

    eng2, store2 = make()
    eng2.submit(events)
    for _ in range(3):
        eng2.step()
    eng2.checkpoint(str(tmp_path), 1)
    processed = eng2.metrics.events_processed

    eng3, store3 = make()
    eng3.restore(str(tmp_path))
    replay = [dataclasses.replace(ev, seqno=i)
              for i, ev in enumerate(events)]
    eng3.submit(replay)
    assert eng3.n_pending == len(events) - processed
    eng3.run_until_drained()
    np.testing.assert_allclose(
        np.asarray(store3.state.materialized_user_vecs()),
        np.asarray(store1.state.materialized_user_vecs()),
        rtol=1e-4, atol=1e-5)


def test_hot_user_conflict_deferral_order(rng):
    """A hot user's events are applied one per batch, in order, while
    other users keep flowing (per-user pending queues)."""
    store = StateStore(StoreConfig(n_users=M, n_items=P.n_items,
                                   max_baskets=N, max_basket_size=B,
                                   max_groups=K))
    eng = StreamingEngine(store, P, batch_size=4)
    ref = RefEngine(P, dtype=np.float32)
    for t in range(12):
        b = rng.choice(P.n_items, size=3, replace=False)
        eng.add_basket(5, b)
        ref.add_basket(5, b)
        if t % 3 == 0:
            b2 = rng.choice(P.n_items, size=2, replace=False)
            eng.add_basket(t % 4, b2)
            ref.add_basket(t % 4, b2)
    eng.delete_basket(5, 2)
    ref.delete_basket(5, 2)
    eng.run_until_drained()
    assert eng.n_pending == 0
    assert_matches_ref(store.state, ref, M)
