"""Corpus case: dot accumulating in float16 (expected KC05).

preferred_element_type is present but names a low-precision dtype —
the contract requires f32 (or i32 for int8 operands).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, m):
    tile = pl.program_id(1)
    scores = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float16)
    scores = jnp.where(tile >= m, 0.0, scores)
    acc_ref[...] = scores
    o_ref[...] = acc_ref[...]


def thing(x, w, n, m, bq=128, bm=256):
    grid = (pl.cdiv(n, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
            pl.BlockSpec((bm, bm), lambda qi, mi: (mi, mi)),
        ],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )(x, w)
