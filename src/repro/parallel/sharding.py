"""Sharding rules: map parameter/activation *logical axes* to mesh axes.

Strategy (DESIGN.md §5):

* **Tensor parallelism** over ``"model"``: attention heads, FFN hidden,
  vocabulary, MoE experts, embedding-table rows, kNN item dim.
* **FSDP / ZeRO** over ``"data"``: the largest remaining dim of each
  large parameter is additionally sharded over ``"data"`` (params are
  all-gathered per layer at use; gradients reduce-scattered). Optimizer
  state inherits the param sharding → ZeRO for free.
* **Batch** over ``("pod", "data")`` (the pod axis composes with data
  parallelism; hierarchical gradient reduction crosses DCI once).
* **Sequence/context** over ``"model"`` for long-context decode caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class UserShardSpec:
    """User-axis partitioning contract for the sharded streaming engine.

    Users are assigned **round-robin** (DESIGN.md §7): global user ``u``
    lives on shard ``u % n_shards`` at local row ``u // n_shards``.  The
    mapping is a bijection between global ids and ``(shard, row)`` pairs,
    it is stable under growth of ``n_users`` (existing users never move
    when new ids are appended), and it interleaves ids so per-shard
    candidate lists merge with the same tie-break order as a single
    corpus (``core.knn.sharded_recommend_for_users``).  Shards own
    near-equal user counts (they differ by at most one row), so no
    per-shard padding rows exist — every corpus row is a real user.

    Resharding (restoring an N-shard checkpoint into M shards,
    ``ShardedStreamingEngine.restore``) is pure re-indexing under this
    contract: ``u = row·N + shard`` recovers the global id, which then
    re-partitions under M.
    """

    n_users: int
    n_shards: int

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.n_users < 1:
            raise ValueError(f"n_users must be >= 1, got {self.n_users}")

    def shard_of(self, user):
        """Owning shard of global user id(s) ``user`` (int or array)."""
        return user % self.n_shards

    def local_row(self, user):
        """Local state-store row of global user id(s) ``user``."""
        return user // self.n_shards

    def global_user(self, shard, row):
        """Inverse mapping: global id of local ``row`` on ``shard``."""
        return row * self.n_shards + shard

    def shard_users(self, shard: int) -> int:
        """Number of users owned by ``shard`` (its state-store size)."""
        return (self.n_users - shard + self.n_shards - 1) // self.n_shards

    def owned_users(self, shard: int) -> np.ndarray:
        """Global ids owned by ``shard``, in local-row order."""
        return np.arange(shard, self.n_users, self.n_shards)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Named logical axes → physical mesh axes.

    ``fsdp`` may span several axes (e.g. ("pod","data") on the multi-pod
    mesh) — parameters are then ZeRO-3 sharded across all of them.
    """
    batch: tuple = ("pod", "data")
    fsdp: tuple = ("pod", "data")
    tensor: str = "model"
    expert: str = "model"
    context: str = "model"     # long-sequence KV cache sharding

    def fsdp_axes(self, mesh: Mesh) -> tuple:
        return tuple(a for a in self.fsdp if a in mesh.axis_names)

    def fsdp_size(self, mesh: Mesh) -> int:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        return int(np.prod([sizes[a] for a in self.fsdp_axes(mesh)])) \
            if self.fsdp_axes(mesh) else 1


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        return int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                            for a in axis]))
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def batch_axes(mesh: Mesh, rules: ShardingRules):
    """The batch sharding axes present in this mesh (pod may be absent)."""
    return tuple(a for a in rules.batch if a in mesh.axis_names)


def logical_to_physical(mesh: Mesh, rules: ShardingRules, logical: tuple):
    """Translate a tuple of logical axis names (or None) to a NamedSharding.

    Example: ("vocab_tp", "fsdp") → P("model", "data").
    Mapping: "batch"→rules.batch axes, "tp"→model, "fsdp"→data,
    "expert"→model, "ctx"→model, None→replicated.
    """
    table = {
        None: None,
        "batch": batch_axes(mesh, rules),
        "tp": rules.tensor,
        "fsdp": rules.fsdp_axes(mesh),
        "expert": rules.expert,
        "ctx": rules.context,
    }
    spec = P(*[table[x] for x in logical])
    return NamedSharding(mesh, spec)


def pick_fsdp_dim(shape, mesh: Mesh, rules: ShardingRules,
                  taken: Optional[int] = None) -> Optional[int]:
    """Choose a dim (not ``taken``) divisible by the fsdp axis size.

    Prefers the largest eligible dim. Returns None if nothing divides.
    """
    n = rules.fsdp_size(mesh)
    if n <= 1:
        return None
    candidates = [(d, s) for d, s in enumerate(shape)
                  if d != taken and s % n == 0 and s >= n]
    if not candidates:
        return None
    return max(candidates, key=lambda t: t[1])[0]


def param_spec(shape, mesh: Mesh, rules: ShardingRules,
               tp_dim: Optional[int] = None) -> P:
    """TP on ``tp_dim`` (if divisible) + FSDP on the best other dim."""
    axes = [None] * len(shape)
    if tp_dim is not None and rules.tensor in mesh.axis_names:
        n = _mesh_axis_size(mesh, rules.tensor)
        if shape[tp_dim] % n == 0 and shape[tp_dim] >= n:
            axes[tp_dim] = rules.tensor
        else:
            tp_dim = None
    fs = pick_fsdp_dim(shape, mesh, rules, taken=tp_dim)
    if fs is not None:
        axes[fs] = rules.fsdp_axes(mesh)
    return P(*axes)


def shard_params_pytree(params, spec_fn, mesh: Mesh):
    """Build NamedShardings for a pytree of params via spec_fn(path, leaf)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = [NamedSharding(mesh, spec_fn(path, leaf))
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def zero_like_sharded(params_shardings):
    """Optimizer-state shardings = param shardings (ZeRO via FSDP dims)."""
    return params_shardings
