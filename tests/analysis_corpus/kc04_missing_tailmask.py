"""Corpus case: cdiv grid axis with no tail handling (expected KC04).

Axis 1 tiles m with pl.cdiv but the contract declares no tail entry
for it — the tail block would reduce over garbage lanes.
"""
import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref):
    vals = x_ref[...]
    acc_ref[...] = vals * jnp.float32(2.0)
    o_ref[...] = acc_ref[...]


def thing(x, n, m, bq=128, bm=256):
    grid = (pl.cdiv(n, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi))],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )(x)
