"""Kernel contracts: the declared side of the KC lint rules (§10.1).

Every ``pl.pallas_call`` entry point registers a :class:`KernelContract`
at import time (a sidecar ``register(...)`` block at the bottom of its
module — a decorator would have to thread through the ``functools
.partial(jax.jit, ...)`` wrappers).  The contract states what the kernel
*promises* — grid rank, scalar-prefetch count, tail-mask coverage,
divisibility preconditions, accumulator dtypes, exact-parity status and
an analytic VMEM model with declared max shapes — and the AST rules in
``repro.analysis.kernel_rules`` verify the code keeps each promise.

The registry key is ``(module, entry)``; ``repro.analysis.linter``
imports :data:`KERNEL_MODULES` to populate it before scanning.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

# Marker for a grid axis whose tail is handled by Pallas' out-of-range
# write masking (output-block rows past the array end are dropped).
OOB_WRITE = "oob-write"


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Declared invariants of one ``pl.pallas_call`` entry point.

    ``module``/``entry`` key the registry; ``body`` names the kernel
    body function the dtype rules (KC05/KC07) inspect.  ``tail`` maps
    each non-divisible (``pl.cdiv``) grid axis to how its tail block is
    handled: :data:`OOB_WRITE`, or a source snippet (whitespace-
    insensitive) that must appear in the body — e.g. the mask predicate
    ``"tile_idx >= m"``.  ``divisible=True`` declares that every
    exact-division grid axis is guarded by an entry-side divisibility
    ``assert`` (KC04).  ``accumulators`` are the VMEM scratch dtypes in
    declaration order (KC08).  ``exact_parity=False`` opts the body out
    of the no-approximate-transcendentals rule (KC07) — the only such
    kernel is flash_attention, whose oracle is allclose, not bitwise.
    ``vmem_model(**max_shapes)`` must stay under the 16 MiB budget
    (KC03) and is pinned to real block allocations by
    tests/test_vmem_model.py.
    """

    module: str
    entry: str
    body: str
    grid_rank: int
    scalar_prefetch: int = 0
    tail: Mapping[int, str] = dataclasses.field(default_factory=dict)
    divisible: bool = False
    exact_parity: bool = True
    accumulators: Tuple[str, ...] = ()
    vmem_model: Optional[Callable[..., int]] = None
    max_shapes: Optional[Mapping[str, int]] = None

    def max_vmem_bytes(self) -> int:
        """The model evaluated at the declared max shapes."""
        if self.vmem_model is None or self.max_shapes is None:
            raise ValueError(
                f"{self.module}.{self.entry}: no vmem model declared")
        return self.vmem_model(**dict(self.max_shapes))


REGISTRY: Dict[Tuple[str, str], KernelContract] = {}


def register(contract: KernelContract) -> KernelContract:
    """Register ``contract`` under ``(module, entry)`` (idempotent)."""
    REGISTRY[(contract.module, contract.entry)] = contract
    return contract


# Modules the linter imports to populate the registry (and the only
# modules allowed to contain pallas_call sites — KC01 scans the whole
# kernels/ directory).
KERNEL_MODULES = (
    "repro.kernels.knn_topk",
    "repro.kernels.serving_topn",
    "repro.kernels.sparse_row_scatter",
    "repro.kernels.sparse_row_gather",
    "repro.kernels.decayed_scatter",
    "repro.kernels.flash_attention",
)

# Intentionally duplicated function pairs that must stay AST-identical
# (OR03).  Both exist because kernels/ref.py must not import the module
# that owns the original; the lint rule normalizes ``pl.cdiv(a, b)`` to
# ``-(-a // b)`` and strips docstrings before comparing.
DUPLICATE_PAIRS = (
    (("repro.kernels.knn_topk", "tiled_sqnorm"),
     ("repro.kernels.ref", "tiled_sqnorm_ref")),
    (("repro.core.knn", "pairwise_scores"),
     ("repro.kernels.ref", "_pairwise_scores")),
)
