"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-plus]
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, no-bias."""
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.lm_shapes import standard_lm_cells
from repro.models.transformer import TransformerConfig


def make_config():
    return TransformerConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv_heads=8, d_head=128, d_ff=33792, vocab_size=256000,
        tie_embeddings=True, dtype=jnp.bfloat16)


def smoke_config():
    return TransformerConfig(
        name="command-r-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_head=16, d_ff=256, vocab_size=128,
        q_block=8, dtype=jnp.float32)


ARCH = ArchDef(
    name="command-r-plus-104b", family="lm",
    cells=standard_lm_cells(make_config),
    make_smoke=smoke_config,
    notes="dense GQA 104B; kv=8 → attention FSDP-only TP fallback; "
          "d_ff TP-sharded (33792/16=2112).")
