"""Corpus case: float64 in a kernel module (expected KC06).

TPUs have no f64 unit; under jax's default x64-disabled config the
cast silently degrades to f32, and with x64 enabled it would fail to
lower — either way the annotation is a lie.
"""
import functools

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc_ref, *, m):
    tile = pl.program_id(1)
    vals = x_ref[...].astype(jnp.float64)
    vals = jnp.where(tile >= m, 0.0, vals)
    acc_ref[...] = vals.astype(jnp.float32)
    o_ref[...] = acc_ref[...]


def thing(x, n, m, bq=128, bm=256):
    grid = (pl.cdiv(n, bq), pl.cdiv(m, bm))
    kernel = functools.partial(_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi))],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
        scratch_shapes=[pltpu.VMEM((bq, bm), jnp.float32)],
    )(x)
