"""TIFU-kNN serving driver: batched next-basket recommendation requests
against a live (stream-maintained) state store.

Requests go through the ENGINE-SIDE BATCHER (`StreamingEngine.recommend`,
DESIGN.md §8): the engine reads its cached materialized corpus
(``StateStore.corpus()`` — between requests the micro-batches invalidate
only the touched rows, so each request pays an O(dirty·I) row refresh
instead of a full [M, I] densification), pads the query batch to a pow2
bucket and serves it through the fused pipeline
(``kernels.ops.fused_recommend``: the Pallas streaming-top-k + one-hot
blend/top-n kernels on TPU, the bitwise-identical XLA reference on CPU).

The trickle demo varies the request batch size on purpose: the printed
compiled-program-cache size must stay at the pow2-bucket count, not the
distinct-request-size count — if it tracks the latter, the request
bucketing has regressed (the serving bench gates this, see
benchmarks/bench_serving.py).

    PYTHONPATH=src python -m repro.launch.serve --users 2000 --requests 5
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.types import KIND_ADD_BASKET
from repro.data import synthetic
from repro.kernels import ops
from repro.streaming import Event, StateStore, StoreConfig, StreamingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tafeng")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=256,
                    help="max request batch size (actual sizes vary per "
                         "request to exercise the pow2 bucketing)")
    ap.add_argument("--topn", type=int, default=10)
    ap.add_argument("--trickle", type=int, default=64,
                    help="streaming events applied between requests "
                         "(exercises the corpus-cache row invalidation)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bounded ingestion (DESIGN.md §9): high-water "
                         "mark on the engine's pending queues; trickle "
                         "events past it are shed (counted, resubmitted "
                         "next round) instead of growing memory "
                         "unboundedly")
    ap.add_argument("--poison", type=int, default=0,
                    help="malformed events injected per trickle round "
                         "(out-of-range items): they must land in the "
                         "dead-letter queue, not wedge serving")
    args = ap.parse_args()

    ds = synthetic.generate(args.dataset, scale=args.scale)
    p = ds.params
    n_users = len(ds.histories)
    store = StateStore(StoreConfig(
        n_users=n_users, n_items=p.n_items,
        max_baskets=max(len(h) for h in ds.histories.values()) + 8,
        max_basket_size=max((len(b) for h in ds.histories.values()
                             for b in h), default=8) + 2))
    eng = StreamingEngine(store, p, batch_size=512)
    t0 = time.perf_counter()
    for u, h in ds.histories.items():
        for b in h:
            eng.add_basket(u, b)
    n = eng.run_until_drained()
    # the high-water mark bounds the live trickle, not the bulk load
    eng.max_pending = args.max_pending
    print(f"loaded {n} baskets for {n_users} users in "
          f"{time.perf_counter()-t0:.1f}s")

    rng = np.random.default_rng(0)
    recs = None
    for r in range(args.requests):
        if r and args.trickle:
            # live updates between requests: only these users' corpus
            # rows are refreshed by the next store.corpus() call.  The
            # whole round goes through one admission-checked submit —
            # shed events just lower this round's trickle volume (a real
            # source resends them), poison quarantines, serving answers
            # regardless.
            trickle = [Event(KIND_ADD_BASKET, int(u),
                             items=rng.choice(p.n_items,
                                              size=int(rng.integers(1, 6)),
                                              replace=False).astype(
                                                  np.int32))
                       for u in rng.choice(n_users,
                                           size=min(args.trickle, n_users),
                                           replace=False)]
            trickle += [Event(KIND_ADD_BASKET, 0,
                              items=np.asarray([p.n_items + i], np.int32))
                        for i in range(args.poison)]
            adm = eng.submit(trickle, on_invalid="quarantine",
                             on_overflow="shed")
            if adm.rejected or adm.quarantined:
                print(f"  admission: {adm.admitted} admitted, "
                      f"{adm.rejected} shed (backpressure), "
                      f"{adm.quarantined} dead-lettered")
            eng.run_until_drained()
        # deliberately ragged request sizes: they must all land in a
        # handful of pow2 buckets, not one compile per size
        size = int(rng.integers(max(1, args.batch // 2), args.batch + 1))
        users = rng.choice(n_users, size=min(size, n_users), replace=False)
        t0 = time.perf_counter()
        recs = eng.recommend(users, topn=args.topn)
        dt = time.perf_counter() - t0
        print(f"request batch {r}: {len(users)} users → top-{args.topn} "
              f"in {dt*1e3:.1f} ms ({dt/len(users)*1e6:.0f} us/user)")
    print(f"corpus cache: {store.corpus_full_builds} full build(s), "
          f"{store.corpus_rows_refreshed} row refreshes")
    print(f"serving compiled-program cache: "
          f"{eng.metrics.serve_compiled_shapes} shape bucket(s) across "
          f"{eng.metrics.serve_requests} requests "
          f"({ops.serving_cache_size()} live compiled programs)")
    print(f"ingestion: {eng.metrics.events_processed} events applied, "
          f"{eng.metrics.backpressure_rejections} shed by backpressure, "
          f"{eng.metrics.dead_letters} dead-lettered")
    print("sample recommendation for user 0:", np.asarray(recs[0]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
