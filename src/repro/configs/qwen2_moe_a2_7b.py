"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]
24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts (shared ffn 4*1408=5632)."""
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.lm_shapes import standard_lm_cells
from repro.models.transformer import TransformerConfig


def make_config():
    return TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=5632, vocab_size=151936,
        moe=True, n_experts=60, n_experts_padded=64,  # 64 % 16 == 0 (EP)
        n_shared_experts=4, top_k=4, moe_d_ff=1408,
        tie_embeddings=True, dtype=jnp.bfloat16)


def smoke_config():
    return TransformerConfig(
        name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256,
        moe=True, n_experts=6, n_experts_padded=8, n_shared_experts=2,
        top_k=2, moe_d_ff=32, capacity_factor=2.0, q_block=8,
        dtype=jnp.float32)


ARCH = ArchDef(
    name="qwen2-moe-a2.7b", family="lm",
    cells=standard_lm_cells(make_config),
    make_smoke=smoke_config,
    notes="60 routed experts padded to 64 for EP over the 16-way model "
          "axis (pad experts receive no routes: router stays 60-wide).")
