"""Tier-1 coverage for the Table-2 predictive path (ISSUE 9).

Two layers: the ranking-metric helpers in ``core/knn.py`` pinned
against hand-computed values on a 3-user fixture (they previously had
no direct unit tests), and ``benchmarks/table2_predictive.py`` run
end-to-end on a tiny synthetic dataset — the exactness claim
(incremental == baseline) and metric sanity, at seconds of runtime.
"""
import os
import sys

import numpy as np

from repro.core import knn

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import table2_predictive  # noqa: E402


# ---------------------------------------------------------------------------
# Hand-computed 3-user fixture
# ---------------------------------------------------------------------------

# user 0: hits ranks 1 and 3 of {1, 3}; user 1: no hits of {9};
# user 2: EMPTY truth — must be skipped, not averaged as zero.
RECS = np.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]])
TRUTH = [np.array([1, 3]), np.array([9]), np.array([], np.int64)]

D2 = 1.0 / np.log2(3.0)          # rank-2 discount 1/log2(2+1)
D3 = 0.5                         # rank-3 discount 1/log2(4)


def test_recall_at_k_hand_computed():
    # k=2: user 0 recalls 1 of 2 truth items, user 1 none of 1
    """Recall@k against hand-computed fixture values."""
    assert knn.recall_at_k(RECS, TRUTH, 2) == (0.5 + 0.0) / 2
    # k=3: user 0 recalls both truth items
    assert knn.recall_at_k(RECS, TRUTH, 3) == (1.0 + 0.0) / 2


def test_ndcg_at_k_hand_computed():
    # user 0 @3: rel = [1, 0, 1] -> DCG = 1 + D3, IDCG = 1 + D2
    """NDCG@k against hand-computed DCG/IDCG values."""
    ndcg0 = (1.0 + D3) / (1.0 + D2)
    np.testing.assert_allclose(knn.ndcg_at_k(RECS, TRUTH, 3),
                               (ndcg0 + 0.0) / 2, rtol=1e-12)
    # user 0 @2: rel = [1, 0] -> DCG = 1, IDCG = 1 + D2 (2 truth items)
    np.testing.assert_allclose(knn.ndcg_at_k(RECS, TRUTH, 2),
                               (1.0 / (1.0 + D2)) / 2, rtol=1e-12)


def test_metrics_skip_users_with_empty_truth():
    # only empty-truth users -> defined as 0.0, not NaN
    """Empty-truth users are skipped, never averaged as zero."""
    assert knn.recall_at_k(RECS[:1], [np.array([])], 2) == 0.0
    assert knn.ndcg_at_k(RECS[:1], [np.array([])], 2) == 0.0


def test_perfect_and_miss_extremes():
    """Both metrics hit exactly 1.0 and 0.0 at the extremes."""
    recs = np.array([[3, 1, 2]])
    assert knn.recall_at_k(recs, [np.array([1, 2, 3])], 3) == 1.0
    assert knn.ndcg_at_k(recs, [np.array([1, 2, 3])], 3) == 1.0
    assert knn.recall_at_k(recs, [np.array([9])], 3) == 0.0
    assert knn.ndcg_at_k(recs, [np.array([9])], 3) == 0.0


# ---------------------------------------------------------------------------
# End-to-end smoke through benchmarks/table2_predictive.py
# ---------------------------------------------------------------------------

def test_table2_tiny_end_to_end():
    """table2_predictive.run on a tiny corpus: exactness + sanity."""
    rows, max_vec_diff = table2_predictive.run("tafeng", scale=0.002,
                                               seed=0)
    # the paper's exactness claim: incremental == baseline
    assert max_vec_diff < 1e-10
    metrics = {r[1]: r for r in rows}
    assert set(metrics) == {"recall@10", "ndcg@10", "recall@20",
                            "ndcg@20"}
    for _, _, base, incr, decr in rows:
        assert base == incr          # same vectors -> same metrics
        for v in (base, incr, decr):
            assert 0.0 <= v <= 1.0
    # ranking on a real corpus must find SOME signal at k=20
    assert metrics["recall@20"][2] > 0.0
