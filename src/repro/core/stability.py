"""Numerical-stability tracking for decremental updates (beyond-paper).

The paper (§6.3) shows that each decremental group-vanish update scales
the user-vector error by  alpha = k / ((k-1) r_g) > 1  — exponential
error growth.  The paper measures this and argues it is tolerable in
practice; we make it a *managed* property:

  * every engine (ref + JAX) maintains a per-user worst-case error
    multiplier ``err_mult`` updated with the exact coefficients of each
    applied rule;

  * ``users_needing_refresh`` flags users whose bound
    ``err_mult * eps_machine`` exceeds a target relative error;

  * the streaming engine transparently refreshes flagged users from
    their history (exact recomputation) — bounded-staleness unlearning
    with O(1) amortised overhead because refreshes are rare
    (the paper's measurement: ~180 consecutive deletions to reach 1%
    relative error at f64; fewer at f32, see EXPERIMENTS.md).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def deletion_budget(k_groups: int, r_g: float, target_rel_err: float,
                    eps: float) -> int:
    """How many consecutive group-vanish deletions until the worst-case
    relative error bound crosses ``target_rel_err``.

    err_n = eps * alpha^n with alpha = k/((k-1) r_g)  →
    n = log(target/eps) / log(alpha).
    """
    alpha = k_groups / ((k_groups - 1.0) * r_g)
    if alpha <= 1.0:
        return np.iinfo(np.int64).max
    return int(np.floor(np.log(target_rel_err / eps) / np.log(alpha)))


def users_needing_refresh(err_mult, target_rel_err: float = 1e-2,
                          eps: float = np.finfo(np.float32).eps):
    """Boolean mask of users whose error bound crossed the target."""
    return err_mult * eps > target_rel_err


def refresh_threshold(target_rel_err: float = 1e-2,
                      eps: float = np.finfo(np.float32).eps) -> float:
    """err_mult threshold equivalent to users_needing_refresh."""
    return target_rel_err / eps


def max_error_growth(n_deletions, k_groups, r_g):
    """Worst-case error multiplier after n consecutive deletions (jnp)."""
    alpha = k_groups / ((k_groups - 1.0) * r_g)
    return jnp.power(alpha, n_deletions)
