"""GDPR unlearning compliance: retained-equivalence certification.

The certification subsystem behind ``StreamingEngine.forget_user`` and
the ``arm="compliance"`` benchmark (DESIGN.md §11): given an engine and
the event log it processed, prove that the maintained state is
equivalent to a model fit on the retained data only — bitwise for
pure-add histories, within the derived §4.3 path-dependence envelope for
deletion-bearing histories — and that forgotten users left no trace in
any live or persisted artifact.
"""
from repro.compliance.certify import (CheckResult, ComplianceReport,
                                      basket_weights, certify,
                                      divergence_envelope,
                                      retained_histories)

__all__ = ["CheckResult", "ComplianceReport", "basket_weights", "certify",
           "divergence_envelope", "retained_histories"]
