"""Corpus case: trip() on an unregistered fault-site name (EN02).

The site name is misspelled ("pre_wriet"), so the fault injector never
fires there and the chaos suite silently stops covering that crash
window.
"""
from repro.streaming import faults


def commit(path, payload):
    faults.trip("npz.pre_wriet")
    with open(path, "w") as f:
        f.write(payload)
