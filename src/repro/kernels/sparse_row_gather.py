"""Sparse per-row gather from a [M, I] table (TPU Pallas).

The sparse decremental paths (core.updates.apply_del_basket_batch /
apply_del_item_batch, DESIGN.md §3.5) and the sparse add path both need
the *current raw values* of a [M, I] state table at a per-event support
``(rows[U], ids[U, W])`` with W ≪ I:

    vals[r, w] = table[rows[r], ids[r, w]]          (PAD ids give 0)

This is the read half of the ``sparse_row_scatter`` pair and shares its
scaffolding: the scalar-prefetched ``rows`` drive the table block index
map, so a grid step only DMAs the [1, bi] tile of the row it actually
reads — HBM traffic is O(U·I) worst case (touched rows only), never
O(M·I).  TPUs dislike data-dependent gather, so per tile the read is a
compare + reduce: the [W, bi] one-hot of the row's ids against the item
tile's iota, contracted with the tile values.

Grid = (U batch rows, I / bi item tiles), tiles innermost: each row's
output block is revisited only on consecutive grid steps (zeroed on the
first tile, accumulated across the sweep), which is the same
consecutive-revisit contract the scatter kernel relies on.  Unlike the
scatter, duplicate target rows need no sorting — reads commute.

The XLA reference path (kernels.ref.sparse_row_gather_ref) is already
O(U·W) and is what CPU/GPU use (kernels.ops dispatches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, ids_ref, tab_ref, out_ref, *, bi: int):
    del rows_ref  # consumed by the index maps only
    ii = pl.program_id(1)

    @pl.when(ii == 0)
    def _zero():
        out_ref[0, :] = jnp.zeros_like(out_ref[0, :])

    ids = ids_ref[0, :]                              # [W] i32, PAD=-1
    tile_vals = tab_ref[0, :]                        # [bi] f32
    base = ii * bi
    tile = base + jax.lax.broadcasted_iota(jnp.int32,
                                           (ids.shape[0], bi), 1)
    onehot = (ids[:, None] == tile).astype(tile_vals.dtype)  # PAD misses
    out_ref[0, :] += jnp.sum(onehot * tile_vals[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("bi", "interpret"))
def sparse_row_gather(table, rows, ids, bi: int = 512,
                      interpret: bool = False):
    """vals f32[U, W] = table[rows i32[U], ids i32[U, W]] (PAD ids → 0).

    Requires I % bi == 0 — the ops.py dispatcher picks bi / falls back
    to the XLA reference.
    """
    m, n_items = table.shape
    u, w = ids.shape
    bi = min(bi, n_items)
    assert n_items % bi == 0, (n_items, bi)
    rows = jnp.clip(rows, 0, m - 1).astype(jnp.int32)

    grid = (u, n_items // bi)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda r, ii, rows: (r, 0)),
            pl.BlockSpec((1, bi), lambda r, ii, rows: (rows[r], ii)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda r, ii, rows: (r, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, bi=bi),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((u, w), table.dtype),
        interpret=interpret,
    )(rows, ids, table)
