"""Embedding tables + EmbeddingBag for the recsys family (JAX-native).

JAX has no ``nn.EmbeddingBag`` and no CSR sparse — per the assignment this
substrate IS part of the system:

  * reference path: ``jnp.take`` + ``jax.ops.segment_sum`` (this module);
  * TPU fast path: ``kernels.decayed_scatter`` one-hot-matmul (the same
    kernel that builds TIFU-kNN user vectors — DESIGN.md §3.1: a bag sum
    is the r=1 special case of the paper's decayed average, and bag
    add/remove uses the paper's Eq. 3/4 maintenance rules).

Tables from many features are concatenated row-wise into ONE
``[total_rows, dim]`` matrix with per-feature offsets, row-sharded over
the "model" mesh axis (classic DLRM model-parallel embeddings +
data-parallel MLPs split).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TableSpec:
    vocab_sizes: tuple        # rows per feature
    dim: int
    dtype: str = "float32"

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]])

    @property
    def total_rows(self) -> int:
        return int(np.sum(self.vocab_sizes))

    def padded_rows(self, multiple: int = 1024) -> int:
        # multiple of 1024 ⇒ row-shardable over the 512-chip multi-pod mesh
        t = self.total_rows
        return (t + multiple - 1) // multiple * multiple


def init_table(key, spec: TableSpec, dtype=jnp.float32):
    return (jax.random.normal(key, (spec.padded_rows(), spec.dim),
                              jnp.float32)
            / np.sqrt(spec.dim)).astype(dtype)


def flat_ids(ids, spec: TableSpec):
    """Per-feature local ids [B, F] (or [B,F,H]) → global row ids."""
    offs = jnp.asarray(spec.offsets, jnp.int32)
    if ids.ndim == 2:
        return ids + offs[None, :]
    return ids + offs[None, :, None]


def embedding_lookup(table, ids, spec: TableSpec, chunk: int = 65536):
    """Single-hot lookup: ids [B, F] → [B, F, dim].

    For huge batches the lookup runs in ``chunk``-row slices (lax.map):
    XLA's distributed gather from an all-axes row-sharded table
    materializes a replicated output before resharding — chunking bounds
    that transient to [chunk, F, dim] (measured: DLRM retrieval_cand 1M
    rows: 25 GiB → ~2 GiB peak)."""
    b = ids.shape[0]
    if chunk and b > chunk:
        while b % chunk:           # largest divisor of b not above chunk
            chunk -= 1
        chunks = ids.reshape(b // chunk, chunk, *ids.shape[1:])
        out = jax.lax.map(
            lambda i: jnp.take(table, flat_ids(i, spec), axis=0), chunks)
        return out.reshape(b, *out.shape[2:])
    return jnp.take(table, flat_ids(ids, spec), axis=0)


def embedding_bag(table, ids, spec: TableSpec, weights=None, mode="sum"):
    """Multi-hot bag: ids [B, F, H] (−1 padded) → [B, F, dim].

    Reference EmbeddingBag: gather + masked (weighted) reduction.
    """
    gids = flat_ids(jnp.maximum(ids, 0), spec)
    emb = jnp.take(table, gids, axis=0)                   # [B,F,H,dim]
    mask = (ids >= 0).astype(emb.dtype)[..., None]
    if weights is not None:
        mask = mask * weights[..., None]
    out = jnp.sum(emb * mask, axis=2)
    if mode == "mean":
        out = out / jnp.maximum(jnp.sum(mask, axis=2), 1.0)
    return out


def bag_incremental_add(bag_sum, count, new_vec, r: float = 1.0):
    """Paper Eq. 3 applied to a bag (r=1 ⇒ plain running mean).

    Maintains the *decayed average* of a user's interaction embeddings —
    how the paper's technique attaches to DLRM/DeepFM/two-tower user
    state (DESIGN.md §4)."""
    return (r * count * bag_sum + new_vec) / (count + 1)


def bag_decremental_delete(bag_avg, count, suffix_vecs, i: int, r: float = 1.0):
    """Paper Eq. 4 applied to a bag of interaction embeddings."""
    from repro.core.decay import decremental_delete
    return decremental_delete(bag_avg, count, suffix_vecs, i, r, xp=jnp)
