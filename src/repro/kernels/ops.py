"""Jit'd dispatch wrappers: Pallas on TPU, interpret/reference on CPU.

The public entry points the rest of the system calls; each picks the
fastest implementation available for the current backend and is
guaranteed (by tests/test_kernels.py shape/dtype sweeps) to match the
ref.py oracles.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decayed_scatter import (batched_decayed_scatter,
                                           decayed_scatter)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.knn_topk import knn_topk as _knn_pallas
from repro.kernels.sparse_row_gather import \
    sparse_row_gather as _sparse_gather_pallas
from repro.kernels.sparse_row_scatter import \
    sparse_row_scatter as _sparse_scatter_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def knn_topk(queries, corpus, k: int, impl: str = "auto", **kw):
    """Fused similarity + top-k. impl: auto | pallas | interpret | ref."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.knn_topk_ref(queries, corpus, k,
                                kw.get("metric", "euclidean"))
    return _knn_pallas(queries, corpus, k,
                       interpret=(impl == "interpret" or not _on_tpu()),
                       **kw)


def multihot_scatter(ids, weights, n_items: int, impl: str = "auto"):
    """Weighted multi-hot scatter (TIFU user-vector builder)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.decayed_scatter_ref(ids, weights, n_items)
    if ids.ndim == 3:
        return batched_decayed_scatter(ids, weights, n_items,
                                       interpret=(impl == "interpret"
                                                  or not _on_tpu()))
    return decayed_scatter(ids, weights, n_items,
                           interpret=(impl == "interpret" or not _on_tpu()))


def sparse_row_scatter(table, rows, ids, vals, impl: str = "auto"):
    """Sparse per-row scatter-add into a [M, I] table (add-path deltas).

    XLA's native scatter is already O(U·W) on CPU/GPU; the Pallas kernel
    is the TPU path (streams only the touched rows, in place).
    """
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.sparse_row_scatter_ref(table, rows, ids, vals)
    n_items = table.shape[1]
    for bi in (512, 256, 128):
        if n_items % bi == 0:
            return _sparse_scatter_pallas(
                table, rows, ids, vals, bi=bi,
                interpret=(impl == "interpret" or not _on_tpu()))
    return ref.sparse_row_scatter_ref(table, rows, ids, vals)


def sparse_row_gather(table, rows, ids, impl: str = "auto"):
    """Sparse per-row gather from a [M, I] table (update-path supports).

    XLA's native gather is already O(U·W) on CPU/GPU; the Pallas kernel
    is the TPU path (streams only the touched rows' tiles).
    """
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.sparse_row_gather_ref(table, rows, ids)
    n_items = table.shape[1]
    for bi in (512, 256, 128):
        if n_items % bi == 0:
            return _sparse_gather_pallas(
                table, rows, ids, bi=bi,
                interpret=(impl == "interpret" or not _on_tpu()))
    return ref.sparse_row_gather_ref(table, rows, ids)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    impl: str = "auto", **kw):
    """Blocked attention. [B,S,H,D] each → [B,S,H,D]."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal, window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=(impl == "interpret" or not _on_tpu()),
                         **kw)
