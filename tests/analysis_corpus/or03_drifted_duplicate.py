"""Corpus case: intentional duplicate whose body drifted (OR03).

tiled_thing_ref spells its tile count with floor division instead of
ceil division — the classic off-by-one-tile drift the normalized body
comparison exists to catch (cdiv(a, b) normalizes to -(-a // b), which
is NOT a // b).
"""
from jax.experimental import pallas as pl


def tiled_thing(x, d, bd=256):
    nt = pl.cdiv(d, bd)
    acc = 0.0
    for t in range(nt):
        acc = acc + x[t]
    return acc


def tiled_thing_ref(x, d, bd=256):
    nt = d // bd
    acc = 0.0
    for t in range(nt):
        acc = acc + x[t]
    return acc
